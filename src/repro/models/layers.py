"""Shared layer primitives: norms, RoPE/M-RoPE, attention, MLPs.

Pure functions over explicit param pytrees (dicts of arrays).  Every
initializer returns params in ``cfg.dtype`` (bf16 by default) and all
norm/softmax/recurrence math runs in f32.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .config import ModelConfig


def truncnorm(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- RMSNorm
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    norm = xf * jax.lax.rsqrt(var + eps)
    # (1 + scale) parameterization (gemma/llama-style zero-centered scale)
    return (norm * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, D); positions: (B, T) int32."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, T, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: tuple[int, int, int]
) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions: (3, B, T) for (t, h, w) axes.

    The frequency spectrum (D/2 freqs) is partitioned into three sections,
    each rotated by its own position stream.  For text tokens the three
    streams are equal and M-RoPE reduces to standard RoPE.
    """
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # (D/2,)
    sec = jnp.asarray(
        sum(([i] * s for i, s in enumerate(sections)), []), dtype=jnp.int32
    )  # (D/2,) section id per frequency
    # pick the position stream per frequency: (B, T, D/2)
    pos_sec = jnp.take(positions.astype(jnp.float32), sec, axis=0)  # (D/2 picks) -> (D/2, B, T)
    angles = jnp.moveaxis(pos_sec, 0, -1) * freqs  # (B, T, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def positional_rotate(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.rope_type == "mrope":
        if positions.ndim == 2:  # text-only stream: replicate across axes
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


# ---------------------------------------------------------------- attention
def attention_init(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "wq": truncnorm(kq, (d, cfg.n_heads * hd), s, dtype),
        "wk": truncnorm(kk, (d, cfg.n_kv_heads * hd), s, dtype),
        "wv": truncnorm(kv, (d, cfg.n_kv_heads * hd), s, dtype),
        "wo": truncnorm(ko, (cfg.n_heads * hd, d), 1.0 / math.sqrt(cfg.n_heads * hd), dtype),
    }


def attention_fwd(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,          # (B, T, d)
    positions: jax.Array,  # (B, T) or (3, B, T)
    window: int | None,
) -> jax.Array:
    B, T, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, T, H, hd)
    k = (x @ params["wk"]).reshape(B, T, Hkv, hd)
    v = (x @ params["wv"]).reshape(B, T, Hkv, hd)
    q = positional_rotate(cfg, q, positions)
    k = positional_rotate(cfg, k, positions)
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    use_kernel = cfg.use_kernels and T % 128 == 0
    o = kops.flash_attention(
        qh, kh, vh, causal=cfg.causal, window=window, use_kernel=use_kernel
    )
    o = o.transpose(0, 2, 1, 3).reshape(B, T, H * hd)
    return o @ params["wo"]


def attention_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,        # (B, 1, d)
    cache_k: jax.Array,  # (B, S, Hkv, hd)
    cache_v: jax.Array,
    pos: jax.Array,      # () int32 current position
    window: int | None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode over a KV cache; returns (out, new_k, new_v).

    For windowed layers the cache has S = window slots written round-robin.
    """
    B, _, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    S = cache_k.shape[1]
    G = H // Hkv
    q = (x @ params["wq"]).reshape(B, 1, H, hd)
    k = (x @ params["wk"]).reshape(B, 1, Hkv, hd)
    v = (x @ params["wv"]).reshape(B, 1, Hkv, hd)
    posb = jnp.broadcast_to(pos[None, None], (B, 1))
    q = positional_rotate(cfg, q, posb)
    k = positional_rotate(cfg, k, posb)
    slot = pos if window is None else pos % S
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))

    qf = q.astype(jnp.float32).reshape(B, H, hd)
    kf = cache_k.astype(jnp.float32)
    vf = cache_v.astype(jnp.float32)
    # scores: (B, H, S) via grouped heads
    qg = qf.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kf) / math.sqrt(hd)
    idx = jnp.arange(S)
    if window is None:
        valid = idx <= pos
    else:
        # Ring cache holds the last S absolute positions; before wrap-around
        # only slots <= pos have been written.
        valid = (idx <= pos) | (pos >= S)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        s = jnp.tanh(s / c) * c
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, vf).reshape(B, 1, H * hd).astype(x.dtype)
    return o @ params["wo"], cache_k, cache_v


# ---------------------------------------------------------------- MLP
def mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": truncnorm(k1, (d, d_ff), 1.0 / math.sqrt(d), dtype),
        "wg": truncnorm(k2, (d, d_ff), 1.0 / math.sqrt(d), dtype),
        "wo": truncnorm(k3, (d_ff, d), 1.0 / math.sqrt(d_ff), dtype),
    }


def mlp_fwd(params: dict, x: jax.Array, act: str) -> jax.Array:
    gate = x @ params["wg"]
    gate = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
    return (gate * (x @ params["wi"])) @ params["wo"]


# ---------------------------------------------------------------- embedding
def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return truncnorm(key, (vocab, d), 1.0, dtype)


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(table_or_head: jax.Array, x: jax.Array, tied: bool) -> jax.Array:
    w = table_or_head.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if tied:
        return xf @ w.T
    return xf @ w

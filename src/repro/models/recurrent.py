"""Recurrent time-mixing blocks: RG-LRU (Griffin/RecurrentGemma), mLSTM and
sLSTM (xLSTM).  All are sub-quadratic in sequence length, which is what
qualifies their architectures for the ``long_500k`` shape.

Conventions match ``layers.py``: explicit param dicts, f32 recurrence math,
params stored in model dtype.  Each block exposes:
  *_init(key, cfg, dtype) -> params
  *_fwd(params, cfg, x)   -> (y, final_state)   # full-sequence (train/prefill)
  *_decode(params, cfg, x, state) -> (y, state) # single-token step
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .config import ModelConfig
from .layers import truncnorm

# =========================================================== RG-LRU block
# Griffin recurrent block (arXiv:2402.19427): two input branches; the x
# branch goes through a short causal conv then the RG-LRU; the gate branch
# modulates via GeLU; output projection mixes back to d_model.
_C_RGLRU = 8.0  # Griffin's fixed recurrence sharpness


def rglru_block_init(key, cfg: ModelConfig, dtype) -> dict:
    d, dr = cfg.d_model, cfg.rnn_dim
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    # Lambda init: a = sigmoid(lam)**c uniform-ish in (0.9, 0.999)
    u = jax.random.uniform(ks[4], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log((u ** (1.0 / _C_RGLRU)) / (1.0 - u ** (1.0 / _C_RGLRU)))
    return {
        "wx": truncnorm(ks[0], (d, dr), s, dtype),
        "wg": truncnorm(ks[1], (d, dr), s, dtype),
        "conv": truncnorm(ks[2], (cfg.conv_width, dr), 1.0 / math.sqrt(cfg.conv_width), dtype),
        "wa": truncnorm(ks[3], (dr, dr), 1.0 / math.sqrt(dr), dtype),
        "lam": lam.astype(jnp.float32),
        "wi": truncnorm(ks[5], (dr, dr), 1.0 / math.sqrt(dr), dtype),
        "wo": truncnorm(jax.random.fold_in(key, 7), (dr, d), 1.0 / math.sqrt(dr), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, carry: jax.Array | None):
    """Depthwise causal conv. x: (B, T, D); w: (W, D); carry: (B, W-1, D)."""
    W = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)  # (B, T+W-1, D)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(W))
    new_carry = xp[:, -(W - 1) :] if W > 1 else carry
    return out, new_carry


def _rglru_gates(params, xc: jax.Array):
    """Decay a_t and normalized input for the linear recurrence (f32)."""
    rt = jax.nn.sigmoid((xc @ params["wa"].astype(xc.dtype)).astype(jnp.float32))
    it = jax.nn.sigmoid((xc @ params["wi"].astype(xc.dtype)).astype(jnp.float32))
    log_a = -_C_RGLRU * rt * jax.nn.softplus(params["lam"])
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    xin = xc.astype(jnp.float32) * it * mult
    return a, xin


def rglru_block_fwd(params: dict, cfg: ModelConfig, x: jax.Array, state=None):
    """x: (B, T, d). state: None or dict(conv=(B,W-1,dr), h=(B,dr))."""
    xb = x @ params["wx"]
    gate = jax.nn.gelu((x @ params["wg"]).astype(jnp.float32))
    conv_carry = None if state is None else state["conv"]
    xc, conv_carry = _causal_conv(xb, params["conv"], conv_carry)
    a, xin = _rglru_gates(params, xc)
    h0 = None if state is None else state["h"]
    use_kernel = cfg.use_kernels and xin.shape[1] % 256 == 0 and xin.shape[2] % 256 == 0
    h, h_last = kops.rglru(
        xin.astype(jnp.float32), a, h0, use_kernel=use_kernel
    )
    y = (h.astype(jnp.float32) * gate).astype(x.dtype) @ params["wo"]
    return y, {"conv": conv_carry, "h": h_last.astype(jnp.float32)}


def rglru_block_decode(params: dict, cfg: ModelConfig, x: jax.Array, state: dict):
    """x: (B, 1, d) single step."""
    return rglru_block_fwd(params, cfg, x, state)


def rglru_init_state(cfg: ModelConfig, batch: int) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_dim), _dt(cfg)),
        "h": jnp.zeros((batch, cfg.rnn_dim), jnp.float32),
    }


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# =========================================================== mLSTM block
# xLSTM (arXiv:2405.04517) matrix-memory block, pre-up-projection style:
# up-project 2x, causal conv feeds q/k, exponential-gated matrix memory,
# learnable skip, gated down-projection.  Parallel (training) form uses the
# stabilized decay-matrix formulation; decode uses the recurrent form with
# state (C, n, m) per head.


def mlstm_block_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = 2 * d  # inner dim
    H = cfg.n_heads
    ks = jax.random.split(key, 9)
    s = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(di)
    return {
        "w_up": truncnorm(ks[0], (d, di), s, dtype),
        "w_gate": truncnorm(ks[1], (d, di), s, dtype),
        "conv": truncnorm(ks[2], (cfg.conv_width, di), 0.5, dtype),
        "wq": truncnorm(ks[3], (di, di), si, dtype),
        "wk": truncnorm(ks[4], (di, di), si, dtype),
        "wv": truncnorm(ks[5], (di, di), si, dtype),
        "w_if": truncnorm(ks[6], (di, 2 * H), si, dtype),
        "b_if": jnp.concatenate(
            [jnp.zeros((H,), jnp.float32), 3.0 + jnp.arange(H, dtype=jnp.float32) * 0.5]
        ),
        "skip": jnp.ones((di,), dtype),
        "w_down": truncnorm(ks[7], (di, d), si, dtype),
    }


MLSTM_CHUNK = 256  # chunkwise-parallel block length


def mlstm_chunked(q, k, v, log_i, log_f, state, chunk: int = MLSTM_CHUNK):
    """Chunkwise-parallel stabilized mLSTM (the xLSTM training form).

    q/k/v: (B, T, H, hd) f32; log_i/log_f: (B, T, H) f32.
    state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)).
    Within a chunk the decay matrix is quadratic (chunk x chunk); across
    chunks the (C, n, m) state is carried recurrently — O(T*chunk) memory
    instead of O(T^2), which is what makes train_4k / long-context shapes
    feasible.  Returns (h (B,T,H,hd), (C, n, m) final).
    """
    B, T, H, hd = q.shape
    c = min(chunk, T)
    if T % c:
        raise ValueError(f"T={T} must be divisible by chunk={c}")
    nc = T // c

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, nc, c, *x.shape[2:]), 1, 0)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lic, lfc = to_chunks(log_i), to_chunks(log_f)

    def chunk_step(carry, inp):
        C0, n0, m0 = carry  # (B,H,hd,hd), (B,H,hd), (B,H)
        qt, kt, vt, li, lf = inp  # (B,c,H,hd) / (B,c,H)
        F = jnp.cumsum(lf, axis=1)  # (B,c,H) decay from chunk start to t incl.
        # per-step stabilizer: m_t = max(F_t + m0, max_{s<=t}(F_t - F_s + li_s))
        g = li - F  # (B,c,H): li_s - F_s
        g_run = jax.lax.cummax(g, axis=1)
        m_t = jnp.maximum(F + m0[:, None], F + g_run)  # (B,c,H)

        # inter-chunk term
        scale_in = jnp.exp(F + m0[:, None] - m_t)  # (B,c,H)
        h_inter = jnp.einsum("bchd,bhde->bche", qt, C0) * scale_in[..., None]
        n_inter = jnp.einsum("bchd,bhd->bch", qt, n0) * scale_in

        # intra-chunk term: D[t,s] = exp(F_t - F_s + li_s - m_t), s <= t
        dmat = F[:, :, None] - F[:, None, :] + li[:, None, :] - m_t[:, :, None]
        mask = jnp.tril(jnp.ones((c, c), bool))
        dexp = jnp.where(mask[None, :, :, None], jnp.exp(dmat), 0.0)  # (B,c,c,H)
        s_qk = jnp.einsum("bthd,bshd->btsh", qt, kt) * dexp
        h_intra = jnp.einsum("btsh,bshd->bthd", s_qk, vt)
        n_intra = jnp.sum(s_qk, axis=2)  # (B,c,H)

        norm = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_t))
        h = (h_inter + h_intra) / (norm[..., None] + 1e-6)

        # state update to chunk end
        F_end = F[:, -1]  # (B,H)
        m_end = jnp.maximum(F_end + m0, F_end + g_run[:, -1])
        sc_state = jnp.exp(F_end[:, None] + li - F - m_end[:, None])  # (B,c,H)
        C1 = C0 * jnp.exp(F_end + m0 - m_end)[..., None, None] + jnp.einsum(
            "bchd,bche,bch->bhde", kt, vt, sc_state
        )
        n1 = n0 * jnp.exp(F_end + m0 - m_end)[..., None] + jnp.einsum(
            "bchd,bch->bhd", kt, sc_state
        )
        return (C1, n1, m_end), h

    (C, n, m), hs = jax.lax.scan(
        chunk_step, state, (qc, kc, vc, lic, lfc)
    )
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, H, hd)
    return h, (C, n, m)


def mlstm_block_fwd(params: dict, cfg: ModelConfig, x: jax.Array, state=None):
    """Chunkwise-parallel form. x: (B, T, d) -> (y, state)."""
    B, T, d = x.shape
    H = cfg.n_heads
    di = 2 * d
    hd = di // H
    up = x @ params["w_up"]  # (B, T, di)
    gate = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
    conv_carry = None if state is None else state["conv"]
    qk_src, conv_carry = _causal_conv(up, params["conv"], conv_carry)
    qk_src = jax.nn.silu(qk_src.astype(jnp.float32)).astype(x.dtype)
    q = (qk_src @ params["wq"]).reshape(B, T, H, hd).astype(jnp.float32)
    k = (qk_src @ params["wk"]).reshape(B, T, H, hd).astype(jnp.float32) / math.sqrt(hd)
    v = (up @ params["wv"]).reshape(B, T, H, hd).astype(jnp.float32)
    gif = (qk_src @ params["w_if"]).astype(jnp.float32) + params["b_if"]
    log_i = gif[..., :H]  # (B, T, H) input gate (pre-exp)
    log_f = jax.nn.log_sigmoid(gif[..., H:])  # (B, T, H)

    if state is None:
        rec0 = (
            jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.full((B, H), -jnp.inf, jnp.float32),
        )
    else:
        rec0 = (state["C"], state["n"], state["m"])
    chunk = min(MLSTM_CHUNK, T)
    h, (C, n, m) = mlstm_chunked(q, k, v, log_i, log_f, rec0, chunk=chunk)

    h = h.reshape(B, T, di)
    y = (h * gate + up.astype(jnp.float32) * params["skip"].astype(jnp.float32)).astype(
        x.dtype
    ) @ params["w_down"]
    new_state = {"conv": conv_carry, "C": C, "n": n, "m": m}
    return y, new_state


def mlstm_block_decode(params: dict, cfg: ModelConfig, x: jax.Array, state: dict):
    """Recurrent form, x: (B, 1, d)."""
    B, _, d = x.shape
    H = cfg.n_heads
    di = 2 * d
    hd = di // H
    up = x @ params["w_up"]
    gate = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
    qk_src, conv_carry = _causal_conv(up, params["conv"], state["conv"])
    qk_src = jax.nn.silu(qk_src.astype(jnp.float32)).astype(x.dtype)
    q = (qk_src @ params["wq"]).reshape(B, H, hd).astype(jnp.float32)
    k = (qk_src @ params["wk"]).reshape(B, H, hd).astype(jnp.float32) / math.sqrt(hd)
    v = (up @ params["wv"]).reshape(B, H, hd).astype(jnp.float32)
    gif = (qk_src[:, 0] @ params["w_if"]).astype(jnp.float32) + params["b_if"]
    log_i = gif[:, :H]
    log_f = jax.nn.log_sigmoid(gif[:, H:])

    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(log_f + m, log_i)
    fdec = jnp.exp(log_f + m - m_new)
    iexp = jnp.exp(log_i - m_new)
    C = C * fdec[..., None, None] + iexp[..., None, None] * (k[..., :, None] @ v[..., None, :])
    n = n * fdec[..., None] + iexp[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    h = (num / (den[..., None] + 1e-6)).reshape(B, 1, di)
    y = (h * gate + up.astype(jnp.float32) * params["skip"].astype(jnp.float32)).astype(
        x.dtype
    ) @ params["w_down"]
    return y, {"conv": conv_carry, "C": C, "n": n, "m": m_new}


def mlstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    di = 2 * cfg.d_model
    H = cfg.n_heads
    hd = di // H
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), _dt(cfg)),
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
    }


# =========================================================== sLSTM block
# Scalar-memory LSTM with exponential gating.  Two structural properties of
# the xLSTM paper are load-bearing for performance and kept here:
#   * input projections W_{i,f,z,o} x_t do not depend on the recurrence, so
#     they are hoisted out of the scan into one (B,T,d)x(d,4d) MXU matmul —
#     the scan body touches only the recurrent weights;
#   * recurrent matrices R_* are BLOCK-DIAGONAL per head (xLSTM §"sLSTM"),
#     shrinking the per-step weight traffic from 4*d^2 to 4*d^2/H and making
#     the recurrence bandwidth-feasible (EXPERIMENTS.md §Perf, xlstm cell).


def slstm_block_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 10)
    s = 1.0 / math.sqrt(d)
    p = {}
    for i, g in enumerate(("i", "f", "z", "o")):
        p[f"w_{g}"] = truncnorm(ks[i], (d, d), s, dtype)
        # block-diagonal recurrence: one (hd, hd) block per head
        p[f"r_{g}"] = truncnorm(ks[4 + i], (H, hd, hd), 1.0 / math.sqrt(hd), dtype)
    p["b_i"] = jnp.zeros((d,), jnp.float32)
    p["b_f"] = jnp.full((d,), 3.0, jnp.float32)
    p["b_z"] = jnp.zeros((d,), jnp.float32)
    p["b_o"] = jnp.zeros((d,), jnp.float32)
    # gated FFN (factor 4/3, GeGLU-ish) after the recurrence, per xLSTM
    dff = max(4 * d // 3, 8)
    p["ff_wi"] = truncnorm(ks[8], (d, dff), s, dtype)
    p["ff_wg"] = truncnorm(jax.random.fold_in(key, 11), (d, dff), s, dtype)
    p["ff_wo"] = truncnorm(ks[9], (dff, d), 1.0 / math.sqrt(dff), dtype)
    return p


def _slstm_pre(params, x: jax.Array) -> jax.Array:
    """Hoisted input projections: (B, T, 4, d) f32."""
    pre = jnp.stack(
        [x @ params[f"w_{g}"] for g in ("i", "f", "z", "o")], axis=2
    ).astype(jnp.float32)
    bias = jnp.stack(
        [params["b_i"], params["b_f"], params["b_z"], params["b_o"]], axis=0
    )
    return pre + bias[None, None]


# --- custom-VJP recurrence -------------------------------------------------
# Differentiating the scan naively makes XLA emit the recurrent-weight
# gradient reduction (a cross-batch all-reduce under data parallelism)
# INSIDE the backward loop — one collective per timestep (measured: 24,576
# all-reduces for xlstm train_4k).  The restructured backward below collects
# the per-step gate adjoints as scan outputs and computes
#   dR_g = sum_t h_{t-1} (x) dpre_g,t
# as ONE einsum after the loop, so the weight-grad all-reduce fires once.
# (EXPERIMENTS.md §Perf, xlstm cell, iteration 3.)


def _r_tree(params):
    return {g: params[f"r_{g}"] for g in ("i", "f", "z", "o")}


@jax.custom_vjp
def _slstm_scan(r, pre, carry0):
    """r: {g: (H,hd,hd)}; pre: (B,T,4,d) f32; carry0: (c,n,h,m) (B,d) f32.

    Returns (hs (B,T,d) f32, carry_final)."""
    hs, carry, _ = _slstm_scan_fwd_impl(r, pre, carry0)
    return hs, carry


def _slstm_step(r, carry, pre_t):
    c, n, h, m = carry
    B, d = h.shape
    H = r["i"].shape[0]
    hd = d // H
    hb = h.reshape(B, H, hd)

    def rmat(g):
        # r stays in its storage dtype (bf16) on the wire; accumulate f32.
        return jax.lax.dot_general(
            hb.astype(r[g].dtype), r[g],
            (((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32,
        ).transpose(1, 0, 2).reshape(B, d)

    li = pre_t[:, 0] + rmat("i")
    lf = jax.nn.log_sigmoid(pre_t[:, 1] + rmat("f"))
    z = jnp.tanh(pre_t[:, 2] + rmat("z"))
    o = jax.nn.sigmoid(pre_t[:, 3] + rmat("o"))
    m_new = jnp.maximum(lf + m, li)
    c = c * jnp.exp(lf + m - m_new) + jnp.exp(li - m_new) * z
    n = n * jnp.exp(lf + m - m_new) + jnp.exp(li - m_new)
    h = o * c / jnp.maximum(n, 1.0)
    return (c, n, h, m_new)


def _slstm_scan_fwd_impl(r, pre, carry0):
    # kernels/ops.slstm_scan keeps R VMEM-resident on TPU; the jnp scan twin
    # runs elsewhere.  Sequences come back (B, T, d); the backward wants the
    # PRE-step carries as (T, B, d), reconstructed by shifting.
    hs, (cs, ns, ms), carry = kops.slstm_scan(r, pre, carry0)

    def prev_seq(seq, first):
        seq_t = jnp.moveaxis(seq, 1, 0)  # (T, B, d)
        return jnp.concatenate([first[None], seq_t[:-1]], axis=0)

    c0, n0, h0, m0 = carry0
    carries_prev = (
        prev_seq(cs, c0), prev_seq(ns, n0), prev_seq(hs, h0), prev_seq(ms, m0)
    )
    return hs, carry, carries_prev


def _slstm_scan_fwd(r, pre, carry0):
    hs, carry, carries_prev = _slstm_scan_fwd_impl(r, pre, carry0)
    return (hs, carry), (r, pre, carries_prev)


def _slstm_scan_bwd(res, grads):
    r, pre, carries_prev = res
    dhs, dcarry_final = grads
    pre_seq = jnp.moveaxis(pre, 1, 0)  # (T,B,4,d)
    dhs_seq = jnp.moveaxis(dhs.astype(jnp.float32), 1, 0)  # (T,B,d)

    def body(g, inp):
        carry_prev, pre_t, dh_t = inp
        g = (g[0], g[1], g[2] + dh_t, g[3])
        # pull the adjoint through one step, r treated as a constant
        _, vjp_fn = jax.vjp(lambda cc, pp: _slstm_step(r, cc, pp), carry_prev, pre_t)
        g_prev, dpre_t = vjp_fn(g)
        return g_prev, dpre_t

    g0 = jax.tree.map(lambda x: x.astype(jnp.float32), dcarry_final)
    g_init, dpre_seq = jax.lax.scan(
        body, g0, (carries_prev, pre_seq, dhs_seq), reverse=True
    )
    # one reduction for the recurrent weights, outside the loop:
    h_prev_seq = carries_prev[2]  # (T, B, d)
    T, B, d = h_prev_seq.shape
    H, hd, _ = r["i"].shape
    hb = h_prev_seq.reshape(T, B, H, hd)
    gate_idx = {"i": 0, "f": 1, "z": 2, "o": 3}
    dr = {
        g: jnp.einsum(
            "tbhd,tbhe->hde", hb, dpre_seq[:, :, gi].reshape(T, B, H, hd)
        ).astype(r[g].dtype)
        for g, gi in gate_idx.items()
    }
    dpre = jnp.moveaxis(dpre_seq, 0, 1)  # (B,T,4,d)
    return dr, dpre, g_init


_slstm_scan.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)


def slstm_block_fwd(params: dict, cfg: ModelConfig, x: jax.Array, state=None):
    B, T, d = x.shape
    pre = _slstm_pre(params, x)  # (B, T, 4, d) — one MXU matmul, not T
    if state is None:
        carry = _slstm_zero_carry(B, d)
    else:
        carry = (state["c"], state["n"], state["h"], state["m"])

    hs, carry = _slstm_scan(_r_tree(params), pre, carry)
    h = hs.astype(x.dtype)  # (B, T, d)
    gate = jax.nn.gelu((h @ params["ff_wg"]).astype(jnp.float32)).astype(x.dtype)
    y = (gate * (h @ params["ff_wi"])) @ params["ff_wo"]
    new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    return y, new_state


def slstm_block_decode(params: dict, cfg: ModelConfig, x: jax.Array, state: dict):
    return slstm_block_fwd(params, cfg, x, state)


def _slstm_zero_carry(B, d):
    z = jnp.zeros((B, d), jnp.float32)
    return (z, z, z, jnp.full((B, d), -jnp.inf, jnp.float32))


def slstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -jnp.inf, jnp.float32)}

"""Model assembly: init / forward / prefill / decode for every assigned arch.

A model is a sequence of *segments*; each segment is (pattern, n_stages)
where ``pattern`` is a tuple of layer kinds (e.g. ('rglru','rglru',
'attn_local')) and the segment's parameters are stacked over stages and
executed with ``lax.scan`` — one compiled stage body per segment regardless
of depth.  This is the Switchboard "prebuilt simulator per unique block"
principle applied to model compilation (DESIGN.md §3): compile cost is
O(#unique layer kinds), not O(n_layers).

Layer kinds: attn | attn_local | rglru | mlstm | slstm.
Every layer is pre-norm residual; transformer-family kinds carry their own
MLP (dense or MoE); xLSTM kinds are self-contained blocks (d_ff = 0).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import layers as L
from . import recurrent as R
from .config import ModelConfig
from .moe import moe_init, moe_fwd

PyTree = Any


# ----------------------------------------------------------------- segments
def segments_of(cfg: ModelConfig) -> list[tuple[tuple[str, ...], int]]:
    segs = [(cfg.block_pattern, cfg.n_stages)]
    if cfg.remainder:
        segs.append((cfg.remainder, 1))
    return segs


ATTN_KINDS = ("attn", "attn_local", "attn_moe")


def _has_mlp(cfg: ModelConfig, kind: str) -> bool:
    if kind == "attn_moe":
        return True
    return kind in ("attn", "attn_local", "rglru") and cfg.d_ff > 0


def _uses_moe(cfg: ModelConfig, kind: str) -> bool:
    return kind == "attn_moe"


# ----------------------------------------------------------------- init
def _layer_init(key, cfg: ModelConfig, kind: str, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict = {"norm1": L.rmsnorm_init(cfg.d_model, dtype)}
    if kind in ATTN_KINDS:
        p["mix"] = L.attention_init(k1, cfg, dtype)
    elif kind == "rglru":
        p["mix"] = R.rglru_block_init(k1, cfg, dtype)
    elif kind == "mlstm":
        p["mix"] = R.mlstm_block_init(k1, cfg, dtype)
    elif kind == "slstm":
        p["mix"] = R.slstm_block_init(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if _has_mlp(cfg, kind):
        p["norm2"] = L.rmsnorm_init(cfg.d_model, dtype)
        if _uses_moe(cfg, kind):
            p["mlp"] = moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 4)
    params: dict = {"embed": L.embed_init(keys[0], cfg.vocab, cfg.d_model, dtype)}
    segs = []
    for si, (pattern, n_stages) in enumerate(segments_of(cfg)):
        def stage_init(k):
            ks = jax.random.split(k, len(pattern))
            return tuple(
                _layer_init(ks[i], cfg, kind, dtype) for i, kind in enumerate(pattern)
            )
        stage_keys = jax.random.split(jax.random.fold_in(keys[1], si), n_stages)
        segs.append(jax.vmap(stage_init)(stage_keys))
    params["segments"] = segs
    params["final_norm"] = L.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.truncnorm(
            keys[2], (cfg.d_model, cfg.vocab), 1.0 / math.sqrt(cfg.d_model), dtype
        )
    return params


# ----------------------------------------------------------------- forward
def _layer_fwd(
    p: dict, cfg: ModelConfig, kind: str, x: jax.Array, positions: jax.Array,
    state: PyTree, constrain: Callable,
):
    """One layer, full-sequence. Returns (x, new_state, aux)."""
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    new_state: PyTree = state
    if kind in ATTN_KINDS:
        window = cfg.attn_window if kind == "attn_local" else None
        mix = L.attention_fwd(p["mix"], cfg, h, positions, window)
    elif kind == "rglru":
        mix, new_state = R.rglru_block_fwd(p["mix"], cfg, h, state)
    elif kind == "mlstm":
        mix, new_state = R.mlstm_block_fwd(p["mix"], cfg, h, state)
    elif kind == "slstm":
        mix, new_state = R.slstm_block_fwd(p["mix"], cfg, h, state)
    else:
        raise ValueError(kind)
    x = x + constrain(mix, "residual")
    if _has_mlp(cfg, kind):
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if _uses_moe(cfg, kind):
            ff, aux = moe_fwd(p["mlp"], cfg, h2, constrain)
        else:
            ff = L.mlp_fwd(p["mlp"], h2, cfg.hidden_act)
        x = x + constrain(ff, "residual")
    return x, new_state, aux


def forward(
    params: PyTree,
    cfg: ModelConfig,
    inputs: jax.Array,       # tokens (B, S) int32  OR embeddings (B, S, d)
    constrain: Callable = lambda a, kind: a,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward (training / encoder). Returns (logits, moe_aux)."""
    if cfg.input_mode == "embeddings":
        x = inputs.astype(jnp.dtype(cfg.dtype))
        B, S = x.shape[:2]
    else:
        x = L.embed_lookup(params["embed"], inputs)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        B, S = inputs.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = constrain(x, "activation")

    aux_total = jnp.zeros((), jnp.float32)
    for seg_idx, (pattern, n_stages) in enumerate(segments_of(cfg)):
        seg_params = params["segments"][seg_idx]

        def stage_body(carry, stage_p):
            x, aux = carry
            for i, kind in enumerate(pattern):
                x, _, a = _layer_fwd(stage_p[i], cfg, kind, x, positions, None, constrain)
                aux = aux + a
            return (x, aux), None

        body = jax.checkpoint(stage_body) if cfg.remat else stage_body
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), seg_params, length=n_stages)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x, cfg.tie_embeddings)
    return logits, aux_total


# ----------------------------------------------------------------- loss
def loss_fn(
    params: PyTree, cfg: ModelConfig, batch: dict, constrain: Callable = lambda a, k: a
) -> tuple[jax.Array, dict]:
    inputs = batch["inputs"]
    labels = batch["labels"]  # (B, S) int32
    logits, aux = forward(params, cfg, inputs, constrain)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - ll).mean()
    z_loss = 1e-4 * (logz**2).mean()
    moe_loss = 0.01 * aux
    loss = nll + z_loss + moe_loss
    return loss, {"nll": nll, "z_loss": z_loss, "moe_aux": aux}


# ----------------------------------------------------------------- decode
def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> list:
    """Per-segment stacked per-layer states for autoregressive decoding."""
    dtype = jnp.dtype(cfg.dtype)

    def one(kind):
        if kind in ATTN_KINDS:
            S = max_seq if kind == "attn" else min(cfg.attn_window or max_seq, max_seq)
            shape = (batch, S, cfg.n_kv_heads, cfg.head_dim)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if kind == "rglru":
            return R.rglru_init_state(cfg, batch)
        if kind == "mlstm":
            return R.mlstm_init_state(cfg, batch)
        if kind == "slstm":
            return R.slstm_init_state(cfg, batch)
        raise ValueError(kind)

    states = []
    for pattern, n_stages in segments_of(cfg):
        stage_state = tuple(one(kind) for kind in pattern)
        states.append(
            jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_stages,) + x.shape), stage_state
            )
        )
    return states


def _layer_decode(
    p: dict, cfg: ModelConfig, kind: str, x: jax.Array, pos: jax.Array, state: PyTree,
    constrain: Callable,
):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ATTN_KINDS:
        window = cfg.attn_window if kind == "attn_local" else None
        mix, ck, cv = L.attention_decode(
            p["mix"], cfg, h, state["k"], state["v"], pos, window
        )
        new_state = {"k": ck, "v": cv}
    elif kind == "rglru":
        mix, new_state = R.rglru_block_decode(p["mix"], cfg, h, state)
    elif kind == "mlstm":
        mix, new_state = R.mlstm_block_decode(p["mix"], cfg, h, state)
    elif kind == "slstm":
        mix, new_state = R.slstm_block_decode(p["mix"], cfg, h, state)
    else:
        raise ValueError(kind)
    x = x + mix
    if _has_mlp(cfg, kind):
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if _uses_moe(cfg, kind):
            ff, _ = moe_fwd(p["mlp"], cfg, h2, constrain)
        else:
            ff = L.mlp_fwd(p["mlp"], h2, cfg.hidden_act)
        x = x + ff
    return x, new_state


def decode_step(
    params: PyTree,
    cfg: ModelConfig,
    states: list,
    token: jax.Array,  # (B,) int32 current token
    pos: jax.Array,    # ()   int32 its position
    constrain: Callable = lambda a, k: a,
) -> tuple[list, jax.Array]:
    """One autoregressive step. Returns (new_states, logits (B, vocab))."""
    x = L.embed_lookup(params["embed"], token[:, None])
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    new_states = []
    for seg_idx, (pattern, n_stages) in enumerate(segments_of(cfg)):
        seg_params = params["segments"][seg_idx]
        seg_state = states[seg_idx]

        def stage_body(x, inp):
            stage_p, stage_s = inp
            new_s = []
            for i, kind in enumerate(pattern):
                x, s = _layer_decode(
                    stage_p[i], cfg, kind, x, pos, stage_s[i], constrain
                )
                new_s.append(s)
            return x, tuple(new_s)

        x, new_seg_state = jax.lax.scan(
            stage_body, x, (seg_params, seg_state), length=n_stages
        )
        new_states.append(new_seg_state)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x, cfg.tie_embeddings)
    return new_states, logits[:, 0, :]


def prefill(
    params: PyTree, cfg: ModelConfig, inputs: jax.Array, max_seq: int,
    constrain: Callable = lambda a, k: a,
) -> tuple[list, jax.Array]:
    """Run the prompt through the model, building decode states.

    Returns (states, last-token logits (B, vocab)).
    """
    if cfg.input_mode == "embeddings":
        x = inputs.astype(jnp.dtype(cfg.dtype))
        B, S = x.shape[:2]
    else:
        x = L.embed_lookup(params["embed"], inputs)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        B, S = inputs.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = constrain(x, "activation")
    states = init_decode_state(cfg, B, max_seq)
    new_states = []
    for seg_idx, (pattern, n_stages) in enumerate(segments_of(cfg)):
        seg_params = params["segments"][seg_idx]
        seg_state = states[seg_idx]

        def stage_body(x, inp):
            stage_p, stage_s = inp
            new_s = []
            for i, kind in enumerate(pattern):
                if kind in ATTN_KINDS:
                    window = cfg.attn_window if kind == "attn_local" else None
                    h = L.rmsnorm(stage_p[i]["norm1"], x, cfg.norm_eps)
                    mix, kk, vv = _attention_prefill(
                        stage_p[i]["mix"], cfg, h, positions, window, stage_s[i]
                    )
                    x = x + constrain(mix, "residual")
                    if _has_mlp(cfg, kind):
                        h2 = L.rmsnorm(stage_p[i]["norm2"], x, cfg.norm_eps)
                        if _uses_moe(cfg, kind):
                            ff, _ = moe_fwd(stage_p[i]["mlp"], cfg, h2, constrain)
                        else:
                            ff = L.mlp_fwd(stage_p[i]["mlp"], h2, cfg.hidden_act)
                        x = x + constrain(ff, "residual")
                    new_s.append({"k": kk, "v": vv})
                else:
                    x, s, _ = _layer_fwd(
                        stage_p[i], cfg, kind, x, positions, None, constrain
                    )
                    # thread final recurrent state into the decode cache
                    s = _coerce_rnn_state(cfg, kind, s)
                    new_s.append(s)
            return x, tuple(new_s)

        x, new_seg_state = jax.lax.scan(
            stage_body, x, (seg_params, seg_state), length=n_stages
        )
        new_states.append(new_seg_state)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x[:, -1:, :], cfg.tie_embeddings)
    return new_states, logits[:, 0, :]


def _attention_prefill(p, cfg, h, positions, window, state):
    """Full-sequence attention that also fills the KV cache."""
    B, T, _ = h.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k = (h @ p["wk"]).reshape(B, T, Hkv, hd)
    v = (h @ p["wv"]).reshape(B, T, Hkv, hd)
    k = L.positional_rotate(cfg, k, positions)
    mix = L.attention_fwd(p, cfg, h, positions, window)
    S = state["k"].shape[1]
    if T >= S:
        ck = k[:, -S:, :, :]
        cv = v[:, -S:, :, :]
        if window is not None:
            # ring layout: absolute position p lives at slot p % S
            roll = (T % S)
            ck = jnp.roll(ck, roll, axis=1)
            cv = jnp.roll(cv, roll, axis=1)
    else:
        ck = jax.lax.dynamic_update_slice(state["k"], k, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(state["v"], v, (0, 0, 0, 0))
    return mix, ck, cv


def _coerce_rnn_state(cfg, kind, s):
    return s

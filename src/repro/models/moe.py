"""Mixture-of-Experts FFN with capacity-based dispatch (GShard-style,
scatter/gather formulation — no O(tokens x experts x capacity) one-hot
einsums, so HLO FLOPs stay close to the model's useful FLOPs).

Dispatch pipeline per token group (a group = the tokens of one data shard):
  1. router logits -> top_k experts + gate weights,
  2. position_in_expert via cumsum of expert one-hots (int32),
  3. tokens scattered into (E, capacity, d) expert buffers (dropped beyond
     capacity — the paper-standard "dropping" strategy),
  4. expert matmuls as batched einsum over the expert dim,
  5. gather back + gate-weighted combine.

Sharding: groups (G) ride the data axes; expert buffers are annotated to
the 'model' axis between steps 3 and 4, which makes GSPMD materialize the
dispatch all-to-all exactly once (see sharding/partition.py).  The expert
dimension is the EP axis.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from .config import ModelConfig, MoEConfig
from .layers import truncnorm, mlp_init, mlp_fwd


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    mc = cfg.moe
    d, dff, E = cfg.d_model, mc.d_ff_expert, mc.n_experts
    kr, ki, kg, ko, ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": truncnorm(kr, (d, E), s, jnp.float32),
        "wi": truncnorm(ki, (E, d, dff), s, dtype),
        "wg": truncnorm(kg, (E, d, dff), s, dtype),
        "wo": truncnorm(ko, (E, dff, d), 1.0 / math.sqrt(dff), dtype),
    }
    if mc.shared_expert:
        p["shared"] = mlp_init(ks, d, mc.d_ff_expert, dtype)
    return p


def _router(params, mc: MoEConfig, x: jax.Array):
    """x: (G, S, d) -> (expert_idx (G,S,k), gates (G,S,k), aux_loss ())."""
    logits = (x.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    if mc.gate_fn == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(scores, mc.top_k)  # (G,S,k)
    if mc.router_norm_topk and mc.top_k > 1:
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    # Switch-style load-balancing aux loss (scatter-add, no one-hots).
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    counts = jnp.zeros((E,), jnp.float32).at[idx[..., 0].reshape(-1)].add(1.0)
    ce = counts / (idx.shape[0] * idx.shape[1])
    aux = E * jnp.sum(me * ce)
    return idx, gates, aux


def moe_fwd(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d) — B doubles as the group dim
    constrain: Callable[[jax.Array, str], jax.Array] = lambda a, kind: a,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B, S, d), aux_loss ()).

    ``constrain`` is the sharding hook: called with ('dispatch' | 'combine')
    buffers so the partitioner can pin the EP resharding points.
    """
    mc = cfg.moe
    B, S, d = x.shape
    E, k = mc.n_experts, mc.top_k
    cap = max(int(mc.capacity_factor * S * k / E), 4)

    idx, gates, aux = _router(params, mc, x)  # (B,S,k)

    # position_in_expert over the flattened (S*k) choices of each group.
    flat_idx = idx.reshape(B, S * k)
    onehot = (flat_idx[..., None] == jnp.arange(E, dtype=jnp.int32)).astype(jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1  # inclusive -> own position
    position = jnp.take_along_axis(
        pos_in_e, flat_idx[..., None], axis=-1
    )[..., 0]  # (B, S*k)
    keep = (position < cap).reshape(B, S, k)
    slot = jnp.where(
        keep, flat_idx.reshape(B, S, k) * cap + position.reshape(B, S, k), E * cap
    )  # (B, S, k); overflow slot swallows drops

    # scatter tokens -> (B, E*cap+1, d).  One scatter per choice rank so the
    # (B, S*k, d) token replication is never materialized.
    buf = jnp.zeros((B, E * cap + 1, d), x.dtype)
    for i in range(k):
        buf = jax.vmap(lambda b, s_, v: b.at[s_].add(v))(buf, slot[:, :, i], x)
    expert_in = buf[:, : E * cap].reshape(B, E, cap, d)
    expert_in = constrain(expert_in, "dispatch")

    # expert computation (batched over E — the EP-sharded einsum).
    gate_h = jnp.einsum("becd,edf->becf", expert_in, params["wg"])
    act = jax.nn.silu(gate_h) if cfg.hidden_act == "silu" else jax.nn.gelu(gate_h)
    h = act * jnp.einsum("becd,edf->becf", expert_in, params["wi"])
    expert_out = jnp.einsum("becf,efd->becd", h, params["wo"])  # (B,E,cap,d)
    expert_out = constrain(expert_out, "combine")

    # gather + weighted combine (again per choice rank).
    flat_out = expert_out.reshape(B, E * cap, d)
    flat_out = jnp.concatenate([flat_out, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    y = jnp.zeros((B, S, d), jnp.float32)
    for i in range(k):
        got = jax.vmap(lambda f, s_: f[s_])(flat_out, slot[:, :, i])  # (B,S,d)
        w = (gates[:, :, i] * keep[:, :, i]).astype(jnp.float32)
        y = y + got.astype(jnp.float32) * w[..., None]
    y = y.astype(x.dtype)

    if mc.shared_expert:
        y = y + mlp_fwd(params["shared"], x, cfg.hidden_act)
    return y, aux

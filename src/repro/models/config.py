"""Model configuration dataclasses for all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_norm_topk: bool = True   # normalize top-k probs (qwen3)
    shared_expert: bool = False     # llama4: shared expert alongside routed
    gate_fn: str = "softmax"        # 'softmax' | 'sigmoid' (llama4 top-1)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # layer pattern, cycled to fill n_layers; remainder = prefix of pattern.
    # kinds: attn | attn_local | rglru | mlstm | slstm
    block_pattern: tuple[str, ...] = ("attn",)

    hidden_act: str = "silu"     # silu => SwiGLU, gelu => GeGLU
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False    # gemma-style sqrt(d_model) embedding scale
    rope_theta: float = 500_000.0
    rope_type: str = "default"   # default | mrope
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    attn_window: int | None = None  # for attn_local layers
    causal: bool = True          # False: encoder-only (hubert)
    attn_logit_softcap: float | None = None

    moe: MoEConfig | None = None
    # recurrent-block hyperparams
    rnn_width: int | None = None   # RG-LRU lru_width (defaults d_model)
    conv_width: int = 4

    input_mode: str = "tokens"   # tokens | embeddings (vlm/audio stub frontend)

    dtype: Any = "bfloat16"
    remat: bool = True
    # smoke-test configs set this False so tiny shapes skip kernel blocking
    use_kernels: bool = True

    def __post_init__(self):
        if self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    @property
    def n_stages(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def remainder(self) -> tuple[str, ...]:
        return self.block_pattern[: self.n_layers % len(self.block_pattern)]

    @property
    def rnn_dim(self) -> int:
        return self.rnn_width or self.d_model

    def param_count(self) -> int:
        """Total parameters (embedding + layers), for roofline MODEL_FLOPS."""
        d, hd = self.d_model, self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        mlp = 3 * d * self.d_ff if self.d_ff else 0
        moe_mlp = 0
        if self.moe is not None:
            moe_mlp = d * self.moe.n_experts + self.moe.n_experts * 3 * d * self.moe.d_ff_expert
            if self.moe.shared_expert:
                moe_mlp += 3 * d * self.moe.d_ff_expert
        counts = {}
        counts["attn"] = attn + mlp + 2 * d
        counts["attn_local"] = counts["attn"]
        counts["attn_moe"] = attn + moe_mlp + 2 * d
        dr = self.rnn_dim
        counts["rglru"] = d * dr * 2 + self.conv_width * dr + 2 * dr + dr * d + mlp + 2 * d
        # mlstm: up-proj x2 (factor 2), q/k/v over inner dim, out, gates
        di = 2 * d
        counts["mlstm"] = d * di * 2 + 3 * di * di // 1 + di * d + 2 * d
        hd_s = d // self.n_heads
        counts["slstm"] = (
            4 * d * d + 4 * self.n_heads * hd_s * hd_s  # input + block-diag R
            + 3 * d * (4 * d // 3) + 2 * d
        )
        n_full = self.n_stages
        total = 0
        for kind in self.block_pattern:
            total += counts[kind] * n_full
        for kind in self.remainder:
            total += counts[kind]
        total += self.vocab * d  # embed
        if not self.tie_embeddings:
            total += self.vocab * d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        routed = self.moe.n_experts * 3 * self.d_model * self.moe.d_ff_expert
        active = self.moe.top_k * 3 * self.d_model * self.moe.d_ff_expert
        n_moe_layers = sum(
            1 for k in self.block_pattern * self.n_stages + self.remainder
            if k == "attn_moe"
        )
        return full - n_moe_layers * (routed - active)

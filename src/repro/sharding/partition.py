"""Sharding rules: logical param/activation axes -> mesh PartitionSpecs.

Strategy (2-D or 3-D mesh):
  * data axes ``dp`` = ('data',) or ('pod', 'data')  — batch + FSDP
  * tensor axis ``tp`` = 'model'                      — TP / EP

Parameter rules (FSDP x TP 2-D sharding — every large matrix is sharded on
BOTH mesh axis groups, so per-device bytes scale 1/(dp*tp)):

  embed (V, d)          : (tp, dp)       vocab over model, d over data
  attn wq/wk/wv (d, HD) : (dp, tp)
  attn wo (HD, d)       : (tp, dp)
  mlp wi/wg (d, f)      : (dp, tp)
  mlp wo (f, d)         : (tp, dp)
  moe router (d, E)     : (dp, None)
  moe wi/wg (E, d, f)   : (tp, dp, None)  EP: experts over model
  moe wo (E, f, d)      : (tp, None, dp)
  rglru/mlstm/slstm mats: (dp, tp) input-major, (tp, dp) output-major
  norms / scalars       : replicated

Every rule is divisibility-guarded: an axis that does not divide the dim
falls back to None (e.g. hubert's vocab=504 on a 16-way model axis).
Stacked-segment params get a leading None for the stage dim automatically.

Activation constraints (used via ``constrain(x, kind)``):
  activation/residual (B, S, d): (dp, sp?, None) — optional sequence
  sharding over 'model' for long-context prefill,
  dispatch/combine (G, E, cap, d): (dp, tp, None, None) — pins the MoE
  all-to-all boundary.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Strategy:
    """How a given (arch, shape, mesh) is partitioned."""

    dp: tuple[str, ...] = ("data",)   # batch + FSDP axes
    tp: str | None = "model"          # tensor/expert axis
    seq_shard: bool = False           # Megatron-style sequence sharding (SP)
    fsdp: bool = True                 # shard the non-tp dim of matrices over dp

    def dp_size(self, mesh: Mesh) -> int:
        n = 1
        for a in self.dp:
            n *= mesh.shape[a]
        return n

    def tp_size(self, mesh: Mesh) -> int:
        return mesh.shape[self.tp] if self.tp else 1


def _canon(entry):
    """Canonicalize a spec entry: a 1-tuple of axes means the axis itself.

    Newer JAX does this inside PartitionSpec equality; older versions treat
    ``P(('data',))`` and ``P('data')`` as distinct, so we normalize at the
    source to keep specs comparable (and HLO shardings identical) across
    versions.
    """
    if isinstance(entry, tuple) and len(entry) == 1:
        return entry[0]
    return entry


def _div(n: int, axes, mesh: Mesh):
    """Return axes if they evenly divide n, else None."""
    if axes is None:
        return None
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return axes if n % size == 0 else None


# --------------------------------------------------------------- params
_RULES: list[tuple[str, Any]] = [
    # (regex on 'path/like/this' with shapes appended at match time)
    (r"embed$", ("tp", "dp")),
    (r"lm_head$", ("dp", "tp")),
    (r"(norm1|norm2|final_norm).*scale$", (None,)),
    (r"mix/w[qkv]$", ("dp", "tp")),
    (r"mix/wo$", ("tp", "dp")),
    (r"mlp/(wi|wg)$", ("dp", "tp")),
    (r"mlp/wo$", ("tp", "dp")),
    (r"mlp/router$", ("dp", None)),
    (r"mlp/shared/(wi|wg)$", ("dp", "tp")),
    (r"mlp/shared/wo$", ("tp", "dp")),
    # rglru
    (r"mix/(wx|wg)$", ("dp", "tp")),
    (r"mix/conv$", (None, "tp")),
    (r"mix/(wa|wi)$", ("dp", "tp")),
    (r"mix/lam$", ("tp",)),
    (r"mix/wo$", ("tp", "dp")),
    # mlstm
    (r"mix/(w_up|w_gate)$", ("dp", "tp")),
    (r"mix/w_if$", ("dp", None)),
    (r"mix/w_down$", ("tp", "dp")),
    (r"mix/skip$", ("tp",)),
    (r"mix/b_if$", (None,)),
    # slstm
    (r"mix/(w|r)_[ifzo]$", ("dp", "tp")),
    (r"mix/b_[ifzo]$", (None,)),
    (r"mix/ff_(wi|wg)$", ("dp", "tp")),
    (r"mix/ff_wo$", ("tp", "dp")),
]

# MoE expert tensors (3-D) handled specially.
_MOE_3D = [
    (r"mlp/(wi|wg)$", ("tp", "dp", None)),
    (r"mlp/wo$", ("tp", None, "dp")),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        elif isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params_shapes: PyTree, strategy: Strategy, mesh: Mesh) -> PyTree:
    """PartitionSpec tree for a params (or ShapeDtypeStruct) tree."""

    def resolve(tag, dim):
        if tag == "dp":
            axes = strategy.dp if strategy.fsdp else None
        elif tag == "tp":
            axes = strategy.tp
        else:
            axes = tag
        return _div(dim, axes, mesh)

    def spec_for(path, leaf) -> P:
        ps = _path_str(path)
        shape = leaf.shape
        in_segments = "segments" in ps
        rank = len(shape)
        eff_shape = shape[1:] if in_segments else shape  # strip stage dim

        rules = _MOE_3D + _RULES if len(eff_shape) == 3 else _RULES
        for pat, axes in rules:
            if re.search(pat, ps):
                if len(axes) != len(eff_shape):
                    continue
                resolved = tuple(_canon(resolve(a, d)) for a, d in zip(axes, eff_shape))
                if in_segments:
                    resolved = (None,) + resolved
                return P(*resolved)
        return P()  # replicate by default

    return jax.tree_util.tree_map_with_path(spec_for, params_shapes)


def named_shardings(params_shapes: PyTree, strategy: Strategy, mesh: Mesh) -> PyTree:
    specs = param_specs(params_shapes, strategy, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------- activations
def make_constrain(strategy: Strategy, mesh: Mesh, seq_len: int | None = None):
    """Returns constrain(x, kind) applying with_sharding_constraint."""
    dp = strategy.dp

    def constrain(x, kind: str):
        if mesh is None:
            return x
        if kind in ("activation", "residual"):
            if x.ndim != 3:
                return x
            sp = None
            if strategy.seq_shard and strategy.tp and seq_len and seq_len % mesh.shape[strategy.tp] == 0:
                sp = strategy.tp
            spec = P(_div(x.shape[0], dp, mesh), sp, None)
        elif kind == "dispatch" or kind == "combine":
            # Both expert buffers stay expert-sharded (EP).  Measured
            # alternatives for 'combine' (qwen3-moe train, §Perf bonus):
            # resharding expert_out back to token ranks before the gather
            # moves the 10x-padded capacity buffer over ICI (coll 144->405 s,
            # refuted); the winning schedule (future work) is a shard_map'd
            # combine: local gather + local top-k sum, then ONE (B,S,d)
            # all-reduce (~2 GB/layer instead of 26 GB/layer).
            spec = P(
                _div(x.shape[0], dp, mesh),
                _div(x.shape[1], strategy.tp, mesh),
                None,
                None,
            )
        elif kind == "logits":
            spec = P(_div(x.shape[0], dp, mesh), None, _div(x.shape[-1], strategy.tp, mesh))
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


# --------------------------------------------------------------- batch/cache
def batch_specs(cfg, shape, strategy: Strategy, mesh: Mesh) -> PyTree:
    """Input shardings for a train batch."""
    b = shape.global_batch
    dp = _div(b, strategy.dp, mesh)
    if cfg.input_mode == "embeddings":
        return {"inputs": P(dp, None, None), "labels": P(dp, None)}
    return {"inputs": P(dp, None), "labels": P(dp, None)}


def decode_state_specs(state_shapes: PyTree, cfg, strategy: Strategy, mesh: Mesh) -> PyTree:
    """Shardings for decode caches: batch over dp; heads/features over tp
    with divisibility fallback to head_dim, then replicate."""

    def spec_for(path, leaf) -> P:
        shape = leaf.shape
        ps = _path_str(path)
        # stacked (n_stages, B, ...) leaves
        stage = ("segments" in ps) or True  # decode states are always stacked
        eff = shape[1:]
        if len(eff) == 4 and ps.endswith(("k", "v")):  # (B, S, Hkv, hd)
            b, s, hkv, hd = eff
            tp_on_heads = _div(hkv, strategy.tp, mesh)
            tp_on_hd = _div(hd, strategy.tp, mesh) if tp_on_heads is None else None
            return P(None, _canon(_div(b, strategy.dp, mesh)), None,
                     _canon(tp_on_heads), _canon(tp_on_hd))
        # recurrent states: (B, ...) — batch over dp, last dim over tp
        resolved = [None, _canon(_div(eff[0], strategy.dp, mesh))]
        for d in eff[1:-1]:
            resolved.append(None)
        if len(eff) > 1:
            resolved.append(_canon(_div(eff[-1], strategy.tp, mesh)))
        return P(*resolved)

    return jax.tree_util.tree_map_with_path(spec_for, state_shapes)

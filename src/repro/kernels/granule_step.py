"""Generalized per-granule fused-epoch kernel (§Perf).

``systolic_step`` fuses ONE hand-written block type (the systolic MAC
cell) into a Pallas kernel.  This module generalizes that move to ANY
lowered ``ChannelGraph`` granule: the fused engine
(``repro.core.fused``) hands over a pure single-cycle function — depth-1
register channels + boundary queues + the vmapped block steps — and
``epoch_loop`` executes the whole K-cycle tier-inner epoch as one fused
computation instead of ~10 interpreted queue ops per cycle:

  * ``mode="xla"`` — one ``fori_loop`` whose carry is the compact
    register-file state (the deep queue buffers and lookup tables stay
    out of the carry).  One jitted XLA computation per epoch; the default
    off-TPU.
  * ``mode="unroll"`` — the cycle body is Python-unrolled into a single
    straight-line computation.  Opt-in: on XLA:CPU the loop form measures
    ~3x faster, but the unrolled form can win where cross-cycle fusion
    pays (small K, wide granules).
  * ``mode="pallas"`` — the same body wrapped in ONE ``pallas_call`` so
    the epoch executes with the granule state resident in VMEM (TPU).
    ``interpret=True`` runs the kernel path on CPU for CI.

Contract for ``cycle_fn``: pytree -> pytree with identical treedef,
shapes, and dtypes (the fused engine's local cycle satisfies it; the
wrapper checks and raises otherwise).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PyTree = Any


def resolve_mode(mode: str = "auto") -> str:
    """Pick the execution strategy for a K-cycle epoch body.

    "auto" resolves to the Pallas kernel on TPU and the ``fori_loop`` body
    elsewhere — measured on XLA:CPU the loop beats full unrolling ~3x (the
    straight-line body defeats the emitter's locality), so "unroll" is
    opt-in only.
    """
    if mode != "auto":
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _check_stable(step: Any, carry: PyTree) -> None:
    """Abstractly evaluate one cycle and verify the carry contract."""
    out = jax.eval_shape(step, carry)
    ok = jax.tree.structure(carry) == jax.tree.structure(out) and all(
        a.shape == b.shape and a.dtype == b.dtype
        for a, b in zip(jax.tree.leaves(carry), jax.tree.leaves(out))
    )
    if not ok:
        raise TypeError(
            "epoch_loop cycle_fn must preserve the carry's treedef, shapes "
            "and dtypes"
        )


def pallas_epoch(
    cycle_fn: Callable[..., PyTree],
    carry: PyTree,
    k_cycles: int,
    *,
    consts: PyTree | None = None,
    interpret: bool = False,
) -> PyTree:
    """Run ``k_cycles`` of ``cycle_fn`` inside ONE ``pallas_call``.

    The carry pytree is flattened into kernel refs; the kernel loads every
    leaf once, iterates the cycle body with the state resident in kernel
    memory (VMEM on TPU), and stores every leaf once — the granule state
    touches HBM exactly twice per epoch regardless of K.  ``consts``
    (lookup tables) are extra read-only refs.  Zero-size leaves carry no
    data and ``pallas_call`` rejects them, so they are filtered out and
    reconstructed inside the kernel.
    """
    c_leaves, c_def = jax.tree.flatten(carry)
    k_leaves, k_def = jax.tree.flatten(consts if consts is not None else ())
    c_live = [i for i, l in enumerate(c_leaves) if l.size > 0]
    k_live = [i for i, l in enumerate(k_leaves) if l.size > 0]
    nc, nk = len(c_live), len(k_live)

    def rebuild(live_vals, idx, template, treedef):
        full = [jnp.zeros(l.shape, l.dtype) for l in template]
        for i, v in zip(idx, live_vals):
            full[i] = v
        return jax.tree.unflatten(treedef, full)

    def kernel(*refs):
        cvals = tuple(r[...] for r in refs[:nc])
        consts_v = rebuild(
            tuple(r[...] for r in refs[nc:nc + nk]), k_live, k_leaves, k_def
        )

        def body(_, vs):
            c = rebuild(vs, c_live, c_leaves, c_def)
            out = cycle_fn(c, consts_v) if consts is not None else cycle_fn(c)
            out_leaves = jax.tree.leaves(out)
            return tuple(out_leaves[i] for i in c_live)

        cvals = jax.lax.fori_loop(0, k_cycles, body, cvals)
        for r, v in zip(refs[nc + nk:], cvals):
            r[...] = v

    outs = pl.pallas_call(
        kernel,
        out_shape=tuple(
            jax.ShapeDtypeStruct(c_leaves[i].shape, c_leaves[i].dtype)
            for i in c_live
        ),
        interpret=interpret,
    )(*(c_leaves[i] for i in c_live), *(k_leaves[i] for i in k_live))
    return rebuild(list(outs), c_live, c_leaves, c_def)


def epoch_loop(
    cycle_fn: Callable[..., PyTree],
    carry: PyTree,
    k_cycles: int,
    *,
    consts: PyTree | None = None,
    mode: str = "auto",
    interpret: bool = False,
) -> PyTree:
    """Execute ``k_cycles`` of ``cycle_fn`` as one fused epoch body.

    ``cycle_fn(carry)`` — or ``cycle_fn(carry, consts)`` when ``consts``
    is given — must return a carry with identical structure/shapes/dtypes
    (checked abstractly up front on every mode).
    """
    if k_cycles == 0:
        return carry
    step = (lambda c: cycle_fn(c, consts)) if consts is not None else cycle_fn
    _check_stable(step, carry)
    mode = resolve_mode(mode)
    if mode == "unroll":
        out = carry
        for _ in range(k_cycles):
            out = step(out)
        return out
    if mode == "xla":
        if k_cycles == 1:
            return step(carry)
        return jax.lax.fori_loop(0, k_cycles, lambda _, c: step(c), carry)
    if mode == "pallas":
        return pallas_epoch(
            cycle_fn, carry, k_cycles, consts=consts, interpret=interpret
        )
    raise ValueError(f"unknown epoch mode {mode!r} (auto|unroll|xla|pallas)")

"""Generalized per-granule fused-epoch kernel (§Perf).

``systolic_step`` fuses ONE hand-written block type (the systolic MAC
cell) into a Pallas kernel.  This module generalizes that move to ANY
lowered ``ChannelGraph`` granule: the fused engine
(``repro.core.fused``) hands over a pure single-cycle function — depth-1
register channels + boundary queues + the vmapped block steps — and
``epoch_loop`` executes the whole K-cycle tier-inner epoch as one fused
computation instead of ~10 interpreted queue ops per cycle:

  * ``mode="xla"`` — one ``fori_loop`` whose carry is the compact
    register-file state (the deep queue buffers and lookup tables stay
    out of the carry).  One jitted XLA computation per epoch; the default
    off-TPU.
  * ``mode="unroll"`` — the cycle body is Python-unrolled into a single
    straight-line computation.  Opt-in: on XLA:CPU the loop form measures
    ~3x faster, but the unrolled form can win where cross-cycle fusion
    pays (small K, wide granules).
  * ``mode="pallas"`` — the same body wrapped in ONE ``pallas_call`` so
    the epoch executes with the granule state resident in VMEM (TPU).
    ``interpret=True`` runs the kernel path on CPU for CI.

Contract for ``cycle_fn``: pytree -> pytree with identical treedef,
shapes, and dtypes (the fused engine's local cycle satisfies it; the
wrapper checks and raises otherwise).
"""
from __future__ import annotations

import os
from typing import Any, Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PyTree = Any

#: Op list executed by :func:`epoch_program`: ``("C", n)`` runs ``n``
#: cycles of the cycle body; ``("X", t)`` runs the caller's exchange
#: function for tier ``t``.  The whole program is ONE fused computation.
Program = Sequence[Tuple[str, int]]

_MODES = ("auto", "unroll", "xla", "pallas")


def resolve_mode(mode: str = "auto") -> str:
    """Pick the execution strategy for a K-cycle epoch body.

    The environment variable ``REPRO_EPOCH_MODE`` (one of
    ``auto|unroll|xla|pallas``) overrides a caller-passed ``"auto"`` so CI
    can force the pallas body (under interpret, see
    :func:`resolve_interpret`) without threading a flag through every
    engine.  An explicit non-"auto" argument always wins over the env.

    "auto" resolves to the Pallas kernel on TPU and the ``fori_loop`` body
    elsewhere — measured on XLA:CPU the loop beats full unrolling ~3x (the
    straight-line body defeats the emitter's locality), so "unroll" is
    opt-in only.
    """
    if mode == "auto":
        env = os.environ.get("REPRO_EPOCH_MODE", "auto").strip().lower()
        if env and env != "auto":
            if env not in _MODES:
                raise ValueError(
                    f"REPRO_EPOCH_MODE={env!r} not in {_MODES}")
            return env
    if mode != "auto":
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def resolve_interpret(interpret: Any = "auto") -> bool:
    """Resolve the pallas ``interpret`` knob.

    ``"auto"`` means: run the kernel natively on TPU, fall back to the
    Pallas interpreter everywhere else — so ``mode="pallas"`` is never
    dead code off-TPU (the ISSUE 6 CI requirement).  The env override
    ``REPRO_PALLAS_INTERPRET=0|1`` forces either way (e.g. to exercise the
    interpreter on TPU hosts).  Booleans pass through unchanged.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip()
    if env:
        return env not in ("0", "false", "False")
    if interpret == "auto":
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _check_stable(step: Any, carry: PyTree) -> None:
    """Abstractly evaluate one cycle and verify the carry contract."""
    out = jax.eval_shape(step, carry)
    ok = jax.tree.structure(carry) == jax.tree.structure(out) and all(
        a.shape == b.shape and a.dtype == b.dtype
        for a, b in zip(jax.tree.leaves(carry), jax.tree.leaves(out))
    )
    if not ok:
        raise TypeError(
            "epoch_loop cycle_fn must preserve the carry's treedef, shapes "
            "and dtypes"
        )


def pallas_program(
    cycle_fn: Callable[..., PyTree],
    carry: PyTree,
    program: Program,
    *,
    exchange_fn: Callable[..., PyTree] | None = None,
    consts: PyTree | None = None,
    interpret: Any = "auto",
) -> PyTree:
    """Run a ``("C", n)`` / ``("X", t)`` op program inside ONE
    ``pallas_call`` — the resident multi-epoch kernel.

    The carry pytree is flattened into kernel refs; the kernel loads every
    leaf once, then walks the whole program — every inner-epoch cycle
    block as a ``fori_loop`` of the cycle body and every tier exchange as
    an inline call to ``exchange_fn`` — with the granule state resident in
    kernel memory (VMEM on TPU) for the program's whole lifetime.  The
    state touches HBM exactly twice regardless of how many epochs and
    tier boundaries the program spans; ``pallas_call`` stages the
    HBM<->VMEM slab transfers at kernel entry/exit asynchronously, so the
    boundary staging overlaps the surrounding dispatch.  ``consts``
    (lookup tables) are extra read-only refs.  Zero-size leaves carry no
    data and ``pallas_call`` rejects them, so they are filtered out and
    reconstructed inside the kernel.
    """
    c_leaves, c_def = jax.tree.flatten(carry)
    k_leaves, k_def = jax.tree.flatten(consts if consts is not None else ())
    c_live = [i for i, l in enumerate(c_leaves) if l.size > 0]
    k_live = [i for i, l in enumerate(k_leaves) if l.size > 0]
    nc, nk = len(c_live), len(k_live)

    def rebuild(live_vals, idx, template, treedef):
        full = [jnp.zeros(l.shape, l.dtype) for l in template]
        for i, v in zip(idx, live_vals):
            full[i] = v
        return jax.tree.unflatten(treedef, full)

    def kernel(*refs):
        cvals = tuple(r[...] for r in refs[:nc])
        consts_v = rebuild(
            tuple(r[...] for r in refs[nc:nc + nk]), k_live, k_leaves, k_def
        )

        def live_out(out):
            out_leaves = jax.tree.leaves(out)
            return tuple(out_leaves[i] for i in c_live)

        def body(_, vs):
            c = rebuild(vs, c_live, c_leaves, c_def)
            out = cycle_fn(c, consts_v) if consts is not None else cycle_fn(c)
            return live_out(out)

        for op, arg in program:
            if op == "C":
                if arg == 1:
                    cvals = body(0, cvals)
                elif arg > 1:
                    cvals = jax.lax.fori_loop(0, arg, body, cvals)
            else:  # "X"
                c = rebuild(cvals, c_live, c_leaves, c_def)
                out = (exchange_fn(c, arg, consts_v) if consts is not None
                       else exchange_fn(c, arg))
                cvals = live_out(out)
        for r, v in zip(refs[nc + nk:], cvals):
            r[...] = v

    outs = pl.pallas_call(
        kernel,
        out_shape=tuple(
            jax.ShapeDtypeStruct(c_leaves[i].shape, c_leaves[i].dtype)
            for i in c_live
        ),
        interpret=resolve_interpret(interpret),
    )(*(c_leaves[i] for i in c_live), *(k_leaves[i] for i in k_live))
    return rebuild(list(outs), c_live, c_leaves, c_def)


def pallas_epoch(
    cycle_fn: Callable[..., PyTree],
    carry: PyTree,
    k_cycles: int,
    *,
    consts: PyTree | None = None,
    interpret: Any = False,
) -> PyTree:
    """Run ``k_cycles`` of ``cycle_fn`` inside ONE ``pallas_call``.

    The single-epoch special case of :func:`pallas_program` (a program of
    one ``("C", k_cycles)`` op); see there for the memory contract.
    """
    return pallas_program(
        cycle_fn, carry, (("C", k_cycles),), consts=consts,
        interpret=interpret,
    )


def epoch_loop(
    cycle_fn: Callable[..., PyTree],
    carry: PyTree,
    k_cycles: int,
    *,
    consts: PyTree | None = None,
    mode: str = "auto",
    interpret: Any = False,
) -> PyTree:
    """Execute ``k_cycles`` of ``cycle_fn`` as one fused epoch body.

    ``cycle_fn(carry)`` — or ``cycle_fn(carry, consts)`` when ``consts``
    is given — must return a carry with identical structure/shapes/dtypes
    (checked abstractly up front on every mode).
    """
    if k_cycles == 0:
        return carry
    step = (lambda c: cycle_fn(c, consts)) if consts is not None else cycle_fn
    _check_stable(step, carry)
    mode = resolve_mode(mode)
    if mode == "unroll":
        out = carry
        for _ in range(k_cycles):
            out = step(out)
        return out
    if mode == "xla":
        if k_cycles == 1:
            return step(carry)
        return jax.lax.fori_loop(0, k_cycles, lambda _, c: step(c), carry)
    if mode == "pallas":
        return pallas_epoch(
            cycle_fn, carry, k_cycles, consts=consts, interpret=interpret
        )
    raise ValueError(f"unknown epoch mode {mode!r} (auto|unroll|xla|pallas)")


def epoch_program(
    cycle_fn: Callable[..., PyTree],
    carry: PyTree,
    program: Program,
    *,
    exchange_fn: Callable[..., PyTree] | None = None,
    consts: PyTree | None = None,
    mode: str = "auto",
    interpret: Any = "auto",
) -> PyTree:
    """Execute a multi-epoch op program as ONE fused computation.

    ``program`` is a flat op list: ``("C", n)`` steps the cycle body ``n``
    cycles; ``("X", t)`` applies ``exchange_fn`` for tier ``t`` (a pure
    local tier exchange — drain egress queues into slab rows, scatter
    ingress rows back).  This is the resident-kernel generalization of
    :func:`epoch_loop`: a whole K_outer x K_inner span between two
    device-boundary exchanges runs as one body, so under ``mode="pallas"``
    the register/queue state stays resident in VMEM across every inner
    epoch and local tier boundary it contains.  The xla/unroll modes
    execute the *same* op sequence (bit-exact twins for CPU CI), just as
    jitted XLA loops instead of one kernel.

    Both ``cycle_fn`` and ``exchange_fn`` must preserve the carry's
    treedef/shapes/dtypes (checked abstractly up front).
    """
    program = tuple((op, int(arg)) for op, arg in program)
    for op, _ in program:
        if op not in ("C", "X"):
            raise ValueError(f"unknown program op {op!r} (C|X)")
    if any(op == "X" for op, _ in program) and exchange_fn is None:
        raise ValueError("program has ('X', t) ops but no exchange_fn")
    if not program:
        return carry
    step = (lambda c: cycle_fn(c, consts)) if consts is not None else cycle_fn
    _check_stable(step, carry)
    for t in sorted({arg for op, arg in program if op == "X"}):
        _check_stable(
            (lambda c, _t=t: exchange_fn(c, _t, consts)) if consts is not None
            else (lambda c, _t=t: exchange_fn(c, _t)),
            carry,
        )
    mode = resolve_mode(mode)
    if mode == "pallas":
        return pallas_program(
            cycle_fn, carry, program, exchange_fn=exchange_fn, consts=consts,
            interpret=interpret,
        )
    if mode not in ("xla", "unroll"):
        raise ValueError(f"unknown epoch mode {mode!r} (auto|unroll|xla|pallas)")
    out = carry
    for op, arg in program:
        if op == "C":
            if mode == "unroll":
                for _ in range(arg):
                    out = step(out)
            elif arg == 1:
                out = step(out)
            elif arg > 1:
                out = jax.lax.fori_loop(0, arg, lambda _, c: step(c), out)
        else:
            out = (exchange_fn(out, arg, consts) if consts is not None
                   else exchange_fn(out, arg))
    return out

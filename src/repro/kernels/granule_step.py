"""Generalized per-granule fused-epoch kernel (§Perf).

``systolic_step`` fuses ONE hand-written block type (the systolic MAC
cell) into a Pallas kernel.  This module generalizes that move to ANY
lowered ``ChannelGraph`` granule: the fused engine
(``repro.core.fused``) hands over a pure single-cycle function — depth-1
register channels + boundary queues + the vmapped block steps — and
``epoch_loop`` executes the whole K-cycle tier-inner epoch as one fused
computation instead of ~10 interpreted queue ops per cycle:

  * ``mode="xla"`` — one ``fori_loop`` whose carry is the compact
    register-file state (the deep queue buffers and lookup tables stay
    out of the carry).  One jitted XLA computation per epoch; the default
    off-TPU.
  * ``mode="unroll"`` — the cycle body is Python-unrolled into a single
    straight-line computation.  Opt-in: on XLA:CPU the loop form measures
    ~3x faster, but the unrolled form can win where cross-cycle fusion
    pays (small K, wide granules).
  * ``mode="pallas"`` — the same body wrapped in ONE ``pallas_call`` so
    the epoch executes with the granule state resident in VMEM (TPU).
    ``interpret=True`` runs the kernel path on CPU for CI.

Contract for ``cycle_fn``: pytree -> pytree with identical treedef,
shapes, and dtypes (the fused engine's local cycle satisfies it; the
wrapper checks and raises otherwise).
"""
from __future__ import annotations

import os
from typing import Any, Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PyTree = Any

#: Op list executed by :func:`epoch_program`: ``("C", n)`` runs ``n``
#: cycles of the cycle body; ``("X", t)`` runs the caller's exchange
#: function for tier ``t``; ``("XI", t)`` / ``("XC", t)`` are the split
#: form of the same exchange — issue (drain + start transfer) and commit
#: (finish transfer + fill) — so intervening ops overlap the transfer.
#: The whole program is ONE fused computation.
Program = Sequence[Tuple[str, int]]

_MODES = ("auto", "unroll", "xla", "pallas")


def resolve_mode(mode: str = "auto") -> str:
    """Pick the execution strategy for a K-cycle epoch body.

    The environment variable ``REPRO_EPOCH_MODE`` (one of
    ``auto|unroll|xla|pallas``) overrides a caller-passed ``"auto"`` so CI
    can force the pallas body (under interpret, see
    :func:`resolve_interpret`) without threading a flag through every
    engine.  An explicit non-"auto" argument always wins over the env.

    "auto" resolves to the Pallas kernel on TPU and the ``fori_loop`` body
    elsewhere — measured on XLA:CPU the loop beats full unrolling ~3x (the
    straight-line body defeats the emitter's locality), so "unroll" is
    opt-in only.
    """
    if mode == "auto":
        env = os.environ.get("REPRO_EPOCH_MODE", "auto").strip().lower()
        if env and env != "auto":
            if env not in _MODES:
                raise ValueError(
                    f"REPRO_EPOCH_MODE={env!r} not in {_MODES}")
            return env
    if mode != "auto":
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def resolve_interpret(interpret: Any = "auto") -> bool:
    """Resolve the pallas ``interpret`` knob.

    ``"auto"`` means: run the kernel natively on TPU, fall back to the
    Pallas interpreter everywhere else — so ``mode="pallas"`` is never
    dead code off-TPU (the ISSUE 6 CI requirement).  The env override
    ``REPRO_PALLAS_INTERPRET=0|1`` forces either way (e.g. to exercise the
    interpreter on TPU hosts).  Booleans pass through unchanged.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip()
    if env:
        return env not in ("0", "false", "False")
    if interpret == "auto":
        return jax.default_backend() != "tpu"
    return bool(interpret)


def resolve_overlap(overlap: Any = "auto") -> bool:
    """Resolve the overlapped-exchange knob (split issue/commit phases).

    Same precedence as :func:`resolve_mode`: an explicit non-"auto"
    argument (bool, or one of ``on|off|1|0|true|false``) always wins; the
    environment variable ``REPRO_OVERLAP`` overrides a caller-passed
    ``"auto"`` so CI can flip every engine to the split schedule without
    threading a flag through; "auto" resolves to off — the serial
    schedule stays the default, and the split schedule is bit-identical
    by construction so flipping it per-run is always safe.
    """
    def parse(v: Any, src: str) -> bool:
        if isinstance(v, bool):
            return v
        s = str(v).strip().lower()
        if s in ("1", "on", "true", "yes"):
            return True
        if s in ("0", "off", "false", "no"):
            return False
        raise ValueError(f"{src}={v!r} not a boolean (on|off|1|0|auto)")

    if not (isinstance(overlap, str) and overlap.strip().lower() == "auto"):
        return parse(overlap, "overlap")
    env = os.environ.get("REPRO_OVERLAP", "auto").strip().lower()
    if env and env != "auto":
        return parse(env, "REPRO_OVERLAP")
    return False


def overlap_program(program: Program) -> Program:
    """Rewrite a serial op program into the split-exchange schedule.

    Every maximal run of consecutive ``("X", t)`` ops — the tiers firing
    at one sync boundary — becomes all their issues followed by all their
    commits: ``X_a, X_b -> XI_a, XI_b, XC_a, XC_b``.  A slab drained at
    the end of epoch window *w* is only consumed at the start of window
    *w+1*, and drains touch only egress queues while fills touch only
    ingress queues (disjoint state), so this reorder is bit-safe: every
    tier's drain still precedes its own fill, and every fill still
    precedes the first cycle that could pop its packets.  What it buys:
    all of a boundary's transfers are in flight at once, and each
    transfer's completion is only awaited at fill time (next-window
    start), giving the scheduler/DMA engine the whole boundary to hide
    the transfer latency.
    """
    out: list[Tuple[str, int]] = []
    run: list[int] = []

    def flush() -> None:
        out.extend(("XI", t) for t in run)
        out.extend(("XC", t) for t in run)
        run.clear()

    for op, arg in program:
        if op == "X":
            run.append(arg)
        else:
            flush()
            out.append((op, arg))
    flush()
    return tuple(out)


def _check_stable(step: Any, carry: PyTree) -> None:
    """Abstractly evaluate one cycle and verify the carry contract."""
    out = jax.eval_shape(step, carry)
    ok = jax.tree.structure(carry) == jax.tree.structure(out) and all(
        a.shape == b.shape and a.dtype == b.dtype
        for a, b in zip(jax.tree.leaves(carry), jax.tree.leaves(out))
    )
    if not ok:
        raise TypeError(
            "epoch_loop cycle_fn must preserve the carry's treedef, shapes "
            "and dtypes"
        )


def pallas_program(
    cycle_fn: Callable[..., PyTree],
    carry: PyTree,
    program: Program,
    *,
    exchange_fn: Callable[..., PyTree] | None = None,
    issue_fn: Callable[..., Tuple[PyTree, PyTree]] | None = None,
    commit_fn: Callable[..., PyTree] | None = None,
    consts: PyTree | None = None,
    interpret: Any = "auto",
) -> PyTree:
    """Run a ``("C", n)`` / ``("X", t)`` op program inside ONE
    ``pallas_call`` — the resident multi-epoch kernel.

    The carry pytree is flattened into kernel refs; the kernel loads every
    leaf once, then walks the whole program — every inner-epoch cycle
    block as a ``fori_loop`` of the cycle body and every tier exchange as
    an inline call to ``exchange_fn`` — with the granule state resident in
    kernel memory (VMEM on TPU) for the program's whole lifetime.  The
    state touches HBM exactly twice regardless of how many epochs and
    tier boundaries the program spans; ``pallas_call`` stages the
    HBM<->VMEM slab transfers at kernel entry/exit asynchronously, so the
    boundary staging overlaps the surrounding dispatch.  ``consts``
    (lookup tables) are extra read-only refs.  Zero-size leaves carry no
    data and ``pallas_call`` rejects them, so they are filtered out and
    reconstructed inside the kernel.

    Split ops ``("XI", t)`` / ``("XC", t)`` double-buffer the exchange
    slabs: the issued slab pytree is written into one of two VMEM staging
    buffers per tier and moved by an async DMA copy that is started at
    issue and only awaited at commit, so every op between the two phases
    — the other tiers' issues and fills, and on TPU the next window's
    step loop — runs while the copy is in flight.  Two slots per tier
    (selected by a compile-time firing counter) let a second issue start
    before the previous window's copy is awaited.
    """
    c_leaves, c_def = jax.tree.flatten(carry)
    k_leaves, k_def = jax.tree.flatten(consts if consts is not None else ())
    c_live = [i for i, l in enumerate(c_leaves) if l.size > 0]
    k_live = [i for i, l in enumerate(k_leaves) if l.size > 0]
    nc, nk = len(c_live), len(k_live)

    def rebuild(live_vals, idx, template, treedef):
        full = [jnp.zeros(l.shape, l.dtype) for l in template]
        for i, v in zip(idx, live_vals):
            full[i] = v
        return jax.tree.unflatten(treedef, full)

    def call_with_consts(fn, *a, consts_v):
        return fn(*a, consts_v) if consts is not None else fn(*a)

    # Per-tier staging for split exchanges: the pending pytree's shape is
    # derived abstractly, then each live leaf gets (src, dst) VMEM staging
    # buffers with two slots and a 2-slot DMA semaphore.
    split_tiers = sorted({arg for op, arg in program if op == "XI"})
    scratch_shapes: list = []
    stage_info: dict = {}
    for t in split_tiers:
        _, p_shape = jax.eval_shape(
            lambda c, _t=t: call_with_consts(issue_fn, c, _t, consts_v=consts),
            carry)
        p_leaves, p_def = jax.tree.flatten(p_shape)
        p_live = [i for i, l in enumerate(p_leaves) if l.size > 0]
        base = len(scratch_shapes)
        for i in p_live:
            leaf = p_leaves[i]
            scratch_shapes.append(pltpu.VMEM((2,) + leaf.shape, leaf.dtype))
            scratch_shapes.append(pltpu.VMEM((2,) + leaf.shape, leaf.dtype))
            scratch_shapes.append(pltpu.SemaphoreType.DMA((2,)))
        stage_info[t] = (p_leaves, p_def, p_live, base)

    def kernel(*refs):
        cvals = tuple(r[...] for r in refs[:nc])
        consts_v = rebuild(
            tuple(r[...] for r in refs[nc:nc + nk]), k_live, k_leaves, k_def
        )
        scratch = refs[nc + nk + nc:]

        def live_out(out):
            out_leaves = jax.tree.leaves(out)
            return tuple(out_leaves[i] for i in c_live)

        def body(_, vs):
            c = rebuild(vs, c_live, c_leaves, c_def)
            out = cycle_fn(c, consts_v) if consts is not None else cycle_fn(c)
            return live_out(out)

        def stage_refs(t, j):
            base = stage_info[t][3]
            return scratch[base + 3 * j], scratch[base + 3 * j + 1], \
                scratch[base + 3 * j + 2]

        fired = {t: 0 for t in split_tiers}
        pending_slot: dict = {}
        for op, arg in program:
            if op == "C":
                if arg == 1:
                    cvals = body(0, cvals)
                elif arg > 1:
                    cvals = jax.lax.fori_loop(0, arg, body, cvals)
            elif op == "X":
                c = rebuild(cvals, c_live, c_leaves, c_def)
                out = (exchange_fn(c, arg, consts_v) if consts is not None
                       else exchange_fn(c, arg))
                cvals = live_out(out)
            elif op == "XI":
                c = rebuild(cvals, c_live, c_leaves, c_def)
                out, pend = (issue_fn(c, arg, consts_v) if consts is not None
                             else issue_fn(c, arg))
                cvals = live_out(out)
                slot = fired[arg] % 2
                fired[arg] += 1
                pending_slot[arg] = slot
                p_vals = jax.tree.leaves(pend)
                for j, i in enumerate(stage_info[arg][2]):
                    src, dst, sem = stage_refs(arg, j)
                    src[slot] = p_vals[i]
                    pltpu.make_async_copy(
                        src.at[slot], dst.at[slot], sem.at[slot]).start()
            else:  # "XC"
                slot = pending_slot.pop(arg)
                p_leaves_t, p_def_t, p_live_t, _ = stage_info[arg]
                vals = []
                for j in range(len(p_live_t)):
                    src, dst, sem = stage_refs(arg, j)
                    pltpu.make_async_copy(
                        src.at[slot], dst.at[slot], sem.at[slot]).wait()
                    vals.append(dst[slot])
                pend = rebuild(vals, p_live_t, p_leaves_t, p_def_t)
                c = rebuild(cvals, c_live, c_leaves, c_def)
                out = (commit_fn(c, arg, pend, consts_v)
                       if consts is not None else commit_fn(c, arg, pend))
                cvals = live_out(out)
        for r, v in zip(refs[nc + nk:nc + nk + nc], cvals):
            r[...] = v

    outs = pl.pallas_call(
        kernel,
        out_shape=tuple(
            jax.ShapeDtypeStruct(c_leaves[i].shape, c_leaves[i].dtype)
            for i in c_live
        ),
        scratch_shapes=scratch_shapes,
        interpret=resolve_interpret(interpret),
    )(*(c_leaves[i] for i in c_live), *(k_leaves[i] for i in k_live))
    return rebuild(list(outs), c_live, c_leaves, c_def)


def pallas_epoch(
    cycle_fn: Callable[..., PyTree],
    carry: PyTree,
    k_cycles: int,
    *,
    consts: PyTree | None = None,
    interpret: Any = False,
) -> PyTree:
    """Run ``k_cycles`` of ``cycle_fn`` inside ONE ``pallas_call``.

    The single-epoch special case of :func:`pallas_program` (a program of
    one ``("C", k_cycles)`` op); see there for the memory contract.
    """
    return pallas_program(
        cycle_fn, carry, (("C", k_cycles),), consts=consts,
        interpret=interpret,
    )


def epoch_loop(
    cycle_fn: Callable[..., PyTree],
    carry: PyTree,
    k_cycles: int,
    *,
    consts: PyTree | None = None,
    mode: str = "auto",
    interpret: Any = False,
) -> PyTree:
    """Execute ``k_cycles`` of ``cycle_fn`` as one fused epoch body.

    ``cycle_fn(carry)`` — or ``cycle_fn(carry, consts)`` when ``consts``
    is given — must return a carry with identical structure/shapes/dtypes
    (checked abstractly up front on every mode).
    """
    if k_cycles == 0:
        return carry
    step = (lambda c: cycle_fn(c, consts)) if consts is not None else cycle_fn
    _check_stable(step, carry)
    mode = resolve_mode(mode)
    if mode == "unroll":
        out = carry
        for _ in range(k_cycles):
            out = step(out)
        return out
    if mode == "xla":
        if k_cycles == 1:
            return step(carry)
        return jax.lax.fori_loop(0, k_cycles, lambda _, c: step(c), carry)
    if mode == "pallas":
        return pallas_epoch(
            cycle_fn, carry, k_cycles, consts=consts, interpret=interpret
        )
    raise ValueError(f"unknown epoch mode {mode!r} (auto|unroll|xla|pallas)")


def validate_program(program: Program) -> Tuple[Tuple[str, int], ...]:
    """Normalize + statically validate an op program.

    Checks the op vocabulary and the split-exchange pairing discipline:
    every ``("XI", t)`` must be followed by exactly one ``("XC", t)``
    before the tier issues again, and the program must end with every
    issue committed (a pending transfer crossing the program boundary
    would leak the in-flight slab).
    """
    program = tuple((op, int(arg)) for op, arg in program)
    pending: set = set()
    for op, arg in program:
        if op not in ("C", "X", "XI", "XC"):
            raise ValueError(f"unknown program op {op!r} (C|X|XI|XC)")
        if op == "XI":
            if arg in pending:
                raise ValueError(
                    f"tier {arg} issued twice without an intervening commit")
            pending.add(arg)
        elif op == "XC":
            if arg not in pending:
                raise ValueError(f"tier {arg} committed with no pending issue")
            pending.remove(arg)
        elif op == "X" and arg in pending:
            raise ValueError(
                f"tier {arg} has a serial exchange while a split one is "
                f"pending")
    if pending:
        raise ValueError(
            f"program ends with uncommitted exchanges for tiers "
            f"{sorted(pending)}")
    return program


def epoch_program(
    cycle_fn: Callable[..., PyTree],
    carry: PyTree,
    program: Program,
    *,
    exchange_fn: Callable[..., PyTree] | None = None,
    issue_fn: Callable[..., Tuple[PyTree, PyTree]] | None = None,
    commit_fn: Callable[..., PyTree] | None = None,
    consts: PyTree | None = None,
    mode: str = "auto",
    interpret: Any = "auto",
) -> PyTree:
    """Execute a multi-epoch op program as ONE fused computation.

    ``program`` is a flat op list: ``("C", n)`` steps the cycle body ``n``
    cycles; ``("X", t)`` applies ``exchange_fn`` for tier ``t`` (a pure
    local tier exchange — drain egress queues into slab rows, scatter
    ingress rows back).  This is the resident-kernel generalization of
    :func:`epoch_loop`: a whole K_outer x K_inner span between two
    device-boundary exchanges runs as one body, so under ``mode="pallas"``
    the register/queue state stays resident in VMEM across every inner
    epoch and local tier boundary it contains.  The xla/unroll modes
    execute the *same* op sequence (bit-exact twins for CPU CI), just as
    jitted XLA loops instead of one kernel.

    Split ops ``("XI", t)`` / ``("XC", t)`` (see :func:`overlap_program`)
    run the exchange in two phases: ``issue_fn(carry, t[, consts]) ->
    (carry, pending)`` drains and starts the transfer, and
    ``commit_fn(carry, t, pending[, consts]) -> carry`` finishes it and
    fills.  In the xla/unroll lowerings the pending pytree is threaded
    between the two phases as ordinary values, so every op emitted between
    issue and commit is data-independent of the in-flight slab and XLA's
    latency-hiding scheduler is free to overlap the transfer with it; the
    pallas lowering stages the slab through double-buffered VMEM with an
    async copy (started at issue, awaited at commit).  All lowerings
    remain bit-exact twins.

    ``cycle_fn``, ``exchange_fn``, and the issue/commit round trip must
    preserve the carry's treedef/shapes/dtypes (checked abstractly up
    front).
    """
    program = validate_program(program)
    if any(op == "X" for op, _ in program) and exchange_fn is None:
        raise ValueError("program has ('X', t) ops but no exchange_fn")
    if any(op in ("XI", "XC") for op, _ in program) and (
            issue_fn is None or commit_fn is None):
        raise ValueError(
            "program has split ('XI'/'XC') ops but no issue_fn/commit_fn")
    if not program:
        return carry
    step = (lambda c: cycle_fn(c, consts)) if consts is not None else cycle_fn
    _check_stable(step, carry)
    for t in sorted({arg for op, arg in program if op == "X"}):
        _check_stable(
            (lambda c, _t=t: exchange_fn(c, _t, consts)) if consts is not None
            else (lambda c, _t=t: exchange_fn(c, _t)),
            carry,
        )
    for t in sorted({arg for op, arg in program if op == "XI"}):
        def _roundtrip(c, _t=t):
            if consts is not None:
                c2, pend = issue_fn(c, _t, consts)
                return commit_fn(c2, _t, pend, consts)
            c2, pend = issue_fn(c, _t)
            return commit_fn(c2, _t, pend)
        _check_stable(_roundtrip, carry)
    mode = resolve_mode(mode)
    if mode == "pallas":
        return pallas_program(
            cycle_fn, carry, program, exchange_fn=exchange_fn,
            issue_fn=issue_fn, commit_fn=commit_fn, consts=consts,
            interpret=interpret,
        )
    if mode not in ("xla", "unroll"):
        raise ValueError(f"unknown epoch mode {mode!r} (auto|unroll|xla|pallas)")
    out = carry
    pending: dict = {}
    for op, arg in program:
        if op == "C":
            if mode == "unroll":
                for _ in range(arg):
                    out = step(out)
            elif arg == 1:
                out = step(out)
            elif arg > 1:
                out = jax.lax.fori_loop(0, arg, lambda _, c: step(c), out)
        elif op == "X":
            out = (exchange_fn(out, arg, consts) if consts is not None
                   else exchange_fn(out, arg))
        elif op == "XI":
            out, pending[arg] = (
                issue_fn(out, arg, consts) if consts is not None
                else issue_fn(out, arg))
        else:  # "XC"
            out = (commit_fn(out, arg, pending.pop(arg), consts)
                   if consts is not None
                   else commit_fn(out, arg, pending.pop(arg)))
    return out

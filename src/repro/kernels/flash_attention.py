"""Flash attention Pallas TPU kernel (blocked online-softmax).

The LM stack's dominant compute hot spot for training and prefill.  Tiled
for the TPU memory hierarchy:

  * grid = (batch*q_heads, T/bq, S/bk) — the innermost grid dimension
    streams KV blocks, so VMEM holds only (bq, d) of Q, (bk, d) of K and V,
    and the f32 accumulators; nothing scales with S.
  * online-softmax accumulators (acc, m, l) live in VMEM scratch and persist
    across the sequential innermost grid steps (TPU grid semantics).
  * matmuls are (bq, d) x (d, bk) and (bq, bk) x (bk, d) with 128-aligned
    shapes -> MXU.
  * causal / sliding-window masking prunes whole KV blocks with ``pl.when``
    (skipped blocks do no compute), so windowed layers cost O(T*window) —
    this is what keeps RecurrentGemma's local-attention layers sub-quadratic
    for the ``long_500k`` shape.

Supports GQA/MQA (``h_q`` query heads share ``h_kv`` KV heads) and f32
accumulation over bf16 inputs.

Oracle: ``repro.kernels.ref.attention_ref`` (pure jnp, dense mask).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128  # accumulator minor dim (TPU lane width)


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
    *, bq: int, bk: int, n_kv: int, causal: bool, window: int | None,
    sm_scale: float,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    q_start = qi * bq
    k_start = ki * bk

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Block-level visibility: any (q, k) pair in this tile may interact.
    visible = jnp.bool_(True)
    if causal:
        visible &= k_start <= q_start + bq - 1
    if window is not None:
        visible &= k_start + bk - 1 > q_start - window

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_ref[:, 0]
        lsafe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / lsafe[:, None]).astype(o_ref.dtype)
        lse = jnp.where(l == 0.0, NEG_INF, m_ref[:, 0] + jnp.log(lsafe))
        lse_ref[0, 0] = lse.astype(lse_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Hq, T, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    return_lse: bool = False,
):
    """Blocked attention; returns (B, Hq, T, D) in q.dtype.

    With ``return_lse=True`` also returns the log-sum-exp (B, Hq, T) f32 —
    the residual the custom VJP needs (ops.py wires the backward pass)."""
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    G = Hq // Hkv
    bq = min(block_q, T)
    bk = min(block_k, S)
    if T % bq or S % bk:
        raise ValueError(f"T={T}, S={S} must divide block sizes ({bq}, {bk})")
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(D)

    n_kv = S // bk
    # 4-D grid (B, Hq, T/bq, S/bk): no batch/head reshape, so GSPMD sharding
    # on (batch, heads) propagates straight through the pallas_call.
    grid = (B, Hq, T // bq, n_kv)

    kernel = functools.partial(
        _fa_kernel, bq=bq, bk=bk, n_kv=n_kv, causal=causal, window=window,
        sm_scale=sm_scale,
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, T, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, T), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if return_lse:
        return out, lse
    return out

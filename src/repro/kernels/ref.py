"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Hq, T, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
) -> jax.Array:
    """Dense-mask attention oracle with GQA and sliding window."""
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(D)
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32) * sm_scale
    s = jnp.einsum("bhtd,bhsd->bhts", qf, kf)
    q_pos = jnp.arange(T)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # Fully-masked rows produce uniform softmax; zero them like the kernel.
    any_valid = mask.any(axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", p, vf)
    out = jnp.where(any_valid[None, None, :, None], out, 0.0)
    return out.astype(q.dtype)


def rglru_ref(
    x: jax.Array,  # (B, T, D) gated input
    a: jax.Array,  # (B, T, D) per-step decay in (0, 1)
    h0: jax.Array | None = None,  # (B, D) initial state
) -> tuple[jax.Array, jax.Array]:
    """RG-LRU linear recurrence oracle: h_t = a_t * h_{t-1} + x_t.

    Returns (all hidden states (B, T, D), final state (B, D)).
    Uses an associative scan in f32 (numerically the strongest formulation).
    """
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    if h0 is not None:
        # Fold the initial state into step 0: h_0' = a_0*h0 + x_0.
        xf = xf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, a2 * x1 + x2

    a_sc, h = jax.lax.associative_scan(combine, (af, xf), axis=1)
    return h.astype(x.dtype), h[:, -1].astype(x.dtype)


def _onehot(idx: jax.Array, n: int) -> jax.Array:
    """(..., n) one-hot of idx — the TPU-safe gather/scatter primitive used
    by both the kernel and this oracle so float op order matches exactly."""
    return (idx[..., None] == jnp.arange(n, dtype=jnp.int32)).astype(jnp.float32)


def systolic_step_ref(state: dict, k_cycles: int) -> dict:
    """Oracle for the elastic-register systolic tile (K cycles, pure jnp).

    Semantics (identical to kernels/systolic_step.py):

    A tile of (R, C) MAC cells with *depth-1 elastic register* channels —
    each cell owns one eastward register (a_reg, a_v) and one southward
    register (p_reg, p_v).  A cell FIREs when both inputs are valid and both
    of its own registers are free; firing latches outputs into its registers,
    which downstream cells consume on a later cycle (latency-insensitive, so
    the final result is unchanged vs. the deep-queue engine — only timing
    differs).

    Tile boundaries are *slabs* (the epoch exchange unit):
      west_slab (R, K)/west_cnt: packets available to column 0 this epoch,
      north_slab (C, K)/north_cnt: packets available to row 0,
      east_slab (R, K)/east_cnt: packets emitted by column C-1,
      south_slab (C, K)/south_cnt: packets emitted by row R-1.

    Edge-of-grid behaviour via flags: is_west cells stream from a_buf
    (one-hot gather), is_north synthesize 0, is_south collect into y_buf,
    is_east drop.

    state keys: b, a_reg, a_v, p_reg, p_v, a_idx, y_idx, a_buf, y_buf,
    is_west, is_north, is_south, is_east, west_slab, west_cnt, north_slab,
    north_cnt, east_slab, east_cnt, south_slab, south_cnt, widx, nidx.
    """
    s = {k: jnp.asarray(v) for k, v in state.items()}
    R, C = s["b"].shape
    M = s["a_buf"].shape[-1]
    K = s["west_slab"].shape[-1]

    def cycle(s, _):
        a_reg, a_v = s["a_reg"], s["a_v"]
        p_reg, p_v = s["p_reg"], s["p_v"]

        # West input of cell (r, c): c>0 -> neighbour register; c==0 -> slab.
        w_slab_val = jnp.sum(s["west_slab"] * _onehot(s["widx"], K), axis=-1)
        w_slab_ok = s["widx"] < s["west_cnt"]
        w_val = jnp.concatenate([w_slab_val[:, None], a_reg[:, :-1]], axis=1)
        w_vld = jnp.concatenate([w_slab_ok[:, None], a_v[:, :-1]], axis=1)
        n_slab_val = jnp.sum(s["north_slab"] * _onehot(s["nidx"], K), axis=-1)
        n_slab_ok = s["nidx"] < s["north_cnt"]
        n_val = jnp.concatenate([n_slab_val[None, :], p_reg[:-1, :]], axis=0)
        n_vld = jnp.concatenate([n_slab_ok[None, :], p_v[:-1, :]], axis=0)

        a_src = jnp.sum(s["a_buf"] * _onehot(s["a_idx"], M), axis=-1)
        a_in = jnp.where(s["is_west"], a_src, w_val)
        a_ok = jnp.where(s["is_west"], s["a_idx"] < M, w_vld)
        p_in = jnp.where(s["is_north"], 0.0, n_val)
        p_ok = jnp.where(s["is_north"], True, n_vld)

        # Output readiness: own register free, or edge/boundary sink.
        # Column C-1 emits into east_slab (capacity K, never fills in K
        # cycles); row R-1 into south_slab.
        e_lim = s.get("east_limit", jnp.full((R,), K, jnp.int32))
        s_lim = s.get("south_limit", jnp.full((C,), K, jnp.int32))
        e_free = ~a_v
        e_free = e_free.at[:, C - 1].set(s["east_cnt"] < e_lim)
        e_free = e_free | s["is_east"]
        s_free = ~p_v
        s_free = s_free.at[R - 1, :].set(s["south_cnt"] < s_lim)
        s_free = s_free | s["is_south"]

        fire = a_ok & p_ok & e_free & s_free
        y = p_in + a_in * s["b"]

        # Drain consumed upstream storage.
        cons_a = fire & ~s["is_west"]  # consumed west input
        cons_p = fire & ~s["is_north"]
        widx = s["widx"] + cons_a[:, 0].astype(jnp.int32)
        nidx = s["nidx"] + cons_p[0, :].astype(jnp.int32)
        drain_a = jnp.concatenate(  # east neighbour consumed my a_reg
            [cons_a[:, 1:], jnp.zeros((R, 1), bool)], axis=1
        )
        drain_p = jnp.concatenate([cons_p[1:, :], jnp.zeros((1, C), bool)], axis=0)
        a_v2 = a_v & ~drain_a
        p_v2 = p_v & ~drain_p

        # Latch fired outputs.
        emit_e = fire & ~s["is_east"]
        emit_s = fire & ~s["is_south"]
        a_reg2 = jnp.where(fire, a_in, a_reg)
        p_reg2 = jnp.where(fire, y, p_reg)
        # Column C-1 / row R-1 emissions go to slabs, not registers.
        to_east = emit_e[:, C - 1]
        to_south = emit_s[R - 1, :]
        a_v3 = jnp.where(emit_e, True, a_v2).at[:, C - 1].set(a_v2[:, C - 1])
        p_v3 = jnp.where(emit_s, True, p_v2).at[R - 1, :].set(p_v2[R - 1, :])
        east_slab = s["east_slab"] + (
            a_in[:, C - 1, None] * _onehot(s["east_cnt"], K)
        ) * to_east[:, None]
        east_cnt = s["east_cnt"] + to_east.astype(jnp.int32)
        south_slab = s["south_slab"] + (
            y[R - 1, :, None] * _onehot(s["south_cnt"], K)
        ) * to_south[:, None]
        south_cnt = s["south_cnt"] + to_south.astype(jnp.int32)

        collect = fire & s["is_south"]
        y_buf = s["y_buf"] + (y[:, :, None] * _onehot(s["y_idx"], M)) * collect[
            :, :, None
        ]
        s2 = dict(
            s,
            a_reg=a_reg2, a_v=a_v3, p_reg=p_reg2, p_v=p_v3,
            a_idx=s["a_idx"] + (fire & s["is_west"]).astype(jnp.int32),
            y_buf=y_buf,
            y_idx=s["y_idx"] + collect.astype(jnp.int32),
            widx=widx, nidx=nidx,
            east_slab=east_slab, east_cnt=east_cnt,
            south_slab=south_slab, south_cnt=south_cnt,
        )
        return s2, None

    out, _ = jax.lax.scan(cycle, s, None, length=k_cycles)
    return out


def slstm_scan_ref(r: dict, pre: jax.Array, carry0: tuple):
    """Oracle for kernels/slstm_scan.py (plain lax.scan, f32).

    r: {'i','f','z','o': (H, hd, hd)}; pre: (B, T, 4, d); carry0: 4x(B, d).
    Returns (hs, (cs, ns, ms), final_carry) like the kernel.
    """
    B, T, _, d = pre.shape
    H = r["i"].shape[0]
    hd = d // H

    def step(carry, pre_t):
        c, n, h, m = carry
        hb = h.reshape(B, H, hd)

        def rmat(g):
            return jax.lax.dot_general(
                hb, r[g].astype(jnp.float32), (((2,), (1,)), ((1,), (0,))),
                preferred_element_type=jnp.float32,
            ).transpose(1, 0, 2).reshape(B, d)

        li = pre_t[:, 0] + rmat("i")
        lf = jax.nn.log_sigmoid(pre_t[:, 1] + rmat("f"))
        z = jnp.tanh(pre_t[:, 2] + rmat("z"))
        o = jax.nn.sigmoid(pre_t[:, 3] + rmat("o"))
        m_new = jnp.maximum(lf + m, li)
        c = c * jnp.exp(lf + m - m_new) + jnp.exp(li - m_new) * z
        n = n * jnp.exp(lf + m - m_new) + jnp.exp(li - m_new)
        h = o * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), (c, n, h, m_new)

    carry, (cs, ns, hs, ms) = jax.lax.scan(
        step, carry0, jnp.moveaxis(pre.astype(jnp.float32), 1, 0)
    )
    mv = lambda x: jnp.moveaxis(x, 0, 1)
    return mv(hs), (mv(cs), mv(ns), mv(ms)), carry

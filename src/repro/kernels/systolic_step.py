"""Pallas TPU kernel for the manycore hot loop (paper §IV-B).

Advances a granule's tile of systolic MAC cells **K cycles entirely in
VMEM**, replacing ~10 HBM-roundtrip XLA ops per cycle (peek / step /
push / pop of the generic queue engine) with one fused kernel.  This is the
"FPGA bridge" move of the paper (Table I): the same latency-insensitive
block behaviour, implemented on a faster backend behind identical epoch
boundaries.

Channel model inside the tile: depth-1 elastic registers (a valid/value
pair per hop) instead of 62-deep queues — a legal latency-insensitive
implementation choice, so the computed result is identical (property-tested
against both the oracle and the deep-queue engine).  Tile boundaries are
epoch slabs (up to K packets per boundary row/column per epoch), which is
exactly the granule-exchange unit of ``core.distributed``.

All per-cell dynamic indexing (stream source gather, output collection,
slab append) is expressed as one-hot multiply-accumulate — the TPU-safe
formulation (no data-dependent gathers in VMEM) and the same op order as
``ref.systolic_step_ref``, giving bitwise-comparable f32 results.

VMEM budget (interior tile, M=1): ~13 (R, C) f32/bool arrays + 4 (R|C, K)
slabs ≈ 0.15 MB at (32, 64), K=62 — far under budget, so R, C can grow to
fill VMEM (the perf knob in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _onehot(idx, n):
    return (idx[..., None] == jnp.arange(n, dtype=jnp.int32)).astype(jnp.float32)


def _systolic_kernel(
    # inputs (refs)
    b_ref, a_reg_ref, a_v_ref, p_reg_ref, p_v_ref, a_idx_ref, y_idx_ref,
    a_buf_ref, y_buf_ref, is_w_ref, is_n_ref, is_s_ref, is_e_ref,
    west_slab_ref, west_cnt_ref, north_slab_ref, north_cnt_ref,
    e_limit_ref, s_limit_ref,
    # outputs (refs)
    a_reg_o, a_v_o, p_reg_o, p_v_o, a_idx_o, y_idx_o, y_buf_o,
    widx_o, nidx_o, east_slab_o, east_cnt_o, south_slab_o, south_cnt_o,
    *, k_cycles: int,
):
    b = b_ref[...]
    R, C = b.shape
    M = a_buf_ref.shape[-1]
    K = west_slab_ref.shape[-1]
    is_w, is_n = is_w_ref[...], is_n_ref[...]
    is_s, is_e = is_s_ref[...], is_e_ref[...]
    a_buf = a_buf_ref[...]
    west_slab, west_cnt = west_slab_ref[...], west_cnt_ref[...]
    north_slab, north_cnt = north_slab_ref[...], north_cnt_ref[...]
    e_limit, s_limit = e_limit_ref[...], s_limit_ref[...]

    def cycle(_, carry):
        (a_reg, a_v, p_reg, p_v, a_idx, y_idx, y_buf,
         widx, nidx, east_slab, east_cnt, south_slab, south_cnt) = carry

        w_slab_val = jnp.sum(west_slab * _onehot(widx, K), axis=-1)
        w_slab_ok = widx < west_cnt
        w_val = jnp.concatenate([w_slab_val[:, None], a_reg[:, :-1]], axis=1)
        w_vld = jnp.concatenate([w_slab_ok[:, None], a_v[:, :-1]], axis=1)
        n_slab_val = jnp.sum(north_slab * _onehot(nidx, K), axis=-1)
        n_slab_ok = nidx < north_cnt
        n_val = jnp.concatenate([n_slab_val[None, :], p_reg[:-1, :]], axis=0)
        n_vld = jnp.concatenate([n_slab_ok[None, :], p_v[:-1, :]], axis=0)

        a_src = jnp.sum(a_buf * _onehot(a_idx, M), axis=-1)
        a_in = jnp.where(is_w, a_src, w_val)
        a_ok = jnp.where(is_w, a_idx < M, w_vld)
        p_in = jnp.where(is_n, 0.0, n_val)
        p_ok = jnp.where(is_n, True, n_vld)

        # boundary emission is credit-bounded: col C-1 / row R-1 may only
        # fire while the receiver has advertised slab space.
        e_free = ~a_v
        e_free = e_free.at[:, C - 1].set(east_cnt < e_limit) | is_e
        s_free = ~p_v
        s_free = s_free.at[R - 1, :].set(south_cnt < s_limit) | is_s

        fire = a_ok & p_ok & e_free & s_free
        y = p_in + a_in * b

        cons_a = fire & ~is_w
        cons_p = fire & ~is_n
        widx = widx + cons_a[:, 0].astype(jnp.int32)
        nidx = nidx + cons_p[0, :].astype(jnp.int32)
        drain_a = jnp.concatenate([cons_a[:, 1:], jnp.zeros((R, 1), bool)], axis=1)
        drain_p = jnp.concatenate([cons_p[1:, :], jnp.zeros((1, C), bool)], axis=0)
        a_v2 = a_v & ~drain_a
        p_v2 = p_v & ~drain_p

        emit_e = fire & ~is_e
        emit_s = fire & ~is_s
        a_reg = jnp.where(fire, a_in, a_reg)
        p_reg = jnp.where(fire, y, p_reg)
        to_east = emit_e[:, C - 1]
        to_south = emit_s[R - 1, :]
        a_v = jnp.where(emit_e, True, a_v2).at[:, C - 1].set(a_v2[:, C - 1])
        p_v = jnp.where(emit_s, True, p_v2).at[R - 1, :].set(p_v2[R - 1, :])
        east_slab = east_slab + (a_in[:, C - 1, None] * _onehot(east_cnt, K)) * to_east[:, None]
        east_cnt = east_cnt + to_east.astype(jnp.int32)
        south_slab = south_slab + (y[R - 1, :, None] * _onehot(south_cnt, K)) * to_south[:, None]
        south_cnt = south_cnt + to_south.astype(jnp.int32)

        collect = fire & is_s
        y_buf = y_buf + (y[:, :, None] * _onehot(y_idx, M)) * collect[:, :, None]
        a_idx = a_idx + (fire & is_w).astype(jnp.int32)
        y_idx = y_idx + collect.astype(jnp.int32)
        return (a_reg, a_v, p_reg, p_v, a_idx, y_idx, y_buf,
                widx, nidx, east_slab, east_cnt, south_slab, south_cnt)

    R_, C_ = b.shape
    K_ = west_slab.shape[-1]
    init = (
        a_reg_ref[...], a_v_ref[...], p_reg_ref[...], p_v_ref[...],
        a_idx_ref[...], y_idx_ref[...], y_buf_ref[...],
        jnp.zeros((R_,), jnp.int32), jnp.zeros((C_,), jnp.int32),
        jnp.zeros((R_, K_), jnp.float32), jnp.zeros((R_,), jnp.int32),
        jnp.zeros((C_, K_), jnp.float32), jnp.zeros((C_,), jnp.int32),
    )
    (a_reg, a_v, p_reg, p_v, a_idx, y_idx, y_buf,
     widx, nidx, east_slab, east_cnt, south_slab, south_cnt) = jax.lax.fori_loop(
        0, k_cycles, cycle, init
    )
    a_reg_o[...] = a_reg
    a_v_o[...] = a_v
    p_reg_o[...] = p_reg
    p_v_o[...] = p_v
    a_idx_o[...] = a_idx
    y_idx_o[...] = y_idx
    y_buf_o[...] = y_buf
    widx_o[...] = widx
    nidx_o[...] = nidx
    east_slab_o[...] = east_slab
    east_cnt_o[...] = east_cnt
    south_slab_o[...] = south_slab
    south_cnt_o[...] = south_cnt


def systolic_step(state: dict, k_cycles: int, *, interpret: bool = False) -> dict:
    """Run K cycles of a systolic tile; returns the updated state dict.

    ``state`` uses the layout documented in ``ref.systolic_step_ref``;
    ``widx``/``nidx`` are reset to 0 on entry (slab indices are per-epoch)
    and the east/south slabs are produced fresh.
    """
    R, C = state["b"].shape
    M = state["a_buf"].shape[-1]
    K = state["west_slab"].shape[-1]
    f32 = jnp.float32
    i32 = jnp.int32
    out_shape = dict(
        a_reg=jax.ShapeDtypeStruct((R, C), f32),
        a_v=jax.ShapeDtypeStruct((R, C), jnp.bool_),
        p_reg=jax.ShapeDtypeStruct((R, C), f32),
        p_v=jax.ShapeDtypeStruct((R, C), jnp.bool_),
        a_idx=jax.ShapeDtypeStruct((R, C), i32),
        y_idx=jax.ShapeDtypeStruct((R, C), i32),
        y_buf=jax.ShapeDtypeStruct((R, C, M), f32),
        widx=jax.ShapeDtypeStruct((R,), i32),
        nidx=jax.ShapeDtypeStruct((C,), i32),
        east_slab=jax.ShapeDtypeStruct((R, K), f32),
        east_cnt=jax.ShapeDtypeStruct((R,), i32),
        south_slab=jax.ShapeDtypeStruct((C, K), f32),
        south_cnt=jax.ShapeDtypeStruct((C,), i32),
    )
    names = list(out_shape)
    kernel = functools.partial(_systolic_kernel, k_cycles=k_cycles)
    outs = pl.pallas_call(
        kernel,
        out_shape=tuple(out_shape[n] for n in names),
        interpret=interpret,
    )(
        state["b"], state["a_reg"], state["a_v"], state["p_reg"], state["p_v"],
        state["a_idx"], state["y_idx"], state["a_buf"], state["y_buf"],
        state["is_west"], state["is_north"], state["is_south"], state["is_east"],
        state["west_slab"], state["west_cnt"], state["north_slab"], state["north_cnt"],
        state.get("east_limit", jnp.full((R,), K, jnp.int32)),
        state.get("south_limit", jnp.full((C,), K, jnp.int32)),
    )
    new = dict(state)
    new.update({n: o for n, o in zip(names, outs)})
    return new

"""Pallas TPU kernel for the RG-LRU linear recurrence (RecurrentGemma).

Computes h_t = a_t * h_{t-1} + x_t over the time axis — the recurrent hot
spot of the hybrid archs (and the only sequential op in their decode path's
prefill).  Tiling:

  * grid = (B, D/bd, T/bt); the innermost grid dim walks time chunks
    sequentially, carrying the recurrent state in VMEM scratch.
  * within a chunk, the scan is computed with a Hillis–Steele doubling
    network (log2(bt) passes of static-shift elementwise ops) — no
    data-dependent control flow, fully vectorizable on the VPU; O(bt·log bt)
    work instead of bt sequential steps.
  * f32 accumulation regardless of input dtype.

Oracle: ``ref.rglru_ref`` (associative_scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(x_ref, a_ref, h0_ref, h_ref, hlast_ref, h_scratch, *, bt: int, n_t: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        h_scratch[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)  # (bt, bd)
    a = a_ref[0].astype(jnp.float32)  # (bt, bd)

    # Hillis-Steele inclusive scan of the affine maps (a, x).
    A, X = a, x
    d = 1
    while d < bt:
        A_s = jnp.concatenate([jnp.ones_like(A[:d]), A[:-d]], axis=0)
        X_s = jnp.concatenate([jnp.zeros_like(X[:d]), X[:-d]], axis=0)
        X = X + A * X_s
        A = A * A_s
        d *= 2

    h_in = h_scratch[...]  # (1, bd)
    h = X + A * h_in  # (bt, bd) — chunk-carry applied
    h_scratch[...] = h[-1:, :]
    h_ref[0] = h.astype(h_ref.dtype)

    @pl.when(t == n_t - 1)
    def _final():
        hlast_ref[...] = h[-1:, :].astype(hlast_ref.dtype)


def rglru_scan(
    x: jax.Array,  # (B, T, D)
    a: jax.Array,  # (B, T, D) decay in (0, 1)
    h0: jax.Array | None = None,  # (B, D)
    *,
    block_t: int = 256,
    block_d: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (h (B, T, D), h_last (B, D))."""
    B, T, D = x.shape
    bt = min(block_t, T)
    bd = min(block_d, D)
    if T % bt or D % bd:
        raise ValueError(f"T={T}, D={D} must divide blocks ({bt}, {bd})")
    if h0 is None:
        h0 = jnp.zeros((B, D), x.dtype)
    n_t = T // bt
    grid = (B, D // bd, n_t)

    kernel = functools.partial(_rglru_kernel, bt=bt, n_t=n_t)
    h, hlast = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, j, t: (b, t, j)),
            pl.BlockSpec((1, bt, bd), lambda b, j, t: (b, t, j)),
            pl.BlockSpec((1, bd), lambda b, j, t: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, j, t: (b, t, j)),
            pl.BlockSpec((1, bd), lambda b, j, t: (b, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, D), x.dtype),
            jax.ShapeDtypeStruct((B, D), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(x, a, h0)
    return h, hlast

"""Jit'd public wrappers for the Pallas kernels, with custom VJPs.

Backend selection per op:

  * ``pallas`` — the Mosaic TPU kernel (this container validates it in
    interpret mode through the unit tests; on TPU it is the default).
  * ``xla`` — a blocked pure-XLA implementation with the *same* tiling
    structure (scan over KV/Q blocks, online/two-pass softmax, O(T*block)
    memory).  This is what jit paths use on CPU — including the dry-run, so
    the lowered HLO's FLOPs/bytes/collectives are representative of the
    kernel's behaviour rather than of the interpret-mode emulation loop.
  * ``ref`` — dense jnp oracle for tiny smoke-test shapes.

All blocked implementations are written carry-free (block results are scan
*outputs*, never carried accumulators) so GSPMD never has to pick a sharding
for a big loop-carried tensor — that single property is worth ~3x peak temp
memory at train_4k scale (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import rglru_scan as _rg
from . import systolic_step as _sy
from . import ref as ref

_ON_TPU = None


def _on_tpu() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.default_backend() == "tpu"
    return _ON_TPU


# ===================================================== flash attention
def _block_mask(q0, k0, bq, bk, T, causal, window):
    q_pos = q0 + jnp.arange(bq)
    k_pos = k0 + jnp.arange(bk)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    return mask


def _xla_flash_fwd_impl(q, k, v, causal, window, scale, bq, bk):
    """Two-pass blocked attention in XLA: returns (o, lse).

    Pass 1 computes per-row LSE by scanning Q blocks; pass 2 recomputes
    scores and combines with V.  2x score FLOPs (like any recompute-based
    flash) but zero big carries and O(bq*S) transient memory.
    """
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, T, D).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    nq = T // bq

    qb = jnp.moveaxis(qg.reshape(B, Hkv, G, nq, bq, D), 3, 0)  # (nq,B,Hkv,G,bq,D)

    def one_block(args):
        qi, i = args
        s = jnp.einsum("bkgqd,bksd->bkgqs", qi, kf)  # (B,Hkv,G,bq,S)
        mask = _block_mask(i * bq, 0, bq, S, T, causal, window)
        s = jnp.where(mask[None, None, None], s, -1e30)
        lse = jax.nn.logsumexp(s, axis=-1)  # (B,Hkv,G,bq)
        p = jnp.exp(s - lse[..., None])
        o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
        return o, lse

    def scan_body(_, args):
        return None, one_block(args)

    _, (ob, lseb) = jax.lax.scan(scan_body, None, (qb, jnp.arange(nq)))
    o = jnp.moveaxis(ob, 0, 3).reshape(B, Hq, T, D)
    lse = jnp.moveaxis(lseb, 0, 3).reshape(B, Hq, T)
    return o.astype(q.dtype), lse


def _flash_bwd_impl(q, k, v, o, lse, do, causal, window, scale, bq, bk):
    """Carry-free flash backward: two block scans with stacked outputs."""
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, T, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dog = do.reshape(B, Hkv, G, T, D).astype(jnp.float32)
    lseg = lse.reshape(B, Hkv, G, T)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    deltag = delta.reshape(B, Hkv, G, T)

    # ---- dk, dv: scan over KV blocks (each depends on all Q — no carry).
    nk = S // bk
    kb = jnp.moveaxis(kf.reshape(B, Hkv, nk, bk, D), 2, 0)
    vb = jnp.moveaxis(vf.reshape(B, Hkv, nk, bk, D), 2, 0)

    def kv_block(_, args):
        kj, vj, j = args
        s = jnp.einsum("bkgtd,bksd->bkgts", qg, kj) * scale  # (B,Hkv,G,T,bk)
        mask = _block_mask(0, j * bk, T, bk, T, causal, window)
        p = jnp.where(mask[None, None, None], jnp.exp(s - lseg[..., None]), 0.0)
        dvj = jnp.einsum("bkgts,bkgtd->bksd", p, dog)
        dp = jnp.einsum("bkgtd,bksd->bkgts", dog, vj)
        ds = p * (dp - deltag[..., None]) * scale
        dkj = jnp.einsum("bkgts,bkgtd->bksd", ds, qg)
        return None, (dkj, dvj)

    _, (dkb, dvb) = jax.lax.scan(kv_block, None, (kb, vb, jnp.arange(nk)))
    dk = jnp.moveaxis(dkb, 0, 2).reshape(B, Hkv, S, D)
    dv = jnp.moveaxis(dvb, 0, 2).reshape(B, Hkv, S, D)

    # ---- dq: scan over Q blocks (each depends on all KV — no carry).
    nq = T // bq
    qb = jnp.moveaxis(qg.reshape(B, Hkv, G, nq, bq, D), 3, 0)
    dob = jnp.moveaxis(dog.reshape(B, Hkv, G, nq, bq, D), 3, 0)
    lseb = jnp.moveaxis(lseg.reshape(B, Hkv, G, nq, bq), 3, 0)
    deltab = jnp.moveaxis(deltag.reshape(B, Hkv, G, nq, bq), 3, 0)

    def q_block(_, args):
        qi, doi, lsei, deltai, i = args
        s = jnp.einsum("bkgqd,bksd->bkgqs", qi, kf) * scale
        mask = _block_mask(i * bq, 0, bq, S, T, causal, window)
        p = jnp.where(mask[None, None, None], jnp.exp(s - lsei[..., None]), 0.0)
        dp = jnp.einsum("bkgqd,bksd->bkgqs", doi, vf)
        ds = p * (dp - deltai[..., None]) * scale
        dqi = jnp.einsum("bkgqs,bksd->bkgqd", ds, kf)
        return None, dqi

    _, dqb = jax.lax.scan(q_block, None, (qb, dob, lseb, deltab, jnp.arange(nq)))
    dq = jnp.moveaxis(dqb, 0, 3).reshape(B, Hq, T, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, scale, bq, bk, backend):
    if backend == "pallas":
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window, sm_scale=scale,
            block_q=bq, block_k=bk, interpret=not _on_tpu(),
        )
    o, _ = _xla_flash_fwd_impl(q, k, v, causal, window, scale, bq, bk)
    return o


def _flash_fwd(q, k, v, causal, window, scale, bq, bk, backend):
    if backend == "pallas":
        o, lse = _fa.flash_attention(
            q, k, v, causal=causal, window=window, sm_scale=scale,
            block_q=bq, block_k=bk, interpret=not _on_tpu(), return_lse=True,
        )
    else:
        o, lse = _xla_flash_fwd_impl(q, k, v, causal, window, scale, bq, bk)
    return o, (q, k, v, o, lse)


def _flash_vjp_bwd(causal, window, scale, bq, bk, backend, res, do):
    q, k, v, o, lse = res
    return _flash_bwd_impl(q, k, v, o, lse, do, causal, window, scale, bq, bk)


_flash.defvjp(_flash_fwd, _flash_vjp_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_k",
                     "use_kernel", "backend"),
)
def flash_attention(
    q, k, v, *, causal=True, window=None, sm_scale=None,
    block_q=512, block_k=512, use_kernel=True, backend=None,
):
    """(B, Hq, T, D) x (B, Hkv, S, D)^2 -> (B, Hq, T, D).

    backend: None (auto: pallas on TPU, xla elsewhere) | 'pallas' | 'xla'.
    ``use_kernel=False`` falls back to the dense jnp oracle (tiny shapes).
    """
    if not use_kernel:
        return ref.attention_ref(q, k, v, causal=causal, window=window, sm_scale=sm_scale)
    if backend is None:
        backend = "pallas" if _on_tpu() else "xla"
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(q.shape[-1])
    if backend == "pallas":
        bq, bk = min(128, block_q), min(128, block_k)
    else:
        bq, bk = block_q, block_k
    bq = min(bq, q.shape[2])
    bk = min(bk, k.shape[2])
    return _flash(q, k, v, causal, window, scale, bq, bk, backend)


# ===================================================== rglru
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _rglru(x, a, h0, block_t, block_d, backend):
    if backend == "pallas":
        return _rg.rglru_scan(
            x, a, h0, block_t=block_t, block_d=block_d, interpret=not _on_tpu()
        )
    return ref.rglru_ref(x, a, h0)


def _rglru_fwd(x, a, h0, block_t, block_d, backend):
    h, h_last = _rglru(x, a, h0, block_t, block_d, backend)
    return (h, h_last), (a, h, h0)


def _rglru_bwd(block_t, block_d, backend, res, grads):
    a, h, h0 = res
    dh, dh_last = grads
    dh = dh.astype(jnp.float32)
    af = a.astype(jnp.float32)
    hf = h.astype(jnp.float32)
    dh = dh.at[:, -1].add(dh_last.astype(jnp.float32))

    # adjoint of h_t = a_t h_{t-1} + x_t:
    #   g_t = dh_t + a_{t+1} g_{t+1}  (reverse linear recurrence)
    #   dx_t = g_t ; da_t = g_t * h_{t-1} ; dh0 = a_0 g_0
    a_next = jnp.concatenate([af[:, 1:], jnp.zeros_like(af[:, :1])], axis=1)

    def combine(c2, c1):  # reverse scan
        a2, g2 = c2
        a1, g1 = c1
        return a1 * a2, g1 + a1 * g2

    _, g = jax.lax.associative_scan(combine, (a_next, dh), axis=1, reverse=True)
    h_prev = jnp.concatenate([h0.astype(jnp.float32)[:, None], hf[:, :-1]], axis=1)
    dx = g.astype(a.dtype)
    da = (g * h_prev).astype(a.dtype)
    dh0 = (af[:, 0] * g[:, 0]).astype(a.dtype)
    return dx, da, dh0


_rglru.defvjp(_rglru_fwd, _rglru_bwd)


@functools.partial(jax.jit, static_argnames=("block_t", "block_d", "use_kernel", "backend"))
def rglru(x, a, h0=None, *, block_t=256, block_d=256, use_kernel=True, backend=None):
    """Linear recurrence h_t = a_t h_{t-1} + x_t -> (h, h_last)."""
    if not use_kernel:
        return ref.rglru_ref(x, a, h0)
    if backend is None:
        backend = "pallas" if _on_tpu() else "xla"
    if h0 is None:
        h0 = jnp.zeros((x.shape[0], x.shape[2]), x.dtype)
    return _rglru(x, a, h0, block_t, block_d, backend)


# ===================================================== systolic
@functools.partial(jax.jit, static_argnames=("k_cycles",))
def systolic_step(state: dict, k_cycles: int) -> dict:
    """K fused cycles of a systolic tile (see kernels/systolic_step.py)."""
    return _sy.systolic_step(state, k_cycles, interpret=not _on_tpu())


# ===================================================== slstm
from . import slstm_scan as _sl  # noqa: E402


def slstm_scan(r: dict, pre, carry0, *, block_t: int = 128, backend=None):
    """sLSTM recurrence with R resident in VMEM (TPU) / lax.scan (CPU).

    Returns (hs, (cs, ns, ms), final_carry).  Used by the custom-VJP
    forward in models/recurrent.py; the backward consumes the sequences.
    """
    if backend is None:
        backend = "pallas" if _on_tpu() else "xla"
    T = pre.shape[1]
    if backend == "pallas" and T % min(block_t, T) == 0:
        return _sl.slstm_scan(
            r, pre, carry0, block_t=block_t, interpret=not _on_tpu()
        )
    return ref.slstm_scan_ref(r, pre, carry0)

"""Pallas TPU kernel for the sLSTM recurrence (xLSTM).

The sLSTM step is strictly sequential, so its performance is set by how
often the recurrent weights R_{i,f,z,o} travel HBM->VMEM.  The XLA scan
re-reads them every timestep (~1.2 MB x T x layers); this kernel keeps all
four block-diagonal R matrices **resident in VMEM for the whole sequence**
and streams only the hoisted gate pre-activations:

  * grid = (T/bt,): time chunks arrive as (B, bt, 4, d) blocks; the carry
    (c, n, h, m) persists in VMEM scratch across sequential grid steps.
  * per step: 4 head-blocked (B,H,hd)x(H,hd,hd) matmuls (MXU) + the
    exponential-gating pointwise update (VPU).
  * outputs: the full h/c/n/m sequences (the custom-VJP backward in
    models/recurrent.py consumes them as residuals) + final carry.

Oracle: ``ref.slstm_scan_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _step(r_i, r_f, r_z, r_o, c, n, h, m, pre_t):
    """One sLSTM step; all f32. pre_t: (B, 4, d)."""
    B, d = h.shape
    H = r_i.shape[0]
    hd = d // H
    hb = h.reshape(B, H, hd)

    def rmat(r):
        return jax.lax.dot_general(
            hb, r, (((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32,
        ).transpose(1, 0, 2).reshape(B, d)

    li = pre_t[:, 0] + rmat(r_i)
    lf = jax.nn.log_sigmoid(pre_t[:, 1] + rmat(r_f))
    z = jnp.tanh(pre_t[:, 2] + rmat(r_z))
    o = jax.nn.sigmoid(pre_t[:, 3] + rmat(r_o))
    m_new = jnp.maximum(lf + m, li)
    c = c * jnp.exp(lf + m - m_new) + jnp.exp(li - m_new) * z
    n = n * jnp.exp(lf + m - m_new) + jnp.exp(li - m_new)
    h = o * c / jnp.maximum(n, 1.0)
    return c, n, h, m_new


def _slstm_kernel(
    ri_ref, rf_ref, rz_ref, ro_ref, pre_ref, c0_ref, n0_ref, h0_ref, m0_ref,
    hs_ref, cs_ref, ns_ref, ms_ref, cf_ref, nf_ref, hf_ref, mf_ref,
    c_s, n_s, h_s, m_s,
    *, bt: int, n_t: int,
):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        c_s[...] = c0_ref[...].astype(jnp.float32)
        n_s[...] = n0_ref[...].astype(jnp.float32)
        h_s[...] = h0_ref[...].astype(jnp.float32)
        m_s[...] = m0_ref[...].astype(jnp.float32)

    r_i = ri_ref[...].astype(jnp.float32)
    r_f = rf_ref[...].astype(jnp.float32)
    r_z = rz_ref[...].astype(jnp.float32)
    r_o = ro_ref[...].astype(jnp.float32)
    pre = pre_ref[...].astype(jnp.float32)  # (B, bt, 4, d)

    def body(i, carry):
        c, n, h, m = carry
        pre_t = jax.lax.dynamic_index_in_dim(pre, i, axis=1, keepdims=False)
        c, n, h, m = _step(r_i, r_f, r_z, r_o, c, n, h, m, pre_t)
        hs_ref[:, i] = h.astype(hs_ref.dtype)
        cs_ref[:, i] = c.astype(cs_ref.dtype)
        ns_ref[:, i] = n.astype(ns_ref.dtype)
        ms_ref[:, i] = m.astype(ms_ref.dtype)
        return c, n, h, m

    carry = (c_s[...], n_s[...], h_s[...], m_s[...])
    c, n, h, m = jax.lax.fori_loop(0, bt, body, carry)
    c_s[...] = c
    n_s[...] = n
    h_s[...] = h
    m_s[...] = m

    @pl.when(t == n_t - 1)
    def _final():
        cf_ref[...] = c
        nf_ref[...] = n
        hf_ref[...] = h
        mf_ref[...] = m


def slstm_scan(
    r: dict,            # {'i','f','z','o': (H, hd, hd)}
    pre: jax.Array,     # (B, T, 4, d) f32
    carry0: tuple,      # (c, n, h, m) each (B, d) f32
    *,
    block_t: int = 128,
    interpret: bool = False,
):
    """Returns (hs (B,T,d), (c,n,h,m) sequences (B,T,d), final carry)."""
    B, T, _, d = pre.shape
    bt = min(block_t, T)
    if T % bt:
        raise ValueError(f"T={T} must divide block_t={bt}")
    n_t = T // bt
    c0, n0, h0, m0 = carry0
    f32 = jnp.float32
    seq = jax.ShapeDtypeStruct((B, T, d), f32)
    vec = jax.ShapeDtypeStruct((B, d), f32)

    kernel = functools.partial(_slstm_kernel, bt=bt, n_t=n_t)
    grid = (n_t,)
    seq_spec = pl.BlockSpec((B, bt, d), lambda t: (0, t, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda t: tuple(0 for _ in shape))
    hs, cs, ns, ms, cf, nf, hf, mf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            full(r["i"].shape), full(r["f"].shape), full(r["z"].shape),
            full(r["o"].shape),
            pl.BlockSpec((B, bt, 4, d), lambda t: (0, t, 0, 0)),
            full((B, d)), full((B, d)), full((B, d)), full((B, d)),
        ],
        out_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                   full((B, d)), full((B, d)), full((B, d)), full((B, d))],
        out_shape=[seq, seq, seq, seq, vec, vec, vec, vec],
        scratch_shapes=[pltpu.VMEM((B, d), f32)] * 4,
        interpret=interpret,
    )(r["i"], r["f"], r["z"], r["o"], pre, c0, n0, h0, m0)
    return hs, (cs, ns, ms), (cf, nf, hf, mf)

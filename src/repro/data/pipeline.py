"""Deterministic synthetic LM data pipeline.

Production-shaped: per-host sharding, document structure (EOS-delimited
spans so sequence packing is exercised), background prefetch, and a
checkpointable cursor (``state()`` / ``restore()``) so training resumes
bit-exactly after a failure (runtime/fault_tolerance.py relies on this).

The token distribution is a fixed-seed Zipfian mixture — deterministic
given (seed, host, step), so any restart on any host count reproduces the
same global stream (elastic-resharding safe: the stream is keyed by GLOBAL
batch row, not by host).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    embed_dim: int | None = None  # set for embedding-input archs (vlm/audio)


class TokenPipeline:
    """Deterministic, shardable, checkpointable synthetic token stream."""

    def __init__(self, cfg: PipelineConfig, host_id: int = 0, n_hosts: int = 1):
        if cfg.global_batch % n_hosts:
            raise ValueError("global_batch must divide n_hosts")
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self._step = 0

    # ---------------------------------------------------------------- state
    def state(self) -> dict:
        return {"step": self._step}

    def restore(self, state: dict) -> None:
        self._step = int(state["step"])

    # ---------------------------------------------------------------- batch
    def _row(self, step: int, global_row: int) -> np.ndarray:
        """One (seq_len + 1,) token row, deterministic in (seed, step, row)."""
        cfg = self.cfg
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step * 4093 + global_row) % (2**31 - 1)
        )
        n = cfg.seq_len + 1
        out = np.empty(n, dtype=np.int32)
        pos = 0
        while pos < n:
            doc_len = max(int(rng.exponential(cfg.mean_doc_len)), 8)
            # Zipf-ish: squash uniform^3 toward frequent ids; id 0 = EOS/pad
            u = rng.rand(min(doc_len, n - pos))
            toks = (u**3 * (cfg.vocab - 2)).astype(np.int32) + 2
            out[pos : pos + len(toks)] = toks
            pos += len(toks)
            if pos < n:
                out[pos] = 1  # EOS
                pos += 1
        return out

    def batch(self, step: int | None = None) -> dict:
        """{'inputs': (local_batch, S) or (local_batch, S, D), 'labels': (local_batch, S)}."""
        if step is None:
            step = self._step
            self._step += 1
        cfg = self.cfg
        rows = np.stack(
            [
                self._row(step, self.host_id * self.local_batch + i)
                for i in range(self.local_batch)
            ]
        )
        labels = rows[:, 1:]
        if cfg.embed_dim is not None:
            # stub modality frontend: deterministic embeddings per token id
            rng = np.random.RandomState(cfg.seed + 17)
            table = rng.randn(256, cfg.embed_dim).astype(np.float32) * 0.02
            inputs = table[rows[:, :-1] % 256]
        else:
            inputs = rows[:, :-1]
        return {"inputs": inputs, "labels": labels}

    # ------------------------------------------------------------- prefetch
    def prefetch(self, depth: int = 2):
        """Iterator with a background producer thread (depth-bounded)."""
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def producer():
            while not stop.is_set():
                b = self.batch()
                while not stop.is_set():
                    try:
                        q.put(b, timeout=0.1)
                        break
                    except queue.Full:
                        continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()

        class _Iter:
            def __iter__(self):
                return self

            def __next__(self):
                return q.get()

            def close(self):
                stop.set()

        return _Iter()

"""llama4-maverick-400b-a17b [moe]: 48L d5120 40H (GQA kv=8) v202048.

[hf:meta-llama/Llama-4-Maverick] 128 experts top-1 with a shared expert
(sigmoid gate), early-fusion multimodal (frontend out of scope — text
backbone modeled), expert ff 8192.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=202048, hidden_act="silu", rope_theta=500_000.0,
    block_pattern=("attn", "attn_moe"),
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192,
                  capacity_factor=1.25, router_norm_topk=False,
                  shared_expert=True, gate_fn="sigmoid"),
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, hidden_act="silu",
    block_pattern=("attn", "attn_moe"),
    moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=64, capacity_factor=2.0,
                  router_norm_topk=False, shared_expert=True,
                  gate_fn="sigmoid"),
    use_kernels=False, dtype="float32",
)

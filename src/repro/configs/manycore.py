"""manycore — the paper's own application (§IV-B).

A 1024x1024 grid of systolic MAC cores (the PicoRV32 array's dataflow)
computing Y = A @ B for M=1024 streamed rows, distributed over the
production mesh with the epoch-batched queue engine.  This config is
exercised by launch/dryrun.py --arch manycore and by the benchmarks;
it is not part of the 40 LM cells.

The sync rates are tiered (DESIGN.md §3): intra-pod (ICI) boundaries
exchange every ``k_inner`` cycles, inter-pod (DCI) boundaries every
``k_inner * k_outer`` — the paper's fast-shm/slow-TCP split.  The flat
single-K schedule is ``k_outer = 1``.  ``WAFER`` is the CPU-runnable
flagship shape consumed by ``examples/wafer_scale.py``
(``benchmarks/wafer_scale.py`` sweeps schedules around it).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ManycoreConfig:
    grid_rows: int = 1024
    grid_cols: int = 1024
    m_stream: int = 1024
    k_inner: int = 16          # intra-pod cycles per exchange (Fig. 15 knob)
    k_outer: int = 4           # inner rounds per inter-pod exchange
    pods: int = 2              # outer-tier (DCI) split of the grid rows
    queue_capacity: int = 62   # paper §III-B
    payload_words: int = 2

    @property
    def k_epoch(self) -> int:
        """Back-compat alias: the innermost sync rate."""
        return self.k_inner

    @property
    def pod_period(self) -> int:
        """Cycles between inter-pod synchronizations."""
        return self.k_inner * self.k_outer


CONFIG = ManycoreConfig()
SMOKE = ManycoreConfig(grid_rows=8, grid_cols=8, m_stream=8, k_inner=4,
                       k_outer=2, queue_capacity=8)
# >= 64k cores, sized to finish in minutes on host-CPU fake devices.
WAFER = ManycoreConfig(grid_rows=256, grid_cols=256, m_stream=0,
                       k_inner=8, k_outer=4, queue_capacity=8)

"""manycore — the paper's own application (§IV-B).

A 1024x1024 grid of systolic MAC cores (the PicoRV32 array's dataflow)
computing Y = A @ B for M=1024 streamed rows, distributed over the
production mesh with the epoch-batched queue engine.  This config is
exercised by launch/dryrun.py --arch manycore and by the benchmarks;
it is not part of the 40 LM cells.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ManycoreConfig:
    grid_rows: int = 1024
    grid_cols: int = 1024
    m_stream: int = 1024
    k_epoch: int = 16          # cycles per epoch (Fig. 15 knob)
    queue_capacity: int = 62   # paper §III-B
    payload_words: int = 2


CONFIG = ManycoreConfig()
SMOKE = ManycoreConfig(grid_rows=8, grid_cols=8, m_stream=8, k_epoch=4,
                       queue_capacity=8)

"""llama3.2-1b [dense]: 16L d2048 32H (GQA kv=8) ff8192 v128256.

[hf:meta-llama/Llama-3.2-1B] Tied embeddings, SwiGLU, RoPE theta 5e5.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=128256, hidden_act="silu", rope_theta=500_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, hidden_act="silu", tie_embeddings=True,
    use_kernels=False, dtype="float32",
)

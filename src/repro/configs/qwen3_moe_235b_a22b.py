"""qwen3-moe-235b-a22b [moe]: 94L d4096 64H (GQA kv=4) v151936.

[hf:Qwen/Qwen3-235B-A22B] 128 experts, top-8, expert ff 1536,
normalized top-k router.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab=151936, hidden_act="silu", rope_theta=1_000_000.0,
    block_pattern=("attn_moe",),
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536,
                  capacity_factor=1.25, router_norm_topk=True),
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=0, vocab=512, hidden_act="silu",
    block_pattern=("attn_moe",),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                  capacity_factor=2.0, router_norm_topk=True),
    use_kernels=False, dtype="float32",
)

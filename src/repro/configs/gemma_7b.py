"""gemma-7b [dense]: 28L d3072 16H (kv=16, MHA) ff24576 v256000.

[arXiv:2403.08295] GeGLU, head_dim=256, sqrt(d) embedding scale, tied
embeddings, RoPE theta 1e4.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000, hidden_act="gelu", rope_theta=10_000.0,
    tie_embeddings=True, embed_scale=True,
)

SMOKE = ModelConfig(
    name="gemma-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab=512, hidden_act="gelu", tie_embeddings=True,
    embed_scale=True, use_kernels=False, dtype="float32",
)

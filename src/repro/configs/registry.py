"""Architecture registry: full configs, smoke configs, and shape sets.

Every assigned architecture is selectable via ``--arch <id>``.  Each arch
pairs with the LM shape set; inapplicable (arch, shape) cells are recorded
as explicit skips with reasons (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Iterable

from ..models.config import ModelConfig

ARCH_IDS = [
    "llama3_2_1b",
    "llama3_2_3b",
    "gemma_7b",
    "gemma_2b",
    "qwen2_vl_72b",
    "hubert_xlarge",
    "qwen3_moe_235b_a22b",
    "llama4_maverick_400b_a17b",
    "xlstm_125m",
    "recurrentgemma_2b",
    # the paper's own application (not part of the 40 LM cells)
    "manycore",
]

# canonical external names (with dots/dashes) -> module ids
ALIASES = {
    "llama3.2-1b": "llama3_2_1b",
    "llama3.2-3b": "llama3_2_3b",
    "gemma-7b": "gemma_7b",
    "gemma-2b": "gemma_2b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "xlstm-125m": "xlstm_125m",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs whose sequence mixing is sub-quadratic end-to-end (recurrent state /
# bounded-window KV) — the only ones that run long_500k.
SUBQUADRATIC = {"xlstm_125m", "recurrentgemma_2b"}
ENCODER_ONLY = {"hubert_xlarge"}


def skip_reason(arch: str, shape: str) -> str | None:
    arch = ALIASES.get(arch, arch)
    if arch == "manycore":
        return None if shape == "manycore" else "manycore uses its own shape"
    if arch in ENCODER_ONLY and SHAPES[shape].step == "decode":
        return "encoder-only arch has no decode step"
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return "pure full-attention arch: 500k dense KV cache infeasible (see DESIGN.md §5)"
    return None


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE if smoke else mod.CONFIG


def lm_cells() -> Iterable[tuple[str, str]]:
    """All 40 assigned (arch, shape) cells, including skipped ones."""
    for arch in ARCH_IDS:
        if arch == "manycore":
            continue
        for shape in SHAPES:
            yield arch, shape

"""gemma-2b [dense]: 18L d2048 8H (MQA kv=1) ff16384 v256000.

[arXiv:2403.08295] GeGLU, head_dim=256, MQA, sqrt(d) embed scale, tied.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, hidden_act="gelu", rope_theta=10_000.0,
    tie_embeddings=True, embed_scale=True,
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=256, vocab=512, hidden_act="gelu", tie_embeddings=True,
    embed_scale=True, use_kernels=False, dtype="float32",
)

from .registry import (
    ARCH_IDS, ALIASES, SHAPES, SUBQUADRATIC, ENCODER_ONLY,
    get_config, skip_reason, lm_cells, ShapeSpec,
)

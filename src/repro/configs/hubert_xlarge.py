"""hubert-xlarge [audio]: 48L d1280 16H ff5120 v504 — encoder-only.

[arXiv:2106.07447] Same backbone as wav2vec2; the CNN feature extractor is
a STUB: input_specs() provides precomputed frame embeddings (B, S, d).
Masked-unit prediction over 504 cluster targets.  No decode shapes.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504, hidden_act="gelu", causal=False,
    input_mode="embeddings",
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=32, hidden_act="gelu", causal=False,
    input_mode="embeddings", use_kernels=False, dtype="float32",
)

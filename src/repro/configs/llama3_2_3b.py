"""llama3.2-3b [dense]: 28L d3072 24H (GQA kv=8) ff8192 v128256."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=128256, hidden_act="silu", rope_theta=500_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-3b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab=512, hidden_act="silu", tie_embeddings=True,
    use_kernels=False, dtype="float32",
)

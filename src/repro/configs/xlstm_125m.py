"""xlstm-125m [ssm]: 12L d768 4H v50304 — alternating mLSTM/sLSTM blocks.

[arXiv:2405.04517] Pre-up-projection mLSTM (matrix memory, chunkwise
parallel) + post-FFN sLSTM (scalar memory, strictly sequential).  d_ff=0:
blocks are self-contained.  Sub-quadratic => runs long_500k.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab=50304, block_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="xlstm-125m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab=512, block_pattern=("mlstm", "slstm"),
    tie_embeddings=True, use_kernels=False, dtype="float32",
)

"""qwen2-vl-72b [vlm]: 80L d8192 64H (GQA kv=8) ff29568 v152064.

[arXiv:2409.12191] M-RoPE (sections 16/24/24), dynamic-resolution vision
frontend is a STUB: input_specs() provides precomputed patch embeddings
(B, S, d_model); the transformer BACKBONE is modeled here.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064, hidden_act="silu", rope_theta=1_000_000.0,
    rope_type="mrope", mrope_sections=(16, 24, 24), input_mode="embeddings",
)

SMOKE = ModelConfig(
    name="qwen2-vl-72b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=512, hidden_act="silu", rope_type="mrope",
    mrope_sections=(2, 1, 1), input_mode="embeddings",
    use_kernels=False, dtype="float32",
)

"""recurrentgemma-2b [hybrid]: 26L d2560 10H (MQA kv=1) ff7680 v256000.

[arXiv:2402.19427 Griffin] Pattern (RG-LRU, RG-LRU, local-attn) — 2:1
recurrent:attention, window 2048, GeGLU MLP after every temporal block,
head_dim 256, sqrt(d) embed scale.  26 = 8 full patterns + (rglru, rglru).
Sub-quadratic (bounded window + recurrent state) => runs long_500k.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000, hidden_act="gelu",
    block_pattern=("rglru", "rglru", "attn_local"), attn_window=2048,
    rnn_width=2560, conv_width=4, rope_theta=10_000.0,
    tie_embeddings=True, embed_scale=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=512, hidden_act="gelu",
    block_pattern=("rglru", "rglru", "attn_local"), attn_window=16,
    rnn_width=64, conv_width=4, tie_embeddings=True, embed_scale=True,
    use_kernels=False, dtype="float32",
)

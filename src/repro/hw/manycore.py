"""Wafer-scale many-core fabric — message-passing mini-cores on a torus
(paper §IV-B at its intended scale; ``examples/wafer_scale.py``).

The paper's headline demo is a million RISC-V cores exchanging packets over
latency-insensitive channels, spread over thousands of cloud cores by the
tiered shm/TCP transport.  The analogue here is a uniform R×C **torus** of
``ManycoreCell`` blocks — one block type, so the whole fabric steps as a
single vmapped body regardless of core count — running a two-phase
ring-allreduce entirely in the data plane:

  phase 0 (row rings, east links):   every core circulates its value around
          its row and accumulates the row sum;
  phase 1 (column rings, south links): row sums circulate around each
          column, accumulating the global sum.

When a core's ``phase`` reaches 2, ``total`` holds the sum of every core's
``value`` — a global invariant that checks end-to-end packet delivery
across every granule and tier boundary with one equality.  All traffic is
ready/valid handshaked, so results are **bit-exact for any partition and
any per-tier sync rate** (the property ``tests/test_tiered.py`` leans on).

Protocol per ring of length L (phase 0: L = C, phase 1: L = R): a core
sends ``L-1`` packets — its own contribution first, then the first ``L-2``
values it receives, forwarded in arrival order through a 1-deep elastic
register — and accumulates the ``L-1`` values it receives.  A value
occupies one buffer (queue slot or forward register) at a time and each
ring holds L live values against >= 2L buffer slots, so the rings cannot
deadlock even at queue capacity 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.block import Block
from ..core.struct import pytree_dataclass

PAYLOAD_WORDS = 2  # [value, hop tag]


@pytree_dataclass
class CoreState:
    value: jax.Array   # () f32 — this core's contribution (from params)
    own: jax.Array     # () f32 — value this core injects in the current phase
    acc: jax.Array     # () f32 — running accumulator for the current phase
    total: jax.Array   # () f32 — global sum (valid once phase == 2)
    phase: jax.Array   # () int32: 0 = row ring, 1 = column ring, 2 = done
    sent: jax.Array    # () int32 packets sent this phase
    rcvd: jax.Array    # () int32 packets received this phase
    fwd: jax.Array     # () f32 — 1-deep forward register
    fwd_v: jax.Array   # () bool
    fires: jax.Array   # () int32 — total handshakes (perf counter, §II-C)


@pytree_dataclass
class CoreParams:
    """Per-instance parameters (stacked leading dim by the builders)."""

    value: jax.Array  # () f32


class ManycoreCell(Block):
    """Message-passing mini-core for an R×C torus (ports match
    ``ChannelGraph.torus``: west/north in, east/south out)."""

    in_ports = ("w_in", "n_in")
    out_ports = ("e_out", "s_out")
    payload_words = PAYLOAD_WORDS

    def __init__(self, R: int, C: int):
        self.R = int(R)
        self.C = int(C)

    def init_state(self, key: jax.Array, params: CoreParams | None = None) -> CoreState:
        if params is None:
            raise ValueError("ManycoreCell requires per-instance params")
        v = jnp.asarray(params.value, jnp.float32)
        zero_i = jnp.zeros((), jnp.int32)
        return CoreState(
            value=v, own=v, acc=v,
            total=jnp.zeros((), jnp.float32),
            phase=zero_i, sent=zero_i, rcvd=zero_i,
            fwd=jnp.zeros((), jnp.float32),
            fwd_v=jnp.zeros((), bool),
            fires=zero_i,
        )

    def step(self, state: CoreState, rx, tx_ready):
        (w_pay, w_valid) = rx["w_in"]
        (n_pay, n_valid) = rx["n_in"]
        in_row = state.phase == 0  # else column ring (or done)
        live = state.phase < 2
        # packets to send == packets to receive this phase: ring length - 1
        need = jnp.where(in_row, self.C - 1, self.R - 1).astype(jnp.int32)

        in_val = jnp.where(in_row, w_pay[0], n_pay[0])
        in_valid = live & jnp.where(in_row, w_valid, n_valid)
        out_ready = jnp.where(in_row, tx_ready["e_out"], tx_ready["s_out"])

        # ---- send: own value first, then forwards, in arrival order
        out_val = jnp.where(state.sent == 0, state.own, state.fwd)
        can_send = live & (state.sent < need) & ((state.sent == 0) | state.fwd_v)
        did_send = can_send & out_ready
        fwd_freed = did_send & (state.sent > 0)

        # ---- receive: accept unless the forward register is (still) busy
        will_fwd = state.rcvd < need - 1  # the last arrival is not re-sent
        may_accept = live & (state.rcvd < need) & (
            ~will_fwd | ~state.fwd_v | fwd_freed
        )
        accept = may_accept & in_valid

        sent = state.sent + did_send.astype(jnp.int32)
        rcvd = state.rcvd + accept.astype(jnp.int32)
        acc = state.acc + jnp.where(accept, in_val, 0.0)
        fwd_v = (state.fwd_v & ~fwd_freed) | (accept & will_fwd)
        fwd = jnp.where(accept & will_fwd, in_val, state.fwd)

        # ---- phase transition: all sent and all received => ring complete
        done_phase = live & (sent == need) & (rcvd == need)
        finishing = done_phase & (state.phase == 1)
        new_phase = state.phase + done_phase.astype(jnp.int32)

        payload = jnp.stack([out_val, state.sent.astype(jnp.float32)])
        tx = {
            "e_out": (payload, did_send & in_row),
            "s_out": (payload, did_send & ~in_row),
        }
        rx_ready = {
            "w_in": may_accept & in_row,
            "n_in": may_accept & ~in_row,
        }
        new_state = CoreState(
            value=state.value,
            own=jnp.where(done_phase, acc, state.own),
            acc=acc,
            total=jnp.where(finishing, acc, state.total),
            phase=new_phase,
            sent=jnp.where(done_phase, 0, sent),
            rcvd=jnp.where(done_phase, 0, rcvd),
            fwd=fwd,
            fwd_v=fwd_v,
            fires=state.fires
            + did_send.astype(jnp.int32)
            + accept.astype(jnp.int32),
        )
        return new_state, rx_ready, tx


def make_core_params(values: np.ndarray) -> CoreParams:
    """Stacked per-core params from an (R, C) value array (row-major)."""
    v = np.asarray(values, np.float32)
    return CoreParams(value=jnp.asarray(v.reshape(-1)))


def allreduce_done(cell_states: CoreState, active=None) -> jax.Array:
    """() bool — every (active) core finished both ring phases.

    ``active`` masks padding slots when the partition is uneven (pass
    ``local.tables.active[0]`` from a ``run_until`` predicate).
    """
    done = cell_states.phase >= 2
    if active is not None:
        done = done | ~active
    return done.all()


def expected_total(values: np.ndarray) -> float:
    """The invariant every core must converge to: the global sum."""
    return float(np.asarray(values, np.float64).sum())

"""A minimal latency-insensitive pipeline stage (paper §II-A's "DUT").

The simplest useful Block: forward the inbound packet, adding ``delta``
to word 0, under a full ready/valid handshake.  One block type, arbitrary
chain/ring topologies — the unit cell for host-I/O scenarios, the
engine-parity benchmarks, and the multiprocess runtime's build-time
suite (its workers unpickle the block by reference, so it lives in the
package, not in a script).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.block import Block
from ..core.network import Network
from ..core.struct import pytree_dataclass


@pytree_dataclass
class PipeStageState:
    count: jax.Array  # () int32 — handshakes forwarded


class PipeStage(Block):
    """Forward ``in`` -> ``out``, adding ``delta`` to word 0 on the way."""

    in_ports = ("in",)
    out_ports = ("out",)
    payload_words = 2

    def __init__(self, delta: float = 1.0):
        self.delta = float(delta)

    def init_state(self, key):
        return PipeStageState(count=jnp.zeros((), jnp.int32))

    def step(self, state, rx, tx_ready):
        pay, valid = rx["in"]
        fire = valid & tx_ready["out"]
        return (
            state.replace(count=state.count + fire.astype(jnp.int32)),
            {"in": fire},
            {"out": (pay.at[0].add(self.delta), fire)},
        )


def make_chain(n: int, capacity: int = 8, delta: float = 1.0) -> Network:
    """n-stage chain with host ports "tx" (into stage 0) and "rx" (out of
    stage n-1) — the canonical host-I/O scenario."""
    net = Network(payload_words=2, capacity=capacity)
    blk = PipeStage(delta)
    insts = [net.instantiate(blk, name=f"s{i}") for i in range(n)]
    net.external_in(insts[0]["in"], "tx")
    for a, b in zip(insts, insts[1:]):
        net.connect(a["out"], b["in"])
    net.external_out(insts[-1]["out"], "rx")
    return net


def make_ring(n: int, capacity: int = 8, delta: float = 1.0) -> Network:
    """n-stage closed ring — one block type, perfectly uniform topology
    (every granule of a one-stage-per-worker partition has the same
    compiled shape: the prebuilt-cache build-time scenario)."""
    net = Network(payload_words=2, capacity=capacity)
    blk = PipeStage(delta)
    insts = [net.instantiate(blk, name=f"s{i}") for i in range(n)]
    for i in range(n):
        net.connect(insts[i]["out"], insts[(i + 1) % n]["in"])
    return net

"""Systolic matrix-multiply core grid — the million-core experiment (§IV-B).

The paper's flagship run simulates a 1024×1024 grid of RISC-V cores computing
``Y = A @ B``: each core stores one element of B, A-elements stream in from
the west and move east, partial sums flow north→south, rows of Y appear at
the south edge (paper Fig. 12).  We model the *unit cell* as a
latency-insensitive MAC core:

    fire  = a_valid & psum_valid & east_ready & south_ready
    on fire: emit a eastward, emit (psum + a*b) southward

Because every channel is flow-controlled there is **no wavefront skew
logic** — ordering is enforced entirely by handshakes, which is exactly the
paper's argument for latency-insensitive design (§II-D).

Edge behaviour is folded into the cell via per-instance flags so the grid is
perfectly uniform (one block type ⇒ one prebuilt simulator ⇒ one vmapped
step at any scale):

  * ``is_west``:  synthesize the A stream from a local buffer instead of the
    west port (the paper's stimulus enters at the west edge).
  * ``is_north``: synthesize ``psum = 0`` (always valid).
  * ``is_south``: collect outputs into a local result buffer (always ready) —
    the south-edge "sink".
  * ``is_east``:  drop the eastward output (always ready).

Packet payload: 2 words — [value, tag] where tag is the A-row index ``m``
(used by tests to assert in-order delivery).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.block import Block
from ..core.network import Network
from ..core.struct import pytree_dataclass

PAYLOAD_WORDS = 2  # [value, tag]


@pytree_dataclass
class CellState:
    b: jax.Array        # () stationary B element
    is_west: jax.Array  # () bool
    is_north: jax.Array
    is_south: jax.Array
    is_east: jax.Array
    a_buf: jax.Array    # (M,) A-stream source (west cells), zeros elsewhere
    a_idx: jax.Array    # () int32 next stream element
    y_buf: jax.Array    # (M,) collected outputs (south cells)
    y_idx: jax.Array    # () int32
    fires: jax.Array    # () int32 — handshake counter (perf stats)


@pytree_dataclass
class SystolicParams:
    """Per-instance parameters, stacked by the network builder."""

    b: jax.Array
    is_west: jax.Array
    is_north: jax.Array
    is_south: jax.Array
    is_east: jax.Array
    a_buf: jax.Array  # (M,)


class SystolicCell(Block):
    in_ports = ("w_in", "n_in")
    out_ports = ("e_out", "s_out")
    payload_words = PAYLOAD_WORDS

    def __init__(self, m_stream: int):
        self.m_stream = int(m_stream)  # #A-rows streamed through the array

    def init_state(self, key: jax.Array, params: SystolicParams | None = None) -> CellState:
        if params is None:
            raise ValueError("SystolicCell requires per-instance params")
        return CellState(
            b=params.b,
            is_west=params.is_west,
            is_north=params.is_north,
            is_south=params.is_south,
            is_east=params.is_east,
            a_buf=params.a_buf,
            a_idx=jnp.zeros((), jnp.int32),
            y_buf=jnp.zeros((self.m_stream,), jnp.float32),
            y_idx=jnp.zeros((), jnp.int32),
            fires=jnp.zeros((), jnp.int32),
        )

    def step(self, state: CellState, rx, tx_ready):
        (w_pay, w_valid) = rx["w_in"]
        (n_pay, n_valid) = rx["n_in"]
        e_ready = tx_ready["e_out"]
        s_ready = tx_ready["s_out"]

        # Effective inputs after edge synthesis.
        stream_left = state.a_idx < self.m_stream
        a_val = jnp.where(state.is_west, state.a_buf[state.a_idx % self.m_stream], w_pay[0])
        a_tag = jnp.where(state.is_west, state.a_idx.astype(jnp.float32), w_pay[1])
        a_valid = jnp.where(state.is_west, stream_left, w_valid)
        psum = jnp.where(state.is_north, 0.0, n_pay[0])
        psum_valid = jnp.where(state.is_north, True, n_valid)

        e_rdy = state.is_east | e_ready
        s_rdy = state.is_south | s_ready

        fire = a_valid & psum_valid & e_rdy & s_rdy
        y = psum + a_val * state.b

        # Handshakes back to queues (only for non-synthesized ports).
        rx_ready = {
            "w_in": fire & ~state.is_west,
            "n_in": fire & ~state.is_north,
        }
        tx = {
            "e_out": (jnp.stack([a_val, a_tag]), fire & ~state.is_east),
            "s_out": (jnp.stack([y, a_tag]), fire & ~state.is_south),
        }

        collect = fire & state.is_south
        new_state = state.replace(
            a_idx=state.a_idx + (fire & state.is_west).astype(jnp.int32),
            y_buf=jnp.where(
                collect,
                state.y_buf.at[state.y_idx % self.m_stream].set(y),
                state.y_buf,
            ),
            y_idx=state.y_idx + collect.astype(jnp.int32),
            fires=state.fires + fire.astype(jnp.int32),
        )
        return new_state, rx_ready, tx


def make_cell_params(a: np.ndarray, b: np.ndarray) -> SystolicParams:
    """Stacked per-cell params for grid (rows=K, cols=N) computing A@B.

    a: (M, K) — streamed west→east (core row r carries A[:, r]).
    b: (K, N) — stationary (core (r, c) holds B[r, c]).
    Returns params with leading dims (K, N).
    """
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    rr, cc = np.meshgrid(np.arange(k), np.arange(n), indexing="ij")
    a_buf = np.zeros((k, n, m), np.float32)
    a_buf[:, 0, :] = a.T  # west-edge cells stream A[:, r]
    return SystolicParams(
        b=jnp.asarray(b),
        is_west=jnp.asarray(cc == 0),
        is_north=jnp.asarray(rr == 0),
        is_south=jnp.asarray(rr == k - 1),
        is_east=jnp.asarray(cc == n - 1),
        a_buf=jnp.asarray(a_buf),
    )


def make_systolic_network(a: np.ndarray, b: np.ndarray, capacity: int = 8) -> tuple[Network, list]:
    """Build a single-netlist Network for Y = A @ B (ground-truth engine).

    Returns (network, grid_of_instances).
    """
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    m, k = a.shape
    _, n = b.shape
    params = make_cell_params(a, b)
    cell = SystolicCell(m_stream=m)
    net = Network(payload_words=PAYLOAD_WORDS, capacity=capacity)
    grid = [
        [
            net.instantiate(
                cell,
                name=f"c{r}_{c}",
                params=jax.tree.map(lambda x: x[r, c], params),
            )
            for c in range(n)
        ]
        for r in range(k)
    ]
    for r in range(k):
        for c in range(n):
            if c + 1 < n:
                net.connect(grid[r][c]["e_out"], grid[r][c + 1]["w_in"])
            if r + 1 < k:
                net.connect(grid[r][c]["s_out"], grid[r + 1][c]["n_in"])
    return net, grid


def collect_result(sim, state, grid) -> np.ndarray:
    """Read Y (M, N) out of the south-edge cells' y_buf."""
    k = len(grid)
    n = len(grid[0])
    cols = []
    for c in range(n):
        st = sim.group_state(state, grid[k - 1][c])
        cols.append(np.asarray(st.y_buf))
    return np.stack(cols, axis=1)  # (M, N)


def cycles_needed(m: int, k: int, n: int) -> int:
    """Loose upper bound on cycles for the single-netlist run to finish."""
    return 4 * (m + k + n) + 64

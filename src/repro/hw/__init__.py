"""Hardware-block library: the paper's application blocks, modeled in JAX.

Heterogeneous model types (paper Fig. 3) all implement the same Block
protocol and interoperate through queues: ``systolic.SystolicCell`` is the
cycle-accurate "RTL-like" MAC core (the million-core experiment's unit
cell, §IV-B); the functional "SW-model" DRAM and the piecewise-linear
"SPICE" block live in examples/heterogeneous_soc.py (§IV-A analogue).
"""
from .systolic import SystolicCell, SystolicParams, make_systolic_network, collect_result
from .manycore import (
    ManycoreCell, CoreParams, allreduce_done, expected_total, make_core_params,
)
from .pipestage import PipeStage, make_chain, make_ring

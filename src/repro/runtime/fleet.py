"""Multi-host fleet runtime: host placement, rendezvous, control links.

Shards a procs fleet across N *launcher* processes ("hosts") connected
only by TCP — the paper's multi-machine deployment (§III-B), run
in-container over loopback so CI exercises the real wire path.  The
pieces:

  * ``HostPlan`` — assigns each partition-tree granule to a named host.
    Placement is by contiguous granule ranges by default (``auto``), so
    host cuts land on partition-subtree boundaries and the number of
    cross-host channels stays small.
  * ``Link``/``build_links`` — one TCP link per host pair with boundary
    traffic, carrying ALL that pair's channels (``runtime.bridge`` pairs
    the per-channel shm rings over it).  Accept side = lower plan-order
    host; link ids are deterministic (plan order + channel ids), so every
    host derives the SAME link map independently — rendezvous only has to
    exchange ports, never topology.
  * Rendezvous — the leader (plan host 0) binds ONE control listener;
    follower launchers dial it and send a hello carrying their accept-
    side bridge ports; the leader aggregates the full ``link -> (addr,
    port)`` map and broadcasts it; dial-side bridges connect directly
    (worker traffic never transits the control link).  A per-incarnation
    token rides every hello/HELLO so a stale process from a previous
    incarnation can never splice into a re-rendezvoused fleet.
  * ``follower_entry`` — a follower IS a full ``ProcsEngine`` (same
    lowering, same rings, same monitor) restricted to its host's
    granules, serving the leader's control protocol: one pickled frame
    per engine op (init / run / gather / scatter / probe / stats / ext
    I/O), with typed ``("fault", ...)`` replies so a follower-side
    ``WorkerDiedError``/``RingCorruptionError`` re-raises ON THE LEADER
    and routes into the ordinary recovery path (cross-host recovery:
    teardown, re-rendezvous, restore, replay — ``runtime.recovery``).

Env knobs: ``REPRO_HOSTS`` (host count ``"2"`` or names ``"a,b"``) and
``REPRO_BRIDGE_PORT`` (base port for deterministic bridge ports;
0/unset = ephemeral).  Explicit constructor args win over env.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import select
import socket
import sys
import time
import traceback

import numpy as np

from .fault_tolerance import FleetStallError, LinkDownError, WorkerDiedError


# ------------------------------------------------------------- host plans
@dataclasses.dataclass(frozen=True)
class HostPlan:
    """Granule -> host placement.  ``hosts[0]`` is the leader (it owns the
    user-facing engine object, the control listener, and ext-port I/O
    fan-out); the rest are follower launchers."""

    hosts: tuple
    assignment: tuple  # granule index -> host name

    @property
    def leader(self) -> str:
        return self.hosts[0]

    @property
    def n_hosts(self) -> int:
        return len(self.hosts)

    def host_of(self, g: int) -> str:
        return self.assignment[g]

    def index(self, host: str) -> int:
        return self.hosts.index(host)

    def granules_of(self, host: str) -> tuple:
        return tuple(g for g, h in enumerate(self.assignment) if h == host)

    @classmethod
    def auto(cls, hosts, n_granules: int) -> "HostPlan":
        """Contiguous equal split of granule ids over ``hosts`` (granule
        order follows the partition tree, so contiguous ranges hug
        subtree boundaries and minimise cross-host channels)."""
        hosts = tuple(hosts)
        if len(hosts) > n_granules:
            raise ValueError(
                f"host plan has {len(hosts)} hosts but the partition only "
                f"has {n_granules} granule(s)")
        chunks = np.array_split(np.arange(n_granules), len(hosts))
        assignment = [None] * n_granules
        for h, chunk in zip(hosts, chunks):
            for g in chunk:
                assignment[int(g)] = h
        return cls(hosts, tuple(assignment))

    def validate(self, n_granules: int) -> None:
        if len(self.assignment) != n_granules:
            raise ValueError(
                f"host plan assigns {len(self.assignment)} granule(s) but "
                f"the partition has {n_granules}")
        if len(set(self.hosts)) != len(self.hosts):
            raise ValueError(f"duplicate host names in plan: {self.hosts}")
        stray = sorted(set(self.assignment) - set(self.hosts))
        if stray:
            raise ValueError(f"granules assigned to unknown host(s) {stray}; "
                             f"plan hosts are {self.hosts}")
        for h in self.hosts:
            if h not in self.assignment:
                raise ValueError(f"host {h!r} has no granules assigned")


def resolve_host_plan(hosts, n_granules: int):
    """Constructor arg / ``REPRO_HOSTS`` env -> ``HostPlan`` or None.

    Accepts: None (env, else single-host), an int or digit-string host
    count (auto names ``h0..hN-1``), a comma list of names, a sequence of
    names, a ``{host: [granule, ...]}`` dict, or a ready ``HostPlan``.
    A count of 1 resolves to None — the plain single-host engine."""
    if hosts is None:
        hosts = os.environ.get("REPRO_HOSTS", "").strip() or None
        if hosts is None:
            return None
    if isinstance(hosts, HostPlan):
        plan = hosts
    elif isinstance(hosts, dict):
        names = tuple(hosts)
        assignment = [None] * n_granules
        for h, gs in hosts.items():
            for g in gs:
                if not (0 <= int(g) < n_granules):
                    raise ValueError(f"host {h!r} assigned granule {g}, but "
                                     f"the partition has {n_granules}")
                assignment[int(g)] = h
        missing = [g for g, h in enumerate(assignment) if h is None]
        if missing:
            raise ValueError(f"granule(s) {missing} not assigned to any host")
        plan = HostPlan(names, tuple(assignment))
    else:
        if isinstance(hosts, str):
            hosts = (int(hosts) if hosts.isdigit()
                     else tuple(s.strip() for s in hosts.split(",")
                                if s.strip()))
        if isinstance(hosts, int):
            if hosts <= 1:
                return None
            hosts = tuple(f"h{i}" for i in range(hosts))
        plan = HostPlan.auto(tuple(hosts), n_granules)
    if plan.n_hosts <= 1:
        return None
    plan.validate(n_granules)
    return plan


def resolve_base_port(port) -> int:
    """Explicit arg > ``REPRO_BRIDGE_PORT`` env > 0 (ephemeral)."""
    if port is not None:
        return int(port)
    return int(os.environ.get("REPRO_BRIDGE_PORT", "0") or 0)


# ------------------------------------------------------------------ links
@dataclasses.dataclass(frozen=True)
class Link:
    """One TCP link between a host pair, carrying every boundary channel
    whose endpoints straddle that pair.  ``chans`` is a tuple of
    ``(chan, src_host)`` sorted by channel id."""

    link: int
    accept: str   # lower plan-order host: binds the listener
    dial: str
    chans: tuple

    @property
    def label(self) -> str:
        return f"link{self.link}:{self.accept}<->{self.dial}"

    def peer_of(self, host: str) -> str:
        return self.dial if host == self.accept else self.accept


def build_links(plan: HostPlan, chan_hosts: dict) -> tuple:
    """Deterministic link map from ``chan -> (src_host, dst_host)``.

    Every host computes this independently from the (deterministic)
    lowering + plan, so rendezvous only exchanges ports."""
    order = {h: i for i, h in enumerate(plan.hosts)}
    pairs: dict = {}
    for c, (sh, dh) in sorted(chan_hosts.items()):
        if sh == dh:
            continue
        a, b = sorted((sh, dh), key=order.__getitem__)
        pairs.setdefault((a, b), []).append((c, sh))
    links = []
    for i, (a, b) in enumerate(sorted(pairs, key=lambda p: (order[p[0]],
                                                            order[p[1]]))):
        links.append(Link(i, a, b, tuple(sorted(pairs[(a, b)]))))
    return tuple(links)


# --------------------------------------------------------- control links
class CtlConn:
    """Framed pickled control messages over a fleet TCP socket.

    One message per frame (``bridge.FLAVOR_CTL``); ``poll`` lets the
    leader watch for early ``("fault", ...)`` frames from a follower
    while it is blocked on something else."""

    def __init__(self, sock: socket.socket):
        from .bridge import FrameReader

        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.setblocking(False)
        self.sock = sock
        self._reader = FrameReader()
        self._msgs: list = []

    def fileno(self) -> int:
        return self.sock.fileno()

    def send(self, obj) -> None:
        data = pickle.dumps(obj)
        from .bridge import _FRAME, FLAVOR_CTL

        hdr = _FRAME.pack(FLAVOR_CTL, 0, 0, len(data))
        self.sock.setblocking(True)
        try:
            self.sock.sendall(hdr + data)
        finally:
            self.sock.setblocking(False)

    def _pump(self, timeout: float) -> None:
        r, _, _ = select.select([self.sock], [], [], timeout)
        if not r:
            return
        try:
            data = self.sock.recv(1 << 16)
        except BlockingIOError:
            return
        if not data:
            raise ConnectionError("control link closed by peer")
        self._reader.feed(data)
        while True:
            f = self._reader.next_frame()
            if f is None:
                break
            self._msgs.append(pickle.loads(f[3]))

    def poll(self, timeout: float = 0.0) -> bool:
        if not self._msgs:
            self._pump(timeout)
        return bool(self._msgs)

    def peek(self):
        """First buffered message without consuming it (None if none) —
        the leader's early-fault probe."""
        if not self._msgs:
            self._pump(0.0)
        return self._msgs[0] if self._msgs else None

    def take(self):
        """Consume the first buffered message (must exist — pair with
        ``poll``/``peek``)."""
        return self._msgs.pop(0)

    def recv(self, timeout: float | None = None):
        deadline = None if timeout is None else time.monotonic() + timeout
        while not self._msgs:
            remain = 0.2 if deadline is None else deadline - time.monotonic()
            if remain <= 0:
                raise TimeoutError("no control message within "
                                   f"{timeout}s")
            self._pump(min(remain, 0.2))
        return self._msgs.pop(0)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def accept_followers(listener: socket.socket, expected: tuple, token: str,
                     timeout: float, on_wait=None) -> dict:
    """Leader side of rendezvous: accept one hello per expected follower
    host, verify the incarnation token, return
    ``{host: (CtlConn, accept_ports)}``.  ``on_wait`` runs each poll tick
    (the leader uses it to notice a follower that died before dialing)."""
    conns: dict = {}
    deadline = time.monotonic() + timeout
    while len(conns) < len(expected):
        if on_wait is not None:
            on_wait()
        r, _, _ = select.select([listener], [], [], 0.2)
        if not r:
            if time.monotonic() > deadline:
                missing = sorted(set(expected) - set(conns))
                raise TimeoutError(
                    f"follower host(s) {missing} never dialed the fleet "
                    f"control listener within {timeout:.0f}s")
            continue
        sock, _ = listener.accept()
        ctl = CtlConn(sock)
        op, payload = ctl.recv(timeout=30.0)
        if (op != "hello" or payload.get("token") != token
                or payload.get("host") not in expected):
            ctl.close()  # stale incarnation or stranger: refuse
            continue
        conns[payload["host"]] = (ctl, payload.get("accept_ports", {}))
    return conns


# ------------------------------------------------------------ fault codec
def encode_fault(exc: BaseException) -> dict:
    """Typed fault payload for the control link (mirrors the worker pipe
    protocol, extended with the monitor's exception types)."""
    from .shmem import RingCorruptionError

    d = {"type": type(exc).__name__, "message": str(exc)}
    if isinstance(exc, WorkerDiedError):
        d.update(worker=exc.worker, reason=exc.reason,
                 log_tail=exc.log_tail, label=exc.label)
    elif isinstance(exc, FleetStallError):
        d.update(cycle=exc.cycle, details=exc.details)
    elif isinstance(exc, RingCorruptionError):
        d.update(args=exc.to_payload())
    return d


def decode_fault(d: dict, host: str = "") -> Exception:
    """Rebuild a follower's fault so it raises ON THE LEADER with the same
    type (recovery policy keys on isinstance) and a host-tagged label."""
    from .shmem import RingCorruptionError, RingTimeout

    t = d.get("type")
    label = d.get("label")
    if host and label:
        label = f"{label} [host {host}]"
    if t == "LinkDownError":
        return LinkDownError(d["worker"], d["reason"],
                             d.get("log_tail", ""), label=label)
    if t == "WorkerDiedError":
        return WorkerDiedError(d["worker"], d["reason"],
                               d.get("log_tail", ""), label=label)
    if t == "FleetStallError":
        return FleetStallError(d["cycle"], d["details"])
    if t == "RingCorruptionError":
        return RingCorruptionError(**d["args"])
    if t == "RingTimeout":
        return RingTimeout(d.get("message", "ring timeout on follower"))
    msg = d.get("message", "")
    return RuntimeError(f"follower {host or '?'} fault {t}: {msg}")


# -------------------------------------------------------------- followers
@dataclasses.dataclass(frozen=True)
class FollowerBoot:
    """Spawn args for one follower launcher process (picklable)."""

    host: str
    leader_addr: tuple        # ("127.0.0.1", ctl_port)
    token: str
    build: bytes              # pickled (graph, partition, engine kwargs)
    timeout: float
    incarnation: int = 0      # leader's restart count (arms :r<N> faults)


def follower_entry(boot_pickle: bytes, log_path: str | None) -> None:
    """Follower launcher process entry: dial the leader, build the
    host-local ``ProcsEngine`` (same graph, same lowering, restricted to
    this host's granules), rendezvous the bridges, then serve the control
    protocol until "exit".  Any local fleet fault travels to the leader
    as a typed ``("fault", ...)`` frame; the follower then parks until
    the leader tears the incarnation down."""
    boot: FollowerBoot = pickle.loads(boot_pickle)
    if log_path:
        f = open(log_path, "a", buffering=1)
        os.dup2(f.fileno(), 1)
        os.dup2(f.fileno(), 2)
        sys.stdout = os.fdopen(1, "w", buffering=1)
        sys.stderr = os.fdopen(2, "w", buffering=1)
    print(f"[follower {boot.host}] dialing leader {boot.leader_addr}",
          flush=True)
    from .bridge import connect_retry

    ctl = None
    engine = None
    try:
        ctl = CtlConn(connect_retry(tuple(boot.leader_addr),
                                    max(boot.timeout, 300.0)))
        from .launcher import ProcsEngine

        graph, partition, kwargs = pickle.loads(boot.build)
        engine = ProcsEngine(graph, partition, host=boot.host, **kwargs)
        # the leader's restart count arms incarnation-scoped (:r<N>) fault
        # actions identically on every host — set before any worker spawns;
        # same for the incarnation token the bridges' HELLO handshake
        # verifies (every host must present the LEADER's token)
        engine._incarnation = boot.incarnation
        engine._fleet_token = boot.token
        engine.launch()
        ctl.send(("hello", {"host": boot.host, "token": boot.token,
                            "accept_ports": engine._accept_ports}))
        op, payload = ctl.recv(timeout=max(boot.timeout, 600.0))
        if op != "rendezvous":
            raise RuntimeError(f"expected rendezvous, got {op!r}")
        engine._finish_rendezvous(payload)
        ctl.send(("ok", {"ready": boot.host}))
        print(f"[follower {boot.host}] up: workers "
              f"{sorted(engine._local_ws)}, {len(engine._bridge_procs)} "
              f"bridge(s)", flush=True)
        _serve(ctl, engine, boot)
        print(f"[follower {boot.host}] clean exit", flush=True)
    except (ConnectionError, TimeoutError) as e:
        # Leader gone (or never reachable): nothing to report to.
        print(f"[follower {boot.host}] control link lost: {e}", flush=True)
        if engine is not None:
            engine.close()
        os._exit(1)
    except Exception as e:  # noqa: BLE001 — reported to the leader
        traceback.print_exc()
        try:
            if ctl is not None:
                ctl.send(("fault", encode_fault(e)))
        except Exception:
            pass
        if engine is not None:
            engine.close()
        os._exit(1)
    finally:
        if engine is not None:
            engine.close()
        if ctl is not None:
            ctl.close()


def _serve(ctl: CtlConn, engine, boot: FollowerBoot) -> None:
    """The follower's command loop: one engine op per control frame."""
    from .shmem import RingCorruptionError, RingTimeout

    while True:
        msg = ctl.recv(timeout=None)
        op, args = msg[0], msg[1:]
        if op == "exit":
            ctl.send(("ok", None))
            return
        try:
            ctl.send(("ok", engine._fleet_dispatch(op, args)))
        except (WorkerDiedError, FleetStallError, RingCorruptionError,
                RingTimeout) as e:
            traceback.print_exc()
            ctl.send(("fault", encode_fault(e)))
            _park(ctl)
            return
        except Exception:  # noqa: BLE001 — reported to the leader
            tb = traceback.format_exc()
            sys.stderr.write(tb)
            ctl.send(("err", tb))


def _park(ctl: CtlConn) -> None:
    """After reporting a fault: the local engine is closed; wait (bounded)
    for the leader's teardown "exit" so the leader never races a
    half-dead follower during re-rendezvous."""
    deadline = time.monotonic() + 600.0
    try:
        while time.monotonic() < deadline:
            msg = ctl.recv(timeout=1.0) if ctl.poll(0.2) else None
            if msg is None:
                continue
            if msg[0] == "exit":
                ctl.send(("ok", None))
                return
            ctl.send(("fault", {"type": "RuntimeError",
                                "message": "follower is faulted"}))
    except (ConnectionError, TimeoutError):
        return


__all__ = [
    "CtlConn", "FollowerBoot", "HostPlan", "Link", "accept_followers",
    "build_links", "decode_fault", "encode_fault", "follower_entry",
    "resolve_base_port", "resolve_host_plan",
]

"""Deterministic, plan-driven fault injection for the procs fleet (ISSUE 8).

The paper's headline run loses cloud workers routinely; drilling the
recovery path requires *reproducible* losses.  A fault plan is a comma-
separated list of actions, each ``kind:worker@epoch`` with optional
``:``-separated modifiers, e.g.::

    REPRO_FAULT_PLAN="kill:1@5"            # SIGKILL worker 1 before epoch 5
    REPRO_FAULT_PLAN="exit0:2@3"           # worker 2 exits CLEANLY mid-run
    REPRO_FAULT_PLAN="hang:0@4"            # worker 0 stops dead (no beats)
    REPRO_FAULT_PLAN="slow:1@2:0.05"       # +50ms per epoch from epoch 2 on
    REPRO_FAULT_PLAN="mute:1@2"            # worker 1 drops heartbeats
    REPRO_FAULT_PLAN="corrupt:0@2:c7"      # flip a byte in worker 0's next
                                           #   slab push on channel 7
    REPRO_FAULT_PLAN="kill:1@5, kill:1@9:r1"  # second kill arms only in
                                           #   fleet incarnation 1 (post-
                                           #   recovery), so drills can
                                           #   fault the REPLAY too
    REPRO_FAULT_PLAN="linkkill:0@3"        # kill bridge LINK 0's proxy at
                                           #   the epoch-3 boundary (multi-
                                           #   host fleets; see LINK_KINDS)

Modifiers: ``r<N>`` — the fleet incarnation (restart count) the action
arms in, default 0, so a fired kill does not re-fire during the recovery
replay; ``c<N>`` — a channel id (``corrupt``); a bare float — seconds
(``slow``).

Execution is epoch-deterministic: each worker evaluates its actions at
the top of ``one_epoch`` against its own ``epochs_done`` counter, through
the same ``fault_tolerance.FailureInjector`` trigger the training loop
uses (fire-once semantics), so a drill is bit-reproducible regardless of
fleet interleaving.  The launcher filters the plan per worker and per
incarnation at spawn time and ships the actions inside the spawn args —
workers never re-parse the environment (no double-fire).
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass
from typing import Sequence

from .fault_tolerance import FailureInjector

#: Link (bridge-proxy) fault kinds: the action's target index is a
#: BRIDGE LINK index (``runtime.fleet`` link map order), not a worker —
#: ``linkkill:0@3`` kills link 0's bridge proxy at the epoch-3 command
#: boundary, ``linkslow:0@3:0.05`` stalls its pump 50ms, ``linkcorrupt``
#: flips a byte in its next forwarded slab frame ON THE WIRE (the far
#: consumer's seq+crc verification trips — end-to-end detection).  The
#: launcher executes these at run boundaries; workers never see them.
LINK_KINDS = ("linkkill", "linkslow", "linkcorrupt")

KINDS = ("kill", "exit0", "hang", "slow", "mute", "corrupt") + LINK_KINDS

_TOKEN = re.compile(r"^(?P<kind>[a-z0-9]+):(?P<worker>\d+)@(?P<epoch>\d+)"
                    r"(?P<mods>(?::[^:,\s]+)*)$")


@dataclass(frozen=True)
class FaultAction:
    """One planned fault: do ``kind`` to ``worker`` just before it runs
    epoch ``epoch`` (its local ``epochs_done`` counter), in fleet
    incarnation ``restart``."""
    kind: str
    worker: int
    epoch: int
    arg: float | None = None   # slow: seconds/epoch; corrupt: channel id
    restart: int = 0           # fleet incarnation this action arms in

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {KINDS}")


def parse_fault_plan(text: str) -> tuple[FaultAction, ...]:
    """Parse a ``REPRO_FAULT_PLAN`` string into actions (see module doc)."""
    actions = []
    for token in re.split(r"[,\s]+", text.strip()):
        if not token:
            continue
        m = _TOKEN.match(token)
        if m is None:
            raise ValueError(
                f"bad fault-plan token {token!r}; expected "
                "kind:worker@epoch[:c<chan>][:r<restart>][:<seconds>]")
        arg, restart = None, 0
        for mod in m.group("mods").split(":"):
            if not mod:
                continue
            if re.fullmatch(r"r\d+", mod):
                restart = int(mod[1:])
            elif re.fullmatch(r"c\d+", mod):
                arg = float(mod[1:])
            else:
                arg = float(mod)  # raises ValueError on junk
        actions.append(FaultAction(m.group("kind"), int(m.group("worker")),
                                   int(m.group("epoch")), arg, restart))
    return tuple(actions)


def resolve_fault_plan(plan) -> tuple[FaultAction, ...]:
    """Resolve a constructor argument / env var into actions.

    Explicit non-None argument wins (a plan string or a sequence of
    ``FaultAction``); otherwise ``REPRO_FAULT_PLAN``; otherwise empty —
    the same precedence as the other runtime env knobs."""
    if plan is None:
        plan = os.environ.get("REPRO_FAULT_PLAN", "")
    if isinstance(plan, str):
        return parse_fault_plan(plan)
    return tuple(plan)


def actions_for(plan: Sequence[FaultAction], worker: int,
                incarnation: int) -> tuple[FaultAction, ...]:
    """The subset of a plan armed for one worker in one fleet incarnation
    (link actions are launcher-executed and never ship to workers)."""
    return tuple(a for a in plan
                 if a.worker == worker and a.restart == incarnation
                 and a.kind not in LINK_KINDS)


def split_plan(plan: Sequence[FaultAction],
               ) -> tuple[tuple[FaultAction, ...], tuple[FaultAction, ...]]:
    """(worker actions, link actions) — link faults target bridge links
    and are executed by the launcher at run boundaries, everything else
    ships to the targeted worker at spawn time."""
    return (tuple(a for a in plan if a.kind not in LINK_KINDS),
            tuple(a for a in plan if a.kind in LINK_KINDS))


class WorkerFaultInjector:
    """Executes a worker's armed actions at epoch boundaries.

    Built on ``FailureInjector`` (fire-once per action); the worker calls
    ``before_epoch(worker)`` at the top of every epoch."""

    def __init__(self, actions: Sequence[FaultAction]):
        self._worker = None
        self._armed = [
            (a, FailureInjector(fail_at=(a.epoch,),
                                on_fail=self._executor(a)))
            for a in actions
        ]

    def __bool__(self):
        return bool(self._armed)

    def before_epoch(self, worker) -> None:
        self._worker = worker
        for _, inj in self._armed:
            inj.maybe_fail(worker.epochs_done)

    # ------------------------------------------------------------- executors
    def _executor(self, a: FaultAction):
        return lambda _step: getattr(self, f"_do_{a.kind}")(a)

    def _log(self, a: FaultAction, what: str) -> None:
        import sys
        print(f"[faultinject] epoch {self._worker.epochs_done}: {what} "
              f"({a.kind}:{a.worker}@{a.epoch})", flush=True)
        sys.stderr.flush()

    def _do_kill(self, a: FaultAction) -> None:
        import signal
        self._log(a, "SIGKILL self")
        os.kill(os.getpid(), signal.SIGKILL)

    def _do_exit0(self, a: FaultAction) -> None:
        # The satellite regression: a CLEAN exit mid-run must still be
        # flagged by ProcessMonitor.check (exitcode 0 is not innocence).
        import sys
        self._log(a, "clean os._exit(0) mid-run")
        sys.stdout.flush()
        sys.stderr.flush()
        os._exit(0)

    def _do_hang(self, a: FaultAction) -> None:
        import time
        self._log(a, "hanging forever (heartbeats stop)")
        time.sleep(1e8)

    def _do_slow(self, a: FaultAction) -> None:
        self._worker.slow_per_epoch = float(a.arg if a.arg is not None
                                            else 0.05)
        self._log(a, f"straggling +{self._worker.slow_per_epoch}s/epoch")

    def _do_mute(self, a: FaultAction) -> None:
        self._worker.hb_muted = True
        self._log(a, "dropping heartbeats (process stays alive)")

    def _do_corrupt(self, a: FaultAction) -> None:
        w = self._worker
        chan = int(a.arg) if a.arg is not None else None
        ring = w.corruptible_ring(chan)
        ring.corrupt_next_push()
        self._log(a, f"corrupting next slab push on {ring.label}")

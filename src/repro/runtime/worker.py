"""Per-granule worker process — a free-running prebuilt granule simulator
(paper §III-F / §IV-B; DESIGN.md §Runtime).

Each worker owns ONE granule of a partitioned ``ChannelGraph``: the
granule-local queues and block states, stepped by exactly the same
``granule_local_cycle`` body the shard_map engines use.  The worker
free-runs epochs — ``K_inner`` local cycles, then per-tier exchanges over
shared-memory rings — gated only by its own ingress/egress credits.
There is no global barrier anywhere: a worker waits only when one of ITS
channels' rings is empty (producer behind) or full (consumer behind), so
two granules drift apart by up to their connecting channel's tier period,
and unconnected granules drift arbitrarily (the paper's "simulations run
as fast as they can" free-running model, with the staleness bound made
explicit).

**Prebuilt-simulator cache** (the paper's flat-build-time claim): the
epoch stepper is AOT-compiled — ``jit(...).lower().compile()`` — from a
state *template* whose port/exchange tables are runtime inputs, so the
compiled artifact depends only on the granule's shape signature
(``PartitionLowering.granule_signature``): block kinds/configs, slot
counts, queue counts, tier rates.  N instances of the same block shape
therefore trace to the SAME jaxpr, the launcher compiles each distinct
signature once, and every worker's own compile is a hit in the JAX
persistent compilation cache — build time grows with *unique* granule
shapes, not with instance count (benchmarked in
``benchmarks/procs_runtime.py``).

Exchange protocol per boundary channel (bit-identical to the engines'
credit protocol, DESIGN.md §3): at the channel's tier cadence the sender
pops one credit record (pre-seeded with capacity-1 at reset), drains its
egress queue bounded by ``min(E_t, credit)``, and pushes one slab record;
the receiver pops one slab record per exchange, fills its ingress queue,
and pushes back its post-fill free space as the next credit.  One slab
record per exchange per channel — even when empty — is what makes the
free-running schedule deterministic and the traffic bit-identical to the
lockstep engines.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import time
import traceback
from typing import Any

import numpy as np

from ..core import queue as qmod
from ..core.struct import pytree_dataclass
from ..obs import telemetry as _telem
from .fault_tolerance import (
    OP_CREDIT_POP, OP_CREDIT_PUSH, OP_SLAB_POP, OP_SLAB_PUSH, encode_blocked,
)
from .shmem import RingCorruptionError, RingTimeout, ShmRing, slab_slot_bytes

PyTree = Any


def configure_compile_cache(cache_dir: str | None) -> None:
    """Point JAX's persistent compilation cache at ``cache_dir`` (shared by
    the launcher's prebuild pass and every worker, so each distinct granule
    signature is compiled once per cache, not once per process)."""
    if not cache_dir:
        return
    import jax

    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)


# ---------------------------------------------------------------- spec
@dataclasses.dataclass
class GroupSpec:
    """One block group's granule-local slice (all numpy, picklable)."""

    block: Any  # the Block instance (pickled by reference to its module)
    n_members: int  # GLOBAL member count (key-split shape, engine-invariant)
    n_slot: int
    member_of: np.ndarray  # (n_slot,) global member index (0 on padding)
    active: np.ndarray  # (n_slot,) bool
    rx_idx: np.ndarray  # (n_slot, n_in) local queue ids
    tx_idx: np.ndarray  # (n_slot, n_out)
    params_local: PyTree | None  # pre-sliced per-slot params (n_slot leading)


@dataclasses.dataclass
class TierSpec:
    """One tier's boundary channels as seen by this granule."""

    K: int
    E: int  # slab depth = min(period, capacity-1)
    egress_chans: tuple[int, ...]  # channel ids, canonical order
    egress_lqids: np.ndarray  # (n_e,) local queue ids
    ingress_chans: tuple[int, ...]
    ingress_lqids: np.ndarray


@dataclasses.dataclass
class GranuleSpec:
    """Everything a worker needs to build and free-run its granule."""

    granule: int
    signature: str
    payload_words: int
    capacity: int
    dtype: str
    n_local: int
    groups: list[GroupSpec]
    tiers: list[TierSpec]  # outermost first
    ext_ports: list[tuple[str, int, int, bool]]  # (name, chan, lqid, is_in)
    ring_prefix: str
    ring_depth: int
    timeout: float
    overlap: bool = False  # split issue/commit exchange (send-early/receive-late)

    @property
    def cycles_per_epoch(self) -> int:
        out = 1
        for t in self.tiers:
            out *= t.K
        return out


@dataclasses.dataclass
class BatchSpec:
    """``nb`` same-signature granules stepped as ONE leading-axis batch
    (``ProcsEngine(batch_signatures=True)``).

    All member specs share ``PartitionLowering.granule_signature`` — same
    block shapes, per-tier egress/ingress channel *counts* and ext-port
    count — so their epoch programs are identical and their per-granule
    tables stack into (nb, ...) arrays consumed by one vmapped stepper.
    The rings stay per channel; only the dispatch is batched.
    """

    members: tuple[int, ...]
    specs: list[GranuleSpec]

    @property
    def signature(self) -> str:
        return self.specs[0].signature


def data_ring_name(prefix: str, chan: int) -> str:
    return f"{prefix}d{chan}"


def credit_ring_name(prefix: str, chan: int) -> str:
    return f"{prefix}c{chan}"


def ext_ring_name(prefix: str, chan: int) -> str:
    return f"{prefix}x{chan}"


def heartbeat_name(prefix: str) -> str:
    return f"{prefix}hb"


# ------------------------------------------------------------- granule sim
class GranuleSim:
    """Pure compute half of a worker: granule state + AOT-compiled steppers.

    Constructed by workers AND by the launcher's prebuild pass (one
    instance per distinct signature) — both compile the same functions
    from the same templates, which is what makes the persistent-cache
    keying line up.
    """

    def __init__(self, spec: GranuleSpec):
        import jax
        import jax.numpy as jnp

        self.spec = spec
        self.jax, self.jnp = jax, jnp
        self.np_dtype = np.dtype(spec.dtype)
        self.dtype = jnp.dtype(self.np_dtype)
        self.W = spec.payload_words
        self.capacity = spec.capacity
        self.n_local = spec.n_local
        self.K_tiers = tuple(t.K for t in spec.tiers)
        self.program = self._build_program()
        self._compiled: dict[Any, Any] = {}

    # ---------------------------------------------------------- the program
    def _build_program(self) -> list[tuple[str, int]]:
        """Flatten the nested tier rounds into ("C", n_cycles) / ("X", tier)
        ops — the same schedule as ``GraphEngine._tier_round``, with
        trailing tiers that have no channels ON THIS GRANULE folded into
        one contiguous cycle block (pure local compute chunks bigger).

        With ``spec.overlap`` the serial exchanges are rewritten to split
        ("XI", t) / ("XC", t) phases by ``granule_step.overlap_program`` —
        at a multi-tier boundary all issues precede all commits, so every
        outgoing slab is pushed before the worker blocks on any incoming
        one (send-early/receive-late).  The compiled stepper set is
        unchanged: XI reuses the drain stepper, XC the fill stepper."""
        tiers = self.spec.tiers
        fold_from = len(tiers)
        while fold_from > 0 and not (
            tiers[fold_from - 1].egress_chans or tiers[fold_from - 1].ingress_chans
        ):
            fold_from -= 1

        def tier_round(t: int) -> list[tuple[str, int]]:
            if t >= fold_from:
                n = 1
                for tt in tiers[t:]:
                    n *= tt.K
                return [("C", n)] if n else []
            ops: list[tuple[str, int]] = []
            if t == len(tiers) - 1:
                ops.append(("C", tiers[t].K))
            else:
                for _ in range(tiers[t].K):
                    ops.extend(tier_round(t + 1))
            ops.append(("X", t))
            return ops

        program = tier_round(0)
        if self.spec.overlap:
            from ..kernels.granule_step import overlap_program

            program = overlap_program(program)
        return program

    # ------------------------------------------------------------- templates
    def init(self, key_data: np.ndarray,
             group_params: list[PyTree | None] | None = None):
        """Initial WorkerState — the same per-member key derivation as
        ``NetworkSim.init`` / ``GraphEngine._init_block_states`` (fold_in
        group index, split over GLOBAL member count, slice local members),
        so per-member init is bit-identical across all five engines."""
        jax, jnp = self.jax, self.jnp
        key = jax.random.wrap_key_data(jnp.asarray(key_data))
        states = []
        for gi, gs in enumerate(self.spec.groups):
            blk = gs.block
            params = gs.params_local
            if group_params is not None and group_params[gi] is not None:
                params = group_params[gi]
            keys = jax.random.split(jax.random.fold_in(key, gi), gs.n_members)
            keys_l = keys[jnp.asarray(gs.member_of)]
            init = jax.vmap(blk.init_state)
            if params is not None:
                params_l = jax.tree.map(jnp.asarray, params)
                st = init(keys_l, params_l)
            else:
                st = init(keys_l)
            states.append(st)
        queues = qmod.make_queues(
            self.n_local, self.W, self.capacity, self.dtype
        )
        from ..core.distributed import _dealias_for_donation

        # block init_state may legitimately reuse one array for several
        # fields; every compiled stepper donates its input, so aliased
        # buffers must be split once here (same rule as the engines)
        return _dealias_for_donation(WorkerState(
            queues=queues,
            block_states=tuple(states),
            cycle=jnp.zeros((), jnp.int32),
            epoch=jnp.zeros((), jnp.int32),
            tables=self.tables(),
        ))

    def tables(self):
        """Granule-local tables as a GraphTables pytree (runtime INPUTS to
        the compiled steppers — the prebuilt-cache property)."""
        from ..core.distributed import GraphTables

        jnp = self.jnp
        return GraphTables(
            rx_idx=tuple(jnp.asarray(g.rx_idx, jnp.int32) for g in self.spec.groups),
            tx_idx=tuple(jnp.asarray(g.tx_idx, jnp.int32) for g in self.spec.groups),
            active=tuple(jnp.asarray(g.active) for g in self.spec.groups),
            send_idx=tuple(jnp.asarray(t.egress_lqids, jnp.int32)
                           for t in self.spec.tiers),
            send_mask=tuple(jnp.ones((len(t.egress_chans),), bool)
                            for t in self.spec.tiers),
            recv_idx=tuple(jnp.asarray(t.ingress_lqids, jnp.int32)
                           for t in self.spec.tiers),
            recv_mask=tuple(jnp.ones((len(t.ingress_chans),), bool)
                            for t in self.spec.tiers),
        )

    # ----------------------------------------------------- compiled steppers
    def _cycles_fn(self, n: int):
        from ..core.distributed import granule_local_cycle

        groups = [g.block for g in self.spec.groups]

        class _G:  # granule_local_cycle wants .block per group
            def __init__(self, block):
                self.block = block

        gdefs = [_G(b) for b in groups]
        jax = self.jax

        def run(st):
            return jax.lax.scan(
                lambda s, _: (
                    granule_local_cycle(gdefs, self.n_local, self.W,
                                        self.dtype, s),
                    None,
                ),
                st, None, length=n,
            )[0]

        return run

    def _drain_fn(self, t: int):
        E = self.spec.tiers[t].E
        jnp = self.jnp

        def drain(st, credits):
            sidx = st.tables.send_idx[t]
            q = st.queues
            sub = qmod.QueueArray(
                buf=q.buf[sidx], head=q.head[sidx], tail=q.tail[sidx],
                capacity=q.capacity,
            )
            sub2, slab, cnt = qmod.drain(sub, E, limit=credits)
            q2 = q.replace(tail=q.tail.at[sidx].set(sub2.tail))
            return st.replace(queues=q2), slab, cnt.astype(jnp.int32)

        return drain

    def _fill_fn(self, t: int):
        from ..core.distributed import qmod_fill_at

        jnp = self.jnp
        cap = self.capacity

        def fill(st, slab, cnt):
            ridx = st.tables.recv_idx[t]
            q = qmod_fill_at(st.queues, ridx, slab, cnt)
            free = (cap - 1) - (q.head[ridx] - q.tail[ridx]) % cap
            return st.replace(queues=q), free.astype(jnp.int32)

        return fill

    def _ingest_fn(self):
        cap = self.capacity

        def ingest(st, lqid, payloads, avail):
            q = st.queues
            buf, head, n = qmod.fill_single(
                q.buf[lqid], q.head[lqid], q.tail[lqid], cap, payloads,
                limit=avail,
            )
            q2 = q.replace(
                buf=q.buf.at[lqid].set(buf), head=q.head.at[lqid].set(head)
            )
            return st.replace(queues=q2), n

        return ingest

    def _flush_fn(self):
        cap = self.capacity

        def flush(st, lqid, room):
            q = st.queues
            pays, tail, cnt = qmod.drain_single(
                q.buf[lqid], q.head[lqid], q.tail[lqid], cap, cap - 1,
                limit=room,
            )
            q2 = q.replace(tail=q.tail.at[lqid].set(tail))
            return st.replace(queues=q2), pays, cnt

        return flush

    def _epoch_tick_fn(self):
        def tick(st):
            return st.replace(epoch=st.epoch + 1)

        return tick

    def prebuild(self, template=None) -> dict:
        """AOT-compile every stepper this granule's epoch program needs.

        ``jit(fn).lower(template).compile()`` — the compiled artifacts land
        in the JAX persistent compilation cache (``configure_compile_cache``),
        so the next process with the same signature compiles ~for free.
        Returns {"seconds": total, "n_functions": count}.
        """
        jax, jnp = self.jax, self.jnp
        if template is None:
            template = self.init(
                np.asarray(jax.random.key_data(jax.random.key(0)))
            )
        t0 = time.perf_counter()
        n_fns = 0
        lengths = sorted({n for op, n in self.program if op == "C"})
        for n in lengths:
            self._compiled[("C", n)] = (
                jax.jit(self._cycles_fn(n), donate_argnums=0)
                .lower(template).compile()
            )
            n_fns += 1
        for t, ts in enumerate(self.spec.tiers):
            if ts.egress_chans:
                creds = jax.ShapeDtypeStruct((len(ts.egress_chans),), jnp.int32)
                self._compiled[("D", t)] = (
                    jax.jit(self._drain_fn(t), donate_argnums=0)
                    .lower(template, creds).compile()
                )
                n_fns += 1
            if ts.ingress_chans:
                n_in = len(ts.ingress_chans)
                slab = jax.ShapeDtypeStruct((n_in, ts.E, self.W), self.dtype)
                cnt = jax.ShapeDtypeStruct((n_in,), jnp.int32)
                self._compiled[("F", t)] = (
                    jax.jit(self._fill_fn(t), donate_argnums=0)
                    .lower(template, slab, cnt).compile()
                )
                n_fns += 1
        if self.spec.ext_ports:
            lqid = jax.ShapeDtypeStruct((), jnp.int32)
            scal = jax.ShapeDtypeStruct((), jnp.int32)
            pays = jax.ShapeDtypeStruct(
                (self.capacity - 1, self.W), self.dtype
            )
            self._compiled["ingest"] = (
                jax.jit(self._ingest_fn(), donate_argnums=0)
                .lower(template, lqid, pays, scal).compile()
            )
            self._compiled["flush"] = (
                jax.jit(self._flush_fn(), donate_argnums=0)
                .lower(template, lqid, scal).compile()
            )
            n_fns += 2
        self._compiled["tick"] = (
            jax.jit(self._epoch_tick_fn(), donate_argnums=0)
            .lower(template).compile()
        )
        n_fns += 1
        return {"seconds": time.perf_counter() - t0, "n_functions": n_fns}


class BatchedGranuleSim(GranuleSim):
    """GranuleSim over a signature batch: state leaves carry a leading
    (nb,) axis and every stepper is the base stepper under ``jax.vmap`` —
    one dispatch advances all nb granules (ISSUE 6's signature-batched
    stepping, procs flavor).  Host-facing ext-port ops address one batch
    row at a time (``row`` becomes a runtime input)."""

    def __init__(self, bspec: BatchSpec):
        self.bspec = bspec
        self.nb = len(bspec.specs)
        self.row_sims = [GranuleSim(s) for s in bspec.specs]
        super().__init__(bspec.specs[0])
        # same signature => same per-tier channel counts => same program
        assert all(rs.program == self.program for rs in self.row_sims), (
            "signature batch members disagree on epoch program"
        )

    def init(self, key_data: np.ndarray,
             group_params: list[list | None] | None = None):
        jnp = self.jnp
        states = [
            self.row_sims[r].init(
                key_data,
                group_params[r] if group_params is not None else None,
            )
            for r in range(self.nb)
        ]
        from ..core.distributed import _dealias_for_donation

        return _dealias_for_donation(
            self.jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        )

    def _cycles_fn(self, n: int):
        jax = self.jax
        row = super()._cycles_fn(1)

        def run(st):
            return jax.lax.scan(
                lambda s, _: (jax.vmap(row)(s), None), st, None, length=n
            )[0]

        return run

    def _drain_fn(self, t: int):
        return self.jax.vmap(super()._drain_fn(t))

    def _fill_fn(self, t: int):
        return self.jax.vmap(super()._fill_fn(t))

    def _ingest_fn(self):
        cap = self.capacity

        def ingest(st, row, lqid, payloads, avail):
            q = st.queues
            buf, head, n = qmod.fill_single(
                q.buf[row, lqid], q.head[row, lqid], q.tail[row, lqid], cap,
                payloads, limit=avail,
            )
            q2 = q.replace(
                buf=q.buf.at[row, lqid].set(buf),
                head=q.head.at[row, lqid].set(head),
            )
            return st.replace(queues=q2), n

        return ingest

    def _flush_fn(self):
        cap = self.capacity

        def flush(st, row, lqid, room):
            q = st.queues
            pays, tail, cnt = qmod.drain_single(
                q.buf[row, lqid], q.head[row, lqid], q.tail[row, lqid], cap,
                cap - 1, limit=room,
            )
            q2 = q.replace(tail=q.tail.at[row, lqid].set(tail))
            return st.replace(queues=q2), pays, cnt

        return flush

    def prebuild(self, template=None) -> dict:
        jax, jnp = self.jax, self.jnp
        if template is None:
            template = self.init(
                np.asarray(jax.random.key_data(jax.random.key(0)))
            )
        t0 = time.perf_counter()
        n_fns = 0
        for n in sorted({n for op, n in self.program if op == "C"}):
            self._compiled[("C", n)] = (
                jax.jit(self._cycles_fn(n), donate_argnums=0)
                .lower(template).compile()
            )
            n_fns += 1
        for t, ts in enumerate(self.spec.tiers):
            if ts.egress_chans:
                creds = jax.ShapeDtypeStruct(
                    (self.nb, len(ts.egress_chans)), jnp.int32
                )
                self._compiled[("D", t)] = (
                    jax.jit(self._drain_fn(t), donate_argnums=0)
                    .lower(template, creds).compile()
                )
                n_fns += 1
            if ts.ingress_chans:
                n_in = len(ts.ingress_chans)
                slab = jax.ShapeDtypeStruct(
                    (self.nb, n_in, ts.E, self.W), self.dtype
                )
                cnt = jax.ShapeDtypeStruct((self.nb, n_in), jnp.int32)
                self._compiled[("F", t)] = (
                    jax.jit(self._fill_fn(t), donate_argnums=0)
                    .lower(template, slab, cnt).compile()
                )
                n_fns += 1
        if any(s.ext_ports for s in self.bspec.specs):
            scal = jax.ShapeDtypeStruct((), jnp.int32)
            pays = jax.ShapeDtypeStruct(
                (self.capacity - 1, self.W), self.dtype
            )
            self._compiled["ingest"] = (
                jax.jit(self._ingest_fn(), donate_argnums=0)
                .lower(template, scal, scal, pays, scal).compile()
            )
            self._compiled["flush"] = (
                jax.jit(self._flush_fn(), donate_argnums=0)
                .lower(template, scal, scal, scal).compile()
            )
            n_fns += 2
        self._compiled["tick"] = (
            jax.jit(self._epoch_tick_fn(), donate_argnums=0)
            .lower(template).compile()
        )
        n_fns += 1
        return {"seconds": time.perf_counter() - t0, "n_functions": n_fns}


@pytree_dataclass
class WorkerState:
    """One granule's device state (no leading device dims) — the squeezed
    analogue of ``GraphState``, stepped by the shared
    ``granule_local_cycle``.  ``tables`` ride in the state so they are
    runtime inputs to the compiled steppers (the prebuilt-cache property);
    credits do NOT — they live in the shm credit rings between exchanges."""

    queues: qmod.QueueArray  # (n_local, capacity, W)
    block_states: tuple  # per group: leaves (n_slot, ...)
    cycle: Any  # () int32
    epoch: Any  # () int32
    tables: Any  # GraphTables (granule-local)


# ----------------------------------------------------------------- worker
class Worker:
    """The free-running process: rings + compiled steppers + command loop."""

    def __init__(self, spec: GranuleSpec, conn, hb: np.ndarray | None,
                 faults=()):
        self.spec = spec
        self.conn = conn
        # (4,) f64 view: [epochs_completed, wallclock, blocked-status, spare]
        self.hb = hb
        self.sim = GranuleSim(spec)
        self.state = None
        self.epochs_done = 0
        self.timeout = spec.timeout
        # Ring waits get twice the launcher's heartbeat timeout: when the
        # whole fleet blocks (deadlock), the launcher's stall diagnoser
        # fires FIRST and names the credit cycle; the worker-side
        # RingTimeout is the backstop, not the headline diagnosis.
        self.ring_timeout = spec.timeout * 2
        self.wait_s = 0.0  # time blocked on peer rings (credits/slabs)
        self.run_s = 0.0  # wallclock inside "run" commands
        self.telem = None  # TelemetryWriter once the entry attaches a ring
        self._init_faults(faults)
        cap_b = spec.capacity
        itemsize = np.dtype(spec.dtype).itemsize
        self.rings: dict[tuple[str, int], ShmRing] = {}
        for ts in spec.tiers:
            for c in ts.egress_chans + ts.ingress_chans:
                self.rings[("d", c)] = ShmRing.attach(
                    data_ring_name(spec.ring_prefix, c),
                    spec.ring_depth + 1, slab_slot_bytes(ts.E, spec.payload_words, itemsize),
                    checked=True, label=f"slab:c{c}",
                )
                self.rings[("c", c)] = ShmRing.attach(
                    credit_ring_name(spec.ring_prefix, c),
                    spec.ring_depth + 2, 4,
                )
        for name, chan, lqid, is_in in spec.ext_ports:
            self.rings[("x", chan)] = ShmRing.attach(
                ext_ring_name(spec.ring_prefix, chan),
                cap_b, spec.payload_words * itemsize,
                checked=True, label=f"ext:{name}",
            )

    def _init_faults(self, faults) -> None:
        from .faultinject import WorkerFaultInjector

        self.injector = WorkerFaultInjector(faults) if faults else None
        self.slow_per_epoch = 0.0  # faultinject "slow" straggler knob
        self.hb_muted = False      # faultinject "mute" (drop heartbeats)

    def corruptible_ring(self, chan: int | None) -> ShmRing:
        """The data ring a ``corrupt`` fault targets: the given channel, or
        this worker's first egress channel when unspecified."""
        if chan is None:
            for ts in self.spec.tiers:
                if ts.egress_chans:
                    chan = ts.egress_chans[0]
                    break
        if chan is None or ("d", chan) not in self.rings:
            raise ValueError(f"no corruptible data ring for channel {chan}")
        return self.rings[("d", chan)]

    def beat(self) -> None:
        if self.hb is not None and not self.hb_muted:
            self.hb[0] = float(self.epochs_done)
            self.hb[1] = time.time()

    def _set_status(self, code: int) -> None:
        """Publish "blocked on ring X" (0 = running) in the heartbeat shm —
        the raw material of the launcher's credit wait-for graph."""
        if self.hb is not None:
            self.hb[2] = float(code)

    def _probe(self, gi: int, slot: int, row: int):
        import jax

        return jax.tree.map(lambda x: x[slot], self.state.block_states[gi])

    # ------------------------------------------------------------ the epoch
    def _ingest_ext(self) -> None:
        jnp = self.sim.jnp
        for name, chan, lqid, is_in in self.spec.ext_ports:
            if not is_in:
                continue
            ring = self.rings[("x", chan)]
            avail = ring.size()
            if not avail:
                continue
            k = min(avail, self.spec.capacity - 1)
            pays = ring.peek_packets(k, self.sim.np_dtype, self.sim.W)
            pad = np.zeros((self.spec.capacity - 1, self.sim.W),
                           self.sim.np_dtype)
            pad[:k] = pays
            self.state, n = self.sim._compiled["ingest"](
                self.state, jnp.int32(lqid), jnp.asarray(pad), jnp.int32(k)
            )
            ring.advance(int(n))

    def _flush_ext(self) -> None:
        """Move ext-out packets from the local queue into the host ring.

        Contract vs the in-process engines: the worker flushes at every
        boundary whether or not the host is draining, so an UNdrained
        output port buffers up to one extra ring (capacity-1 packets) of
        output before backpressuring the producer.  A host that drains at
        boundaries — the session scripts — therefore sees per-boundary
        bit-identical traffic; a host that lets output accumulate sees an
        identical packet *sequence* with producer stalls engaging one ring
        later (the same flavor of contract as the fused engine's
        capacity-2 cycle-accuracy clause; DESIGN.md §Runtime)."""
        jnp = self.sim.jnp
        for name, chan, lqid, is_in in self.spec.ext_ports:
            if is_in:
                continue
            ring = self.rings[("x", chan)]
            room = ring.free()
            if not room:
                continue
            self.state, pays, cnt = self.sim._compiled["flush"](
                self.state, jnp.int32(lqid), jnp.int32(room)
            )
            cnt = int(cnt)
            if cnt:
                landed = ring.push_packets(np.asarray(pays)[:cnt])
                assert landed == cnt  # room was the drain limit

    def _timed(self, fn, *args, status: int = 0):
        """Run one potentially-blocking ring op, accumulating its wallclock
        into ``wait_s`` (the procs blocking-wait metric; same accounting in
        serial and overlapped schedules, so the fraction is comparable).
        ``status`` publishes the blocked-on-ring word for the stall
        diagnoser; deliberately left set when the op raises, so a timed-out
        worker's last status word names the ring it died waiting on."""
        if status:
            self._set_status(status)
        t0 = time.perf_counter()
        try:
            out = fn(*args)
        finally:
            self.wait_s += time.perf_counter() - t0
        if status:
            self._set_status(0)
        return out

    def _pop_order(self, rings, codes=None):
        """Yield ring indices as each becomes non-empty (round-robin poll):
        the receive-late fill consumes whichever peer's slab lands first
        instead of serializing on channel order.  Poll time with no ring
        ready counts as blocking wait; past the deadline the remaining
        indices are yielded so the blocking pop raises ``RingTimeout``
        with its usual diagnostics."""
        pending = list(range(len(rings)))
        deadline = time.monotonic() + self.ring_timeout
        delay = 20e-6
        while pending:
            progressed = False
            for i in list(pending):
                if not rings[i].empty():
                    pending.remove(i)
                    progressed = True
                    yield i
            if pending and not progressed:
                if time.monotonic() > deadline:
                    while pending:
                        yield pending.pop(0)
                    return
                if codes is not None:
                    self._set_status(codes[pending[0]])
                t0 = time.perf_counter()
                time.sleep(delay)
                delay = min(delay * 2, 1e-3)
                self.wait_s += time.perf_counter() - t0
        if codes is not None:
            self._set_status(0)

    def _exchange_issue(self, t: int) -> None:
        """Window-end send: pop credits, drain egress queues, push slabs."""
        jnp = self.sim.jnp
        ts = self.spec.tiers[t]
        if not ts.egress_chans:
            return
        # pop one credit per egress channel: the receiver's post-fill
        # free space from the PREVIOUS exchange (seeded capacity-1)
        creds = np.array(
            [self._timed(self.rings[("c", c)].pop_u32_wait,
                         self.ring_timeout,
                         status=encode_blocked(OP_CREDIT_POP, c))
             for c in ts.egress_chans],
            np.int32,
        )
        self.state, slab, cnt = self.sim._compiled[("D", t)](
            self.state, jnp.asarray(creds)
        )
        slab = np.asarray(slab)
        cnt = np.asarray(cnt)
        for i, c in enumerate(ts.egress_chans):
            self._timed(self.rings[("d", c)].push_slab_wait,
                        int(cnt[i]), slab[i], self.ring_timeout,
                        status=encode_blocked(OP_SLAB_PUSH, c))

    def _exchange_commit(self, t: int) -> None:
        """Receive-late fill: pop slabs (first-ready order), fill ingress
        queues, push back post-fill free space as the next credits."""
        jnp = self.sim.jnp
        ts = self.spec.tiers[t]
        if not ts.ingress_chans:
            return
        n_in = len(ts.ingress_chans)
        slab_in = np.zeros((n_in, ts.E, self.sim.W), self.sim.np_dtype)
        cnt_in = np.zeros((n_in,), np.int32)
        rings = [self.rings[("d", c)] for c in ts.ingress_chans]
        codes = [encode_blocked(OP_SLAB_POP, c) for c in ts.ingress_chans]
        # receive-late is part of the overlap feature; the serial schedule
        # keeps strict channel-order blocking pops (the honest baseline)
        order = (self._pop_order(rings, codes) if self.spec.overlap
                 else range(n_in))
        for i in order:
            cnt_in[i], slab_in[i] = self._timed(
                rings[i].pop_slab_wait,
                (ts.E, self.sim.W), self.sim.np_dtype, self.ring_timeout,
                status=codes[i],
            )
        self.state, free = self.sim._compiled[("F", t)](
            self.state, jnp.asarray(slab_in), jnp.asarray(cnt_in)
        )
        free = np.asarray(free)
        for i, c in enumerate(ts.ingress_chans):
            self._timed(self.rings[("c", c)].push_u32,
                        int(free[i]), self.ring_timeout,
                        status=encode_blocked(OP_CREDIT_PUSH, c))

    def _exchange(self, t: int) -> None:
        self._exchange_issue(t)
        self._exchange_commit(t)

    def one_epoch(self) -> None:
        tl = self.telem
        if tl is not None and tl.enabled:
            return self._traced_epoch(tl)
        if self.injector is not None:
            # plan-driven faults fire at deterministic LOCAL epoch numbers,
            # before any of this epoch's effects — reproducible drills
            self.injector.before_epoch(self)
        if self.slow_per_epoch:
            time.sleep(self.slow_per_epoch)
        self._ingest_ext()
        for op, arg in self.sim.program:
            if op == "C":
                self.state = self.sim._compiled[("C", arg)](self.state)
            elif op == "XI":
                self._exchange_issue(arg)
            elif op == "XC":
                self._exchange_commit(arg)
            else:
                self._exchange(arg)
        self._flush_ext()
        self.state = self.sim._compiled["tick"](self.state)
        self.epochs_done += 1
        self.beat()

    def _traced_epoch(self, tl) -> None:
        """``one_epoch`` with per-phase telemetry records.  Mirrors the
        untraced walk exactly (same ring ops, same op order — traffic
        stays bit-identical); each phase costs one monotonic read and one
        non-blocking 48-byte ring push."""
        if self.injector is not None:
            self.injector.before_epoch(self)
        if self.slow_per_epoch:
            time.sleep(self.slow_per_epoch)
        wait0 = self.wait_s
        e0 = t0 = time.monotonic()
        self._ingest_ext()
        tl.phase(_telem.TEV_INGEST, 0.0, t0)
        for op, arg in self.sim.program:
            t0 = time.monotonic()
            if op == "C":
                self.state = self.sim._compiled[("C", arg)](self.state)
                tl.phase(_telem.TEV_STEP, float(arg), t0)
            elif op == "XI":
                self._exchange_issue(arg)
                tl.phase(_telem.TEV_ISSUE, float(arg), t0)
            elif op == "XC":
                self._exchange_commit(arg)
                tl.phase(_telem.TEV_COMMIT, float(arg), t0)
            else:
                self._exchange_issue(arg)
                tl.phase(_telem.TEV_ISSUE, float(arg), t0)
                t0 = time.monotonic()
                self._exchange_commit(arg)
                tl.phase(_telem.TEV_COMMIT, float(arg), t0)
        t0 = time.monotonic()
        self._flush_ext()
        tl.phase(_telem.TEV_FLUSH, 0.0, t0)
        self.state = self.sim._compiled["tick"](self.state)
        self.epochs_done += 1
        occ = n_d = 0
        for (kind, _c), ring in self.rings.items():
            if kind == "d":
                occ += ring.size()
                n_d += 1
        tl.emit(_telem.TEV_OCC, 0.0, time.monotonic(), 0.0,
                float(occ), float(n_d))
        tl.phase(_telem.TEV_EPOCH, float(self.epochs_done - 1), e0,
                 v0=self.wait_s - wait0)
        self.beat()

    # --------------------------------------------------------- command loop
    def serve(self) -> None:
        import jax

        while True:
            cmd = self.conn.recv()
            op = cmd[0]
            try:
                if op == "init":
                    _, key_data, group_params = cmd
                    self.state = self.sim.init(key_data, group_params)
                    self.epochs_done = 0
                    self.wait_s = 0.0
                    self.run_s = 0.0
                    self.beat()
                    self.conn.send(("ok", 0))
                elif op == "run":
                    t0 = time.perf_counter()
                    for _ in range(int(cmd[1])):
                        self.one_epoch()
                    self.run_s += time.perf_counter() - t0
                    self.conn.send(("ok", self.epochs_done))
                elif op == "probe":
                    _, gi, slot, *rest = cmd
                    out = jax.device_get(self._probe(
                        gi, slot, rest[0] if rest else 0
                    ))
                    self.conn.send(("ok", out))
                elif op == "view":
                    # the done-predicate view: tables are constants the
                    # launcher already holds, so strip them from the
                    # per-epoch pickle (it re-attaches its numpy copies)
                    self.conn.send(("ok", jax.device_get(
                        self.state.replace(tables=None)
                    )))
                elif op == "gather":
                    self.conn.send(("ok", jax.device_get(self.state)))
                elif op == "scatter":
                    _, tree, epochs = cmd
                    from ..core.distributed import _dealias_for_donation

                    self.state = _dealias_for_donation(jax.tree.map(
                        lambda x: self.sim.jnp.asarray(x), tree
                    ))
                    self.epochs_done = int(epochs)
                    self.beat()
                    self.conn.send(("ok", self.epochs_done))
                elif op == "stats":
                    self.conn.send(("ok", self._stats()))
                elif op == "telemetry":
                    on = bool(cmd[1])
                    if self.telem is not None:
                        self.telem.enabled = on
                    self.conn.send(("ok", on and self.telem is not None))
                elif op == "exit":
                    self.conn.send(("ok", None))
                    return
                else:
                    self.conn.send(("err", f"unknown command {op!r}"))
            except (RingCorruptionError, RingTimeout) as e:
                # recoverable fleet faults travel as a typed "fault" reply
                # (not a generic traceback) so the launcher can rebuild the
                # exception and route it into the recovery path
                sys.stderr.write(traceback.format_exc())
                sys.stderr.flush()
                payload = {"error": type(e).__name__, "message": str(e)}
                if isinstance(e, RingCorruptionError):
                    payload["args"] = e.to_payload()
                try:
                    self.conn.send(("fault", payload))
                except Exception:
                    return
            except Exception:  # noqa: BLE001 — reported to the launcher
                sys.stderr.write(traceback.format_exc())
                sys.stderr.flush()
                try:
                    self.conn.send(("err", traceback.format_exc()))
                except Exception:
                    return

    def _stats(self) -> dict:
        import jax

        q = jax.device_get(self.state.queues)
        size = (q.head - q.tail) % q.capacity
        ports = {}
        for name, chan, lqid, is_in in self.spec.ext_ports:
            ports[name] = {
                "occupancy": int(size[lqid]),
                "credit": int(q.capacity - 1 - size[lqid]),
                "is_input": bool(is_in),
            }
        return {
            "granule": self.spec.granule,
            "cycle": int(jax.device_get(self.state.cycle)),
            "epoch": self.epochs_done,
            "ports": ports,
            "signature": self.spec.signature,
            "wait_s": self.wait_s,
            "run_s": self.run_s,
            "wait_fraction": (self.wait_s / self.run_s) if self.run_s else 0.0,
            "telem_dropped": self.telem.dropped if self.telem else 0,
        }


class BatchedWorker(Worker):
    """One process stepping a whole signature batch: a single vmapped
    dispatch advances all nb granules per program op, while the ring
    protocol stays per channel — the batch merely refines the free-running
    schedule (its members run in lockstep, a legal schedule the credit
    chain already admits), so traffic stays bit-identical to per-granule
    workers."""

    def __init__(self, bspec: BatchSpec, conn, hb: np.ndarray | None,
                 faults=()):
        self.bspec = bspec
        self.specs = bspec.specs
        self.spec = bspec.specs[0]  # shared scalars (capacity/W/rings/...)
        self.conn = conn
        self.hb = hb
        self.sim = BatchedGranuleSim(bspec)
        self.state = None
        self.epochs_done = 0
        self.timeout = self.spec.timeout
        self.ring_timeout = self.spec.timeout * 2
        self.wait_s = 0.0
        self.run_s = 0.0
        self.telem = None
        self._init_faults(faults)
        itemsize = np.dtype(self.spec.dtype).itemsize
        self.rings: dict[tuple[str, int], ShmRing] = {}
        for s in self.specs:
            for ts in s.tiers:
                for c in ts.egress_chans + ts.ingress_chans:
                    if ("d", c) in self.rings:
                        continue
                    self.rings[("d", c)] = ShmRing.attach(
                        data_ring_name(s.ring_prefix, c),
                        s.ring_depth + 1,
                        slab_slot_bytes(ts.E, s.payload_words, itemsize),
                        checked=True, label=f"slab:c{c}",
                    )
                    self.rings[("c", c)] = ShmRing.attach(
                        credit_ring_name(s.ring_prefix, c),
                        s.ring_depth + 2, 4,
                    )
            for name, chan, lqid, is_in in s.ext_ports:
                if ("x", chan) not in self.rings:
                    self.rings[("x", chan)] = ShmRing.attach(
                        ext_ring_name(s.ring_prefix, chan),
                        s.capacity, s.payload_words * itemsize,
                        checked=True, label=f"ext:{name}",
                    )

    def _probe(self, gi: int, slot: int, row: int):
        import jax

        return jax.tree.map(
            lambda x: x[row, slot], self.state.block_states[gi]
        )

    def _ingest_ext(self) -> None:
        jnp = self.sim.jnp
        for r, s in enumerate(self.specs):
            for name, chan, lqid, is_in in s.ext_ports:
                if not is_in:
                    continue
                ring = self.rings[("x", chan)]
                avail = ring.size()
                if not avail:
                    continue
                k = min(avail, s.capacity - 1)
                pays = ring.peek_packets(k, self.sim.np_dtype, self.sim.W)
                pad = np.zeros((s.capacity - 1, self.sim.W),
                               self.sim.np_dtype)
                pad[:k] = pays
                self.state, n = self.sim._compiled["ingest"](
                    self.state, jnp.int32(r), jnp.int32(lqid),
                    jnp.asarray(pad), jnp.int32(k),
                )
                ring.advance(int(n))

    def _flush_ext(self) -> None:
        jnp = self.sim.jnp
        for r, s in enumerate(self.specs):
            for name, chan, lqid, is_in in s.ext_ports:
                if is_in:
                    continue
                ring = self.rings[("x", chan)]
                room = ring.free()
                if not room:
                    continue
                self.state, pays, cnt = self.sim._compiled["flush"](
                    self.state, jnp.int32(r), jnp.int32(lqid),
                    jnp.int32(room),
                )
                cnt = int(cnt)
                if cnt:
                    landed = ring.push_packets(np.asarray(pays)[:cnt])
                    assert landed == cnt

    def _exchange_issue(self, t: int) -> None:
        jnp = self.sim.jnp
        rows = [s.tiers[t] for s in self.specs]
        if not rows[0].egress_chans:
            return
        creds = np.array(
            [[self._timed(self.rings[("c", c)].pop_u32_wait,
                          self.ring_timeout,
                          status=encode_blocked(OP_CREDIT_POP, c))
              for c in ts.egress_chans] for ts in rows],
            np.int32,
        )
        self.state, slab, cnt = self.sim._compiled[("D", t)](
            self.state, jnp.asarray(creds)
        )
        slab = np.asarray(slab)
        cnt = np.asarray(cnt)
        for r, ts in enumerate(rows):
            for i, c in enumerate(ts.egress_chans):
                self._timed(self.rings[("d", c)].push_slab_wait,
                            int(cnt[r, i]), slab[r, i], self.ring_timeout,
                            status=encode_blocked(OP_SLAB_PUSH, c))

    def _exchange_commit(self, t: int) -> None:
        jnp = self.sim.jnp
        rows = [s.tiers[t] for s in self.specs]
        if not rows[0].ingress_chans:
            return
        n_in = len(rows[0].ingress_chans)
        nb = len(self.specs)
        slab_in = np.zeros((nb, n_in, rows[0].E, self.sim.W),
                           self.sim.np_dtype)
        cnt_in = np.zeros((nb, n_in), np.int32)
        flat = [(r, i, c, self.rings[("d", c)])
                for r, ts in enumerate(rows)
                for i, c in enumerate(ts.ingress_chans)]
        codes = [encode_blocked(OP_SLAB_POP, c) for _, _, c, _ in flat]
        order = (self._pop_order([ring for _, _, _, ring in flat], codes)
                 if self.spec.overlap else range(len(flat)))
        for k in order:
            r, i, c, ring = flat[k]
            cnt_in[r, i], slab_in[r, i] = self._timed(
                ring.pop_slab_wait,
                (rows[r].E, self.sim.W), self.sim.np_dtype,
                self.ring_timeout, status=codes[k],
            )
        self.state, free = self.sim._compiled[("F", t)](
            self.state, jnp.asarray(slab_in), jnp.asarray(cnt_in)
        )
        free = np.asarray(free)
        for r, ts in enumerate(rows):
            for i, c in enumerate(ts.ingress_chans):
                self._timed(self.rings[("c", c)].push_u32,
                            int(free[r, i]), self.ring_timeout,
                            status=encode_blocked(OP_CREDIT_PUSH, c))

    def _stats(self) -> list[dict]:
        import jax

        q = jax.device_get(self.state.queues)
        size = (q.head - q.tail) % q.capacity  # (nb, n_local)
        cycles = jax.device_get(self.state.cycle)
        out = []
        for r, s in enumerate(self.specs):
            ports = {}
            for name, chan, lqid, is_in in s.ext_ports:
                ports[name] = {
                    "occupancy": int(size[r, lqid]),
                    "credit": int(q.capacity - 1 - size[r, lqid]),
                    "is_input": bool(is_in),
                }
            out.append({
                "granule": s.granule,
                "cycle": int(cycles[r]),
                "epoch": self.epochs_done,
                "ports": ports,
                "signature": s.signature,
                "batch_row": r,
                "batch_size": len(self.specs),
                "wait_s": self.wait_s,
                "run_s": self.run_s,
                "wait_fraction": (self.wait_s / self.run_s)
                if self.run_s else 0.0,
                "telem_dropped": self.telem.dropped if self.telem else 0,
            })
        return out


HB_RECORD_BYTES = 32  # per-worker heartbeat: [epochs, wallclock, status, _]
HB_RECORD_F64 = HB_RECORD_BYTES // 8


def attach_heartbeat(hb_ring_name: str, index: int):
    """Attach one member's heartbeat record (4 f64: [progress counter,
    wallclock, blocked-status word, spare]) in the fleet heartbeat shm.
    Shared by granule workers (index = worker id) and bridge proxies
    (index = NW + local bridge index) — both are first-class members of
    the ProcessMonitor's liveness/stall surface.  Returns (shm, view);
    the caller keeps ``shm`` alive for the view's lifetime."""
    from .shmem import attach_shared_memory

    hb_shm = attach_shared_memory(hb_ring_name)
    hb = np.frombuffer(hb_shm.buf, np.float64, count=HB_RECORD_F64,
                       offset=index * HB_RECORD_BYTES)
    return hb_shm, hb


def worker_entry(conn, spec_pickle: bytes, worker_index: int,
                 log_path: str | None, cache_dir: str | None,
                 hb_ring_name: str | None,
                 faults_pickle: bytes | None = None,
                 telem_ring_name: str | None = None) -> None:
    """Process entry point (spawn context).  Builds the granule simulator
    (hitting the persistent compilation cache warmed by the launcher's
    prebuild pass), then serves the command loop until "exit".
    ``faults_pickle`` carries this worker's armed ``FaultAction``s for the
    current fleet incarnation (drills; empty in production)."""
    import pickle

    # Pin the single-CPU-device env HERE, not only in the parent: under
    # the forkserver context the child inherits the server's frozen env,
    # and XLA reads these at backend init (first use), which is always
    # after this point — no backend exists pre-fork.
    os.environ["JAX_PLATFORMS"] = "cpu"
    _flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
              if "xla_force_host_platform_device_count" not in f]
    if _flags:
        os.environ["XLA_FLAGS"] = " ".join(_flags)
    else:
        os.environ.pop("XLA_FLAGS", None)

    if log_path:
        f = open(log_path, "w", buffering=1)
        os.dup2(f.fileno(), 1)
        os.dup2(f.fileno(), 2)
        sys.stdout = os.fdopen(1, "w", buffering=1)
        sys.stderr = os.fdopen(2, "w", buffering=1)
    try:
        configure_compile_cache(cache_dir)
        spec = pickle.loads(spec_pickle)
        faults = pickle.loads(faults_pickle) if faults_pickle else ()
        if isinstance(spec, BatchSpec):
            print(f"[worker {worker_index}] granules {spec.members} "
                  f"signature {spec.signature} starting (batched)",
                  flush=True)
        else:
            print(f"[worker {worker_index}] granule {spec.granule} "
                  f"signature {spec.signature} starting", flush=True)
        if faults:
            print(f"[worker {worker_index}] armed faults: {faults}",
                  flush=True)
        hb = hb_shm = None
        if hb_ring_name:
            hb_shm, hb = attach_heartbeat(hb_ring_name, worker_index)
        w = (BatchedWorker(spec, conn, hb, faults)
             if isinstance(spec, BatchSpec)
             else Worker(spec, conn, hb, faults))
        if telem_ring_name:
            # flight-recorder ring (repro.obs): worker is sole producer;
            # stored under ("t", 0) so the exit sweep below closes it
            tring = ShmRing.attach(telem_ring_name,
                                   _telem.TELEM_RING_RECORDS,
                                   _telem.TELEM_RECORD_BYTES)
            w.rings[("t", 0)] = tring
            w.telem = _telem.TelemetryWriter(tring)
        build = w.sim.prebuild()
        print(f"[worker {worker_index}] prebuilt {build['n_functions']} fns "
              f"in {build['seconds']:.2f}s", flush=True)
        conn.send(("ready", build))
        w.serve()
        # release every live view of shm before interpreter exit, or the
        # segments' __del__ dies with "cannot close: exported pointers
        # exist" noise in the worker log
        for ring in w.rings.values():
            ring.close()
        w.hb = None
        hb = None
        if hb_shm is not None:
            hb_shm.close()
        print(f"[worker {worker_index}] clean exit", flush=True)
    except Exception:  # noqa: BLE001
        traceback.print_exc()
        sys.stderr.flush()
        try:
            conn.send(("err", traceback.format_exc()))
        except Exception:
            pass
        raise

"""Fault tolerance & elasticity runtime.

Pieces (composed by launch/train.py):

  * ``Watchdog`` — per-step timing with EWMA baseline; flags straggler steps
    (step > mean + k*sigma) and hung steps (> hard timeout).  On a real
    multi-host deployment the flags feed the coordinator; here they are
    logged and surfaced in metrics, and tests assert the detection logic.
  * ``run_resumable`` — the crash/restart loop: training state checkpoints
    every ``ckpt_every``; on any exception the loop restores the latest
    checkpoint (data-pipeline cursor included) and continues.  Elastic:
    the restore path reshard-places arrays onto whatever mesh the restarted
    process built (checkpoint/checkpointing.py).
  * ``FailureInjector`` — deterministic fault injection for tests/drills
    (the paper's cloud runs lose ECS tasks; we simulate that).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class Watchdog:
    ewma_alpha: float = 0.1
    sigma_k: float = 4.0
    hard_timeout_s: float = 600.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    stragglers: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> dict:
        flag = False
        if self.n >= 5:
            sd = math.sqrt(max(self.var, 1e-12))
            if dt > self.mean + self.sigma_k * sd and dt > 1.5 * self.mean:
                flag = True
                self.stragglers.append((step, dt))
        if self.n == 0:
            self.mean, self.var = dt, 0.0
        else:
            d = dt - self.mean
            self.mean += self.ewma_alpha * d
            self.var = (1 - self.ewma_alpha) * (self.var + self.ewma_alpha * d * d)
        self.n += 1
        return {
            "step_time_s": dt,
            "step_time_mean_s": self.mean,
            "straggler": flag,
            "hung": dt > self.hard_timeout_s,
        }


class FailureInjector:
    """Raises RuntimeError at the given (absolute) step numbers, once each."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected failure at step {step}")


def run_resumable(
    *,
    total_steps: int,
    make_state: Callable[[], Any],          # fresh (step0) training state
    restore_state: Callable[[], Any | None],  # latest checkpoint or None
    train_one: Callable[[Any, int], Any],    # state, step -> state
    save_state: Callable[[Any, int], None],
    ckpt_every: int = 50,
    max_restarts: int = 10,
    watchdog: Watchdog | None = None,
    on_metrics: Callable[[int, dict], None] | None = None,
) -> Any:
    """Crash-safe training loop: any exception -> restore + continue."""
    restarts = 0
    while True:
        try:
            restored = restore_state()
            if restored is None:
                state, step = make_state(), 0
            else:
                state, step = restored
            while step < total_steps:
                t0 = time.monotonic()
                state = train_one(state, step)
                step += 1
                if watchdog is not None:
                    m = watchdog.observe(step, time.monotonic() - t0)
                    if on_metrics:
                        on_metrics(step, m)
                if step % ckpt_every == 0 or step == total_steps:
                    save_state(state, step)
            return state
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any worker failure
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(f"exceeded {max_restarts} restarts") from e
            # loop: restore from latest checkpoint and continue
            continue

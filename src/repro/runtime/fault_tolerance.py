"""Fault tolerance & elasticity runtime.

Pieces (composed by launch/train.py and by the multiprocess simulation
launcher, ``repro.runtime.launcher``):

  * ``Watchdog`` — per-step timing with EWMA baseline; flags straggler steps
    (step > mean + k*sigma) and hung steps (> hard timeout).  On a real
    multi-host deployment the flags feed the coordinator; here they are
    logged and surfaced in metrics, and tests assert the detection logic.
  * ``run_resumable`` — the crash/restart loop: training state checkpoints
    every ``ckpt_every``; on any exception the loop restores the latest
    checkpoint (data-pipeline cursor included) and continues.  Elastic:
    the restore path reshard-places arrays onto whatever mesh the restarted
    process built (checkpoint/checkpointing.py).
  * ``FailureInjector`` — deterministic fault injection for tests/drills
    (the paper's cloud runs lose ECS tasks; we simulate that).
  * ``WorkerDiedError`` / ``ProcessMonitor`` — the free-running runtime's
    failure surface: the launcher polls worker liveness (ANY exit while
    replies are pending, clean or not) and per-epoch heartbeats while
    awaiting replies, and a dead or hung granule simulator raises a
    ``WorkerDiedError`` carrying the worker's captured log tail — a
    diagnosis, never a silent hang (``tests/test_runtime.py`` kills a
    worker mid-run to prove it).
  * ``FleetStallError`` + the stall-graph helpers (ISSUE 8) — when no
    heartbeat advances fleet-wide, the per-worker "blocked on ring X"
    status words are decoded into a credit wait-for graph; a cycle is a
    true deadlock and raises ``FleetStallError`` naming it, an acyclic
    chain names its root worker instead.
"""
from __future__ import annotations

import math
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class Watchdog:
    ewma_alpha: float = 0.1
    sigma_k: float = 4.0
    hard_timeout_s: float = 600.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    stragglers: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> dict:
        flag = False
        if self.n >= 5:
            sd = math.sqrt(max(self.var, 1e-12))
            if dt > self.mean + self.sigma_k * sd and dt > 1.5 * self.mean:
                flag = True
                self.stragglers.append((step, dt))
        if self.n == 0:
            self.mean, self.var = dt, 0.0
        else:
            d = dt - self.mean
            self.mean += self.ewma_alpha * d
            self.var = (1 - self.ewma_alpha) * (self.var + self.ewma_alpha * d * d)
        self.n += 1
        return {
            "step_time_s": dt,
            "step_time_mean_s": self.mean,
            "straggler": flag,
            "hung": dt > self.hard_timeout_s,
        }


class WorkerDiedError(RuntimeError):
    """A granule worker process died (any unexpected exit, clean or not)
    or went silent past the heartbeat timeout.  The message carries the
    worker id, its exit status, and the tail of its captured log so the
    failure is diagnosable from the exception alone."""

    def __init__(self, worker: int, reason: str, log_tail: str = "",
                 label: str | None = None):
        self.worker = worker
        self.reason = reason
        self.log_tail = log_tail
        self.label = label or f"worker {worker}"
        msg = f"{self.label} {reason}"
        if log_tail:
            msg += f"\n--- {self.label} log tail ---\n{log_tail}"
        super().__init__(msg)


class LinkDownError(WorkerDiedError):
    """A TCP ring bridge (``runtime.bridge``) died or its link dropped.

    Subclasses ``WorkerDiedError`` so the recovery controller's
    RECOVERABLE surface covers it unchanged: a dead bridge is healed the
    same way as a dead worker — teardown, re-rendezvous, restore, replay
    (``runtime.fleet``).  ``worker`` is the bridge's monitor id
    (``NW + local bridge index``); ``label`` names the link."""


class FleetStallError(RuntimeError):
    """No heartbeat advanced fleet-wide AND the credit wait-for graph —
    reconstructed from the per-worker "blocked on ring X" status words in
    the heartbeat shm — contains a cycle: a true deadlock, not a slow or
    dead worker.  Carries the detected cycle so the diagnosis names the
    exact channels instead of a generic hang."""

    def __init__(self, cycle: list[int], details: list[str]):
        self.cycle = list(cycle)
        self.details = list(details)
        ring = " -> ".join(f"w{w}" for w in self.cycle + self.cycle[:1])
        msg = "fleet-wide stall: credit wait-for cycle " + ring
        if details:
            msg += "\n  " + "\n  ".join(details)
        super().__init__(msg)


# ------------------------------------------------- stall diagnosis (ISSUE 8)
# Workers publish a "blocked on ring X" status word in their heartbeat
# record before every blocking ring op (0 = running).  The launcher decodes
# those words into a wait-for graph over workers when the whole fleet goes
# quiet: pop-waits point at the ring's producer, push-waits at its consumer.
OP_CREDIT_POP, OP_SLAB_POP, OP_SLAB_PUSH, OP_CREDIT_PUSH = 1, 2, 3, 4
# A bridge proxy waiting on its TCP peer: nothing LOCAL holds it up, so
# it contributes no wait-for edge — if workers point at it, the bridge is
# the stall's root and gets named directly (never an innocent worker).
OP_LINK_WAIT = 5
STALL_OPS = {OP_CREDIT_POP: "credit-pop", OP_SLAB_POP: "slab-pop",
             OP_SLAB_PUSH: "slab-push", OP_CREDIT_PUSH: "credit-push",
             OP_LINK_WAIT: "link-wait"}
_STALL_BASE = 1_000_000


def encode_blocked(op: int, chan: int) -> int:
    """Status word for "blocked in ring op ``op`` on channel ``chan``"."""
    return op * _STALL_BASE + chan


def decode_blocked(code: int) -> tuple[int, int]:
    """Inverse of ``encode_blocked`` → (op, chan)."""
    return divmod(int(code), _STALL_BASE)


def stall_wait_edges(blocked: dict[int, int],
                     chan_workers: dict[int, tuple[int, int]],
                     ) -> tuple[dict[int, int], dict[int, str]]:
    """Wait-for edges ``waiter -> holder`` from per-worker status words.

    ``blocked`` maps worker -> status word (0 = not blocked);
    ``chan_workers`` maps channel id -> (producer_worker, consumer_worker)
    of the channel's slab direction.  On a bridged fleet the remote end
    of a cross-host channel is its local bridge proxy's monitor id, so
    the graph stays host-local and blames the bridge, not a worker.
    Self-edges (both ends of a channel batched into one worker) are
    dropped; ``OP_LINK_WAIT`` (a bridge waiting on its TCP peer)
    contributes no edge — nothing local holds it up.  Returns
    (edges, details)."""
    edges: dict[int, int] = {}
    details: dict[int, str] = {}
    for w, code in blocked.items():
        if code <= 0:
            continue
        op, chan = decode_blocked(code)
        if op == OP_LINK_WAIT:
            details[w] = f"member {w} blocked on its TCP link (c{chan})"
            continue
        if op not in STALL_OPS or chan not in chan_workers:
            continue
        sw, dw = chan_workers[chan]
        # Waiting to POP a slab (or PUSH a credit) → the slab producer is
        # behind; waiting to POP a credit (or PUSH a slab) → the consumer.
        peer = dw if op in (OP_CREDIT_POP, OP_SLAB_PUSH) else sw
        if peer == w:
            continue
        edges[w] = peer
        details[w] = (f"worker {w} blocked on {STALL_OPS[op]} c{chan} "
                      f"(w{sw}->w{dw}), held up by worker {peer}")
    return edges, details


def find_stall_cycle(edges: dict[int, int]) -> list[int] | None:
    """First cycle in a functional wait-for graph, or None."""
    for start in sorted(edges):
        path: list[int] = []
        seen: dict[int, int] = {}
        w = start
        while w in edges and w not in seen:
            seen[w] = len(path)
            path.append(w)
            w = edges[w]
        if w in seen:
            return path[seen[w]:]
    return None


def read_log_tail(path: str | None, max_bytes: int = 2048) -> str:
    """Last ``max_bytes`` of a worker's captured log ('' when absent)."""
    if not path or not os.path.exists(path):
        return ""
    try:
        with open(path, "rb") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            f.seek(max(0, size - max_bytes))
            return f.read().decode(errors="replace").strip()
    except OSError:
        return ""


class ProcessMonitor:
    """Liveness/progress checks over a set of worker processes.

    ``check()`` raises ``WorkerDiedError`` for the first worker that (a)
    exited, or (b) — when a heartbeat reader is wired — made no progress
    for ``hang_timeout_s`` while a reply is pending.  Designed to be
    called from inside reply-wait loops, so a dead peer becomes an
    exception in bounded time instead of a hang.
    """

    def __init__(self, procs: dict[int, Any], log_paths: dict[int, str],
                 heartbeat: Callable[[int], float] | None = None,
                 hang_timeout_s: float = 120.0,
                 diagnose: Callable[[tuple[int, ...]], Exception | None]
                 | None = None,
                 labels: dict[int, str] | None = None,
                 link_ids: frozenset | set | None = None):
        self.procs = procs
        self.log_paths = log_paths
        self.heartbeat = heartbeat  # worker -> last-beat wallclock
        self.hang_timeout_s = hang_timeout_s
        self.diagnose = diagnose    # fleet-wide stall -> richer exception
        # Bridge proxies are first-class fleet members: ``labels`` names
        # them in diagnoses, ``link_ids`` routes their deaths to
        # ``LinkDownError`` so a dropped TCP link is distinguishable from
        # a dead granule worker (and both stay RECOVERABLE).
        self.labels = labels or {}
        self.link_ids = frozenset(link_ids or ())
        self._last_progress = {w: time.time() for w in procs}
        self._last_beat = {w: -1.0 for w in procs}

    def died(self, w: int, reason: str) -> WorkerDiedError:
        """The member-appropriate death exception (bridge -> LinkDownError)."""
        cls = LinkDownError if w in self.link_ids else WorkerDiedError
        return cls(w, reason, read_log_tail(self.log_paths.get(w)),
                   label=self.labels.get(w))

    def check(self, waiting_on: tuple[int, ...] | None = None) -> None:
        now = time.time()
        for w, p in self.procs.items():
            if p is not None and p.exitcode is not None:
                # check() only runs while a reply is pending, so ANY exit
                # here — clean or not — is a fault.  exitcode 0 used to be
                # invisible to this check and only surfaced via the slow
                # heartbeat timeout (ISSUE 8 satellite).
                how = (f"died with exitcode {p.exitcode}" if p.exitcode
                       else "exited cleanly (exitcode 0) while replies "
                            "were still pending")
                raise self.died(w, how)
        if self.heartbeat is None or not waiting_on:
            return
        hung, quiet = [], []
        for w in waiting_on:
            beat = self.heartbeat(w)
            if beat != self._last_beat[w]:
                self._last_beat[w] = beat
                self._last_progress[w] = now
                continue
            silent = now - self._last_progress[w]
            if silent > self.hang_timeout_s:
                hung.append(w)
            if silent > self.hang_timeout_s / 2:
                quiet.append(w)
        if not hung:
            return
        # When EVERY pending worker has gone quiet (half-timeout grace
        # absorbs threshold-crossing skew), the hang is fleet-wide: hand
        # the full set to the diagnoser, which reconstructs the credit
        # wait-for graph and names the deadlock cycle / root worker.
        if self.diagnose is not None and set(quiet) >= set(waiting_on):
            exc = self.diagnose(tuple(waiting_on))
            if exc is not None:
                raise exc
        w = hung[0]
        raise self.died(
            w,
            f"made no progress for {self.hang_timeout_s:.0f}s "
            "(hung or deadlocked)",
        )


class FailureInjector:
    """Deterministic fault injection: fires once at each of the given
    (absolute) step numbers.  Without ``on_fail`` it raises RuntimeError
    (the training-loop drill); with it, the callback runs instead — the
    plan-driven worker faults of ``repro.runtime.faultinject`` (kill,
    hang, corrupt-a-slab, ...) are built on this same trigger."""

    def __init__(self, fail_at: tuple[int, ...] = (),
                 on_fail: Callable[[int], None] | None = None):
        self.fail_at = set(fail_at)
        self.on_fail = on_fail

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            if self.on_fail is not None:
                self.on_fail(step)
                return
            raise RuntimeError(f"injected failure at step {step}")


def run_resumable(
    *,
    total_steps: int,
    make_state: Callable[[], Any],          # fresh (step0) training state
    restore_state: Callable[[], Any | None],  # latest checkpoint or None
    train_one: Callable[[Any, int], Any],    # state, step -> state
    save_state: Callable[[Any, int], None],
    ckpt_every: int = 50,
    max_restarts: int = 10,
    watchdog: Watchdog | None = None,
    on_metrics: Callable[[int, dict], None] | None = None,
) -> Any:
    """Crash-safe training loop: any exception -> restore + continue."""
    restarts = 0
    while True:
        try:
            restored = restore_state()
            if restored is None:
                state, step = make_state(), 0
            else:
                state, step = restored
            while step < total_steps:
                t0 = time.monotonic()
                state = train_one(state, step)
                step += 1
                if watchdog is not None:
                    m = watchdog.observe(step, time.monotonic() - t0)
                    if on_metrics:
                        on_metrics(step, m)
                if step % ckpt_every == 0 or step == total_steps:
                    save_state(state, step)
            return state
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any worker failure
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(f"exceeded {max_restarts} restarts") from e
            # loop: restore from latest checkpoint and continue
            continue

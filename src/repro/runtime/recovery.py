"""Coordinated snapshots + automatic fleet recovery (ISSUE 8 tentpole).

The free-running runtime's failure surface (``runtime.fault_tolerance``,
``runtime.shmem``) turns every fleet pathology into a typed exception:
``WorkerDiedError`` (dead or hung process), ``LinkDownError`` (a dead or
wedged TCP bridge proxy on a multi-host fleet — a WorkerDiedError
subclass, so every policy below applies unchanged), ``FleetStallError``
(credit wait-for cycle), ``RingCorruptionError`` (seq/crc mismatch on a
checked ring, including one flipped ON THE WIRE by a bridge),
``RingTimeout`` (worker-side ring deadline, or a cross-host credit that
never came home).  This module is the policy layer above that surface:
with ``ProcsEngine(on_fault="recover")`` (env ``REPRO_ON_FAULT``) those
faults are *healed* instead of raised.  On a bridged fleet the respawn
(``engine._reopen``) tears down and re-rendezvouses the WHOLE fleet —
followers, bridges, TCP links — under a fresh incarnation token.

**Snapshot consistency.**  A coordinated snapshot is just
``gather_state`` taken at a command boundary: every worker has replied to
its ``run`` command, so the whole fleet sits at the SAME epoch with every
data ring empty (asserted by the gather), exactly one credit in flight
per channel, and the external rings quiescent.  That cut is consistent by
construction — no marker algorithm needed, the command protocol IS the
barrier.  The controller chunks ``run_epochs`` so a boundary lands on
every multiple of ``snapshot_every`` and snapshots there.

**Recovery sequence.**  On a recoverable fault mid-chunk:

  1. the detection path has already torn down the remnant fleet
     (``ProcsEngine.close()`` before the raise);
  2. back off ``backoff_s * 2**(restarts-1)`` (a crash loop must not spin);
  3. ``engine._reopen()`` — fresh ring namespace, fresh processes, same
     lowering, warm persistent compilation cache (respawn pays no
     recompiles — the prebuilt-simulator property doing double duty);
  4. ``scatter_state`` the last snapshot (granule states, in-flight
     credits, external-ring packets AND their integrity seq counters);
  5. resume the chunk loop from the snapshot epoch — the lost epochs are
     simply re-run.

Replay determinism is inherited, not engineered: the runtime is bit-
identical to the lockstep engines from any quiesced state, so re-running
epochs ``s..t`` from the epoch-``s`` snapshot produces the same state and
the same host-visible traffic as the fault-free timeline.  Host I/O
between runs is handled by snapshot refresh: the engine reports every
host push/pop to the controller, and the controller re-captures just the
external rings (same epoch) or the full tree (epoch moved) before the
next run — so recovery never re-delivers packets the host already
popped, and never loses ones it pushed.  The reports double as a
**journal**: if the re-capture gather *itself* faults (a bridge link can
die between runs, exactly when the leader next touches it), the only
state not in the last snapshot is the host I/O performed at the current
boundary — so the journaled pops become re-delivery *discards* (the
replay regenerates those packets; the host-facing pop drops them) and
the journaled pushes are *re-injected* into their external rings exactly
when the replay reaches the boundary where the host originally pushed
them, keeping replayed ingress cycle-identical.

**MTTR model** (measured in ``benchmarks/fault_recovery.py``)::

    MTTR ≈ detect + backoff + respawn(warm) + restore + replay
    detect  ~ heartbeat timeout (kill: one poll interval via exitcode)
    respawn ~ forkserver fork (jax import pre-paid) + prebuild cache hit
    replay  ≤ snapshot_every * epoch_time  (the cadence knob)
"""
from __future__ import annotations

import os
import sys
import time
from typing import Any

import numpy as np

from ..obs import trace as _trace
from ..obs.registry import REGISTRY
from .fault_tolerance import FleetStallError, WorkerDiedError
from .shmem import RingCorruptionError, RingTimeout

#: Fleet faults the controller heals; anything else (a worker traceback,
#: a protocol bug) propagates — recovery must not mask logic errors.
RECOVERABLE = (WorkerDiedError, FleetStallError, RingCorruptionError,
               RingTimeout)

_POLICIES = ("raise", "recover")


def resolve_on_fault(on_fault: Any = "auto") -> str:
    """Resolve the fault policy: explicit argument > ``REPRO_ON_FAULT`` >
    default "raise" — the same precedence as the other runtime knobs."""
    if on_fault is None:
        on_fault = "auto"
    on_fault = str(on_fault).lower()
    if on_fault == "auto":
        on_fault = (os.environ.get("REPRO_ON_FAULT", "raise").lower()
                    or "raise")
    if on_fault not in _POLICIES:
        raise ValueError(
            f"on_fault={on_fault!r}: choose 'raise' or 'recover' "
            "(or 'auto' to defer to REPRO_ON_FAULT)"
        )
    return on_fault


class RecoveryController:
    """Snapshot cadence + respawn/restore/replay policy for one engine.

    Deliberately knows the engine only through its public protocol plus
    a handful of recovery hooks (``_run_epochs_raw``, ``_reopen``,
    ``_handle_at``, ``_replay_ext_push``, ``_set_ext_discard``,
    ``_ext_discard_state``) — no launcher import, no ring knowledge."""

    def __init__(self, engine, *, snapshot_every: int = 16,
                 max_restarts: int = 3, backoff_s: float = 0.25):
        self.engine = engine
        self.snapshot_every = max(1, int(snapshot_every))
        self.max_restarts = int(max_restarts)
        self.backoff_s = float(backoff_s)
        self.restarts = 0
        self.snapshots = 0
        self.recovered_epochs = 0
        self._snapshot = None
        self._snapshot_epoch = -1
        self._ext_dirty = False
        self._last_recovery: dict | None = None
        # host-I/O journal since the snapshot's ext capture (pushes keep
        # their payloads, pops just a count), plus the recovery carry-over
        # it folds into: pending re-injections [(epoch, {port: [batch]})]
        # and the (discards, injections) pair frozen with the snapshot
        self._jrnl_push: dict[str, list] = {}
        self._jrnl_pop: dict[str, int] = {}
        self._inject: list[tuple] = []
        self._snap_host: tuple = ({}, [])

    # ------------------------------------------------- engine notifications
    def note_reset(self) -> None:
        """``init`` rewound the fleet — any snapshot is from a dead
        timeline."""
        self._snapshot = None
        self._snapshot_epoch = -1
        self._ext_dirty = False
        self._jrnl_push, self._jrnl_pop = {}, {}
        self._inject = []
        self._snap_host = ({}, [])
        self.engine._set_ext_discard({})

    def note_ext_push(self, state, name: str, batch) -> None:
        """Host pushed ``batch`` into external ring ``name``: mark the
        snapshot ext-dirty AND journal the payloads — if the repair
        gather faults, these are the packets a rewind would lose."""
        if self._snapshot is not None:
            self._ext_dirty = True
            self._jrnl_push.setdefault(name, []).append(
                np.array(batch, copy=True))

    def note_ext_pop(self, state, name: str, n: int) -> None:
        """Host popped ``n`` packets from external ring ``name``: if the
        repair gather faults, a rewound replay regenerates them — the
        journal count becomes the re-delivery discard."""
        if self._snapshot is not None:
            self._ext_dirty = True
            self._jrnl_pop[name] = self._jrnl_pop.get(name, 0) + int(n)

    def note_scatter(self) -> None:
        """An explicit user restore replaced the fleet's history — the
        snapshot no longer describes the current timeline."""
        self._snapshot = None
        self._snapshot_epoch = -1
        self._ext_dirty = False
        self._jrnl_push, self._jrnl_pop = {}, {}
        self._inject = []
        self._snap_host = ({}, [])
        self.engine._set_ext_discard({})

    # ------------------------------------------------------------ main loop
    def run_epochs(self, state, n_epochs: int):
        """Chunked run: a command boundary (and a snapshot) on every
        multiple of ``snapshot_every``; any recoverable fault inside a
        chunk triggers respawn + restore + replay of that chunk.  Chunks
        additionally cut at pending re-injection boundaries so journaled
        host pushes re-enter their rings at the exact epoch the host
        originally pushed them."""
        eng = self.engine
        target = int(state.epoch) + int(n_epochs)
        try:
            self._ensure_snapshot(state)
        except RECOVERABLE as fault:
            # a fault can surface inside the gather itself (e.g. a bridge
            # link died since the last command) — recoverable only if an
            # earlier snapshot exists to rewind to
            if self._snapshot is None:
                raise
            state = self._recover(fault, state)
        while True:
            try:
                self._apply_inject(state)
                here = int(state.epoch)
                if here >= target:
                    return state
                nxt = min(target, self._next_boundary(here))
                for e, _ in self._inject:
                    if here < e < nxt:
                        nxt = e
                state = eng._run_epochs_raw(state, nxt - here)
                if (int(state.epoch) % self.snapshot_every == 0
                        and int(state.epoch) != self._snapshot_epoch):
                    self._take_snapshot(state)
            except RECOVERABLE as fault:
                state = self._recover(fault, state)

    def _next_boundary(self, epoch: int) -> int:
        return (epoch // self.snapshot_every + 1) * self.snapshot_every

    def _apply_inject(self, state) -> None:
        """Re-push journaled host payloads whose boundary the replay has
        reached — replayed epochs then see ingress identical to the
        faulted timeline's."""
        here = int(state.epoch)
        while self._inject and self._inject[0][0] <= here:
            _, pushes = self._inject.pop(0)
            for name, batches in pushes.items():
                for batch in batches:
                    self.engine._replay_ext_push(name, batch)

    # ------------------------------------------------------------ snapshots
    def _absorb_host_io(self) -> None:
        """The snapshot (or its ext refresh) now covers every host push
        and pop so far: drop the journal and freeze the recovery
        carry-over (pending discards + injections) alongside it."""
        self._jrnl_push, self._jrnl_pop = {}, {}
        self._ext_dirty = False
        self._snap_host = (self.engine._ext_discard_state(),
                           list(self._inject))

    def _take_snapshot(self, state) -> None:
        t0 = time.monotonic()
        self._snapshot = self.engine.gather_state(state)
        self._snapshot_epoch = int(state.epoch)
        self._absorb_host_io()
        self.snapshots += 1
        dur = time.monotonic() - t0
        REGISTRY.observe("recovery.snapshot.s", dur)
        _trace.span("snapshot", t0, dur, cat="recovery",
                    args={"epoch": self._snapshot_epoch,
                          "incarnation": int(self.engine._incarnation)})

    def _ensure_snapshot(self, state) -> None:
        """Entering a run: make the snapshot describe the CURRENT quiesced
        fleet, so a fault in the first chunk has something exact to
        restore.  Host I/O since the last snapshot only touched the
        external rings (the fleet was idle), so an unchanged epoch needs
        only the cheap ext-entry refresh; a moved epoch (user scattered or
        ran through another path) needs the full gather."""
        if self._snapshot is None or int(state.epoch) != self._snapshot_epoch:
            self._take_snapshot(state)
        elif self._ext_dirty:
            self._snapshot["ext"] = self.engine._gather_ext()
            self._absorb_host_io()

    # ------------------------------------------------------------- recovery
    def _recover(self, fault, state):
        eng = self.engine
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError(
                f"fleet recovery exhausted after {self.max_restarts} "
                f"restart(s); last fault: {type(fault).__name__}: {fault}"
            ) from fault
        assert self._snapshot is not None  # _ensure_snapshot ran first
        t0 = time.perf_counter()
        delay = self.backoff_s * (2 ** (self.restarts - 1))
        replay = int(state.epoch) - self._snapshot_epoch
        # Fold any un-absorbed host-I/O journal into the snapshot-paired
        # carry-over: the journal holds exactly the I/O the host performed
        # at the current (quiesced) boundary — the only state the snapshot
        # misses when the repair gather itself faulted.  Pops become
        # re-delivery discards, pushes a re-injection pinned to this
        # boundary's epoch.  Folding first makes a second fault idempotent.
        disc, pend = self._snap_host
        disc, pend = dict(disc), list(pend)
        if self._jrnl_pop or self._jrnl_push:
            for name, n in self._jrnl_pop.items():
                disc[name] = disc.get(name, 0) + int(n)
            if self._jrnl_push:
                pend.append((int(state.epoch),
                             {k: list(v) for k, v in self._jrnl_push.items()}))
            self._snap_host = (disc, pend)
            self._jrnl_push, self._jrnl_pop = {}, {}
        print(
            f"[recovery] {type(fault).__name__} at epoch >= "
            f"{int(state.epoch)}: restart {self.restarts}/"
            f"{self.max_restarts}, backoff {delay:.2f}s, restoring epoch "
            f"{self._snapshot_epoch}",
            file=sys.stderr, flush=True,
        )
        if delay > 0:
            time.sleep(delay)
        snap, snap_epoch = self._snapshot, self._snapshot_epoch
        eng._reopen()
        handle = eng._handle_at(snap_epoch)
        handle = eng.scatter_state(handle, snap)
        # scatter_state drops the snapshot AND the host carry-over (it
        # can't tell a user restore from ours) — reinstate both: the
        # restored fleet IS the snapshot, and the replay it is about to
        # re-run owes the host the journaled discards + injections
        self._snapshot, self._snapshot_epoch = snap, int(handle.epoch)
        self._ext_dirty = False
        self._snap_host = (disc, pend)
        self._inject = sorted(pend, key=lambda ep: ep[0])
        eng._set_ext_discard(dict(disc))
        self.recovered_epochs += max(0, replay)
        self._last_recovery = {
            "fault": type(fault).__name__,
            "restored_epoch": self._snapshot_epoch,
            "confirmed_epochs_replayed": max(0, replay),
            "backoff_s": delay,
            "restore_seconds": time.perf_counter() - t0,
        }
        REGISTRY.inc("recovery.restarts")
        REGISTRY.observe("recovery.restore.s",
                         self._last_recovery["restore_seconds"])
        _trace.instant("recovery_incident", cat="recovery",
                       args={**self._last_recovery,
                             "incarnation": int(eng._incarnation)})
        return handle

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "policy": self.engine.on_fault,
            "restarts": self.restarts,
            "max_restarts": self.max_restarts,
            "snapshot_every": self.snapshot_every,
            "snapshots": self.snapshots,
            "last_snapshot_epoch": self._snapshot_epoch,
            "recovered_epochs": self.recovered_epochs,
            "incarnation": self.engine._incarnation,
            "last_recovery": (dict(self._last_recovery)
                              if self._last_recovery else None),
        }

"""Coordinated snapshots + automatic fleet recovery (ISSUE 8 tentpole).

The free-running runtime's failure surface (``runtime.fault_tolerance``,
``runtime.shmem``) turns every fleet pathology into a typed exception:
``WorkerDiedError`` (dead or hung process), ``FleetStallError`` (credit
wait-for cycle), ``RingCorruptionError`` (seq/crc mismatch on a checked
ring), ``RingTimeout`` (worker-side ring deadline).  This module is the
policy layer above that surface: with ``ProcsEngine(on_fault="recover")``
(env ``REPRO_ON_FAULT``) those faults are *healed* instead of raised.

**Snapshot consistency.**  A coordinated snapshot is just
``gather_state`` taken at a command boundary: every worker has replied to
its ``run`` command, so the whole fleet sits at the SAME epoch with every
data ring empty (asserted by the gather), exactly one credit in flight
per channel, and the external rings quiescent.  That cut is consistent by
construction — no marker algorithm needed, the command protocol IS the
barrier.  The controller chunks ``run_epochs`` so a boundary lands on
every multiple of ``snapshot_every`` and snapshots there.

**Recovery sequence.**  On a recoverable fault mid-chunk:

  1. the detection path has already torn down the remnant fleet
     (``ProcsEngine.close()`` before the raise);
  2. back off ``backoff_s * 2**(restarts-1)`` (a crash loop must not spin);
  3. ``engine._reopen()`` — fresh ring namespace, fresh processes, same
     lowering, warm persistent compilation cache (respawn pays no
     recompiles — the prebuilt-simulator property doing double duty);
  4. ``scatter_state`` the last snapshot (granule states, in-flight
     credits, external-ring packets AND their integrity seq counters);
  5. resume the chunk loop from the snapshot epoch — the lost epochs are
     simply re-run.

Replay determinism is inherited, not engineered: the runtime is bit-
identical to the lockstep engines from any quiesced state, so re-running
epochs ``s..t`` from the epoch-``s`` snapshot produces the same state and
the same host-visible traffic as the fault-free timeline.  Host I/O
between runs is handled by snapshot refresh: the engine marks the
snapshot ext-dirty on any host push/pop, and the controller re-captures
just the external rings (same epoch) or the full tree (epoch moved)
before the next run — so recovery never re-delivers packets the host
already popped, and never loses ones it pushed.

**MTTR model** (measured in ``benchmarks/fault_recovery.py``)::

    MTTR ≈ detect + backoff + respawn(warm) + restore + replay
    detect  ~ heartbeat timeout (kill: one poll interval via exitcode)
    respawn ~ forkserver fork (jax import pre-paid) + prebuild cache hit
    replay  ≤ snapshot_every * epoch_time  (the cadence knob)
"""
from __future__ import annotations

import os
import sys
import time
from typing import Any

from .fault_tolerance import FleetStallError, WorkerDiedError
from .shmem import RingCorruptionError, RingTimeout

#: Fleet faults the controller heals; anything else (a worker traceback,
#: a protocol bug) propagates — recovery must not mask logic errors.
RECOVERABLE = (WorkerDiedError, FleetStallError, RingCorruptionError,
               RingTimeout)

_POLICIES = ("raise", "recover")


def resolve_on_fault(on_fault: Any = "auto") -> str:
    """Resolve the fault policy: explicit argument > ``REPRO_ON_FAULT`` >
    default "raise" — the same precedence as the other runtime knobs."""
    if on_fault is None:
        on_fault = "auto"
    on_fault = str(on_fault).lower()
    if on_fault == "auto":
        on_fault = (os.environ.get("REPRO_ON_FAULT", "raise").lower()
                    or "raise")
    if on_fault not in _POLICIES:
        raise ValueError(
            f"on_fault={on_fault!r}: choose 'raise' or 'recover' "
            "(or 'auto' to defer to REPRO_ON_FAULT)"
        )
    return on_fault


class RecoveryController:
    """Snapshot cadence + respawn/restore/replay policy for one engine.

    Deliberately knows the engine only through its public protocol plus
    three recovery hooks (``_run_epochs_raw``, ``_reopen``,
    ``_handle_at``) — no launcher import, no ring knowledge."""

    def __init__(self, engine, *, snapshot_every: int = 16,
                 max_restarts: int = 3, backoff_s: float = 0.25):
        self.engine = engine
        self.snapshot_every = max(1, int(snapshot_every))
        self.max_restarts = int(max_restarts)
        self.backoff_s = float(backoff_s)
        self.restarts = 0
        self.snapshots = 0
        self.recovered_epochs = 0
        self._snapshot = None
        self._snapshot_epoch = -1
        self._ext_dirty = False
        self._last_recovery: dict | None = None

    # ------------------------------------------------- engine notifications
    def note_reset(self) -> None:
        """``init`` rewound the fleet — any snapshot is from a dead
        timeline."""
        self._snapshot = None
        self._snapshot_epoch = -1
        self._ext_dirty = False

    def note_ext_io(self, state) -> None:
        """Host pushed/popped an external ring: the snapshot's ext entries
        are stale.  Cheap to note, repaired lazily before the next run."""
        if self._snapshot is not None:
            self._ext_dirty = True

    def note_scatter(self) -> None:
        """An explicit user restore replaced the fleet's history — the
        snapshot no longer describes the current timeline."""
        self._snapshot = None
        self._snapshot_epoch = -1
        self._ext_dirty = False

    # ------------------------------------------------------------ main loop
    def run_epochs(self, state, n_epochs: int):
        """Chunked run: a command boundary (and a snapshot) on every
        multiple of ``snapshot_every``; any recoverable fault inside a
        chunk triggers respawn + restore + replay of that chunk."""
        eng = self.engine
        target = int(state.epoch) + int(n_epochs)
        self._ensure_snapshot(state)
        while int(state.epoch) < target:
            here = int(state.epoch)
            nxt = min(target, self._next_boundary(here))
            try:
                state = eng._run_epochs_raw(state, nxt - here)
            except RECOVERABLE as fault:
                state = self._recover(fault, state)
                continue
            if (int(state.epoch) % self.snapshot_every == 0
                    and int(state.epoch) != self._snapshot_epoch):
                self._take_snapshot(state)
        return state

    def _next_boundary(self, epoch: int) -> int:
        return (epoch // self.snapshot_every + 1) * self.snapshot_every

    # ------------------------------------------------------------ snapshots
    def _take_snapshot(self, state) -> None:
        self._snapshot = self.engine.gather_state(state)
        self._snapshot_epoch = int(state.epoch)
        self._ext_dirty = False
        self.snapshots += 1

    def _ensure_snapshot(self, state) -> None:
        """Entering a run: make the snapshot describe the CURRENT quiesced
        fleet, so a fault in the first chunk has something exact to
        restore.  Host I/O since the last snapshot only touched the
        external rings (the fleet was idle), so an unchanged epoch needs
        only the cheap ext-entry refresh; a moved epoch (user scattered or
        ran through another path) needs the full gather."""
        if self._snapshot is None or int(state.epoch) != self._snapshot_epoch:
            self._take_snapshot(state)
        elif self._ext_dirty:
            self._snapshot["ext"] = self.engine._gather_ext()
            self._ext_dirty = False

    # ------------------------------------------------------------- recovery
    def _recover(self, fault, state):
        eng = self.engine
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError(
                f"fleet recovery exhausted after {self.max_restarts} "
                f"restart(s); last fault: {type(fault).__name__}: {fault}"
            ) from fault
        assert self._snapshot is not None  # _ensure_snapshot ran first
        t0 = time.perf_counter()
        delay = self.backoff_s * (2 ** (self.restarts - 1))
        replay = int(state.epoch) - self._snapshot_epoch
        print(
            f"[recovery] {type(fault).__name__} at epoch >= "
            f"{int(state.epoch)}: restart {self.restarts}/"
            f"{self.max_restarts}, backoff {delay:.2f}s, restoring epoch "
            f"{self._snapshot_epoch}",
            file=sys.stderr, flush=True,
        )
        if delay > 0:
            time.sleep(delay)
        snap, snap_epoch = self._snapshot, self._snapshot_epoch
        eng._reopen()
        handle = eng._handle_at(snap_epoch)
        handle = eng.scatter_state(handle, snap)
        # scatter_state drops the snapshot (it can't tell a user restore
        # from ours) — reinstate it: the restored fleet IS the snapshot
        self._snapshot, self._snapshot_epoch = snap, int(handle.epoch)
        self._ext_dirty = False
        self.recovered_epochs += max(0, replay)
        self._last_recovery = {
            "fault": type(fault).__name__,
            "restored_epoch": self._snapshot_epoch,
            "confirmed_epochs_replayed": max(0, replay),
            "backoff_s": delay,
            "restore_seconds": time.perf_counter() - t0,
        }
        return handle

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "policy": self.engine.on_fault,
            "restarts": self.restarts,
            "max_restarts": self.max_restarts,
            "snapshot_every": self.snapshot_every,
            "snapshots": self.snapshots,
            "last_snapshot_epoch": self._snapshot_epoch,
            "recovered_epochs": self.recovered_epochs,
            "incarnation": self.engine._incarnation,
            "last_recovery": (dict(self._last_recovery)
                              if self._last_recovery else None),
        }

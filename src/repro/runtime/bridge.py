"""TCP ring bridge — one shm ring pair per host, a framed socket between
(paper §III-B's "fast queues that span machines"; DESIGN.md §Multi-host
fleet; the SimBricks-style proxy of ISSUE 9).

A cross-host boundary channel keeps the standard single-host anatomy on
BOTH hosts: the sender's host owns a local slab ring + credit ring (the
worker's side), and the receiver's host owns its own local pair.  The
bridge proxy process pairs them over TCP:

  * sender host:  pop slab records from the local data ring -> SLAB
    frames on the wire; CREDIT frames from the wire -> push into the
    local credit ring (the sender's next credit);
  * receiver host: SLAB frames -> push into the local data ring; pop the
    receiver's post-fill credits from the local credit ring -> CREDIT
    frames back.

Records travel VERBATIM (``ShmRing.pop_record``/``push_record``): a
checked slab record crosses the wire with its ``[seq][crc32]`` header
intact and is verified only by the far consumer, so corruption anywhere
— producer shm, the TCP path, receiver shm — trips the SAME
``RingCorruptionError`` surface as a single-host run (end-to-end
integrity, nothing re-framed).  The bridge never originates or drops a
record (it only adds latency), so the credit protocol's
one-record-per-exchange invariant and the per-tier staleness bound hold
unchanged across hosts.

Wire format: length-prefixed frames ``[u8 flavor][u8 gen][u32 chan]
[u32 len][payload]``.  Flavors: SLAB / CREDIT (boundary records), PKT
(host packet records on the fleet control link), CTL (pickled control
messages), FENCE (generation barrier: both sides discard in-flight
frames at a quiesced boundary before a ring reset), HELLO (rendezvous
handshake: token + link id, so a stale incarnation can never splice into
a re-rendezvoused fleet).

The proxy is a first-class fleet member: it publishes heartbeats and
"blocked on ring/link" status words into the SAME heartbeat shm as the
granule workers (``fault_tolerance.ProcessMonitor``), answers a command
pipe (fence / resume / stats / slow / corrupt / exit), and accumulates
the per-link observability row surfaced as
``Simulation.stats()["bridges"]``.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import select
import socket
import struct
import sys
import time

import numpy as np

from .fault_tolerance import (
    OP_CREDIT_PUSH, OP_LINK_WAIT, OP_SLAB_POP, OP_SLAB_PUSH, encode_blocked,
)
from .shmem import ShmRing

# ------------------------------------------------------------ wire framing
FLAVOR_SLAB = 1    # boundary slab record, verbatim (checked header included)
FLAVOR_CREDIT = 2  # boundary credit record, verbatim (raw u32)
FLAVOR_PKT = 3     # host packet record (fleet control link ext forwarding)
FLAVOR_CTL = 4     # pickled control message (fleet launcher protocol)
FLAVOR_FENCE = 5   # generation barrier (quiesced-boundary ring reset)
FLAVOR_HELLO = 6   # rendezvous handshake: pickled {token, link, host}

_FRAME = struct.Struct("<BBII")  # flavor, gen, chan, payload length
_MAX_FRAME = 1 << 28             # sanity bound: no record approaches this


def send_frame(sock_, flavor: int, gen: int, chan: int,
               payload: bytes) -> int:
    """Send one length-prefixed frame; returns bytes put on the wire."""
    hdr = _FRAME.pack(flavor, gen & 0xFF, chan, len(payload))
    sock_.sendall(hdr + payload)
    return len(hdr) + len(payload)


class FrameReader:
    """Incremental frame parser over a byte stream (nonblocking reads feed
    ``feed``; complete frames come out of ``next_frame``)."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> None:
        self._buf.extend(data)

    def next_frame(self):
        """(flavor, gen, chan, payload) or None if incomplete."""
        if len(self._buf) < _FRAME.size:
            return None
        flavor, gen, chan, n = _FRAME.unpack_from(self._buf, 0)
        if n > _MAX_FRAME:
            raise ValueError(f"oversized frame: {n} bytes (flavor {flavor})")
        end = _FRAME.size + n
        if len(self._buf) < end:
            return None
        payload = bytes(self._buf[_FRAME.size:end])
        del self._buf[:end]
        return flavor, gen, chan, payload


def recv_frame(sock_, reader: FrameReader, timeout: float):
    """Blocking read of one complete frame through ``reader`` (buffered
    bytes are consumed first).  Raises ConnectionError on EOF, TimeoutError
    on deadline."""
    deadline = time.monotonic() + timeout
    while True:
        f = reader.next_frame()
        if f is not None:
            return f
        remain = deadline - time.monotonic()
        if remain <= 0:
            raise TimeoutError(f"no frame within {timeout}s")
        r, _, _ = select.select([sock_], [], [], min(remain, 0.2))
        if not r:
            continue
        data = sock_.recv(1 << 16)
        if not data:
            raise ConnectionError("peer closed the link")
        reader.feed(data)


def send_msg(sock_, obj, flavor: int = FLAVOR_CTL, gen: int = 0,
             chan: int = 0) -> int:
    """Pickle ``obj`` into one frame (the fleet control protocol)."""
    return send_frame(sock_, flavor, gen, chan, pickle.dumps(obj))


def recv_msg(sock_, reader: FrameReader, timeout: float,
             expect: int = FLAVOR_CTL):
    flavor, gen, chan, payload = recv_frame(sock_, reader, timeout)
    if flavor != expect:
        raise ValueError(f"expected frame flavor {expect}, got {flavor}")
    return pickle.loads(payload)


def connect_retry(addr: tuple[str, int], timeout: float) -> socket.socket:
    """Dial with retries until ``timeout`` (the peer's listener is
    reported before this runs, so retries only absorb scheduling skew)."""
    deadline = time.monotonic() + timeout
    while True:
        try:
            s = socket.create_connection(addr, timeout=min(timeout, 10.0))
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return s
        except OSError:
            if time.monotonic() > deadline:
                raise
            time.sleep(0.05)


# ------------------------------------------------------------- bridge spec
@dataclasses.dataclass(frozen=True)
class BridgeChannel:
    """One bridged boundary channel, seen from THIS host.

    ``side`` is "tx" when the slab producer is local (slabs flow out,
    credits flow in) and "rx" when the consumer is local."""
    chan: int
    side: str                 # "tx" | "rx"
    data_name: str            # local slab ring (checked)
    data_capacity: int
    data_slot_bytes: int
    credit_name: str          # local credit ring (raw u32)
    credit_capacity: int


@dataclasses.dataclass(frozen=True)
class BridgeSpec:
    """Everything one bridge proxy process needs (picklable spawn arg)."""
    link: int                 # global link index (fleet link map order)
    label: str                # e.g. "link0:h0<->h1"
    host: str                 # this side's host name
    peer: str                 # far side's host name
    role: str                 # "accept" | "dial"
    token: str                # fleet incarnation token (handshake check)
    port: int                 # accept side: port to bind (0 = ephemeral)
    channels: tuple           # tuple[BridgeChannel, ...]
    timeout: float
    hb_name: str | None       # heartbeat shm (shared with the workers)
    hb_index: int             # NW + local bridge index


class BridgeProxy:
    """The pump: local rings <-> framed TCP link (single-threaded)."""

    def __init__(self, spec: BridgeSpec, conn):
        self.spec = spec
        self.conn = conn                  # command pipe to the launcher
        self.gen = 0
        self.sock: socket.socket | None = None
        self.reader = FrameReader()
        self._listener: socket.socket | None = None
        self._paused = False
        self._corrupt_next = False
        self._peer_fence: int | None = None
        self._pending: tuple[int, bytes] | None = None  # (chan, record)
        self._exit = False
        # local ring attachments
        self.data: dict[int, ShmRing] = {}
        self.credit: dict[int, ShmRing] = {}
        self.tx_chans = tuple(c.chan for c in spec.channels
                              if c.side == "tx")
        self.rx_chans = tuple(c.chan for c in spec.channels
                              if c.side == "rx")
        for c in spec.channels:
            self.data[c.chan] = ShmRing.attach(
                c.data_name, c.data_capacity, c.data_slot_bytes,
                checked=True, label=f"slab:c{c.chan}")
            self.credit[c.chan] = ShmRing.attach(
                c.credit_name, c.credit_capacity, 4)
        # heartbeat record (first-class fleet member)
        self._hb_shm = self._hb = None
        if spec.hb_name:
            from .worker import attach_heartbeat

            self._hb_shm, self._hb = attach_heartbeat(spec.hb_name,
                                                      spec.hb_index)
        # observability counters (the stats()["bridges"] row)
        self.bytes_tx = self.bytes_rx = 0
        self.slabs_tx = self.slabs_rx = 0
        self.credits_tx = self.credits_rx = 0
        self.frames = 0
        self._rtt_mean = 0.0
        self._rtt_n = 0
        self._slab_sent_t: dict[int, float] = {}
        self._t0 = time.monotonic()
        self._wait_s = 0.0
        # rendezvous wall time, kept OUT of the steady-state pump window:
        # counting cold-start (peer spawn, TCP dial retries) in the
        # wait_fraction denominator used to dilute the stall metric
        self._connect_s = 0.0

    # ------------------------------------------------------------ heartbeat
    def _beat(self, status: int = 0) -> None:
        if self._hb is not None:
            self._hb[0] = float(self.frames)
            self._hb[1] = time.time()
            self._hb[2] = float(status)

    # ------------------------------------------------------------ lifecycle
    def _log(self, msg: str) -> None:
        print(f"[bridge {self.spec.label}/{self.spec.host}] {msg}",
              flush=True)

    def rendezvous(self) -> None:
        """Accept side binds + reports its port, dial side waits for the
        launcher's "dial" command; both then exchange HELLO frames and
        verify the fleet token + link id."""
        spec = self.spec
        if spec.role == "accept":
            self._listener = socket.socket(socket.AF_INET,
                                           socket.SOCK_STREAM)
            self._listener.setsockopt(socket.SOL_SOCKET,
                                      socket.SO_REUSEADDR, 1)
            self._listener.bind(("127.0.0.1", spec.port))
            self._listener.listen(1)
            port = self._listener.getsockname()[1]
            self.conn.send(("ready", port))
            deadline = time.monotonic() + max(spec.timeout, 300.0)
            while True:
                r, _, _ = select.select([self._listener], [], [], 0.2)
                if r:
                    self.sock, _ = self._listener.accept()
                    break
                if self.conn.poll(0) and self._handle_cmd_prelink():
                    return
                if time.monotonic() > deadline:
                    raise TimeoutError("no peer dialed the link")
            self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        else:
            self.conn.send(("ready", None))
            deadline = time.monotonic() + max(spec.timeout, 300.0)
            while True:
                if self.conn.poll(0.2):
                    cmd = self.conn.recv()
                    if cmd[0] == "exit":
                        self._exit = True
                        self.conn.send(("ok", None))
                        return
                    assert cmd[0] == "dial", cmd
                    self.sock = connect_retry(tuple(cmd[1]), spec.timeout)
                    break
                if time.monotonic() > deadline:
                    raise TimeoutError("launcher never sent the dial map")
        hello = {"token": spec.token, "link": spec.link, "host": spec.host}
        send_msg(self.sock, hello, flavor=FLAVOR_HELLO)
        peer = recv_msg(self.sock, self.reader,
                        max(spec.timeout, 300.0), expect=FLAVOR_HELLO)
        if peer.get("token") != spec.token or peer.get("link") != spec.link:
            raise ConnectionError(
                f"rendezvous handshake mismatch on {spec.label}: "
                f"got {peer}, want token={spec.token} link={spec.link}"
            )
        self.sock.settimeout(max(spec.timeout, 60.0))
        # link is up: close the connect window and restart the steady-
        # state clock, so wait_fraction measures pump stalls only
        self._connect_s = time.monotonic() - self._t0
        self._t0 = time.monotonic()
        self._wait_s = 0.0
        self.conn.send(("up", peer.get("host")))
        self._log(f"link up ({spec.role}, peer {peer.get('host')})")

    def _handle_cmd_prelink(self) -> bool:
        """Pre-link command handling (only exit makes sense)."""
        cmd = self.conn.recv()
        if cmd[0] == "exit":
            self._exit = True
            self.conn.send(("ok", None))
            return True
        self.conn.send(("err", f"command {cmd[0]!r} before link up"))
        return False

    # ----------------------------------------------------------------- pump
    def serve(self) -> None:
        self.rendezvous()
        while not self._exit:
            progressed = self._pump_once()
            if self.conn.poll(0):
                self._handle_cmd()
                progressed = True
            if not progressed:
                t = time.monotonic()
                self._beat(encode_blocked(
                    OP_LINK_WAIT,
                    self.tx_chans[0] if self.tx_chans
                    else (self.rx_chans[0] if self.rx_chans else 0)))
                time.sleep(100e-6)
                self._wait_s += time.monotonic() - t
            else:
                self._beat(0)

    def _pump_once(self) -> bool:
        progressed = False
        if self._paused:
            return False
        # retry a parked inbound record first (ordering: nothing newer may
        # land before it)
        if self._pending is not None:
            if not self._flush_pending():
                return False
            progressed = True
        # local -> wire
        for c in self.tx_chans:
            rec = self.data[c].pop_record()
            if rec is not None:
                self._send_record(FLAVOR_SLAB, c, rec)
                progressed = True
        for c in self.rx_chans:
            rec = self.credit[c].pop_record()
            if rec is not None:
                self._send_record(FLAVOR_CREDIT, c, rec)
                progressed = True
        # wire -> local
        r, _, _ = select.select([self.sock], [], [], 0)
        if r:
            data = self.sock.recv(1 << 16)
            if not data:
                raise ConnectionError("peer closed the link")
            self.bytes_rx += len(data)
            self.reader.feed(data)
            progressed = True
        while self._pending is None:
            f = self.reader.next_frame()
            if f is None:
                break
            self._dispatch_frame(*f)
            progressed = True
        return progressed

    def _send_record(self, flavor: int, chan: int, rec: bytes) -> None:
        if flavor == FLAVOR_SLAB:
            if self._corrupt_next:
                self._corrupt_next = False
                rec = bytearray(rec)
                rec[8 if len(rec) > 8 else 0] ^= 0xFF
                rec = bytes(rec)
                self._log(f"fault injection: corrupted slab frame on "
                          f"c{chan} (on the wire)")
            self._slab_sent_t[chan] = time.monotonic()
            self.slabs_tx += 1
        else:
            self.credits_tx += 1
        self.bytes_tx += send_frame(self.sock, flavor, self.gen, chan, rec)
        self.frames += 1

    def _dispatch_frame(self, flavor: int, gen: int, chan: int,
                        payload: bytes) -> None:
        if flavor == FLAVOR_FENCE:
            self._peer_fence = gen
            return
        if gen != (self.gen & 0xFF):
            return  # stale generation (pre-fence leftovers)
        if flavor == FLAVOR_SLAB:
            self.slabs_rx += 1
            self.frames += 1
            if not self.data[chan].push_record(payload):
                self._pending = (chan, payload)
                self._beat(encode_blocked(OP_SLAB_PUSH, chan))
        elif flavor == FLAVOR_CREDIT:
            self.credits_rx += 1
            self.frames += 1
            t0 = self._slab_sent_t.get(chan)
            if t0 is not None:
                rtt = time.monotonic() - t0
                self._rtt_n += 1
                self._rtt_mean += (rtt - self._rtt_mean) / self._rtt_n
            if not self.credit[chan].push_record(payload):
                self._pending = (chan, payload)
                self._beat(encode_blocked(OP_CREDIT_PUSH, chan))
        else:
            raise ValueError(f"unexpected frame flavor {flavor} mid-pump")

    def _flush_pending(self) -> bool:
        chan, payload = self._pending
        ring = (self.data if chan in self.rx_chans else self.credit)[chan]
        if ring.push_record(payload):
            self._pending = None
            return True
        return False

    # ------------------------------------------------------------- commands
    def _handle_cmd(self) -> None:
        cmd = self.conn.recv()
        op = cmd[0]
        if op == "exit":
            self._exit = True
            self.conn.send(("ok", None))
        elif op == "stats":
            self.conn.send(("ok", self.stats()))
        elif op == "fence":
            self._fence(int(cmd[1]))
            self.conn.send(("ok", None))
        elif op == "resume":
            self._paused = False
            self.conn.send(("ok", None))
        elif op == "slow":
            secs = float(cmd[1]) if len(cmd) > 1 and cmd[1] else 0.05
            self._log(f"fault injection: pausing the pump {secs}s")
            end = time.monotonic() + secs
            while time.monotonic() < end:
                self._beat(encode_blocked(
                    OP_LINK_WAIT, self.tx_chans[0] if self.tx_chans else 0))
                time.sleep(min(0.01, max(0.0, end - time.monotonic())))
            self.conn.send(("ok", None))
        elif op == "corrupt":
            self._corrupt_next = True
            self.conn.send(("ok", None))
        else:
            self.conn.send(("err", f"unknown bridge command {op!r}"))

    def _fence(self, gen: int) -> None:
        """Generation barrier at a quiesced boundary: exchange FENCE
        frames, discard anything in flight from the old generation, and
        pause the pump until "resume" (the launcher resets/reseeds the
        rings in between).  Records discarded here are by construction
        re-seeded by the caller (init) or restored (scatter)."""
        send_frame(self.sock, FLAVOR_FENCE, gen, 0, b"")
        deadline = time.monotonic() + max(self.spec.timeout, 60.0)
        while self._peer_fence is None or self._peer_fence != (gen & 0xFF):
            f = self.reader.next_frame()
            if f is not None:
                if f[0] == FLAVOR_FENCE:
                    self._peer_fence = f[1]
                continue  # pre-fence frames of the old generation: discard
            if time.monotonic() > deadline:
                raise TimeoutError(f"peer never fenced (gen {gen})")
            r, _, _ = select.select([self.sock], [], [], 0.2)
            if r:
                data = self.sock.recv(1 << 16)
                if not data:
                    raise ConnectionError("peer closed during fence")
                self.reader.feed(data)
        self.gen = gen
        self._peer_fence = None
        self._pending = None
        self._slab_sent_t.clear()
        self._paused = True
        self._log(f"fenced at generation {gen}")

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        total = max(time.monotonic() - self._t0, 1e-9)
        return {
            "link": self.spec.link,
            "label": self.spec.label,
            "host": self.spec.host,
            "peer": self.spec.peer,
            "role": self.spec.role,
            "channels": len(self.spec.channels),
            "bytes_tx": int(self.bytes_tx),
            "bytes_rx": int(self.bytes_rx),
            "slabs_tx": int(self.slabs_tx),
            "slabs_rx": int(self.slabs_rx),
            "credits_tx": int(self.credits_tx),
            "credits_rx": int(self.credits_rx),
            "credit_rtt_s": float(self._rtt_mean),
            "wait_fraction": float(self._wait_s / total),
            "connect_s": float(self._connect_s),
        }

    def close(self) -> None:
        for ring in (*self.data.values(), *self.credit.values()):
            ring.close()
        self.data.clear()
        self.credit.clear()
        if self._hb_shm is not None:
            self._hb = None
            try:
                self._hb_shm.close()
            except Exception:
                pass
        for s in (self.sock, self._listener):
            if s is not None:
                try:
                    s.close()
                except Exception:
                    pass


def bridge_entry(conn, spec_pickle: bytes, log_path: str | None) -> None:
    """Bridge proxy process entry point (same spawn idiom as
    ``worker_entry``): captured log, command pipe, heartbeat membership.
    Any link failure — peer reset, EOF, frame timeout — exits nonzero, so
    the launcher's ProcessMonitor converts it into ``LinkDownError`` (a
    RECOVERABLE fault) within one poll interval."""
    spec: BridgeSpec = pickle.loads(spec_pickle)
    if log_path:
        f = open(log_path, "a", buffering=1)
        os.dup2(f.fileno(), 1)
        os.dup2(f.fileno(), 2)
        sys.stdout = os.fdopen(1, "w", buffering=1)
        sys.stderr = os.fdopen(2, "w", buffering=1)
    proxy = None
    try:
        proxy = BridgeProxy(spec, conn)
        proxy._log(f"channels tx={list(proxy.tx_chans)} "
                   f"rx={list(proxy.rx_chans)} role={spec.role}")
        proxy.serve()
        proxy._log("clean exit")
    except Exception as e:  # noqa: BLE001 — any link failure is terminal
        print(f"[bridge {spec.label}/{spec.host}] FATAL: "
              f"{type(e).__name__}: {e}", flush=True)
        try:
            if proxy is not None:
                proxy.close()
        finally:
            os._exit(1)
    finally:
        if proxy is not None:
            proxy.close()
        try:
            conn.close()
        except Exception:
            pass


__all__ = [
    "FLAVOR_SLAB", "FLAVOR_CREDIT", "FLAVOR_PKT", "FLAVOR_CTL",
    "FLAVOR_FENCE", "FLAVOR_HELLO", "BridgeChannel", "BridgeSpec",
    "BridgeProxy", "FrameReader", "bridge_entry", "connect_retry",
    "recv_frame", "recv_msg", "send_frame", "send_msg",
]

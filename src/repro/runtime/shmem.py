"""Lock-free SPSC rings over ``multiprocessing.shared_memory`` (paper §III-B).

This is the paper's headline data structure, reproduced at its native
layer: a single-producer single-consumer ring buffer in a shared-memory
segment, connecting two *free-running OS processes* with no locks and no
syscalls on the fast path.  The layout mirrors the paper's queue page —

    byte   0:  head (u32, next slot to WRITE; producer-owned)
    byte  64:  tail (u32, next slot to READ;  consumer-owned)
    byte 128:  capacity slots of ``slot_bytes`` each

— head and tail on separate cache lines so producer and consumer never
false-share (§III-B's "cache-friendly" split), and the ring arithmetic is
**bit-compatible with ``repro.core.queue``**: ``head == tail`` is empty,
``(head + 1) % capacity == tail`` is full, so a ring of capacity C holds
at most C - 1 records (property-tested against the in-process QueueArray
semantics in ``tests/test_runtime.py``).

Ordering: the producer writes the slot payload *before* publishing
``head``; the consumer reads the payload before publishing ``tail``.
CPython's GIL plus x86-TSO store ordering make the aligned u32
publication atomic and ordered for this use — the same argument the
paper makes for its acquire/release pair, at Python's abstraction level.

Three record flavors sit on the same ring:

  * **packet rings** (host Tx/Rx ports): one slot = one W-word packet;
  * **slab rings** (boundary channels): one slot = one epoch's exchange
    slab, ``u32 count + E*W payload`` — the free-running runtime's unit
    of synchronization (DESIGN.md §Runtime);
  * **credit rings** (reverse direction of each boundary channel): one
    slot = one u32 credit, the receiver's post-fill free space.

Blocking helpers (``push_wait`` / ``pop_wait``) spin with a short sleep
and honor a deadline plus an optional liveness ``check`` callback, so a
dead peer surfaces as ``RingTimeout`` (→ ``WorkerDiedError`` in the
launcher) instead of a hang.
"""
from __future__ import annotations

import time
from multiprocessing import shared_memory
from typing import Callable

import numpy as np

_HEAD_OFF = 0
_TAIL_OFF = 64
_DATA_OFF = 128


class RingTimeout(RuntimeError):
    """A blocking ring operation exceeded its deadline."""


def attach_shared_memory(name: str) -> shared_memory.SharedMemory:
    """Attach to an existing segment WITHOUT registering it with the
    resource tracker.

    The launcher owns every segment's lifetime (create + unlink).  A
    worker that attaches through the stock constructor would register the
    name a second time with the shared resource tracker (CPython
    bpo-38119), and the worker's exit would then unlink — or warn about —
    a segment its peers are still using.  Suppressing registration on the
    attach side leaves exactly one owner."""
    from multiprocessing import resource_tracker

    orig = resource_tracker.register

    def _skip(name_, rtype):  # pragma: no cover - trivial shim
        if rtype != "shared_memory":
            orig(name_, rtype)

    resource_tracker.register = _skip
    try:
        return shared_memory.SharedMemory(name=name, create=False)
    finally:
        resource_tracker.register = orig


class ShmRing:
    """One SPSC ring in a named shared-memory segment.

    Exactly one process may push and one may pop (they can be the same
    process).  ``capacity`` counts slots; at most ``capacity - 1`` records
    are ever resident — the ``repro.core.queue`` convention.
    """

    def __init__(self, shm: shared_memory.SharedMemory, capacity: int,
                 slot_bytes: int, *, owner: bool):
        self._shm = shm
        self.name = shm.name
        self.capacity = int(capacity)
        self.slot_bytes = int(slot_bytes)
        self._owner = owner
        buf = shm.buf
        self._head = np.frombuffer(buf, np.uint32, count=1, offset=_HEAD_OFF)
        self._tail = np.frombuffer(buf, np.uint32, count=1, offset=_TAIL_OFF)
        self._slots = np.frombuffer(
            buf, np.uint8, count=self.capacity * self.slot_bytes,
            offset=_DATA_OFF,
        ).reshape(self.capacity, self.slot_bytes)

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def create(cls, name: str, capacity: int, slot_bytes: int) -> "ShmRing":
        if capacity < 2:
            raise ValueError(f"ring capacity must be >= 2, got {capacity}")
        size = _DATA_OFF + capacity * slot_bytes
        shm = shared_memory.SharedMemory(name=name, create=True, size=size)
        shm.buf[:_DATA_OFF] = bytes(_DATA_OFF)
        ring = cls(shm, capacity, slot_bytes, owner=True)
        return ring

    @classmethod
    def attach(cls, name: str, capacity: int, slot_bytes: int) -> "ShmRing":
        return cls(attach_shared_memory(name), capacity, slot_bytes,
                   owner=False)

    def close(self) -> None:
        # Release numpy views before closing the mmap (else BufferError).
        self._head = self._tail = self._slots = None
        try:
            self._shm.close()
        except Exception:
            pass
        if self._owner:
            try:
                self._shm.unlink()
            except Exception:
                pass

    # ------------------------------------------------------------ occupancy
    @property
    def head(self) -> int:
        return int(self._head[0])

    @property
    def tail(self) -> int:
        return int(self._tail[0])

    def size(self) -> int:
        return (self.head - self.tail) % self.capacity

    def free(self) -> int:
        return (self.capacity - 1) - self.size()

    def empty(self) -> bool:
        return self.head == self.tail

    def full(self) -> bool:
        return (self.head + 1) % self.capacity == self.tail

    def reset(self) -> None:
        """Drop all records (single-threaded use only — e.g. session reset,
        while no worker is running)."""
        self._head[0] = 0
        self._tail[0] = 0

    # ------------------------------------------------------------- raw slots
    def push_bytes(self, payload) -> bool:
        """Write one record.  Returns False when full (nothing written)."""
        h, t = self.head, self.tail
        if (h + 1) % self.capacity == t:
            return False
        view = np.frombuffer(payload, np.uint8)
        self._slots[h, : view.size] = view
        self._head[0] = (h + 1) % self.capacity  # publish AFTER the payload
        return True

    def pop_bytes(self) -> bytes | None:
        """Read one record (a copy).  Returns None when empty."""
        h, t = self.head, self.tail
        if h == t:
            return None
        out = self._slots[t].tobytes()
        self._tail[0] = (t + 1) % self.capacity
        return out

    def _wait(self, ready: Callable[[], bool], timeout: float,
              check: Callable[[], None] | None, what: str) -> None:
        deadline = time.monotonic() + timeout
        delay = 20e-6
        while not ready():
            if check is not None:
                check()
            if time.monotonic() > deadline:
                raise RingTimeout(
                    f"{what} on ring {self.name} timed out after {timeout}s "
                    f"(size={self.size()}/{self.capacity - 1})"
                )
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def push_bytes_wait(self, payload, timeout: float,
                        check: Callable[[], None] | None = None) -> None:
        self._wait(lambda: not self.full(), timeout, check, "push")
        assert self.push_bytes(payload)

    def pop_bytes_wait(self, timeout: float,
                       check: Callable[[], None] | None = None) -> bytes:
        self._wait(lambda: not self.empty(), timeout, check, "pop")
        out = self.pop_bytes()
        assert out is not None
        return out

    # ------------------------------------------- packet records (host ports)
    # One slot = one packet of W words; dtype fixed at ring construction by
    # slot_bytes = W * itemsize.  Batched push/pop move what fits and report
    # the count — the same partial-landing contract as queue.fill_single.
    def push_packets(self, arr: np.ndarray) -> int:
        """Push up to len(arr) packets ((k, slot_bytes) as raw rows after a
        view cast); records beyond ``free()`` are refused.  Returns count."""
        if len(arr) == 0:
            return 0
        raw = np.ascontiguousarray(arr).view(np.uint8).reshape(len(arr), -1)
        if raw.shape[1] != self.slot_bytes:
            raise ValueError(
                f"packet rows are {raw.shape[1]}B, ring slots {self.slot_bytes}B"
            )
        n = min(len(raw), self.free())
        h = self.head
        for i in range(n):  # small k (<= capacity-1); clarity over vectorizing
            self._slots[(h + i) % self.capacity] = raw[i]
        if n:
            self._head[0] = (h + n) % self.capacity
        return n

    def peek_packets(self, max_n: int, dtype, words: int) -> np.ndarray:
        """Read up to ``max_n`` packets WITHOUT consuming them — the caller
        commits with ``advance(n)`` after it knows how many landed
        downstream (partial host-tier ingest)."""
        n = min(max_n, self.size())
        t = self.tail
        idx = (t + np.arange(n)) % self.capacity
        raw = self._slots[idx]
        return raw.view(np.dtype(dtype)).reshape(n, words).copy()

    def advance(self, n: int) -> None:
        """Consume ``n`` records previously ``peek``ed."""
        if n:
            self._tail[0] = (self.tail + n) % self.capacity
    def pop_packets(self, max_n: int, dtype, words: int) -> np.ndarray:
        out = self.peek_packets(max_n, dtype, words)
        self.advance(len(out))
        return out

    # --------------------------------------- slab records (boundary channels)
    # One slot = u32 count + E*W payload words: one epoch's exchange slab.
    def push_slab_wait(self, count: int, slab: np.ndarray, timeout: float,
                       check: Callable[[], None] | None = None) -> None:
        rec = np.empty((self.slot_bytes,), np.uint8)
        rec[:4] = np.frombuffer(np.uint32(count).tobytes(), np.uint8)
        raw = np.ascontiguousarray(slab).view(np.uint8).reshape(-1)
        rec[4:4 + raw.size] = raw
        self.push_bytes_wait(rec, timeout, check)

    def pop_slab_wait(self, shape, dtype, timeout: float,
                      check: Callable[[], None] | None = None
                      ) -> tuple[int, np.ndarray]:
        rec = self.pop_bytes_wait(timeout, check)
        count = int(np.frombuffer(rec, np.uint32, count=1)[0])
        slab = np.frombuffer(rec, np.dtype(dtype), offset=4,
                             count=int(np.prod(shape))).reshape(shape)
        return count, slab

    # ------------------------------------------------------- credit records
    def push_u32(self, value: int, timeout: float,
                 check: Callable[[], None] | None = None) -> None:
        self.push_bytes_wait(np.uint32(value).tobytes(), timeout, check)

    def pop_u32_wait(self, timeout: float,
                     check: Callable[[], None] | None = None) -> int:
        return int(np.frombuffer(self.pop_bytes_wait(timeout, check),
                                 np.uint32, count=1)[0])

    # --------------------------------------------- checkpoint gather-scatter
    def snapshot(self) -> np.ndarray:
        """Resident records, oldest first, WITHOUT consuming them —
        (size, slot_bytes) u8.  Single-threaded use only (session rest)."""
        n = self.size()
        idx = (self.tail + np.arange(n)) % self.capacity
        return self._slots[idx].copy()

    def restore(self, records: np.ndarray) -> None:
        """Replace the ring contents with ``records`` ((k, slot_bytes) u8)."""
        records = np.asarray(records, np.uint8).reshape(-1, self.slot_bytes)
        if len(records) > self.capacity - 1:
            raise ValueError(
                f"{len(records)} records > ring capacity-1={self.capacity - 1}"
            )
        self.reset()
        self._slots[: len(records)] = records
        self._head[0] = len(records)

    def __repr__(self):
        return (f"ShmRing({self.name!r}, {self.size()}/{self.capacity - 1} "
                f"x {self.slot_bytes}B)")


def slab_slot_bytes(E: int, W: int, itemsize: int) -> int:
    """Slot size for a boundary-channel slab ring (u32 count + E*W words)."""
    return 4 + E * W * itemsize

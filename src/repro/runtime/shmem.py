"""Lock-free SPSC rings over ``multiprocessing.shared_memory`` (paper §III-B).

This is the paper's headline data structure, reproduced at its native
layer: a single-producer single-consumer ring buffer in a shared-memory
segment, connecting two *free-running OS processes* with no locks and no
syscalls on the fast path.  The layout mirrors the paper's queue page —

    byte   0:  head (u32, next slot to WRITE; producer-owned)
    byte  64:  tail (u32, next slot to READ;  consumer-owned)
    byte 128:  capacity slots of ``slot_bytes`` each

— head and tail on separate cache lines so producer and consumer never
false-share (§III-B's "cache-friendly" split), and the ring arithmetic is
**bit-compatible with ``repro.core.queue``**: ``head == tail`` is empty,
``(head + 1) % capacity == tail`` is full, so a ring of capacity C holds
at most C - 1 records (property-tested against the in-process QueueArray
semantics in ``tests/test_runtime.py``).

Ordering: the producer writes the slot payload *before* publishing
``head``; the consumer reads the payload before publishing ``tail``.
CPython's GIL plus x86-TSO store ordering make the aligned u32
publication atomic and ordered for this use — the same argument the
paper makes for its acquire/release pair, at Python's abstraction level.

Three record flavors sit on the same ring:

  * **packet rings** (host Tx/Rx ports): one slot = one W-word packet;
  * **slab rings** (boundary channels): one slot = one epoch's exchange
    slab, ``u32 count + E*W payload`` — the free-running runtime's unit
    of synchronization (DESIGN.md §Runtime);
  * **credit rings** (reverse direction of each boundary channel): one
    slot = one u32 credit, the receiver's post-fill free space.

Blocking helpers (``push_wait`` / ``pop_wait``) spin with a short sleep
and honor a deadline plus an optional liveness ``check`` callback, so a
dead peer surfaces as ``RingTimeout`` (→ ``WorkerDiedError`` in the
launcher) instead of a hang.

Integrity (ISSUE 8): a ring created with ``checked=True`` prefixes every
record with a ``[u32 seq][u32 crc32]`` header.  The producer stamps a
monotonically increasing sequence number and the crc32 of the payload;
the consumer verifies BOTH before the payload is used, so a torn write,
a stray memory scribble, or a protocol slip (skipped/duplicated record)
raises ``RingCorruptionError`` — naming the channel and the
expected/actual values — instead of silently corrupting simulator state.
The two sequence counters live in the shm header (producer's next to
``head``, consumer's next to ``tail``) so both sides agree across
processes; slab and host-port packet rings are checked, the 4-byte
credit rings are not (their payload IS the protocol invariant, asserted
by ``gather_state``).
"""
from __future__ import annotations

import time
import zlib
from multiprocessing import shared_memory
from typing import Callable

import numpy as np

_HEAD_OFF = 0
_PROD_SEQ_OFF = 8    # producer cache line, next to head
_TAIL_OFF = 64
_CONS_SEQ_OFF = 72   # consumer cache line, next to tail
_DATA_OFF = 128
_HDR_BYTES = 8       # [u32 seq][u32 crc32] per checked record


class RingTimeout(RuntimeError):
    """A blocking ring operation exceeded its deadline."""


class RingCorruptionError(RuntimeError):
    """A checked ring record failed its sequence or crc32 verification.

    Carries the channel label, the mismatch kind (``"seq"`` | ``"crc"``),
    and the expected/actual values so the failure names exactly which
    boundary channel went bad — routed into the recovery path by the
    launcher (``repro.runtime.recovery``)."""

    def __init__(self, channel: str, kind: str, expected: int, actual: int,
                 seq: int | None = None):
        self.channel = channel
        self.kind = kind
        self.expected = int(expected)
        self.actual = int(actual)
        self.seq = None if seq is None else int(seq)
        if kind == "seq":
            msg = (f"ring corruption on {channel}: record sequence expected "
                   f"{self.expected}, got {self.actual}")
        else:
            msg = (f"ring corruption on {channel}: crc32 mismatch at seq "
                   f"{self.seq} (expected {self.expected:#010x}, got "
                   f"{self.actual:#010x})")
        super().__init__(msg)

    def to_payload(self) -> dict:
        """Picklable reconstruction args (worker → launcher fault reply)."""
        return {"channel": self.channel, "kind": self.kind,
                "expected": self.expected, "actual": self.actual,
                "seq": self.seq}


def attach_shared_memory(name: str) -> shared_memory.SharedMemory:
    """Attach to an existing segment WITHOUT registering it with the
    resource tracker.

    The launcher owns every segment's lifetime (create + unlink).  A
    worker that attaches through the stock constructor would register the
    name a second time with the shared resource tracker (CPython
    bpo-38119), and the worker's exit would then unlink — or warn about —
    a segment its peers are still using.  Suppressing registration on the
    attach side leaves exactly one owner."""
    from multiprocessing import resource_tracker

    orig = resource_tracker.register

    def _skip(name_, rtype):  # pragma: no cover - trivial shim
        if rtype != "shared_memory":
            orig(name_, rtype)

    resource_tracker.register = _skip
    try:
        return shared_memory.SharedMemory(name=name, create=False)
    finally:
        resource_tracker.register = orig


class ShmRing:
    """One SPSC ring in a named shared-memory segment.

    Exactly one process may push and one may pop (they can be the same
    process).  ``capacity`` counts slots; at most ``capacity - 1`` records
    are ever resident — the ``repro.core.queue`` convention.
    """

    def __init__(self, shm: shared_memory.SharedMemory, capacity: int,
                 slot_bytes: int, *, owner: bool, checked: bool = False,
                 label: str = ""):
        self._shm = shm
        self.name = shm.name
        self.capacity = int(capacity)
        self.slot_bytes = int(slot_bytes)          # payload bytes per record
        self.checked = bool(checked)
        self.label = label or shm.name
        self.stride = self.slot_bytes + (_HDR_BYTES if checked else 0)
        self._owner = owner
        self._corrupt_next = False                 # fault-injection hook
        buf = shm.buf
        self._head = np.frombuffer(buf, np.uint32, count=1, offset=_HEAD_OFF)
        self._tail = np.frombuffer(buf, np.uint32, count=1, offset=_TAIL_OFF)
        self._pseq = np.frombuffer(buf, np.uint32, count=1,
                                   offset=_PROD_SEQ_OFF)
        self._cseq = np.frombuffer(buf, np.uint32, count=1,
                                   offset=_CONS_SEQ_OFF)
        self._slots = np.frombuffer(
            buf, np.uint8, count=self.capacity * self.stride,
            offset=_DATA_OFF,
        ).reshape(self.capacity, self.stride)

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def create(cls, name: str, capacity: int, slot_bytes: int, *,
               checked: bool = False, label: str = "") -> "ShmRing":
        if capacity < 2:
            raise ValueError(f"ring capacity must be >= 2, got {capacity}")
        stride = slot_bytes + (_HDR_BYTES if checked else 0)
        size = _DATA_OFF + capacity * stride
        shm = shared_memory.SharedMemory(name=name, create=True, size=size)
        shm.buf[:_DATA_OFF] = bytes(_DATA_OFF)
        ring = cls(shm, capacity, slot_bytes, owner=True, checked=checked,
                   label=label)
        return ring

    @classmethod
    def attach(cls, name: str, capacity: int, slot_bytes: int, *,
               checked: bool = False, label: str = "") -> "ShmRing":
        return cls(attach_shared_memory(name), capacity, slot_bytes,
                   owner=False, checked=checked, label=label)

    def close(self) -> None:
        # Release numpy views before closing the mmap (else BufferError).
        self._head = self._tail = self._slots = None
        self._pseq = self._cseq = None
        try:
            self._shm.close()
        except Exception:
            pass
        if self._owner:
            try:
                self._shm.unlink()
            except Exception:
                pass

    # ------------------------------------------------------------ occupancy
    @property
    def head(self) -> int:
        return int(self._head[0])

    @property
    def tail(self) -> int:
        return int(self._tail[0])

    def size(self) -> int:
        return (self.head - self.tail) % self.capacity

    def free(self) -> int:
        return (self.capacity - 1) - self.size()

    def empty(self) -> bool:
        return self.head == self.tail

    def full(self) -> bool:
        return (self.head + 1) % self.capacity == self.tail

    def reset(self) -> None:
        """Drop all records (single-threaded use only — e.g. session reset,
        while no worker is running)."""
        self._head[0] = 0
        self._tail[0] = 0
        self._pseq[0] = 0
        self._cseq[0] = 0

    # ----------------------------------------------------- integrity (ISSUE 8)
    def corrupt_next_push(self) -> None:
        """Fault injection: flip a payload byte of the NEXT pushed record
        AFTER its crc is stamped, so the consumer's verification trips."""
        self._corrupt_next = True

    def _write_slot(self, h: int, view: np.ndarray) -> None:
        """Write one record into slot ``h`` (checked layout: seq+crc hdr)."""
        slot = self._slots[h]
        if not self.checked:
            slot[: view.size] = view
            return
        slot[_HDR_BYTES: _HDR_BYTES + view.size] = view
        if view.size < self.slot_bytes:
            slot[_HDR_BYTES + view.size:] = 0
        seq = int(self._pseq[0])
        crc = zlib.crc32(slot[_HDR_BYTES:].tobytes())
        slot[0:4] = np.frombuffer(np.uint32(seq).tobytes(), np.uint8)
        slot[4:8] = np.frombuffer(np.uint32(crc).tobytes(), np.uint8)
        if self._corrupt_next:
            self._corrupt_next = False
            slot[_HDR_BYTES] ^= 0xFF
        self._pseq[0] = np.uint32(seq + 1)

    def _verify_slot(self, idx: int, expect_seq: int) -> None:
        # Verify a COPY: a raising frame must not pin a live view of the
        # shm buffer in its traceback (the mmap could never close).
        rec = self._slots[idx].tobytes()
        seq = int.from_bytes(rec[0:4], "little")
        if seq != expect_seq % (1 << 32):
            raise RingCorruptionError(self.label, "seq", expect_seq, seq)
        crc_stored = int.from_bytes(rec[4:8], "little")
        crc_actual = zlib.crc32(rec[_HDR_BYTES:])
        if crc_stored != crc_actual:
            raise RingCorruptionError(self.label, "crc", crc_stored,
                                      crc_actual, seq=seq)

    # ------------------------------------------------------------- raw slots
    def push_bytes(self, payload) -> bool:
        """Write one record.  Returns False when full (nothing written)."""
        h, t = self.head, self.tail
        if (h + 1) % self.capacity == t:
            return False
        view = np.frombuffer(payload, np.uint8)
        self._write_slot(h, view)
        self._head[0] = (h + 1) % self.capacity  # publish AFTER the payload
        return True

    def pop_bytes(self) -> bytes | None:
        """Read one record's payload (a copy).  Returns None when empty.
        On a checked ring the record is verified BEFORE the payload is
        returned (raises ``RingCorruptionError`` on mismatch)."""
        h, t = self.head, self.tail
        if h == t:
            return None
        if self.checked:
            self._verify_slot(t, int(self._cseq[0]))
            out = self._slots[t, _HDR_BYTES:].tobytes()
            self._cseq[0] = np.uint32(int(self._cseq[0]) + 1)
        else:
            out = self._slots[t].tobytes()
        self._tail[0] = (t + 1) % self.capacity
        return out

    # -------------------------------------------- verbatim records (bridges)
    # The TCP bridge (``runtime.bridge``) forwards records BETWEEN rings
    # without interpreting them: a checked record travels with its
    # [seq][crc32] header intact, so the far-side consumer's verification
    # covers the wire too (end-to-end integrity, no re-framing).  The
    # local seq counters still advance so native push/pop interoperate
    # with forwarded records on the same ring.
    def pop_record(self) -> bytes | None:
        """Pop one record VERBATIM (checked rings include the seq+crc
        header), without verification.  Returns None when empty."""
        h, t = self.head, self.tail
        if h == t:
            return None
        out = self._slots[t].tobytes()
        if self.checked:
            self._cseq[0] = np.uint32(int(self._cseq[0]) + 1)
        self._tail[0] = (t + 1) % self.capacity
        return out

    def push_record(self, record: bytes) -> bool:
        """Push one VERBATIM record (stride bytes, headers preserved —
        the producer seq is NOT re-stamped).  Returns False when full."""
        view = np.frombuffer(record, np.uint8)
        if view.size != self.stride:
            raise ValueError(
                f"verbatim record is {view.size}B, ring stride is "
                f"{self.stride}B ({self.label})"
            )
        h, t = self.head, self.tail
        if (h + 1) % self.capacity == t:
            return False
        self._slots[h, :] = view
        if self.checked:
            self._pseq[0] = np.uint32(int(self._pseq[0]) + 1)
        self._head[0] = (h + 1) % self.capacity
        return True

    def _wait(self, ready: Callable[[], bool], timeout: float,
              check: Callable[[], None] | None, what: str) -> None:
        deadline = time.monotonic() + timeout
        delay = 20e-6
        while not ready():
            if check is not None:
                check()
            if time.monotonic() > deadline:
                raise RingTimeout(
                    f"{what} on ring {self.name} timed out after {timeout}s "
                    f"(size={self.size()}/{self.capacity - 1})"
                )
            time.sleep(delay)
            delay = min(delay * 2, 1e-3)

    def push_bytes_wait(self, payload, timeout: float,
                        check: Callable[[], None] | None = None) -> None:
        self._wait(lambda: not self.full(), timeout, check, "push")
        assert self.push_bytes(payload)

    def pop_bytes_wait(self, timeout: float,
                       check: Callable[[], None] | None = None) -> bytes:
        self._wait(lambda: not self.empty(), timeout, check, "pop")
        out = self.pop_bytes()
        assert out is not None
        return out

    # ------------------------------------------- packet records (host ports)
    # One slot = one packet of W words; dtype fixed at ring construction by
    # slot_bytes = W * itemsize.  Batched push/pop move what fits and report
    # the count — the same partial-landing contract as queue.fill_single.
    def push_packets(self, arr: np.ndarray) -> int:
        """Push up to len(arr) packets ((k, slot_bytes) as raw rows after a
        view cast); records beyond ``free()`` are refused.  Returns count."""
        if len(arr) == 0:
            return 0
        raw = np.ascontiguousarray(arr).view(np.uint8).reshape(len(arr), -1)
        if raw.shape[1] != self.slot_bytes:
            raise ValueError(
                f"packet rows are {raw.shape[1]}B, ring slots {self.slot_bytes}B"
            )
        n = min(len(raw), self.free())
        h = self.head
        for i in range(n):  # small k (<= capacity-1); clarity over vectorizing
            self._write_slot((h + i) % self.capacity, raw[i])
        if n:
            self._head[0] = (h + n) % self.capacity
        return n

    def peek_packets(self, max_n: int, dtype, words: int) -> np.ndarray:
        """Read up to ``max_n`` packets WITHOUT consuming them — the caller
        commits with ``advance(n)`` after it knows how many landed
        downstream (partial host-tier ingest).  Checked rings verify every
        peeked record (seq + crc) before returning payloads."""
        n = min(max_n, self.size())
        t = self.tail
        idx = (t + np.arange(n)) % self.capacity
        if self.checked:
            base = int(self._cseq[0])
            for j in range(n):
                self._verify_slot(int(idx[j]), base + j)
            raw = np.ascontiguousarray(self._slots[idx][:, _HDR_BYTES:])
        else:
            raw = self._slots[idx]
        return raw.view(np.dtype(dtype)).reshape(n, words).copy()

    def advance(self, n: int) -> None:
        """Consume ``n`` records previously ``peek``ed."""
        if n:
            if self.checked:
                self._cseq[0] = np.uint32(int(self._cseq[0]) + n)
            self._tail[0] = (self.tail + n) % self.capacity
    def pop_packets(self, max_n: int, dtype, words: int) -> np.ndarray:
        out = self.peek_packets(max_n, dtype, words)
        self.advance(len(out))
        return out

    # --------------------------------------- slab records (boundary channels)
    # One slot = u32 count + E*W payload words: one epoch's exchange slab.
    def push_slab_wait(self, count: int, slab: np.ndarray, timeout: float,
                       check: Callable[[], None] | None = None) -> None:
        rec = np.empty((self.slot_bytes,), np.uint8)
        rec[:4] = np.frombuffer(np.uint32(count).tobytes(), np.uint8)
        raw = np.ascontiguousarray(slab).view(np.uint8).reshape(-1)
        rec[4:4 + raw.size] = raw
        self.push_bytes_wait(rec, timeout, check)

    def pop_slab_wait(self, shape, dtype, timeout: float,
                      check: Callable[[], None] | None = None
                      ) -> tuple[int, np.ndarray]:
        rec = self.pop_bytes_wait(timeout, check)
        count = int(np.frombuffer(rec, np.uint32, count=1)[0])
        slab = np.frombuffer(rec, np.dtype(dtype), offset=4,
                             count=int(np.prod(shape))).reshape(shape)
        return count, slab

    # ------------------------------------------------------- credit records
    def push_u32(self, value: int, timeout: float,
                 check: Callable[[], None] | None = None) -> None:
        self.push_bytes_wait(np.uint32(value).tobytes(), timeout, check)

    def pop_u32_wait(self, timeout: float,
                     check: Callable[[], None] | None = None) -> int:
        return int(np.frombuffer(self.pop_bytes_wait(timeout, check),
                                 np.uint32, count=1)[0])

    # --------------------------------------------- checkpoint gather-scatter
    def seq_state(self) -> tuple[int, int]:
        """(producer_seq, consumer_seq) — captured alongside ``snapshot()``
        so a restore into a FRESH segment (fleet respawn) resumes the exact
        sequence-number timeline and stays bit-identical to a fault-free
        run."""
        return int(self._pseq[0]), int(self._cseq[0])

    def snapshot(self) -> np.ndarray:
        """Resident records, oldest first, WITHOUT consuming them —
        (size, stride) u8 (checked rings include the seq+crc headers).
        Single-threaded use only (session rest)."""
        n = self.size()
        idx = (self.tail + np.arange(n)) % self.capacity
        return self._slots[idx].copy()

    def restore(self, records: np.ndarray,
                seq: tuple[int, int] | None = None) -> None:
        """Replace the ring contents with ``records`` ((k, stride) u8).

        For a checked ring, ``seq`` restores the exact producer/consumer
        sequence counters (from ``seq_state()``); without it they are
        resynced from the resident records' headers (0 when empty)."""
        records = np.asarray(records, np.uint8).reshape(-1, self.stride)
        if len(records) > self.capacity - 1:
            raise ValueError(
                f"{len(records)} records > ring capacity-1={self.capacity - 1}"
            )
        self.reset()
        self._slots[: len(records)] = records
        self._head[0] = len(records)
        if self.checked:
            if seq is not None:
                self._pseq[0] = np.uint32(seq[0])
                self._cseq[0] = np.uint32(seq[1])
            elif len(records):
                first = int.from_bytes(records[0, 0:4].tobytes(), "little")
                self._cseq[0] = np.uint32(first)
                self._pseq[0] = np.uint32(first + len(records))

    def __repr__(self):
        kind = "checked " if self.checked else ""
        return (f"ShmRing({self.label!r}, {kind}{self.size()}/"
                f"{self.capacity - 1} x {self.slot_bytes}B)")


def slab_slot_bytes(E: int, W: int, itemsize: int) -> int:
    """Slot size for a boundary-channel slab ring (u32 count + E*W words)."""
    return 4 + E * W * itemsize

"""repro.runtime — the free-running multiprocess runtime (DESIGN.md §Runtime).

The paper's deployment model, realized literally: one *prebuilt* granule
simulator per OS process, connected at runtime by lock-free shared-memory
SPSC queues, free-running with no global barrier — scale-up is "run more
instances" and build time stays flat in instance count.

  shmem            SPSC rings over multiprocessing.shared_memory, layout
                   and semantics bit-compatible with core/queue.py (§III-B)
  worker           per-granule worker process: AOT-compiled epoch stepper
                   (the prebuilt-simulator cache) + credit-gated free run
  launcher         ProcsEngine — Network.build(engine="procs"): spawn,
                   wire, and drive the fleet behind the Simulation facade
  fault_tolerance  watchdogs, crash/restart loops, WorkerDiedError with
                   captured worker log tails
"""
from .fault_tolerance import WorkerDiedError
from .launcher import ProcsEngine, ProcsState
from .shmem import RingTimeout, ShmRing

__all__ = [
    "ProcsEngine", "ProcsState", "RingTimeout", "ShmRing", "WorkerDiedError",
]

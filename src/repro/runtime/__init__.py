"""repro.runtime — the free-running multiprocess runtime (DESIGN.md §Runtime).

The paper's deployment model, realized literally: one *prebuilt* granule
simulator per OS process, connected at runtime by lock-free shared-memory
SPSC queues, free-running with no global barrier — scale-up is "run more
instances" and build time stays flat in instance count.

  shmem            SPSC rings over multiprocessing.shared_memory, layout
                   and semantics bit-compatible with core/queue.py (§III-B)
  worker           per-granule worker process: AOT-compiled epoch stepper
                   (the prebuilt-simulator cache) + credit-gated free run
  launcher         ProcsEngine — Network.build(engine="procs"): spawn,
                   wire, and drive the fleet behind the Simulation facade
  fault_tolerance  watchdogs, crash/restart loops, WorkerDiedError with
                   captured worker log tails, fleet stall diagnosis
                   (credit wait-for graph -> FleetStallError)
  faultinject      deterministic, plan-driven worker faults for drills
                   (REPRO_FAULT_PLAN: kill/exit0/hang/slow/mute/corrupt)
  recovery         coordinated snapshots + respawn/restore/replay — the
                   self-healing policy behind ProcsEngine(on_fault=
                   "recover") / REPRO_ON_FAULT (ISSUE 8)
  bridge           TCP ring bridge proxy: pairs local shm rings with a
                   remote host's over length-prefixed framing, verbatim
                   checked records (end-to-end corruption detection)
  fleet            multi-host fleet runtime (ISSUE 9): HostPlan placement,
                   leader/follower rendezvous, control links, cross-host
                   recovery — ProcsEngine(hosts=...) / REPRO_HOSTS
"""
from .fault_tolerance import FleetStallError, LinkDownError, WorkerDiedError
from .faultinject import FaultAction, parse_fault_plan
from .fleet import HostPlan, resolve_host_plan
from .launcher import ProcsEngine, ProcsState
from .recovery import RECOVERABLE, RecoveryController, resolve_on_fault
from .shmem import RingCorruptionError, RingTimeout, ShmRing

__all__ = [
    "FaultAction", "FleetStallError", "HostPlan", "LinkDownError",
    "ProcsEngine", "ProcsState", "RECOVERABLE", "RecoveryController",
    "RingCorruptionError", "RingTimeout", "ShmRing", "WorkerDiedError",
    "parse_fault_plan", "resolve_host_plan", "resolve_on_fault",
]

"""Multiprocess launcher — ``Network.build(engine="procs")`` (paper §III,
DESIGN.md §Runtime).

``ProcsEngine`` is the fifth engine: it realizes the paper's deployment
model *literally* — one free-running OS process per granule, connected at
runtime by shared-memory SPSC queues — behind the same ``Simulation``
facade as the in-process engines.  The division of labor:

  * ``graph.lower_partition`` assigns every channel its granule-local
    queue (the same lowering the shard_map engines consume, so the
    granule state layouts are bit-identical);
  * the launcher creates one slab ring + one credit ring per boundary
    channel and one packet ring per external port
    (``runtime.shmem.ShmRing``), spawns one worker per granule
    (``runtime.worker``), and speaks the session protocol to them over
    command pipes: ``init`` / ``run`` / ``probe`` / ``stats`` /
    checkpoint ``gather``/``scatter``;
  * host Tx/Rx ports read and write the external rings directly — host
    I/O never interrupts a running worker, it lands at the worker's next
    epoch boundary exactly like the in-process engines' host tier.

**Prebuilt-simulator cache**: before spawning anything, the launcher
AOT-compiles one granule simulator per *distinct granule signature*
(``jit(...).lower().compile()`` into the shared JAX persistent
compilation cache).  Workers then compile against a warm cache, so build
time grows with unique granule shapes — O(#block kinds), not
O(#instances) — the paper's flat-build-time property, measured in
``benchmarks/procs_runtime.py``.

**Failure surface** (``runtime.fault_tolerance``): every reply wait polls
worker exitcodes (ANY exit while replies are pending, clean or not) and
per-epoch heartbeats; a dead or silent worker raises ``WorkerDiedError``
with that worker's captured log tail, and the remaining workers are torn
down — never a hang on a half-dead fleet.  When the WHOLE fleet goes
quiet, the per-worker "blocked on ring X" status words in the heartbeat
shm are decoded into the credit wait-for graph: a cycle raises
``FleetStallError`` naming the deadlock, an acyclic graph names the root
worker.  Checked rings surface slab corruption as
``RingCorruptionError`` (``runtime.shmem``).

**Self-healing** (``runtime.recovery``, ISSUE 8): with
``on_fault="recover"`` (env ``REPRO_ON_FAULT``) the engine takes
coordinated snapshots every ``snapshot_every`` epochs at command
boundaries (the fleet is quiesced there, so ``gather_state`` is a
consistent cut) and, on any recoverable fault, tears down the remnant
fleet, respawns workers from the warm prebuilt-simulator cache,
scatters the last snapshot, and replays the lost epochs — final state
and host Rx traffic bit-identical to a fault-free run.  Deterministic
drills via ``runtime.faultinject`` (``REPRO_FAULT_PLAN``).
"""
from __future__ import annotations

import atexit
import dataclasses
import os
import pickle
import secrets
import socket
import tempfile
import time
import weakref
from multiprocessing import get_context, shared_memory
from typing import Any, Callable, Sequence

import numpy as np

from ..core import queue as qmod
from ..kernels import granule_step
from ..obs import telemetry as _telem
from ..obs import trace as _trace
from ..obs.registry import REGISTRY
from ..obs.telemetry import telemetry_ring_name
from ..core.graph import (
    ChannelGraph, PartitionLowering, PartitionTree, Tier, lower_partition,
    normalize_partition, normalize_tiers,
)
from . import fleet as _fleet
from .bridge import BridgeChannel, BridgeSpec, bridge_entry
from .fault_tolerance import (
    FleetStallError, LinkDownError, ProcessMonitor, WorkerDiedError,
    find_stall_cycle, read_log_tail, stall_wait_edges,
)
from .faultinject import actions_for, resolve_fault_plan, split_plan
from .recovery import RecoveryController, resolve_on_fault
from .shmem import RingCorruptionError, RingTimeout, ShmRing, slab_slot_bytes
from .worker import (
    HB_RECORD_BYTES, HB_RECORD_F64, BatchSpec, BatchedGranuleSim, GranuleSim,
    GranuleSpec, GroupSpec, TierSpec, configure_compile_cache,
    credit_ring_name, data_ring_name, ext_ring_name, worker_entry,
)

PyTree = Any

_DEFAULT_CACHE = (
    os.environ.get("REPRO_PROCS_CACHE_DIR")
    or os.path.join(tempfile.gettempdir(), "repro_procs_cache")
)


def _worker_mp_context():
    """Multiprocessing context for worker processes.

    Default is a ``forkserver`` preloaded with ``repro.runtime.worker``:
    the server pays the jax/repro import ONCE, then every worker — and
    critically every recovery *respawn* — is a cheap fork of it.  Safe
    because importing the worker module initializes no XLA backend and
    starts no threads (each fork creates its own client); the server
    starts inside the ``_child_env`` window, so its frozen environment is
    the canonical single-CPU-device worker env.  ``REPRO_WORKER_SPAWN=
    spawn`` restores plain spawn (each worker re-imports jax, several
    seconds apiece)."""
    method = os.environ.get("REPRO_WORKER_SPAWN", "forkserver")
    if method not in ("forkserver", "spawn"):
        raise ValueError(
            f"REPRO_WORKER_SPAWN={method!r}: expected 'forkserver' or "
            "'spawn'"
        )
    if method == "forkserver":
        try:
            ctx = get_context("forkserver")
            ctx.set_forkserver_preload(["repro.runtime.worker"])
            return ctx
        except (ValueError, OSError):  # platform without forkserver
            pass
    return get_context("spawn")

# Engines are tracked weakly: a garbage-collected engine tears itself down
# via __del__, and whatever is still alive at interpreter exit is closed
# here — worker processes and shm segments never outlive the launcher.
_live_engines: "weakref.WeakSet[ProcsEngine]" = weakref.WeakSet()


def _close_all_engines() -> None:  # pragma: no cover - interpreter exit
    for eng in list(_live_engines):
        try:
            eng.close()
        except Exception:
            pass


atexit.register(_close_all_engines)


@dataclasses.dataclass
class ProcsState:
    """The session's handle on a running fleet — a *reference*, not the
    state itself: granule state lives in the workers (that is the point).
    The handle carries the boundary-synchronized counters plus a
    generation stamp so a stale handle (pre-reset) fails loudly."""

    cycle: np.ndarray  # () int32 — identical on every worker at a boundary
    epoch: np.ndarray  # () int32
    generation: int

    def replace(self, **kw) -> "ProcsState":
        return dataclasses.replace(self, **kw)


class ProcsEngine:
    """Free-running multiprocess engine over a partitioned ChannelGraph.

    graph:      the channel-graph IR.
    partition:  ``PartitionTree`` (tiered), or any flat instance->granule
                map ``normalize_partition`` accepts (with ``n_workers``/
                ``tiers``); granule ids are worker indices.
    n_workers:  worker count for flat partitions (default: max granule+1).
    K:          innermost sync rate (cycles between boundary exchanges).
    tiers:      optional ``(axes, K)`` spec with ``axis_sizes`` supplied by
                a PartitionTree — procs needs no mesh, so pass tiered
                layouts via PartitionTree.
    ring_depth: slab records a boundary ring buffers (>= 2; staleness
                slack for the slab data — the credit chain already bounds
                epoch drift at one exchange period per channel).
    timeout:    seconds a worker waits on a ring / the launcher waits on a
                silent worker before declaring it dead.
    prebuild:   AOT-compile each distinct granule signature in-launcher
                (warming the persistent cache) before any worker spawns.
    cache_dir:  JAX persistent compilation cache directory (shared).
    batch_signatures:
                group same-signature granules (``lowering.batch_plan``)
                into ONE worker process each, stepping the whole group as
                a leading-axis batch with a single vmapped dispatch per
                program op — fewer processes and fewer dispatches for
                replicated designs, bit-identical traffic (the batch is a
                legal lockstep refinement of the free-running schedule).
    overlap:    split every tier exchange into issue (drain + push) and
                commit (pop + fill) phases — at a boundary all outgoing
                slabs are pushed before the worker blocks on any incoming
                one (send-early/receive-late), so peer latencies overlap
                instead of adding.  Bit-identical traffic (the credit
                protocol per channel is unchanged).  "auto"/bool with
                ``REPRO_OVERLAP`` env override; auto = off.
    on_fault:   "raise" (default) propagates the first fleet fault;
                "recover" auto-heals: snapshot periodically, and on a
                dead/hung/corrupted/deadlocked fleet respawn + restore +
                replay (``runtime.recovery``).  "auto"/str with
                ``REPRO_ON_FAULT`` env override; auto = raise.
    snapshot_every:
                coordinated-snapshot cadence in epochs (recover mode; the
                snapshot is taken at the first command boundary on each
                multiple, where the fleet is quiesced).  The default
                trades the steady-state gather tax (benchmarked at
                ~1.2x a raise-mode run on the smoke wafer, budget 1.5x)
                against the replay bound of one cadence of epochs.
    max_restarts:
                recovery attempts before giving up (the original fault is
                re-raised, chained).
    backoff_s:  base of the exponential respawn backoff (doubles per
                consecutive restart).
    fault_plan: deterministic fault injection for drills — a plan string
                (see ``runtime.faultinject``) or a sequence of
                ``FaultAction``; default: env ``REPRO_FAULT_PLAN``.
                Link-fault kinds (``linkkill``/``linkslow``/``linkcorrupt``)
                target bridged links and are executed launcher-side at
                epoch boundaries.
    hosts:      multi-host fleet placement (ISSUE 9): a host count, comma
                list of names, ``{host: [granule, ...]}`` dict, or a
                ``runtime.fleet.HostPlan``; default env ``REPRO_HOSTS``,
                else single-host.  The partition's granules are sharded
                across that many launcher processes, connected ONLY by TCP
                ring bridges (``runtime.bridge``) — traffic, state, and
                the per-tier staleness bound are bit-identical to the
                single-host engine.
    host:       which plan host THIS engine instance is (internal: set by
                ``fleet.follower_entry``; user code leaves it None and
                gets the leader).
    base_port:  deterministic bridge/control port base (link i listens on
                ``base_port + i``); default env ``REPRO_BRIDGE_PORT``,
                else ephemeral ports exchanged at rendezvous.
    """

    engine_kind = "procs"

    def __init__(
        self,
        graph: ChannelGraph,
        partition=None,
        *,
        n_workers: int | None = None,
        K: int = 1,
        tiers: Sequence | None = None,
        ring_depth: int = 2,
        timeout: float = 60.0,
        prebuild: bool = True,
        cache_dir: str | None = None,
        log_dir: str | None = None,
        batch_signatures: bool = False,
        overlap: Any = "auto",
        on_fault: str = "auto",
        snapshot_every: int = 16,
        max_restarts: int = 3,
        backoff_s: float = 0.25,
        fault_plan: Any = None,
        hosts: Any = None,
        host: str | None = None,
        base_port: int | None = None,
    ):
        self.graph = graph
        if isinstance(partition, PartitionTree):
            if tiers is not None:
                raise ValueError("pass tiers via the PartitionTree, not both")
            ptree = partition
        else:
            if tiers is not None:
                tspec = normalize_tiers(tiers)
                raise ValueError(
                    "procs has no mesh to size tier axes "
                    f"{[t.axes for t in tspec]} — pass a PartitionTree"
                )
            if n_workers is None:
                part0 = normalize_partition(graph, partition, 1 << 30)
                n_workers = int(part0.max()) + 1 if part0.size else 1
            part = normalize_partition(graph, partition, n_workers)
            ptree = PartitionTree(
                part, (Tier(axes=("w",), K=int(K)),), {"w": int(n_workers)}
            )
        self.ptree = ptree
        self.tiers = ptree.tiers
        self.K_tiers = ptree.K_tiers
        self.periods = ptree.periods()
        self.cycles_per_epoch = ptree.cycles_per_epoch
        self.K = self.K_tiers[-1]
        self.G = ptree.n_granules
        self.n_workers = self.G
        self.E_tiers = tuple(min(p, graph.capacity - 1) for p in self.periods)
        self.W = graph.payload_words
        self.payload_words = graph.payload_words
        self.capacity = graph.capacity
        self.dtype = np.dtype(graph.dtype if graph.dtype is not None
                              else np.float32)
        self.part = ptree.part
        # A boundary slab ring must hold one exchange window in flight PLUS
        # the next window the overlapped (send-early/receive-late) schedule
        # pushes before the previous one is consumed.  Shallower rings
        # deadlock the free-running fleet (historically surfacing only as
        # the CI watchdog timeout) — fail fast at build time instead.
        ring_depth = int(ring_depth)
        if ring_depth < 2:
            raise ValueError(
                f"ring_depth={ring_depth} is too shallow: boundary slab "
                f"rings must hold two exchange windows (>= 2 slab records "
                f"of E_t slots each; tier slab depths E_t={self.E_tiers}) "
                f"so the overlapped schedule can push window w+1 before "
                f"window w is consumed — a shallower ring deadlocks the "
                f"free-running fleet instead of failing fast"
            )
        self.ring_depth = ring_depth
        self.overlap = granule_step.resolve_overlap(overlap)
        self.timeout = float(timeout)
        self.cache_dir = cache_dir if cache_dir is not None else _DEFAULT_CACHE
        self.on_fault = resolve_on_fault(on_fault)
        self.fault_plan = resolve_fault_plan(fault_plan)
        self._incarnation = 0  # bumped on every recovery respawn

        low = lower_partition(graph, ptree)
        self.lowering = low
        self.n_local = low.n_local
        self._chan_owner = low.chan_owner
        self._tx_local, self._rx_local = low.tx_local, low.rx_local

        self._ring_prefix = f"sb{os.getpid() % 100000:x}{secrets.token_hex(3)}"
        self._log_dir = log_dir or tempfile.mkdtemp(prefix="repro_procs_")
        self._specs = [self._granule_spec(g) for g in range(self.G)]
        self.signatures = [s.signature for s in self._specs]

        # ---- signature-batch plan: one worker per granule, or (with
        # batch_signatures) one worker per signature group stepping the
        # whole group as a leading-axis batch
        self.batch_signatures = bool(batch_signatures)
        if self.batch_signatures:
            groups, where = low.batch_plan()
            self._worker_members = [tuple(ms) for ms in groups]
            self._worker_of = {g: b for g, (b, r) in where.items()}
            self._row_of = {g: r for g, (b, r) in where.items()}
        else:
            self._worker_members = [(g,) for g in range(self.G)]
            self._worker_of = {g: g for g in range(self.G)}
            self._row_of = {g: 0 for g in range(self.G)}
        self._wspecs: list[Any] = [
            self._specs[ms[0]] if len(ms) == 1
            else BatchSpec(members=ms, specs=[self._specs[g] for g in ms])
            for ms in self._worker_members
        ]
        self._is_batch = [isinstance(s, BatchSpec) for s in self._wspecs]
        self.NW = len(self._wspecs)
        # channel id -> (producer worker, consumer worker) of its slab
        # direction: the topology the stall diagnoser decodes status
        # words against
        self._chan_workers = {
            c: (self._worker_of[s], self._worker_of[d])
            for (t, s, d), chans in self.lowering.routes.items()
            for c in chans
        }
        self._chan_tier = {c: t
                           for (t, _s, _d), chans in self.lowering.routes.items()
                           for c in chans}

        # ---- multi-host fleet placement (ISSUE 9; ``runtime.fleet``):
        # shard the worker set over named hosts, one launcher process per
        # host, cross-host channels carried by TCP ring bridges
        self.host_plan = _fleet.resolve_host_plan(hosts, self.G)
        if host is not None and self.host_plan is None:
            raise ValueError(
                "host= names a fleet member but no multi-host plan was "
                "given (pass hosts=)")
        self.host = (host if host is not None
                     else (self.host_plan.leader if self.host_plan else None))
        self.is_leader = (self.host_plan is None
                          or self.host == self.host_plan.leader)
        if self.host_plan is not None:
            if self.host not in self.host_plan.hosts:
                raise ValueError(f"host {self.host!r} is not in the plan "
                                 f"{self.host_plan.hosts}")
            for w, ms in enumerate(self._worker_members):
                hs = sorted({self.host_plan.host_of(g) for g in ms})
                if len(hs) > 1:
                    raise ValueError(
                        f"signature-batch worker {w} spans hosts {hs} "
                        f"(granules {list(ms)}); a batched worker must stay "
                        "on one host — adjust the host plan or disable "
                        "batch_signatures")
            self._host_of_w = {w: self.host_plan.host_of(ms[0])
                               for w, ms in enumerate(self._worker_members)}
            self._local_ws = tuple(w for w in range(self.NW)
                                   if self._host_of_w[w] == self.host)
            self._chan_hosts = {c: (self._host_of_w[sw], self._host_of_w[dw])
                                for c, (sw, dw) in self._chan_workers.items()}
            self._links = _fleet.build_links(self.host_plan, self._chan_hosts)
            self._local_links = tuple(lk for lk in self._links
                                      if self.host in (lk.accept, lk.dial))
            self.NB = len(self._local_links)
            self._bridge_ids = {lk.link: self.NW + i
                                for i, lk in enumerate(self._local_links)}
            self._link_of_chan = {}
            for lk in self._links:
                for c, _sh in lk.chans:
                    self._link_of_chan[c] = lk.link
            # host-local stall topology: a cross-host channel's remote end
            # is its LOCAL bridge proxy's monitor id, so the stall graph
            # blames the bridge, never an innocent remote worker
            self._chan_peers = {}
            for c, (sw, dw) in self._chan_workers.items():
                sh, dh = self._chan_hosts[c]
                if self.host not in (sh, dh):
                    continue
                if sh == dh:
                    self._chan_peers[c] = (sw, dw)
                    continue
                b = self._bridge_ids[self._link_of_chan[c]]
                self._chan_peers[c] = (sw if sh == self.host else b,
                                       dw if dh == self.host else b)
        else:
            self._host_of_w = {w: None for w in range(self.NW)}
            self._local_ws = tuple(range(self.NW))
            self._chan_hosts = {}
            self._links = ()
            self._local_links = ()
            self.NB = 0
            self._bridge_ids = {}
            self._link_of_chan = {}
            self._chan_peers = self._chan_workers
        self._base_port = (_fleet.resolve_base_port(base_port)
                           if self.host_plan is not None else 0)
        self._fleet_token = secrets.token_hex(8)

        self._worker_faults, self._link_faults = split_plan(self.fault_plan)
        bad = [a for a in self._worker_faults if a.worker >= self.NW]
        if bad:
            raise ValueError(
                f"fault plan targets worker(s) {[a.worker for a in bad]} "
                f"but the fleet has {self.NW} worker(s)"
            )
        if self._link_faults:
            if self.host_plan is None:
                raise ValueError(
                    "fault plan has link fault(s) "
                    f"{[a.kind for a in self._link_faults]} but the engine "
                    "has no bridged links (pass hosts=)")
            badl = [a for a in self._link_faults
                    if a.worker >= len(self._links)]
            if badl:
                raise ValueError(
                    f"fault plan targets link(s) "
                    f"{[a.worker for a in badl]} but the fleet has "
                    f"{len(self._links)} bridged link(s)")
        self._fired_links: set = set()

        # ---- the prebuilt-simulator cache: one compile per DISTINCT shape
        self.build_stats: dict[str, Any] = {
            "n_workers": self.NW,
            "n_signatures": len(set(self.signatures)),
            "compiled": {},
            "prebuild_seconds": 0.0,
        }
        if prebuild:
            configure_compile_cache(self.cache_dir)
            t0 = time.perf_counter()
            done: set[tuple[str, int]] = set()
            for wspec in self._wspecs:
                nb = len(wspec.specs) if isinstance(wspec, BatchSpec) else 1
                key = (wspec.signature, nb)
                if key in done:
                    continue
                done.add(key)
                sim = (BatchedGranuleSim(wspec) if isinstance(wspec, BatchSpec)
                       else GranuleSim(wspec))
                stats = sim.prebuild()
                name = (wspec.signature if nb == 1
                        else f"{wspec.signature}x{nb}")
                self.build_stats["compiled"][name] = stats
            self.build_stats["prebuild_seconds"] = time.perf_counter() - t0

        # forkserver preloaded with the worker module: respawns fork the
        # already-imported server instead of re-importing jax (recovery
        # MTTR); starts lazily inside the launch() _child_env window
        self._ctx = _worker_mp_context()
        self._procs: dict[int, Any] = {}
        self._conns: dict[int, Any] = {}
        self._bridge_procs: dict[int, Any] = {}
        self._bridge_conns: dict[int, Any] = {}
        self._bridge_labels: dict[int, str] = {}
        self._bridge_logs: dict[int, str] = {}
        self._accept_ports: dict[int, int] = {}
        self._follower_procs: dict[str, Any] = {}
        self._follower_ctls: dict[str, Any] = {}
        self._follower_mid: dict[str, int] = {}
        self._ctl_listener: socket.socket | None = None
        self._rings: dict[str, ShmRing] = {}
        self._hb_shm: shared_memory.SharedMemory | None = None
        self._hb: np.ndarray | None = None
        self._generation = 0
        self._launched = False
        self._closed = False
        self._monitor: ProcessMonitor | None = None
        # packets per rx port the host already received before a recovery
        # rewind: the replay regenerates them, the host-facing pop drops
        # them (exactly-once delivery; owned by the RecoveryController)
        self._ext_discard: dict[str, int] = {}
        # flight recorder (repro.obs): per-worker telemetry ring names,
        # tracing toggle, and the (pid, tid) tracks already named
        self._telem_on = False
        self._telem_names: dict[int, str] = {}
        self._telem_tracked: set[tuple[int, int]] = set()
        self._recovery = RecoveryController(
            self, snapshot_every=snapshot_every, max_restarts=max_restarts,
            backoff_s=backoff_s,
        )
        _live_engines.add(self)

    # ------------------------------------------------------------- lowering
    def _granule_spec(self, g: int) -> GranuleSpec:
        low, graph = self.lowering, self.graph
        groups = []
        for gi, grp in enumerate(graph.groups):
            mo = low.member_of[gi][g]
            params_local = None
            if grp.params is not None:
                params_local = _tree_np(grp.params, mo)
            groups.append(GroupSpec(
                block=grp.block,
                n_members=grp.n_members,
                n_slot=low.n_slot[gi],
                member_of=mo.copy(),
                active=low.act_tables[gi][g].copy(),
                rx_idx=low.rx_tables[gi][g].copy(),
                tx_idx=low.tx_tables[gi][g].copy(),
                params_local=params_local,
            ))
        tiers = []
        for t in range(self.ptree.n_tiers):
            eg, ing = low.tier_channels(t, g)
            tiers.append(TierSpec(
                K=self.K_tiers[t],
                E=self.E_tiers[t],
                egress_chans=tuple(eg),
                egress_lqids=low.tx_local[eg].astype(np.int32)
                if eg else np.zeros((0,), np.int32),
                ingress_chans=tuple(ing),
                ingress_lqids=low.rx_local[ing].astype(np.int32)
                if ing else np.zeros((0,), np.int32),
            ))
        ext = [
            (name, cid, int(max(low.tx_local[cid], low.rx_local[cid])), is_in)
            for name, cid, is_in in low.ext_channels(g)
        ]
        return GranuleSpec(
            granule=g,
            signature=low.granule_signature(g),
            payload_words=self.W,
            capacity=self.capacity,
            dtype=self.dtype.str,
            n_local=self.n_local,
            groups=groups,
            tiers=tiers,
            ext_ports=ext,
            ring_prefix=self._ring_prefix,
            ring_depth=self.ring_depth,
            timeout=self.timeout,
            overlap=self.overlap,
        )

    # ------------------------------------------------------------- lifecycle
    def launch(self) -> "ProcsEngine":
        """Create this host's rings and spawn its workers + bridges (and,
        on the fleet leader, the follower launchers) — idempotent."""
        if self._launched:
            return self
        if self._closed:
            raise RuntimeError("engine was closed")
        itemsize = self.dtype.itemsize
        for t, ts in enumerate(self.tiers):
            for (tt, s, d), chans in sorted(self.lowering.routes.items()):
                if tt != t:
                    continue
                for c in chans:
                    # a multi-host fleet materialises a channel's rings on
                    # every host that touches it: both endpoints of a
                    # cross-host channel get LOCAL rings under this
                    # launcher's own shm namespace, paired over TCP by the
                    # bridge — workers run completely unmodified
                    if (self.host_plan is not None
                            and self.host not in self._chan_hosts[c]):
                        continue
                    # slab + host-port rings are integrity-checked (per-
                    # record seq + crc32); 4-byte credit rings are not —
                    # their payload IS the protocol invariant
                    self._rings[data_ring_name(self._ring_prefix, c)] = (
                        ShmRing.create(
                            data_ring_name(self._ring_prefix, c),
                            self.ring_depth + 1,
                            slab_slot_bytes(self.E_tiers[t], self.W, itemsize),
                            checked=True, label=f"slab:c{c}",
                        )
                    )
                    self._rings[credit_ring_name(self._ring_prefix, c)] = (
                        ShmRing.create(
                            credit_ring_name(self._ring_prefix, c),
                            self.ring_depth + 2, 4,
                        )
                    )
        for name, (cid, is_in) in self.graph.ext_ports().items():
            if (self.host_plan is not None
                    and self._ext_home_host(cid) != self.host):
                continue
            self._rings[ext_ring_name(self._ring_prefix, cid)] = ShmRing.create(
                ext_ring_name(self._ring_prefix, cid),
                self.capacity, self.W * itemsize,
                checked=True, label=f"ext:{name}",
            )
        self._seed_credit_rings()

        hb_name = f"{self._ring_prefix}hb"
        nhb = self.NW + self.NB  # bridge proxies beat alongside the workers
        self._hb_shm = shared_memory.SharedMemory(
            name=hb_name, create=True, size=HB_RECORD_BYTES * nhb
        )
        self._hb_shm.buf[:] = bytes(HB_RECORD_BYTES * nhb)
        self._hb = np.frombuffer(self._hb_shm.buf, np.float64)

        env_save = _child_env()
        try:
            for g in self._local_ws:
                spec = self._wspecs[g]
                parent, child = self._ctx.Pipe()
                log_path = os.path.join(self._log_dir, f"worker{g}.log")
                faults = actions_for(self.fault_plan, g, self._incarnation)
                # flight-recorder ring: always created (a few hundred KB),
                # records only flow once tracing is switched on
                tname = telemetry_ring_name(self._ring_prefix, g)
                self._rings[tname] = ShmRing.create(
                    tname, _telem.TELEM_RING_RECORDS,
                    _telem.TELEM_RECORD_BYTES,
                )
                self._telem_names[g] = tname
                p = self._ctx.Process(
                    target=worker_entry,
                    args=(child, pickle.dumps(spec), g, log_path,
                          self.cache_dir, hb_name,
                          pickle.dumps(faults) if faults else None, tname),
                    daemon=True,
                    name=f"repro-granule-{g}",
                )
                p.start()
                child.close()
                self._procs[g] = p
                self._conns[g] = parent
            for i, lk in enumerate(self._local_links):
                self._spawn_bridge(i, lk, hb_name)
            if self.host_plan is not None and self.is_leader:
                self._spawn_followers()
        finally:
            _restore_env(env_save)

        # accept-side bridges report their bound listener ports first
        for i, lk in enumerate(self._local_links):
            mid = self.NW + i
            kind, payload = self._bridge_recv(mid, max(self.timeout, 120.0))
            if kind != "ready":
                raise self._bridge_dead(mid, f"failed to start: {payload}")
            if payload is not None:
                self._accept_ports[lk.link] = int(payload)

        procs: dict[int, Any] = dict(self._procs)
        procs.update(self._bridge_procs)
        logs = {g: os.path.join(self._log_dir, f"worker{g}.log")
                for g in self._local_ws}
        logs.update(self._bridge_logs)
        labels = dict(self._bridge_labels)
        for h, mid in self._follower_mid.items():
            procs[mid] = self._follower_procs[h]
            logs[mid] = os.path.join(self._log_dir, f"launcher-{h}.log")
            labels[mid] = f"launcher {h}"
        self._monitor = ProcessMonitor(
            procs,
            logs,
            heartbeat=lambda g: float(self._hb[g * HB_RECORD_F64])
            + float(self._hb[g * HB_RECORD_F64 + 1]),
            hang_timeout_s=self.timeout,
            diagnose=self._diagnose_stall,
            labels=labels,
            link_ids=frozenset(self._bridge_ids.values()),
        )
        self._launched = True
        self.launch_stats = {"ready_seconds": {}}
        for g in self._local_ws:
            t0 = time.perf_counter()
            # no heartbeats exist yet (first beat lands on the init
            # command), so the ready-wait polls exitcodes only under a
            # generous absolute deadline — a cold compilation cache must
            # not read as "hung"
            kind, payload = self._recv(g, timeout=max(self.timeout, 300.0),
                                       hang_check=False)
            if kind != "ready":
                raise WorkerDiedError(g, f"failed to start: {payload}",
                                      read_log_tail(self._monitor.log_paths[g]))
            self.launch_stats["ready_seconds"][g] = time.perf_counter() - t0
        if self.host_plan is not None and self.is_leader:
            self._rendezvous_fleet()
        REGISTRY.set("procs.workers", float(self.NW))
        REGISTRY.set("procs.incarnation", float(self._incarnation))
        if self.build_stats.get("prebuild_seconds"):
            REGISTRY.set("procs.prebuild.s",
                         float(self.build_stats["prebuild_seconds"]))
            REGISTRY.set("procs.compile.count",
                         float(len(self.build_stats.get("compiled", {}))))
        if self._telem_on:
            # a respawn (recovery _reopen) keeps tracing on across
            # incarnations; a pre-launch set_tracing lands here too
            self._apply_tracing()
        # a follower returns here with its bridges still un-dialed:
        # ``fleet.follower_entry`` sends the hello (with _accept_ports)
        # and calls _finish_rendezvous once the leader broadcasts the map
        return self

    # ------------------------------------------------ fleet wiring (leader)
    def _ext_home_host(self, cid: int):
        """The host owning an external port's granule (its ring lives
        there; the leader forwards host I/O to it over the control link)."""
        g = int(self._chan_owner[cid])
        return self._host_of_w[self._worker_of[g]]

    def _spawn_bridge(self, i: int, lk, hb_name: str) -> None:
        mid = self.NW + i
        channels = []
        itemsize = self.dtype.itemsize
        for c, src_host in lk.chans:
            t = self._chan_tier[c]
            channels.append(BridgeChannel(
                chan=c,
                side="tx" if src_host == self.host else "rx",
                data_name=data_ring_name(self._ring_prefix, c),
                data_capacity=self.ring_depth + 1,
                data_slot_bytes=slab_slot_bytes(self.E_tiers[t], self.W,
                                                itemsize),
                credit_name=credit_ring_name(self._ring_prefix, c),
                credit_capacity=self.ring_depth + 2,
            ))
        role = "accept" if lk.accept == self.host else "dial"
        spec = BridgeSpec(
            link=lk.link, label=lk.label, host=self.host,
            peer=lk.peer_of(self.host), role=role, token=self._fleet_token,
            port=(self._base_port + lk.link if self._base_port else 0),
            channels=tuple(channels), timeout=self.timeout,
            hb_name=hb_name, hb_index=mid,
        )
        parent, child = self._ctx.Pipe()
        log_path = os.path.join(self._log_dir, f"bridge{lk.link}.log")
        p = self._ctx.Process(
            target=bridge_entry,
            args=(child, pickle.dumps(spec), log_path),
            daemon=True,
            name=f"repro-bridge-{lk.link}",
        )
        p.start()
        child.close()
        self._bridge_procs[mid] = p
        self._bridge_conns[mid] = parent
        self._bridge_labels[mid] = f"bridge {lk.label}"
        self._bridge_logs[mid] = log_path

    def _spawn_followers(self) -> None:
        """Bind the fleet control listener and spawn one follower launcher
        per non-leader host (each a full ProcsEngine restricted to its
        granules — ``fleet.follower_entry``)."""
        plan = self.host_plan
        port = self._base_port + len(self._links) if self._base_port else 0
        lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        lst.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        lst.bind(("127.0.0.1", port))
        lst.listen(plan.n_hosts)
        self._ctl_listener = lst
        addr = ("127.0.0.1", lst.getsockname()[1])
        kwargs = dict(
            ring_depth=self.ring_depth, timeout=self.timeout,
            prebuild=False, cache_dir=self.cache_dir,
            batch_signatures=self.batch_signatures, overlap=self.overlap,
            on_fault="raise", fault_plan=self.fault_plan,
            hosts=plan, base_port=self._base_port,
        )
        build = pickle.dumps((self.graph, self.ptree, kwargs))
        followers = tuple(h for h in plan.hosts if h != self.host)
        for j, h in enumerate(followers):
            mid = self.NW + self.NB + j
            boot = _fleet.FollowerBoot(
                host=h, leader_addr=addr, token=self._fleet_token,
                build=build, timeout=self.timeout,
                incarnation=self._incarnation,
            )
            log_path = os.path.join(self._log_dir, f"launcher-{h}.log")
            # NOT daemonic: a follower spawns its own worker/bridge
            # children (daemons cannot).  Leader death still reaps it —
            # its control-link recv raises ConnectionError and it exits.
            p = self._ctx.Process(
                target=_fleet.follower_entry,
                args=(pickle.dumps(boot), log_path),
                daemon=False,
                name=f"repro-launcher-{h}",
            )
            p.start()
            self._follower_procs[h] = p
            self._follower_mid[h] = mid

    def _rendezvous_fleet(self) -> None:
        """Leader rendezvous: collect follower hellos (their accept-side
        bridge ports), broadcast the aggregated link -> address map, dial
        the local bridges, then wait for every member's all-links-up."""
        followers = tuple(h for h in self.host_plan.hosts if h != self.host)

        def _alive() -> None:
            for h, p in self._follower_procs.items():
                if p.exitcode is not None:
                    mid = self._follower_mid[h]
                    tail = read_log_tail(
                        os.path.join(self._log_dir, f"launcher-{h}.log"))
                    self.close()
                    raise WorkerDiedError(
                        mid, f"died with exitcode {p.exitcode} during "
                        "rendezvous", tail, label=f"launcher {h}")

        conns = _fleet.accept_followers(
            self._ctl_listener, followers, self._fleet_token,
            timeout=max(self.timeout, 300.0), on_wait=_alive)
        addr_map = {lk: ("127.0.0.1", prt)
                    for lk, prt in self._accept_ports.items()}
        for h, (ctl, ports) in conns.items():
            self._follower_ctls[h] = ctl
            for lk, prt in ports.items():
                addr_map[int(lk)] = ("127.0.0.1", int(prt))
        for h in followers:
            self._follower_ctls[h].send(("rendezvous", addr_map))
        self._finish_rendezvous(addr_map)
        for h in followers:
            self._ctl_wait(h, timeout=max(self.timeout, 300.0))

    def _finish_rendezvous(self, addr_map: dict) -> None:
        """Dial this host's dial-side bridges and wait for every local
        link to come up (HELLO handshake verified bridge-side)."""
        for i, lk in enumerate(self._local_links):
            mid = self.NW + i
            if lk.accept != self.host:
                if lk.link not in addr_map:
                    raise self._bridge_dead(
                        mid, f"rendezvous map lacks {lk.label}")
                self._bridge_conns[mid].send(("dial",
                                              tuple(addr_map[lk.link])))
        for i, lk in enumerate(self._local_links):
            mid = self.NW + i
            kind, payload = self._bridge_recv(mid, max(self.timeout, 300.0))
            if kind != "up":
                raise self._bridge_dead(
                    mid, f"link never came up: got {kind!r} {payload!r}")

    def _seed_credit_rings(self) -> None:
        """Every boundary channel's sender starts with capacity-1 credit —
        the engines' initial-credit convention, as one pre-seeded record.
        On a bridged fleet only the SENDER's host seeds a cross-host
        channel (the receiver host's credit ring starts empty: the bridge
        drains the receiver's post-fill credits into it and forwards them
        over the wire — seeding both sides would double the credit)."""
        for (t, s, d), chans in self.lowering.routes.items():
            for c in chans:
                name = credit_ring_name(self._ring_prefix, c)
                if name not in self._rings:
                    continue  # channel not materialised on this host
                ring = self._rings[name]
                ring.reset()
                if (self.host_plan is None
                        or self._chan_hosts[c][0] == self.host):
                    ring.push_u32(self.capacity - 1, timeout=1.0)

    def close(self) -> None:
        """Tear down workers, bridges, and follower launchers, and unlink
        every shared-memory segment."""
        if self._closed:
            return
        self._closed = True
        if self._telem_on:
            try:  # last drain before the rings unlink (best-effort)
                self._drain_telemetry_once()
            except Exception:
                pass
        # exits go out to everyone first (followers tear their own fleets
        # down concurrently with our local joins)
        for ctl in list(self._follower_ctls.values()):
            try:
                ctl.send(("exit",))
            except Exception:
                pass
        for conn in list(self._bridge_conns.values()):
            try:
                conn.send(("exit",))
            except Exception:
                pass
        for g, conn in list(self._conns.items()):
            try:
                conn.send(("exit",))
            except Exception:
                pass
        for g, p in list(self._procs.items()):
            try:
                p.join(timeout=2.0)
                if p.is_alive():
                    p.terminate()
                    p.join(timeout=2.0)
            except Exception:
                pass
        for mid, p in list(self._bridge_procs.items()):
            try:
                p.join(timeout=2.0)
                if p.is_alive():
                    p.terminate()
                    p.join(timeout=2.0)
            except Exception:
                pass
        for h, p in list(self._follower_procs.items()):
            try:
                p.join(timeout=5.0)
                if p.is_alive():
                    p.terminate()
                    p.join(timeout=2.0)
            except Exception:
                pass
        for conn in (list(self._conns.values())
                     + list(self._bridge_conns.values())):
            try:
                conn.close()
            except Exception:
                pass
        for ctl in list(self._follower_ctls.values()):
            ctl.close()
        if self._ctl_listener is not None:
            try:
                self._ctl_listener.close()
            except Exception:
                pass
            self._ctl_listener = None
        for ring in self._rings.values():
            ring.close()
        self._rings.clear()
        if self._hb_shm is not None:
            self._hb = None
            try:
                self._hb_shm.close()
                self._hb_shm.unlink()
            except Exception:
                pass
        _live_engines.discard(self)

    def _reopen(self) -> None:
        """Respawn the fleet after a fault (the recovery path): fresh ring
        namespace, fresh worker processes, the SAME lowering — and a warm
        persistent compilation cache, so the respawn skips every compile
        the first launch paid for.  The restart count gates incarnation-
        scoped fault-plan actions (``:r<N>``), so a fired drill fault does
        not re-fire during its own replay."""
        if not self._closed:
            self.close()
        self._incarnation += 1
        self._closed = False
        self._launched = False
        self._procs = {}
        self._conns = {}
        self._bridge_procs = {}
        self._bridge_conns = {}
        self._bridge_labels = {}
        self._bridge_logs = {}
        self._accept_ports = {}
        self._follower_procs = {}
        self._follower_ctls = {}
        self._follower_mid = {}
        self._ctl_listener = None
        self._rings = {}
        self._telem_names = {}
        self._hb_shm = None
        self._hb = None
        self._monitor = None
        self._fired_links = set()
        # fresh incarnation token: a bridge or follower surviving from the
        # previous incarnation can never splice into the new rendezvous
        self._fleet_token = secrets.token_hex(8)
        self._ring_prefix = f"sb{os.getpid() % 100000:x}{secrets.token_hex(3)}"
        # specs embed the ring prefix — rebuild them for the new namespace
        self._specs = [self._granule_spec(g) for g in range(self.G)]
        self._wspecs = [
            self._specs[ms[0]] if len(ms) == 1
            else BatchSpec(members=ms, specs=[self._specs[g] for g in ms])
            for ms in self._worker_members
        ]
        self._np_tables_cache = {}
        _live_engines.add(self)
        self.launch()

    def __del__(self):  # best-effort; atexit covers the normal path
        try:
            self.close()
        except Exception:
            pass

    # --------------------------------------------------------------- comms
    def _check_workers(self, waiting_on=None) -> None:
        # Early follower faults FIRST: a remote worker fault lands as a
        # typed ("fault", ...) control frame, usually accompanied by
        # collateral bridge deaths (the follower tears its fleet down
        # before reporting) — prefer the root-cause frame over blaming
        # the first dead bridge the monitor happens to see.  The frame
        # can still lose the race to the monitor (TCP latency), so
        # consumers must treat LinkDownError/typed fault as equivalent
        # triggers; recovery does (both are RECOVERABLE).
        self._poll_follower_faults()
        if self._monitor is not None:
            try:
                self._monitor.check(waiting_on)
            except (WorkerDiedError, FleetStallError):
                # a dead or deadlocked granule poisons the whole fleet (its
                # peers would hang on its rings) — tear everything down
                # before raising
                self.close()
                raise

    def _poll_follower_faults(self) -> None:
        for h, ctl in list(self._follower_ctls.items()):
            try:
                msg = ctl.peek()
            except ConnectionError:
                mid = self._follower_mid.get(h, self.NW + self.NB)
                tail = read_log_tail(
                    os.path.join(self._log_dir, f"launcher-{h}.log"))
                self.close()
                raise WorkerDiedError(
                    mid, "control link closed unexpectedly", tail,
                    label=f"launcher {h}")
            if msg is not None and msg[0] in ("fault", "err"):
                ctl.take()
                self.close()
                if msg[0] == "fault":
                    raise _fleet.decode_fault(msg[1], h)
                raise RuntimeError(f"follower {h} command failed:\n{msg[1]}")

    def _diagnose_stall(self, waiting_on: tuple[int, ...]):
        """Fleet-wide no-heartbeat diagnosis (monitor callback): decode
        every member's "blocked on ring X" status word into the credit
        wait-for graph.  A cycle is a true deadlock → ``FleetStallError``
        naming it; an acyclic graph blames its root member — a bridge
        proxy root raises ``LinkDownError`` (the link, not an innocent
        worker, is the fault); no usable information returns None (the
        monitor falls back to the plain hung-worker error)."""
        if self._hb is None:
            return None
        blocked = {w: int(self._hb[w * HB_RECORD_F64 + 2])
                   for w in self._local_ws}
        for mid in self._bridge_ids.values():
            blocked[mid] = int(self._hb[mid * HB_RECORD_F64 + 2])
        edges, details = stall_wait_edges(blocked, self._chan_peers)
        cycle = find_stall_cycle(edges)
        if cycle is not None:
            return FleetStallError(cycle, [details[w] for w in cycle])
        roots = set(edges.values()) - set(edges)
        if edges and roots:
            w = min(roots)
            cls = LinkDownError if w >= self.NW else WorkerDiedError
            label = (self._monitor.labels.get(w)
                     if self._monitor is not None else None)
            return cls(
                w,
                f"is the root of a fleet-wide stall: {len(edges)} member(s) "
                f"transitively blocked on it while it made no progress for "
                f"{self.timeout:.0f}s",
                read_log_tail(self._monitor.log_paths.get(w)
                              if self._monitor else None),
                label=label,
            )
        return None

    # ------------------------------------------------------- bridge command
    def _bridge_dead(self, mid: int, reason: str) -> LinkDownError:
        label = self._bridge_labels.get(mid, f"bridge {mid}")
        tail = read_log_tail(self._bridge_logs.get(mid))
        self.close()
        return LinkDownError(mid, reason, tail, label=label)

    def _bridge_recv(self, mid: int, timeout: float):
        conn = self._bridge_conns[mid]
        deadline = time.monotonic() + timeout
        while not conn.poll(0.05):
            p = self._bridge_procs.get(mid)
            if p is not None and p.exitcode is not None:
                raise self._bridge_dead(mid,
                                        f"died with exitcode {p.exitcode}")
            if time.monotonic() > deadline:
                raise self._bridge_dead(mid,
                                        f"no reply within {timeout:.0f}s")
        try:
            return conn.recv()
        except (EOFError, OSError):
            raise self._bridge_dead(mid, "command pipe closed")

    def _bridge_cmd(self, mid: int, cmd: tuple,
                    timeout: float | None = None):
        try:
            self._bridge_conns[mid].send(cmd)
        except (BrokenPipeError, OSError):
            raise self._bridge_dead(
                mid, f"died (command pipe closed on {cmd[0]!r})")
        kind, payload = self._bridge_recv(
            mid, timeout if timeout is not None else max(self.timeout, 60.0))
        if kind != "ok":
            raise self._bridge_dead(
                mid, f"command {cmd[0]!r} failed: {kind} {payload}")
        return payload

    # ------------------------------------------------------ follower command
    def _ctl_wait(self, host: str, timeout: float | None = None,
                  progress: bool = False):
        """Await one control reply from a follower; typed fault replies
        re-raise here with the fleet torn down (recovery catches them one
        frame up, exactly like a local worker fault)."""
        ctl = self._follower_ctls[host]
        deadline = (None if progress
                    else time.monotonic() + (timeout or self.timeout))
        while True:
            try:
                if ctl.poll(0.02):
                    break
            except ConnectionError:
                mid = self._follower_mid.get(host, self.NW + self.NB)
                tail = read_log_tail(
                    os.path.join(self._log_dir, f"launcher-{host}.log"))
                self.close()
                raise WorkerDiedError(mid, "control link closed", tail,
                                      label=f"launcher {host}")
            self._check_workers()
            if deadline is not None and time.monotonic() > deadline:
                mid = self._follower_mid.get(host, self.NW + self.NB)
                tail = read_log_tail(
                    os.path.join(self._log_dir, f"launcher-{host}.log"))
                self.close()
                raise WorkerDiedError(
                    mid, f"no control reply within "
                    f"{timeout or self.timeout:.0f}s", tail,
                    label=f"launcher {host}")
        kind, payload = ctl.take()
        if kind == "fault":
            self.close()
            raise _fleet.decode_fault(payload, host)
        if kind == "err":
            self.close()
            raise RuntimeError(f"follower {host} command failed:\n{payload}")
        return payload

    def _ctl_cmd(self, host: str, op: str, *args,
                 timeout: float | None = None, progress: bool = False):
        try:
            self._follower_ctls[host].send((op, *args))
        except (ConnectionError, OSError):
            mid = self._follower_mid.get(host, self.NW + self.NB)
            tail = read_log_tail(
                os.path.join(self._log_dir, f"launcher-{host}.log"))
            self.close()
            raise WorkerDiedError(
                mid, f"control link closed (sending {op!r})", tail,
                label=f"launcher {host}")
        return self._ctl_wait(host, timeout=timeout, progress=progress)

    @property
    def _follower_hosts(self) -> tuple:
        return tuple(h for h in (self.host_plan.hosts if self.host_plan
                                 else ()) if h != self.host)

    def _send(self, g: int, cmd: tuple) -> None:
        """Send one command; a closed pipe means the worker is gone —
        surface WorkerDiedError (with the log tail) instead of
        BrokenPipeError, and tear the fleet down."""
        if self._closed:
            raise RuntimeError(
                "engine is closed (a worker died or close() was called); "
                "build a fresh engine"
            )
        try:
            self._conns[g].send(cmd)
        except (BrokenPipeError, OSError):
            p = self._procs.get(g)
            if p is not None:
                p.join(timeout=1.0)
            rc = p.exitcode if p is not None else None
            tail = read_log_tail(
                self._monitor.log_paths[g] if self._monitor else None
            )
            self.close()
            raise WorkerDiedError(
                g, f"died with exitcode {rc} (command pipe closed)", tail
            )

    def _recv_raw(self, g: int):
        """recv() one reply from a worker whose pipe is ready — EOF-
        hardened (a worker can die between poll() and recv(); poll returns
        True at EOF), and typed ``("fault", ...)`` replies (worker-side
        ring corruption / ring timeout) are rebuilt into their original
        exception with the fleet torn down — the recovery controller
        catches them one frame up."""
        try:
            kind, payload = self._conns[g].recv()
        except (EOFError, OSError):
            p = self._procs.get(g)
            if p is not None:
                p.join(timeout=1.0)
            rc = p.exitcode if p is not None else None
            tail = read_log_tail(
                self._monitor.log_paths[g] if self._monitor else None
            )
            self.close()
            how = (f"died with exitcode {rc}" if rc
                   else "exited cleanly (exitcode 0) while replies were "
                        "still pending")
            raise WorkerDiedError(g, f"{how} (reply pipe closed)", tail)
        if kind == "fault":
            self.close()
            raise _rebuild_fault(g, payload)
        return kind, payload

    def _recv(self, g: int, timeout: float | None = None,
              progress: bool = False, hang_check: bool = True):
        """Await one reply.  ``progress=True`` (run commands): no absolute
        deadline — the ProcessMonitor's heartbeat watchdog converts a
        worker that stops making *epoch progress* for ``timeout`` seconds
        (dead, hung, or deadlocked on a ring) into a WorkerDiedError.
        ``hang_check=False`` (startup): workers emit no heartbeats before
        their first command, so only exitcodes are polled and the
        absolute deadline governs."""
        conn = self._conns[g]
        deadline = (None if progress
                    else time.monotonic() + (timeout or self.timeout))
        while not conn.poll(0.02):
            self._check_workers(waiting_on=(g,) if hang_check else None)
            if deadline is not None and time.monotonic() > deadline:
                tail = read_log_tail(self._monitor.log_paths[g])
                self.close()
                raise WorkerDiedError(
                    g, f"no reply within {timeout or self.timeout:.0f}s", tail
                )
        return self._recv_raw(g)

    def _command(self, g: int, cmd: tuple, timeout: float | None = None):
        self._send(g, cmd)
        kind, payload = self._recv(g, timeout)
        if kind == "err":
            self.close()
            raise RuntimeError(f"worker {g} command {cmd[0]!r} failed:\n{payload}")
        return payload

    def _broadcast(self, cmd: tuple, progress: bool = False) -> dict:
        """Send to every worker ON THIS HOST, then collect every reply —
        the workers run the command concurrently (free-running; no barrier
        inside).  Returns ``{worker: payload}`` keyed by global worker id
        (the leader merges follower dicts on top for fleet-wide ops).

        Replies are consumed READY-FIRST, not in worker order: a typed
        fault reply (ring corruption, worker-side timeout) surfaces the
        moment it lands even while earlier-numbered workers are wedged by
        that same fault — detection latency is one poll interval, and the
        monitor's fleet-wide stall diagnosis reasons over exactly the
        still-pending set."""
        for g in self._local_ws:
            self._send(g, cmd)
        out: dict = {}
        pending = set(self._local_ws)
        deadline = (None if progress
                    else time.monotonic() + self.timeout)
        while pending:
            ready = [g for g in sorted(pending) if self._conns[g].poll(0)]
            for g in ready:
                kind, payload = self._recv_raw(g)
                if kind == "err":
                    self.close()
                    raise RuntimeError(
                        f"worker {g} command {cmd[0]!r} failed:\n{payload}"
                    )
                out[g] = payload
                pending.discard(g)
            if not pending:
                break
            if ready:
                if deadline is not None:  # any reply rearms the deadline
                    deadline = time.monotonic() + self.timeout
                continue
            self._check_workers(waiting_on=tuple(sorted(pending)))
            if self._telem_on:
                # free-running coverage: keep the telemetry rings drained
                # while the fleet runs, so a bounded ring never forces the
                # workers to drop records on long epochs-per-command runs
                self._drain_telemetry_once()
            if deadline is not None and time.monotonic() > deadline:
                g = min(pending)
                tail = read_log_tail(self._monitor.log_paths[g])
                self.close()
                raise WorkerDiedError(
                    g, f"no reply within {self.timeout:.0f}s", tail
                )
            time.sleep(0.02)
        return out

    # ------------------------------------------------------ engine protocol
    def init(self, key, group_params: dict[int, PyTree] | None = None) -> ProcsState:
        import jax

        self.launch()
        self._generation += 1
        self._recovery.note_reset()
        # On a bridged fleet a RE-init can catch the previous run's final
        # credit still inside a TCP pipe — fence every bridge (drain +
        # pause) before reseeding, or that credit would land after the
        # reseed and double-credit its channel.
        self._fence_fleet()
        for ring in self._rings.values():
            ring.reset()
        self._seed_credit_rings()
        import jax.numpy as jnp

        key = jnp.asarray(key)
        if not jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
            key = jax.random.wrap_key_data(key)  # legacy raw uint32 keys
        key_data = np.asarray(jax.device_get(jax.random.key_data(key)))
        per_granule_params: list[list | None] = [None] * self.G
        if group_params is not None:
            for g in range(self.G):
                sliced: list = [None] * len(self.graph.groups)
                for gi, p in group_params.items():
                    mo = self.lowering.member_of[gi][g]
                    sliced[gi] = _tree_np(p, mo)
                per_granule_params[g] = sliced
        payloads: dict[int, Any] = {}
        for w, members in enumerate(self._worker_members):
            if group_params is None:
                payloads[w] = None
            elif self._is_batch[w]:
                payloads[w] = [per_granule_params[g] for g in members]
            else:
                payloads[w] = per_granule_params[members[0]]
        for h in self._follower_hosts:
            remote = {w: payloads[w] for w in range(self.NW)
                      if self._host_of_w[w] == h}
            self._follower_ctls[h].send(("init", key_data, remote))
        for w in self._local_ws:
            self._send(w, ("init", key_data, payloads[w]))
        for g in self._local_ws:
            kind, payload = self._recv(g)
            if kind == "err":
                self.close()
                raise RuntimeError(f"worker {g} init failed:\n{payload}")
        for h in self._follower_hosts:
            self._ctl_wait(h, timeout=max(self.timeout, 300.0))
        self._resume_fleet()
        return ProcsState(
            cycle=np.zeros((), np.int32), epoch=np.zeros((), np.int32),
            generation=self._generation,
        )

    def _fence_fleet(self) -> None:
        """Quiesce every bridge in the fleet.  Each proxy pauses its pump,
        sends a FENCE marker, and discards inbound frames until its peer's
        marker arrives — after which BOTH TCP directions are provably
        empty.  Fence commands go out to every party (local bridges AND
        follower launchers) before any ack is collected: a proxy's fence
        completes only when its peer fences too, so acking serially would
        deadlock the handshake."""
        if self.host_plan is None or not self.is_leader or not self._launched:
            return
        gen = self._generation % 256
        for mid in sorted(self._bridge_conns):
            self._bridge_conns[mid].send(("fence", gen))
        for h in self._follower_hosts:
            self._follower_ctls[h].send(("fence", gen))
        for mid in sorted(self._bridge_conns):
            kind, payload = self._bridge_recv(mid, max(self.timeout, 60.0))
            if kind != "ok":
                raise self._bridge_dead(
                    mid, f"fence failed: {kind} {payload}")
        for h in self._follower_hosts:
            self._ctl_wait(h, timeout=max(self.timeout, 60.0))

    def _resume_fleet(self) -> None:
        """Un-pause every bridge after the fenced section (ring reseed /
        state restore) completes fleet-wide."""
        if self.host_plan is None or not self.is_leader or not self._launched:
            return
        for mid in sorted(self._bridge_conns):
            self._bridge_conns[mid].send(("resume",))
        for h in self._follower_hosts:
            self._follower_ctls[h].send(("resume",))
        for mid in sorted(self._bridge_conns):
            kind, payload = self._bridge_recv(mid, max(self.timeout, 60.0))
            if kind != "ok":
                raise self._bridge_dead(
                    mid, f"resume failed: {kind} {payload}")
        for h in self._follower_hosts:
            self._ctl_wait(h, timeout=max(self.timeout, 60.0))

    def _require(self, state: ProcsState) -> ProcsState:
        if not isinstance(state, ProcsState):
            raise TypeError(f"expected ProcsState, got {type(state).__name__}")
        if state.generation != self._generation:
            raise RuntimeError(
                "stale ProcsState: the engine was re-initialized (reset) "
                "after this handle was issued"
            )
        return state

    def run_epochs(self, state: ProcsState, n_epochs: int, *,
                   donate: bool = True) -> ProcsState:
        """Free-run ``n_epochs`` on every worker.  Returns when the slowest
        worker reaches the target epoch — the only global synchronization
        is this *observation* at the command boundary; during the run each
        worker is gated solely by its own channels' credits.

        With ``on_fault="recover"`` the run goes through the recovery
        controller: coordinated snapshots on the ``snapshot_every`` epoch
        grid, and any recoverable fleet fault (dead / hung / corrupted /
        deadlocked) is healed by respawn + restore + replay instead of
        raised."""
        state = self._require(state)
        if n_epochs <= 0:
            return state
        if self.on_fault == "recover":
            return self._recovery.run_epochs(state, int(n_epochs))
        return self._run_epochs_raw(state, int(n_epochs))

    def _run_epochs_raw(self, state: ProcsState, n_epochs: int) -> ProcsState:
        if self._link_faults and self.is_leader:
            # Link faults are launcher-executed at epoch boundaries (the
            # bridge pump has no epoch counter): split the run at every
            # armed fault epoch, run up to it, fire, continue.  The fault
            # then surfaces from inside the NEXT segment — a killed link
            # stalls its consumers, the monitor's stall diagnosis roots
            # the wait-for graph at the bridge, and LinkDownError goes to
            # the recovery controller like any worker death.
            done = int(state.epoch)
            end = done + int(n_epochs)
            while done < end:
                pending = sorted(a.epoch for a in self._armed_link_faults()
                                 if done <= a.epoch < end)
                cut = pending[0] if pending else end
                if cut > done:
                    state = self._run_all(state, cut - done)
                    done = cut
                for a in self._armed_link_faults():
                    if a.epoch <= done:
                        self._fire_link_fault(a)
            return state
        return self._run_all(state, int(n_epochs))

    def _run_all(self, state: ProcsState, n_epochs: int) -> ProcsState:
        for h in self._follower_hosts:
            self._follower_ctls[h].send(("run", int(n_epochs)))
        epochs = self._broadcast(("run", int(n_epochs)), progress=True)
        for h in self._follower_hosts:
            epochs.update(self._ctl_wait(h, progress=True))
        if self._telem_on:
            self._drain_telemetry_once()
            self._drain_followers()
        done = next(iter(epochs.values()))
        assert all(e == done for e in epochs.values()), epochs
        return state.replace(
            cycle=np.int32(done * self.cycles_per_epoch),
            epoch=np.int32(done),
        )

    def _armed_link_faults(self):
        return tuple(a for a in self._link_faults
                     if a.restart == self._incarnation
                     and (a.kind, a.worker, a.epoch, a.restart)
                     not in self._fired_links)

    def _fire_link_fault(self, a) -> None:
        """Execute one armed link fault.  ``a.worker`` is a bridge LINK
        index; the fault routes to a host incident to that link — local
        side preferred, else over the control link to the accept host (for
        ``linkcorrupt``, to a side that actually SENDS slabs, since the
        corruption flips a byte in the next outbound slab frame)."""
        self._fired_links.add((a.kind, a.worker, a.epoch, a.restart))
        REGISTRY.inc("faults.injected")
        _trace.instant("fault_injected", cat="fault",
                       args={"kind": a.kind, "link": int(a.worker),
                             "incarnation": int(self._incarnation)})
        lk = self._links[int(a.worker)]
        mid = self._bridge_ids.get(lk.link)
        local = mid is not None and mid in self._bridge_conns
        if a.kind == "linkkill":
            if local:
                self._bridge_procs[mid].kill()
            else:
                self._ctl_cmd(lk.accept, "linkfault", "linkkill",
                              lk.link, None)
        elif a.kind == "linkslow":
            secs = float(a.arg) if a.arg is not None else 0.05
            if local:
                self._bridge_cmd(mid, ("slow", secs))
            else:
                self._ctl_cmd(lk.accept, "linkfault", "linkslow",
                              lk.link, secs)
        elif a.kind == "linkcorrupt":
            tx_hosts = sorted({sh for (_c, sh) in lk.chans})
            if local and self.host in tx_hosts:
                self._bridge_cmd(mid, ("corrupt",))
            else:
                self._ctl_cmd(tx_hosts[0], "linkfault", "linkcorrupt",
                              lk.link, None)

    def run_cycles(self, state: ProcsState, n_cycles: int) -> ProcsState:
        return self.run_epochs(
            state, -(-int(n_cycles) // self.cycles_per_epoch)
        )

    def _done_view(self, view):
        return view

    def _np_tables(self, g: int):
        """This granule's GraphTables as numpy (the launcher-side copy the
        lightweight ``view`` replies are rejoined with — tables are
        constant, so they never ride the per-epoch pickle)."""
        if not hasattr(self, "_np_tables_cache"):
            self._np_tables_cache: dict[int, Any] = {}
        if g not in self._np_tables_cache:
            from ..core.distributed import GraphTables

            spec = self._specs[g]
            self._np_tables_cache[g] = GraphTables(
                rx_idx=tuple(gs.rx_idx for gs in spec.groups),
                tx_idx=tuple(gs.tx_idx for gs in spec.groups),
                active=tuple(gs.active for gs in spec.groups),
                send_idx=tuple(t.egress_lqids for t in spec.tiers),
                send_mask=tuple(np.ones(len(t.egress_chans), bool)
                                for t in spec.tiers),
                recv_idx=tuple(t.ingress_lqids for t in spec.tiers),
                recv_mask=tuple(np.ones(len(t.ingress_chans), bool)
                                for t in spec.tiers),
            )
        return self._np_tables_cache[g]

    def _views(self) -> list:
        """Per-GRANULE state views in granule order (batched workers reply
        with the stacked batch; each member's row is sliced back out).
        Remote granules come back over the control links, numpy-leaved."""
        import jax

        for h in self._follower_hosts:
            self._follower_ctls[h].send(("views",))
        out: list = [None] * self.G
        for w, v in self._broadcast(("view",)).items():
            for r, g in enumerate(self._worker_members[w]):
                vv = (jax.tree.map(lambda x: x[r], v) if self._is_batch[w]
                      else v)
                out[g] = vv.replace(tables=self._np_tables(g))
        for h in self._follower_hosts:
            for g, vv in self._ctl_wait(
                    h, timeout=max(self.timeout, 60.0)).items():
                out[g] = vv
        return out

    def eval_done(self, state: ProcsState, done_fn: Callable) -> bool:
        """Evaluate a granule-local predicate on every worker's state view
        (host-side — predicates are arbitrary closures, which do not cross
        process boundaries)."""
        self._require(state)
        return all(bool(np.asarray(done_fn(self._done_view(v))).all())
                   for v in self._views())

    def run_until(self, state: ProcsState, done_fn: Callable,
                  max_epochs: int, *, cache_key: Any = None,
                  donate: bool = True) -> ProcsState:
        """Run until ``done_fn`` holds on every granule (checked at epoch
        boundaries, the engines' cadence), at most ``max_epochs`` more."""
        state = self._require(state)
        ran = 0
        while ran < max_epochs and not self.eval_done(state, done_fn):
            state = self.run_epochs(state, 1)
            ran += 1
        return state

    def run_until_done(self, state: ProcsState, max_epochs: int, **kw) -> ProcsState:
        return self.run_until(
            state, lambda v: np.asarray(True), max_epochs, **kw
        )

    # ------------------------------------------------------------- probing
    def group_state(self, state: ProcsState, inst) -> PyTree:
        """One instance's (unstacked) live state — mirrors the in-process
        engines' ``group_state``."""
        self._require(state)
        inst_id = inst if isinstance(inst, int) else inst.inst_id
        gi, slot_g = self.graph.locate(inst_id)
        g = int(self.lowering.member_granule[gi][slot_g])
        slot = int(self.lowering.member_slot[gi][slot_g])
        w = self._worker_of[g]
        row = self._row_of[g] if self._is_batch[w] else None
        h = self._host_of_w[w]
        if self.host_plan is not None and h != self.host:
            return self._ctl_cmd(h, "probe", w, gi, slot, row)
        if row is not None:
            return self._command(w, ("probe", gi, slot, row))
        return self._command(w, ("probe", gi, slot))

    def gather_group(self, state: ProcsState, gi: int) -> PyTree:
        """Group ``gi``'s member states in global instantiation order."""
        self._require(state)
        views = self._views()
        low = self.lowering
        import jax

        def pick(*leaves):
            stacked = np.stack(
                [leaves[g][low.member_slot[gi][m]]
                 for m, g in enumerate(low.member_granule[gi])]
            ) if len(low.member_granule[gi]) else np.zeros((0,))
            return stacked

        per_worker = [v.block_states[gi] for v in views]
        return jax.tree.map(pick, *per_worker)

    def worker_stats(self, state: ProcsState | None = None) -> list[dict]:
        """One record per GRANULE (batched workers reply with a list, one
        per batch row — flattened here so the schema is engine-invariant)."""
        if state is not None:
            self._require(state)
        for h in self._follower_hosts:
            self._follower_ctls[h].send(("wstats",))
        merged = dict(self._broadcast(("stats",)))
        for h in self._follower_hosts:
            merged.update(self._ctl_wait(h, timeout=max(self.timeout, 60.0)))
        out: list[dict] = []
        for w in sorted(merged):
            payload = merged[w]
            if isinstance(payload, list):
                out.extend(payload)
            else:
                out.append(payload)
        if self._telem_on:
            self._drain_telemetry_once()
        return out

    # ------------------------------------------------------ flight recorder
    def set_tracing(self, on: bool) -> bool:
        """Toggle per-worker phase telemetry fleet-wide (``repro.obs``).
        Pre-launch calls are remembered and applied by ``launch()``; a
        recovery respawn re-applies the setting to the new incarnation."""
        self._telem_on = bool(on)
        if self._launched:
            self._apply_tracing()
            if not self._telem_on:
                self._drain_telemetry_once(force=True)
        return self._telem_on

    def _apply_tracing(self) -> None:
        on = self._telem_on
        for h in self._follower_hosts:
            try:
                self._ctl_cmd(h, "telemetry", on)
            except WorkerDiedError:
                raise
            except Exception:
                pass
        self._broadcast(("telemetry", on))

    def _is_telem_sink(self) -> bool:
        """Only the leader (or a single-host engine) folds records into
        the process-global recorder/registry — a follower ships its raw
        records to the leader via the ``obs_drain`` control op instead."""
        return self.host_plan is None or self.is_leader

    def _drain_telemetry_once(self, force: bool = False) -> None:
        """Pop every pending local telemetry record into the trace
        recorder and metrics registry (cheap no-op when nothing pends)."""
        if not (self._is_telem_sink() or force):
            return
        for g, name in sorted(self._telem_names.items()):
            ring = self._rings.get(name)
            if ring is None:
                continue
            self._fold_records(g, _telem.drain(ring), pid=0,
                               host=self.host or "local")

    def _fold_records(self, g: int, records, *, pid: int,
                      host: str) -> None:
        if records.shape[0] == 0:
            return
        rec = _trace.recorder()
        key = (int(pid), int(g))
        if key not in self._telem_tracked:
            self._telem_tracked.add(key)
            rec.set_process(pid, f"procs:{host}")
            rec.set_track(pid, int(g), f"worker {g}")
        _telem.records_to_events(records, worker=int(g), pid=pid,
                                 recorder=rec, registry=REGISTRY)

    def _drain_followers(self) -> None:
        """Pull follower hosts' raw telemetry records over the control
        links and fold them in under their host's trace pid."""
        if self.host_plan is None or not self.is_leader:
            return
        for i, h in enumerate(self._follower_hosts):
            try:
                got = self._ctl_cmd(h, "obs_drain")
            except Exception:
                continue
            for g in sorted(got):
                rows = np.asarray(got[g], np.float64).reshape(
                    -1, _telem.TELEM_RECORD_F64)
                self._fold_records(g, rows, pid=1 + i, host=h)

    def flush_telemetry(self) -> None:
        """Drain every host's telemetry rings into the recorder/registry —
        the trace-export path (``Simulation.trace`` exit, ``REPRO_TRACE``
        atexit).  Also folds bridge counters in as one track per proxy."""
        if not self._launched or self._closed:
            return
        self._drain_telemetry_once()
        self._drain_followers()
        rec = _trace.recorder()
        for i, row in enumerate(self.bridge_stats()):
            link = int(row.get("link", i))
            REGISTRY.set(f"bridge.l{link}.{row.get('role', 'x')}.bytes_tx",
                         float(row.get("bytes_tx", 0)))
            REGISTRY.set(f"bridge.l{link}.{row.get('role', 'x')}.bytes_rx",
                         float(row.get("bytes_rx", 0)))
            if rec.enabled:
                tid = self.NW + i
                rec.set_track(0, tid,
                              f"bridge {link} ({row.get('host', '?')})")
                rec.instant("bridge_counters", pid=0, tid=tid, cat="bridge",
                            args={k: v for k, v in row.items()
                                  if isinstance(v, (int, float, str))})

    def port_stats(self, state: ProcsState) -> dict[str, dict]:
        """Per external port: shm-ring occupancy (packets the host can pop /
        has parked) plus the owning worker's device-queue occupancy — the
        uniform ``Simulation.stats()["ports"]`` schema, nested by
        direction so a name serving BOTH directions reports each
        channel's own ring/queue."""
        self._require(state)
        remote_ext: dict[str, tuple] = {}
        for h in self._follower_hosts:
            remote_ext.update(self._ctl_cmd(h, "ext_state"))
        wstats = {s["granule"]: s for s in self.worker_stats()}

        def rec(cid, name, is_in):
            rname = ext_ring_name(self._ring_prefix, cid)
            if rname in self._rings:
                size, free = self._rings[rname].size(), self._rings[rname].free()
            else:  # port homed on a follower host
                size, free = remote_ext[name]
            g = int(self._chan_owner[cid])
            dev = wstats[g]["ports"].get(name, {})
            return {
                "occupancy": size + int(dev.get("occupancy", 0)),
                "credit": (self.capacity - 1 - int(dev.get("occupancy", 0)))
                if is_in else free,
                "ring": size,
                "home": g,
            }

        return {
            "tx": {n: rec(c, n, True) for n, c in self.graph.ext_in.items()},
            "rx": {n: rec(c, n, False) for n, c in self.graph.ext_out.items()},
        }

    # ---------------------- host-side external ports (PySbTx/PySbRx surface)
    def _ext_ring(self, table: dict, name: str) -> ShmRing:
        if name not in table:
            raise KeyError(name)
        return self._rings[ext_ring_name(self._ring_prefix, table[name])]

    def _ext_remote(self, table: dict, name: str):
        """The follower host owning this external port's ring, or None if
        the port is local (the leader forwards host I/O over the control
        link so PySbTx/PySbRx keep working on a sharded fleet)."""
        if name not in table:
            raise KeyError(name)
        if self.host_plan is None:
            return None
        h = self._ext_home_host(table[name])
        return None if h == self.host else h

    def _ext_push_raw(self, name: str, arr) -> int:
        """Push packets into an external ingress ring, local or follower-
        homed — no recovery bookkeeping (the controller's replay path
        uses this directly)."""
        h = self._ext_remote(self.graph.ext_in, name)
        if h is not None:
            return int(self._ctl_cmd(h, "ext_push", name, arr))
        return int(self._ext_ring(self.graph.ext_in, name).push_packets(arr))

    def _ext_pop_raw(self, name: str, max_n: int):
        h = self._ext_remote(self.graph.ext_out, name)
        if h is not None:
            return self._ctl_cmd(h, "ext_pop", name, max_n)
        return self._ext_ring(self.graph.ext_out, name).pop_packets(
            max_n, self.dtype, self.W
        )

    def _ext_pop_host(self, state: ProcsState, name: str, max_n: int):
        """Host-facing pop: raw ring pops are journaled for recovery, and
        packets a replay regenerated that the host already received
        before the rewind are silently dropped (exactly-once delivery)."""
        skip = int(self._ext_discard.get(name, 0))
        got = self._ext_pop_raw(name, int(max_n) + skip)
        if len(got):
            self._recovery.note_ext_pop(state, name, len(got))
        if skip:
            dropped = min(skip, len(got))
            self._ext_discard[name] = skip - dropped
            got = got[dropped:]
        return got

    # recovery hooks: exactly-once host delivery across a rewind
    def _replay_ext_push(self, name: str, batch) -> None:
        arr = np.asarray(batch, self.dtype).reshape(-1, self.W)
        self._ext_push_raw(name, arr)

    def _set_ext_discard(self, discards: dict) -> None:
        self._ext_discard = {k: int(v) for k, v in discards.items() if v}

    def _ext_discard_state(self) -> dict:
        return {k: v for k, v in self._ext_discard.items() if v}

    def host_push(self, state: ProcsState, name: str, payload):
        state = self._require(state)
        arr = np.asarray(payload, self.dtype).reshape(1, self.W)
        n = self._ext_push_raw(name, arr)
        if n:
            self._recovery.note_ext_push(state, name, arr[:n])
        return state, np.bool_(n == 1)

    def host_pop(self, state: ProcsState, name: str):
        state = self._require(state)
        got = self._ext_pop_host(state, name, 1)
        if len(got):
            return state, got[0], np.bool_(True)
        return state, np.zeros((self.W,), self.dtype), np.bool_(False)

    def host_push_many(self, state: ProcsState, name: str, payloads):
        state = self._require(state)
        arr = np.asarray(payloads, self.dtype).reshape(-1, self.W)
        arr = arr[: self.capacity - 1]
        n = self._ext_push_raw(name, arr)
        if n:
            self._recovery.note_ext_push(state, name, arr[:n])
        return state, np.int32(n)

    def host_pop_many(self, state: ProcsState, name: str, max_n: int):
        state = self._require(state)
        got = self._ext_pop_host(state, name, max_n)
        out = np.zeros((max_n, self.W), self.dtype)
        out[: len(got)] = got
        return state, out, np.int32(len(got))

    # ------------------------------------------------- checkpoint (gather)
    def gather_state(self, state: ProcsState) -> PyTree:
        """Full-fleet state as one pytree: every worker's granule state,
        every boundary channel's in-flight credit record, every external
        ring's resident packets (fixed-size buffers + counts, so the
        checkpoint template is shape-stable)."""
        state = self._require(state)
        for h in self._follower_hosts:
            self._follower_ctls[h].send(("gather",))
        tree = self._gather_local()
        for h in self._follower_hosts:
            remote = self._ctl_wait(h, timeout=max(self.timeout, 60.0))
            tree["workers"].update(remote["workers"])
            tree["credits"].update(remote["credits"])
            tree["ext"].update(remote["ext"])
        if self.host_plan is not None:
            missing = [g for g in range(self.G)
                       if f"g{g}" not in tree["workers"]]
            assert not missing, f"gather missing granules {missing}"
        return {
            "cycle": np.asarray(state.cycle),
            "epoch": np.asarray(state.epoch),
            "workers": tree["workers"],
            "credits": tree["credits"],
            "ext": tree["ext"],
        }

    def _gather_local(self) -> dict:
        """This host's contribution to the fleet checkpoint: its workers'
        granule states, the resting credit of every channel whose SENDER
        lives here (the credit's home at quiesce), and its external
        rings."""
        import jax

        gathered = self._broadcast(("gather",))
        workers: dict[str, Any] = {}
        for w, tree_w in gathered.items():
            for r, g in enumerate(self._worker_members[w]):
                workers[f"g{g}"] = (jax.tree.map(lambda x: x[r], tree_w)
                                    if self._is_batch[w] else tree_w)
        credits = {}
        for (t, s, d), chans in sorted(self.lowering.routes.items()):
            for c in chans:
                name = credit_ring_name(self._ring_prefix, c)
                if name not in self._rings:
                    continue  # channel not materialised on this host
                if (self.host_plan is not None
                        and self._chan_hosts[c][0] != self.host):
                    continue  # rx side of a cross-host channel: the tx
                    #           host accounts its resting credit
                ring = self._rings[name]
                if (self.host_plan is not None
                        and self._chan_hosts[c][0] != self._chan_hosts[c][1]):
                    self._await_credit(c, ring)
                snap = ring.snapshot()
                # at a command boundary exactly one credit is in flight
                assert len(snap) == 1, (c, len(snap))
                credits[f"c{c}"] = snap[0].copy()
        return {"workers": workers, "credits": credits,
                "ext": self._gather_ext_local()}

    def _await_credit(self, c: int, ring: ShmRing) -> None:
        """A cross-host channel's resting credit can still be in TCP
        flight at the command boundary (the receiver pushed it; the bridge
        pair is forwarding it home).  Poll the tx-side credit ring until
        it lands — a link that never delivers it raises RingTimeout, a
        RECOVERABLE fault (the recovery controller restores from the last
        coordinated snapshot)."""
        deadline = time.monotonic() + max(self.timeout, 10.0)
        while ring.size() != 1:
            self._check_workers()
            if time.monotonic() > deadline:
                self.close()
                raise RingTimeout(
                    f"cross-host credit for channel {c} never arrived "
                    f"within {max(self.timeout, 10.0):.0f}s — link down "
                    "or bridge wedged")
            time.sleep(0.002)

    def _gather_ext(self) -> dict:
        """FLEET-WIDE external-ring snapshot — the recovery controller's
        ext-dirty refresh hook.  Follower-homed ports must ride along
        (over the control links), or a refreshed snapshot would be
        missing their entries and a later cross-host scatter would have
        nothing to restore into the follower's rings."""
        ext = {}
        if self.host_plan is not None and self.is_leader:
            for h in self._follower_hosts:
                ext.update(self._ctl_cmd(h, "ext_gather"))
        ext.update(self._gather_ext_local())
        return ext

    def _gather_ext_local(self) -> dict:
        """THIS host's external rings' resident packets + seq counters.
        Checked rings snapshot WITH their headers, and the (producer,
        consumer) sequence counters ride along so a restore into a FRESH
        segment resumes the exact seq timeline — the bit-identical-
        recovery requirement."""
        ext = {}
        for name, (cid, is_in) in self.graph.ext_ports().items():
            rname = ext_ring_name(self._ring_prefix, cid)
            if rname not in self._rings:
                continue  # port homed on another host
            ring = self._rings[rname]
            snap = ring.snapshot()
            buf = np.zeros((self.capacity - 1, ring.stride), np.uint8)
            buf[: len(snap)] = snap
            ext[name] = {"buf": buf, "count": np.int32(len(snap)),
                         "seq": np.asarray(ring.seq_state(), np.int64)}
        return ext

    def scatter_state(self, state: ProcsState, tree: PyTree) -> ProcsState:
        """Restore a ``gather_state`` tree into the running fleet.  On a
        bridged fleet the restore runs inside a fence: restoring rings
        while a bridge pumps — or with a stale credit still in TCP
        flight — would corrupt the credit protocol."""
        import jax

        state = self._require(state)
        self._recovery.note_scatter()
        tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._fence_fleet()
        for h in self._follower_hosts:
            self._follower_ctls[h].send(("scatter", tree))
        self._scatter_local(tree)
        for h in self._follower_hosts:
            self._ctl_wait(h, timeout=max(self.timeout, 300.0))
        self._resume_fleet()
        epoch = int(np.asarray(tree["epoch"]).ravel()[0])
        return state.replace(
            cycle=np.int32(np.asarray(tree["cycle"]).ravel()[0]),
            epoch=np.int32(epoch),
        )

    def _scatter_local(self, tree: PyTree) -> None:
        """This host's share of a fleet-wide restore: credits land on each
        channel's tx host (the rx side of a cross-host channel resets to
        empty — its resting credit lives at the sender), every local data
        ring resets, local external rings restore, local workers scatter."""
        import jax

        for (t, s, d), chans in sorted(self.lowering.routes.items()):
            for c in chans:
                name = credit_ring_name(self._ring_prefix, c)
                if name not in self._rings:
                    continue
                ring = self._rings[name]
                if (self.host_plan is None
                        or self._chan_hosts[c][0] == self.host):
                    ring.restore(np.asarray(tree["credits"][f"c{c}"])[None])
                else:
                    ring.reset()
        for (t, s, d), chans in sorted(self.lowering.routes.items()):
            for c in chans:
                name = data_ring_name(self._ring_prefix, c)
                if name in self._rings:
                    self._rings[name].reset()
        for name, (cid, is_in) in self.graph.ext_ports().items():
            rname = ext_ring_name(self._ring_prefix, cid)
            if rname not in self._rings:
                continue
            ring = self._rings[rname]
            rec = tree["ext"][name]
            seq = (tuple(int(x) for x in np.asarray(rec["seq"]).ravel())
                   if "seq" in rec else None)
            ring.restore(np.asarray(rec["buf"])[: int(rec["count"])], seq=seq)
        epoch = int(np.asarray(tree["epoch"]).ravel()[0])
        for w in self._local_ws:
            members = self._worker_members[w]
            if self._is_batch[w]:
                rows = [tree["workers"][f"g{g}"] for g in members]
                payload = jax.tree.map(lambda *xs: np.stack(xs), *rows)
            else:
                payload = tree["workers"][f"g{members[0]}"]
            self._send(w, ("scatter", payload, epoch))
        for g in self._local_ws:
            kind, payload = self._recv(g)
            if kind == "err":
                self.close()
                raise RuntimeError(f"worker {g} scatter failed:\n{payload}")

    # ------------------------------------------------------- bridge surface
    def bridge_stats(self) -> list[dict]:
        """One counter row per live bridge proxy, fleet-wide (leader) —
        ``Simulation.stats()["bridges"]``.  Empty on a single-host engine.
        Dead proxies and unreachable followers are skipped, not raised:
        stats must stay callable mid-fault."""
        if self.host_plan is None or not self._launched:
            return []
        rows = self._local_bridge_stats()
        if self.is_leader:
            for h in self._follower_hosts:
                ctl = self._follower_ctls.get(h)
                p = self._follower_procs.get(h)
                if ctl is None or (p is not None and p.exitcode is not None):
                    continue
                try:
                    ctl.send(("bridge_stats",))
                    deadline = time.monotonic() + 10.0
                    msg = None
                    while msg is None:
                        ctl.poll(0.02)
                        msg = ctl.peek()
                        if msg is None and time.monotonic() > deadline:
                            break
                    # a pending typed fault stays queued for _check_workers
                    if msg is not None and msg[0] == "ok":
                        ctl.take()
                        rows.extend(msg[1])
                except Exception:
                    continue
        rows.sort(key=lambda r: (r["link"], r["host"]))
        return rows

    def _local_bridge_stats(self) -> list[dict]:
        rows = []
        for mid in sorted(self._bridge_conns):
            p = self._bridge_procs.get(mid)
            if p is None or p.exitcode is not None:
                continue
            conn = self._bridge_conns[mid]
            try:
                conn.send(("stats",))
                deadline = time.monotonic() + 5.0
                while not conn.poll(0.02):
                    if (time.monotonic() > deadline
                            or p.exitcode is not None):
                        raise TimeoutError
                kind, payload = conn.recv()
            except (TimeoutError, EOFError, OSError, BrokenPipeError):
                continue
            if kind == "ok" and payload is not None:
                rows.append(payload)
        return rows

    # ------------------------------------------- follower control dispatch
    def _fleet_dispatch(self, op: str, args: tuple):
        """Serve one leader control command on a FOLLOWER launcher (called
        from ``fleet.follower_entry``).  Faults raised here are encoded and
        shipped back typed — the leader re-raises them as if local."""
        if op == "run":
            (n,) = args
            return self._broadcast(("run", int(n)), progress=True)
        if op == "init":
            key_data, payloads = args
            self._generation += 1
            self._recovery.note_reset()
            for ring in self._rings.values():
                ring.reset()
            self._seed_credit_rings()
            for w in self._local_ws:
                self._send(w, ("init", key_data, payloads.get(w)))
            for g in self._local_ws:
                kind, payload = self._recv(g, timeout=max(self.timeout, 300.0))
                if kind == "err":
                    raise RuntimeError(f"worker {g} init failed:\n{payload}")
            return True
        if op == "fence":
            (gen,) = args
            for mid in sorted(self._bridge_conns):
                self._bridge_conns[mid].send(("fence", int(gen)))
            for mid in sorted(self._bridge_conns):
                kind, payload = self._bridge_recv(mid,
                                                  max(self.timeout, 60.0))
                if kind != "ok":
                    raise self._bridge_dead(
                        mid, f"fence failed: {kind} {payload}")
            return True
        if op == "resume":
            for mid in sorted(self._bridge_conns):
                self._bridge_conns[mid].send(("resume",))
            for mid in sorted(self._bridge_conns):
                kind, payload = self._bridge_recv(mid,
                                                  max(self.timeout, 60.0))
                if kind != "ok":
                    raise self._bridge_dead(
                        mid, f"resume failed: {kind} {payload}")
            return True
        if op == "gather":
            return self._gather_local()
        if op == "scatter":
            (tree,) = args
            self._scatter_local(tree)
            return True
        if op == "views":
            import jax

            out: dict[int, Any] = {}
            for w, v in self._broadcast(("view",)).items():
                for r, g in enumerate(self._worker_members[w]):
                    vv = (jax.tree.map(lambda x: x[r], v)
                          if self._is_batch[w] else v)
                    vv = vv.replace(tables=self._np_tables(g))
                    out[g] = jax.tree.map(lambda x: np.asarray(x), vv)
            return out
        if op == "probe":
            import jax

            w, gi, slot, row = args
            if row is not None:
                got = self._command(w, ("probe", gi, slot, row))
            else:
                got = self._command(w, ("probe", gi, slot))
            return jax.tree.map(lambda x: np.asarray(x), got)
        if op == "wstats":
            return dict(self._broadcast(("stats",)))
        if op == "ext_state":
            out = {}
            for name, (cid, is_in) in self.graph.ext_ports().items():
                rname = ext_ring_name(self._ring_prefix, cid)
                if rname in self._rings:
                    r = self._rings[rname]
                    out[name] = (r.size(), r.free())
            return out
        if op == "ext_gather":
            return self._gather_ext_local()
        if op == "ext_push":
            name, arr = args
            return int(self._ext_ring(self.graph.ext_in, name)
                       .push_packets(np.asarray(arr)))
        if op == "ext_pop":
            name, n = args
            return self._ext_ring(self.graph.ext_out, name).pop_packets(
                int(n), self.dtype, self.W)
        if op == "bridge_stats":
            return self._local_bridge_stats()
        if op == "telemetry":
            (on,) = args
            self._telem_on = bool(on)
            self._broadcast(("telemetry", bool(on)))
            return True
        if op == "obs_drain":
            # ship raw per-worker records to the leader (the only sink)
            out = {}
            for g, name in sorted(self._telem_names.items()):
                ring = self._rings.get(name)
                if ring is None:
                    continue
                rows = _telem.drain(ring)
                if rows.shape[0]:
                    out[g] = rows
            return out
        if op == "linkfault":
            kind, link, arg = args
            mid = self._bridge_ids[int(link)]
            if kind == "linkkill":
                self._bridge_procs[mid].kill()
            elif kind == "linkslow":
                self._bridge_cmd(mid, ("slow", float(arg)))
            elif kind == "linkcorrupt":
                self._bridge_cmd(mid, ("corrupt",))
            else:
                raise RuntimeError(f"unknown link fault {kind!r}")
            return True
        raise RuntimeError(f"unknown fleet control op {op!r}")

    # -------------------------------------------------------- fault surface
    def fault_stats(self) -> dict:
        """Recovery/fault counters — ``Simulation.stats()["faults"]``."""
        return self._recovery.stats()

    def _handle_at(self, epoch: int) -> ProcsState:
        """A fresh state handle pinned at ``epoch`` — the recovery restore
        path's replacement for the handle that rode into the fault."""
        return ProcsState(
            cycle=np.int32(int(epoch) * self.cycles_per_epoch),
            epoch=np.int32(int(epoch)),
            generation=self._generation,
        )


def _rebuild_fault(worker: int, payload: dict) -> Exception:
    """Rebuild a worker's typed ``("fault", ...)`` reply into its original
    exception (ring corruption / ring timeout) so the recovery controller
    sees the same type it would from a launcher-side detection."""
    if payload.get("error") == "RingCorruptionError":
        return RingCorruptionError(**payload["args"])
    return RingTimeout(
        f"worker {worker}: {payload.get('message', 'ring timeout')}"
    )


def _tree_np(tree: PyTree, idx: np.ndarray) -> PyTree:
    import jax

    return jax.tree.map(lambda x: np.asarray(x)[np.asarray(idx)], tree)


def _child_env() -> dict[str, str | None]:
    """Point spawned workers at a single CPU device: strip the parent's
    fake-device XLA flag and force the CPU platform.  Returns the saved
    parent values for ``_restore_env``."""
    saved = {k: os.environ.get(k) for k in ("XLA_FLAGS", "JAX_PLATFORMS")}
    flags = [
        f for f in os.environ.get("XLA_FLAGS", "").split()
        if "xla_force_host_platform_device_count" not in f
    ]
    if flags:
        os.environ["XLA_FLAGS"] = " ".join(flags)
    else:
        os.environ.pop("XLA_FLAGS", None)
    os.environ["JAX_PLATFORMS"] = "cpu"
    return saved


def _restore_env(saved: dict[str, str | None]) -> None:
    for k, v in saved.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v

"""Multiprocess launcher — ``Network.build(engine="procs")`` (paper §III,
DESIGN.md §Runtime).

``ProcsEngine`` is the fifth engine: it realizes the paper's deployment
model *literally* — one free-running OS process per granule, connected at
runtime by shared-memory SPSC queues — behind the same ``Simulation``
facade as the in-process engines.  The division of labor:

  * ``graph.lower_partition`` assigns every channel its granule-local
    queue (the same lowering the shard_map engines consume, so the
    granule state layouts are bit-identical);
  * the launcher creates one slab ring + one credit ring per boundary
    channel and one packet ring per external port
    (``runtime.shmem.ShmRing``), spawns one worker per granule
    (``runtime.worker``), and speaks the session protocol to them over
    command pipes: ``init`` / ``run`` / ``probe`` / ``stats`` /
    checkpoint ``gather``/``scatter``;
  * host Tx/Rx ports read and write the external rings directly — host
    I/O never interrupts a running worker, it lands at the worker's next
    epoch boundary exactly like the in-process engines' host tier.

**Prebuilt-simulator cache**: before spawning anything, the launcher
AOT-compiles one granule simulator per *distinct granule signature*
(``jit(...).lower().compile()`` into the shared JAX persistent
compilation cache).  Workers then compile against a warm cache, so build
time grows with unique granule shapes — O(#block kinds), not
O(#instances) — the paper's flat-build-time property, measured in
``benchmarks/procs_runtime.py``.

**Failure surface** (``runtime.fault_tolerance``): every reply wait polls
worker exitcodes (ANY exit while replies are pending, clean or not) and
per-epoch heartbeats; a dead or silent worker raises ``WorkerDiedError``
with that worker's captured log tail, and the remaining workers are torn
down — never a hang on a half-dead fleet.  When the WHOLE fleet goes
quiet, the per-worker "blocked on ring X" status words in the heartbeat
shm are decoded into the credit wait-for graph: a cycle raises
``FleetStallError`` naming the deadlock, an acyclic graph names the root
worker.  Checked rings surface slab corruption as
``RingCorruptionError`` (``runtime.shmem``).

**Self-healing** (``runtime.recovery``, ISSUE 8): with
``on_fault="recover"`` (env ``REPRO_ON_FAULT``) the engine takes
coordinated snapshots every ``snapshot_every`` epochs at command
boundaries (the fleet is quiesced there, so ``gather_state`` is a
consistent cut) and, on any recoverable fault, tears down the remnant
fleet, respawns workers from the warm prebuilt-simulator cache,
scatters the last snapshot, and replays the lost epochs — final state
and host Rx traffic bit-identical to a fault-free run.  Deterministic
drills via ``runtime.faultinject`` (``REPRO_FAULT_PLAN``).
"""
from __future__ import annotations

import atexit
import dataclasses
import os
import pickle
import secrets
import tempfile
import time
import weakref
from multiprocessing import get_context, shared_memory
from typing import Any, Callable, Sequence

import numpy as np

from ..core import queue as qmod
from ..kernels import granule_step
from ..core.graph import (
    ChannelGraph, PartitionLowering, PartitionTree, Tier, lower_partition,
    normalize_partition, normalize_tiers,
)
from .fault_tolerance import (
    FleetStallError, ProcessMonitor, WorkerDiedError, find_stall_cycle,
    read_log_tail, stall_wait_edges,
)
from .faultinject import actions_for, resolve_fault_plan
from .recovery import RecoveryController, resolve_on_fault
from .shmem import RingCorruptionError, RingTimeout, ShmRing, slab_slot_bytes
from .worker import (
    HB_RECORD_BYTES, HB_RECORD_F64, BatchSpec, BatchedGranuleSim, GranuleSim,
    GranuleSpec, GroupSpec, TierSpec, configure_compile_cache,
    credit_ring_name, data_ring_name, ext_ring_name, worker_entry,
)

PyTree = Any

_DEFAULT_CACHE = (
    os.environ.get("REPRO_PROCS_CACHE_DIR")
    or os.path.join(tempfile.gettempdir(), "repro_procs_cache")
)


def _worker_mp_context():
    """Multiprocessing context for worker processes.

    Default is a ``forkserver`` preloaded with ``repro.runtime.worker``:
    the server pays the jax/repro import ONCE, then every worker — and
    critically every recovery *respawn* — is a cheap fork of it.  Safe
    because importing the worker module initializes no XLA backend and
    starts no threads (each fork creates its own client); the server
    starts inside the ``_child_env`` window, so its frozen environment is
    the canonical single-CPU-device worker env.  ``REPRO_WORKER_SPAWN=
    spawn`` restores plain spawn (each worker re-imports jax, several
    seconds apiece)."""
    method = os.environ.get("REPRO_WORKER_SPAWN", "forkserver")
    if method not in ("forkserver", "spawn"):
        raise ValueError(
            f"REPRO_WORKER_SPAWN={method!r}: expected 'forkserver' or "
            "'spawn'"
        )
    if method == "forkserver":
        try:
            ctx = get_context("forkserver")
            ctx.set_forkserver_preload(["repro.runtime.worker"])
            return ctx
        except (ValueError, OSError):  # platform without forkserver
            pass
    return get_context("spawn")

# Engines are tracked weakly: a garbage-collected engine tears itself down
# via __del__, and whatever is still alive at interpreter exit is closed
# here — worker processes and shm segments never outlive the launcher.
_live_engines: "weakref.WeakSet[ProcsEngine]" = weakref.WeakSet()


def _close_all_engines() -> None:  # pragma: no cover - interpreter exit
    for eng in list(_live_engines):
        try:
            eng.close()
        except Exception:
            pass


atexit.register(_close_all_engines)


@dataclasses.dataclass
class ProcsState:
    """The session's handle on a running fleet — a *reference*, not the
    state itself: granule state lives in the workers (that is the point).
    The handle carries the boundary-synchronized counters plus a
    generation stamp so a stale handle (pre-reset) fails loudly."""

    cycle: np.ndarray  # () int32 — identical on every worker at a boundary
    epoch: np.ndarray  # () int32
    generation: int

    def replace(self, **kw) -> "ProcsState":
        return dataclasses.replace(self, **kw)


class ProcsEngine:
    """Free-running multiprocess engine over a partitioned ChannelGraph.

    graph:      the channel-graph IR.
    partition:  ``PartitionTree`` (tiered), or any flat instance->granule
                map ``normalize_partition`` accepts (with ``n_workers``/
                ``tiers``); granule ids are worker indices.
    n_workers:  worker count for flat partitions (default: max granule+1).
    K:          innermost sync rate (cycles between boundary exchanges).
    tiers:      optional ``(axes, K)`` spec with ``axis_sizes`` supplied by
                a PartitionTree — procs needs no mesh, so pass tiered
                layouts via PartitionTree.
    ring_depth: slab records a boundary ring buffers (>= 2; staleness
                slack for the slab data — the credit chain already bounds
                epoch drift at one exchange period per channel).
    timeout:    seconds a worker waits on a ring / the launcher waits on a
                silent worker before declaring it dead.
    prebuild:   AOT-compile each distinct granule signature in-launcher
                (warming the persistent cache) before any worker spawns.
    cache_dir:  JAX persistent compilation cache directory (shared).
    batch_signatures:
                group same-signature granules (``lowering.batch_plan``)
                into ONE worker process each, stepping the whole group as
                a leading-axis batch with a single vmapped dispatch per
                program op — fewer processes and fewer dispatches for
                replicated designs, bit-identical traffic (the batch is a
                legal lockstep refinement of the free-running schedule).
    overlap:    split every tier exchange into issue (drain + push) and
                commit (pop + fill) phases — at a boundary all outgoing
                slabs are pushed before the worker blocks on any incoming
                one (send-early/receive-late), so peer latencies overlap
                instead of adding.  Bit-identical traffic (the credit
                protocol per channel is unchanged).  "auto"/bool with
                ``REPRO_OVERLAP`` env override; auto = off.
    on_fault:   "raise" (default) propagates the first fleet fault;
                "recover" auto-heals: snapshot periodically, and on a
                dead/hung/corrupted/deadlocked fleet respawn + restore +
                replay (``runtime.recovery``).  "auto"/str with
                ``REPRO_ON_FAULT`` env override; auto = raise.
    snapshot_every:
                coordinated-snapshot cadence in epochs (recover mode; the
                snapshot is taken at the first command boundary on each
                multiple, where the fleet is quiesced).  The default
                trades the steady-state gather tax (benchmarked at
                ~1.2x a raise-mode run on the smoke wafer, budget 1.5x)
                against the replay bound of one cadence of epochs.
    max_restarts:
                recovery attempts before giving up (the original fault is
                re-raised, chained).
    backoff_s:  base of the exponential respawn backoff (doubles per
                consecutive restart).
    fault_plan: deterministic fault injection for drills — a plan string
                (see ``runtime.faultinject``) or a sequence of
                ``FaultAction``; default: env ``REPRO_FAULT_PLAN``.
    """

    engine_kind = "procs"

    def __init__(
        self,
        graph: ChannelGraph,
        partition=None,
        *,
        n_workers: int | None = None,
        K: int = 1,
        tiers: Sequence | None = None,
        ring_depth: int = 2,
        timeout: float = 60.0,
        prebuild: bool = True,
        cache_dir: str | None = None,
        log_dir: str | None = None,
        batch_signatures: bool = False,
        overlap: Any = "auto",
        on_fault: str = "auto",
        snapshot_every: int = 16,
        max_restarts: int = 3,
        backoff_s: float = 0.25,
        fault_plan: Any = None,
    ):
        self.graph = graph
        if isinstance(partition, PartitionTree):
            if tiers is not None:
                raise ValueError("pass tiers via the PartitionTree, not both")
            ptree = partition
        else:
            if tiers is not None:
                tspec = normalize_tiers(tiers)
                raise ValueError(
                    "procs has no mesh to size tier axes "
                    f"{[t.axes for t in tspec]} — pass a PartitionTree"
                )
            if n_workers is None:
                part0 = normalize_partition(graph, partition, 1 << 30)
                n_workers = int(part0.max()) + 1 if part0.size else 1
            part = normalize_partition(graph, partition, n_workers)
            ptree = PartitionTree(
                part, (Tier(axes=("w",), K=int(K)),), {"w": int(n_workers)}
            )
        self.ptree = ptree
        self.tiers = ptree.tiers
        self.K_tiers = ptree.K_tiers
        self.periods = ptree.periods()
        self.cycles_per_epoch = ptree.cycles_per_epoch
        self.K = self.K_tiers[-1]
        self.G = ptree.n_granules
        self.n_workers = self.G
        self.E_tiers = tuple(min(p, graph.capacity - 1) for p in self.periods)
        self.W = graph.payload_words
        self.payload_words = graph.payload_words
        self.capacity = graph.capacity
        self.dtype = np.dtype(graph.dtype if graph.dtype is not None
                              else np.float32)
        self.part = ptree.part
        # A boundary slab ring must hold one exchange window in flight PLUS
        # the next window the overlapped (send-early/receive-late) schedule
        # pushes before the previous one is consumed.  Shallower rings
        # deadlock the free-running fleet (historically surfacing only as
        # the CI watchdog timeout) — fail fast at build time instead.
        ring_depth = int(ring_depth)
        if ring_depth < 2:
            raise ValueError(
                f"ring_depth={ring_depth} is too shallow: boundary slab "
                f"rings must hold two exchange windows (>= 2 slab records "
                f"of E_t slots each; tier slab depths E_t={self.E_tiers}) "
                f"so the overlapped schedule can push window w+1 before "
                f"window w is consumed — a shallower ring deadlocks the "
                f"free-running fleet instead of failing fast"
            )
        self.ring_depth = ring_depth
        self.overlap = granule_step.resolve_overlap(overlap)
        self.timeout = float(timeout)
        self.cache_dir = cache_dir if cache_dir is not None else _DEFAULT_CACHE
        self.on_fault = resolve_on_fault(on_fault)
        self.fault_plan = resolve_fault_plan(fault_plan)
        self._incarnation = 0  # bumped on every recovery respawn

        low = lower_partition(graph, ptree)
        self.lowering = low
        self.n_local = low.n_local
        self._chan_owner = low.chan_owner
        self._tx_local, self._rx_local = low.tx_local, low.rx_local

        self._ring_prefix = f"sb{os.getpid() % 100000:x}{secrets.token_hex(3)}"
        self._log_dir = log_dir or tempfile.mkdtemp(prefix="repro_procs_")
        self._specs = [self._granule_spec(g) for g in range(self.G)]
        self.signatures = [s.signature for s in self._specs]

        # ---- signature-batch plan: one worker per granule, or (with
        # batch_signatures) one worker per signature group stepping the
        # whole group as a leading-axis batch
        self.batch_signatures = bool(batch_signatures)
        if self.batch_signatures:
            groups, where = low.batch_plan()
            self._worker_members = [tuple(ms) for ms in groups]
            self._worker_of = {g: b for g, (b, r) in where.items()}
            self._row_of = {g: r for g, (b, r) in where.items()}
        else:
            self._worker_members = [(g,) for g in range(self.G)]
            self._worker_of = {g: g for g in range(self.G)}
            self._row_of = {g: 0 for g in range(self.G)}
        self._wspecs: list[Any] = [
            self._specs[ms[0]] if len(ms) == 1
            else BatchSpec(members=ms, specs=[self._specs[g] for g in ms])
            for ms in self._worker_members
        ]
        self._is_batch = [isinstance(s, BatchSpec) for s in self._wspecs]
        self.NW = len(self._wspecs)
        # channel id -> (producer worker, consumer worker) of its slab
        # direction: the topology the stall diagnoser decodes status
        # words against
        self._chan_workers = {
            c: (self._worker_of[s], self._worker_of[d])
            for (t, s, d), chans in self.lowering.routes.items()
            for c in chans
        }
        bad = [a for a in self.fault_plan if a.worker >= self.NW]
        if bad:
            raise ValueError(
                f"fault plan targets worker(s) {[a.worker for a in bad]} "
                f"but the fleet has {self.NW} worker(s)"
            )

        # ---- the prebuilt-simulator cache: one compile per DISTINCT shape
        self.build_stats: dict[str, Any] = {
            "n_workers": self.NW,
            "n_signatures": len(set(self.signatures)),
            "compiled": {},
            "prebuild_seconds": 0.0,
        }
        if prebuild:
            configure_compile_cache(self.cache_dir)
            t0 = time.perf_counter()
            done: set[tuple[str, int]] = set()
            for wspec in self._wspecs:
                nb = len(wspec.specs) if isinstance(wspec, BatchSpec) else 1
                key = (wspec.signature, nb)
                if key in done:
                    continue
                done.add(key)
                sim = (BatchedGranuleSim(wspec) if isinstance(wspec, BatchSpec)
                       else GranuleSim(wspec))
                stats = sim.prebuild()
                name = (wspec.signature if nb == 1
                        else f"{wspec.signature}x{nb}")
                self.build_stats["compiled"][name] = stats
            self.build_stats["prebuild_seconds"] = time.perf_counter() - t0

        # forkserver preloaded with the worker module: respawns fork the
        # already-imported server instead of re-importing jax (recovery
        # MTTR); starts lazily inside the launch() _child_env window
        self._ctx = _worker_mp_context()
        self._procs: dict[int, Any] = {}
        self._conns: dict[int, Any] = {}
        self._rings: dict[str, ShmRing] = {}
        self._hb_shm: shared_memory.SharedMemory | None = None
        self._hb: np.ndarray | None = None
        self._generation = 0
        self._launched = False
        self._closed = False
        self._monitor: ProcessMonitor | None = None
        self._recovery = RecoveryController(
            self, snapshot_every=snapshot_every, max_restarts=max_restarts,
            backoff_s=backoff_s,
        )
        _live_engines.add(self)

    # ------------------------------------------------------------- lowering
    def _granule_spec(self, g: int) -> GranuleSpec:
        low, graph = self.lowering, self.graph
        groups = []
        for gi, grp in enumerate(graph.groups):
            mo = low.member_of[gi][g]
            params_local = None
            if grp.params is not None:
                params_local = _tree_np(grp.params, mo)
            groups.append(GroupSpec(
                block=grp.block,
                n_members=grp.n_members,
                n_slot=low.n_slot[gi],
                member_of=mo.copy(),
                active=low.act_tables[gi][g].copy(),
                rx_idx=low.rx_tables[gi][g].copy(),
                tx_idx=low.tx_tables[gi][g].copy(),
                params_local=params_local,
            ))
        tiers = []
        for t in range(self.ptree.n_tiers):
            eg, ing = low.tier_channels(t, g)
            tiers.append(TierSpec(
                K=self.K_tiers[t],
                E=self.E_tiers[t],
                egress_chans=tuple(eg),
                egress_lqids=low.tx_local[eg].astype(np.int32)
                if eg else np.zeros((0,), np.int32),
                ingress_chans=tuple(ing),
                ingress_lqids=low.rx_local[ing].astype(np.int32)
                if ing else np.zeros((0,), np.int32),
            ))
        ext = [
            (name, cid, int(max(low.tx_local[cid], low.rx_local[cid])), is_in)
            for name, cid, is_in in low.ext_channels(g)
        ]
        return GranuleSpec(
            granule=g,
            signature=low.granule_signature(g),
            payload_words=self.W,
            capacity=self.capacity,
            dtype=self.dtype.str,
            n_local=self.n_local,
            groups=groups,
            tiers=tiers,
            ext_ports=ext,
            ring_prefix=self._ring_prefix,
            ring_depth=self.ring_depth,
            timeout=self.timeout,
            overlap=self.overlap,
        )

    # ------------------------------------------------------------- lifecycle
    def launch(self) -> "ProcsEngine":
        """Create the rings and spawn one worker per granule (idempotent)."""
        if self._launched:
            return self
        if self._closed:
            raise RuntimeError("engine was closed")
        itemsize = self.dtype.itemsize
        for t, ts in enumerate(self.tiers):
            for (tt, s, d), chans in sorted(self.lowering.routes.items()):
                if tt != t:
                    continue
                for c in chans:
                    # slab + host-port rings are integrity-checked (per-
                    # record seq + crc32); 4-byte credit rings are not —
                    # their payload IS the protocol invariant
                    self._rings[data_ring_name(self._ring_prefix, c)] = (
                        ShmRing.create(
                            data_ring_name(self._ring_prefix, c),
                            self.ring_depth + 1,
                            slab_slot_bytes(self.E_tiers[t], self.W, itemsize),
                            checked=True, label=f"slab:c{c}",
                        )
                    )
                    self._rings[credit_ring_name(self._ring_prefix, c)] = (
                        ShmRing.create(
                            credit_ring_name(self._ring_prefix, c),
                            self.ring_depth + 2, 4,
                        )
                    )
        for name, (cid, is_in) in self.graph.ext_ports().items():
            self._rings[ext_ring_name(self._ring_prefix, cid)] = ShmRing.create(
                ext_ring_name(self._ring_prefix, cid),
                self.capacity, self.W * itemsize,
                checked=True, label=f"ext:{name}",
            )
        self._seed_credit_rings()

        hb_name = f"{self._ring_prefix}hb"
        self._hb_shm = shared_memory.SharedMemory(
            name=hb_name, create=True, size=HB_RECORD_BYTES * self.NW
        )
        self._hb_shm.buf[:] = bytes(HB_RECORD_BYTES * self.NW)
        self._hb = np.frombuffer(self._hb_shm.buf, np.float64)

        env_save = _child_env()
        try:
            for g, spec in enumerate(self._wspecs):
                parent, child = self._ctx.Pipe()
                log_path = os.path.join(self._log_dir, f"worker{g}.log")
                faults = actions_for(self.fault_plan, g, self._incarnation)
                p = self._ctx.Process(
                    target=worker_entry,
                    args=(child, pickle.dumps(spec), g, log_path,
                          self.cache_dir, hb_name,
                          pickle.dumps(faults) if faults else None),
                    daemon=True,
                    name=f"repro-granule-{g}",
                )
                p.start()
                child.close()
                self._procs[g] = p
                self._conns[g] = parent
        finally:
            _restore_env(env_save)
        self._monitor = ProcessMonitor(
            self._procs,
            {g: os.path.join(self._log_dir, f"worker{g}.log")
             for g in range(self.NW)},
            heartbeat=lambda g: float(self._hb[g * HB_RECORD_F64])
            + float(self._hb[g * HB_RECORD_F64 + 1]),
            hang_timeout_s=self.timeout,
            diagnose=self._diagnose_stall,
        )
        self._launched = True
        self.launch_stats = {"ready_seconds": {}}
        for g in range(self.NW):
            t0 = time.perf_counter()
            # no heartbeats exist yet (first beat lands on the init
            # command), so the ready-wait polls exitcodes only under a
            # generous absolute deadline — a cold compilation cache must
            # not read as "hung"
            kind, payload = self._recv(g, timeout=max(self.timeout, 300.0),
                                       hang_check=False)
            if kind != "ready":
                raise WorkerDiedError(g, f"failed to start: {payload}",
                                      read_log_tail(self._monitor.log_paths[g]))
            self.launch_stats["ready_seconds"][g] = time.perf_counter() - t0
        return self

    def _seed_credit_rings(self) -> None:
        """Every boundary channel's sender starts with capacity-1 credit —
        the engines' initial-credit convention, as one pre-seeded record."""
        for (t, s, d), chans in self.lowering.routes.items():
            for c in chans:
                ring = self._rings[credit_ring_name(self._ring_prefix, c)]
                ring.reset()
                ring.push_u32(self.capacity - 1, timeout=1.0)

    def close(self) -> None:
        """Tear down workers and unlink every shared-memory segment."""
        if self._closed:
            return
        self._closed = True
        for g, conn in list(self._conns.items()):
            try:
                conn.send(("exit",))
            except Exception:
                pass
        for g, p in list(self._procs.items()):
            try:
                p.join(timeout=2.0)
                if p.is_alive():
                    p.terminate()
                    p.join(timeout=2.0)
            except Exception:
                pass
        for g, conn in list(self._conns.items()):
            try:
                conn.close()
            except Exception:
                pass
        for ring in self._rings.values():
            ring.close()
        self._rings.clear()
        if self._hb_shm is not None:
            self._hb = None
            try:
                self._hb_shm.close()
                self._hb_shm.unlink()
            except Exception:
                pass
        _live_engines.discard(self)

    def _reopen(self) -> None:
        """Respawn the fleet after a fault (the recovery path): fresh ring
        namespace, fresh worker processes, the SAME lowering — and a warm
        persistent compilation cache, so the respawn skips every compile
        the first launch paid for.  The restart count gates incarnation-
        scoped fault-plan actions (``:r<N>``), so a fired drill fault does
        not re-fire during its own replay."""
        if not self._closed:
            self.close()
        self._incarnation += 1
        self._closed = False
        self._launched = False
        self._procs = {}
        self._conns = {}
        self._rings = {}
        self._hb_shm = None
        self._hb = None
        self._monitor = None
        self._ring_prefix = f"sb{os.getpid() % 100000:x}{secrets.token_hex(3)}"
        # specs embed the ring prefix — rebuild them for the new namespace
        self._specs = [self._granule_spec(g) for g in range(self.G)]
        self._wspecs = [
            self._specs[ms[0]] if len(ms) == 1
            else BatchSpec(members=ms, specs=[self._specs[g] for g in ms])
            for ms in self._worker_members
        ]
        self._np_tables_cache = {}
        _live_engines.add(self)
        self.launch()

    def __del__(self):  # best-effort; atexit covers the normal path
        try:
            self.close()
        except Exception:
            pass

    # --------------------------------------------------------------- comms
    def _check_workers(self, waiting_on=None) -> None:
        if self._monitor is not None:
            try:
                self._monitor.check(waiting_on)
            except (WorkerDiedError, FleetStallError):
                # a dead or deadlocked granule poisons the whole fleet (its
                # peers would hang on its rings) — tear everything down
                # before raising
                self.close()
                raise

    def _diagnose_stall(self, waiting_on: tuple[int, ...]):
        """Fleet-wide no-heartbeat diagnosis (monitor callback): decode
        every worker's "blocked on ring X" status word into the credit
        wait-for graph.  A cycle is a true deadlock → ``FleetStallError``
        naming it; an acyclic graph blames its root worker; no usable
        information returns None (the monitor falls back to the plain
        hung-worker error)."""
        if self._hb is None:
            return None
        blocked = {w: int(self._hb[w * HB_RECORD_F64 + 2])
                   for w in range(self.NW)}
        edges, details = stall_wait_edges(blocked, self._chan_workers)
        cycle = find_stall_cycle(edges)
        if cycle is not None:
            return FleetStallError(cycle, [details[w] for w in cycle])
        roots = set(edges.values()) - set(edges)
        if edges and roots:
            w = min(roots)
            return WorkerDiedError(
                w,
                f"is the root of a fleet-wide stall: {len(edges)} worker(s) "
                f"transitively blocked on it while it made no progress for "
                f"{self.timeout:.0f}s",
                read_log_tail(self._monitor.log_paths.get(w)
                              if self._monitor else None),
            )
        return None

    def _send(self, g: int, cmd: tuple) -> None:
        """Send one command; a closed pipe means the worker is gone —
        surface WorkerDiedError (with the log tail) instead of
        BrokenPipeError, and tear the fleet down."""
        if self._closed:
            raise RuntimeError(
                "engine is closed (a worker died or close() was called); "
                "build a fresh engine"
            )
        try:
            self._conns[g].send(cmd)
        except (BrokenPipeError, OSError):
            p = self._procs.get(g)
            if p is not None:
                p.join(timeout=1.0)
            rc = p.exitcode if p is not None else None
            tail = read_log_tail(
                self._monitor.log_paths[g] if self._monitor else None
            )
            self.close()
            raise WorkerDiedError(
                g, f"died with exitcode {rc} (command pipe closed)", tail
            )

    def _recv_raw(self, g: int):
        """recv() one reply from a worker whose pipe is ready — EOF-
        hardened (a worker can die between poll() and recv(); poll returns
        True at EOF), and typed ``("fault", ...)`` replies (worker-side
        ring corruption / ring timeout) are rebuilt into their original
        exception with the fleet torn down — the recovery controller
        catches them one frame up."""
        try:
            kind, payload = self._conns[g].recv()
        except (EOFError, OSError):
            p = self._procs.get(g)
            if p is not None:
                p.join(timeout=1.0)
            rc = p.exitcode if p is not None else None
            tail = read_log_tail(
                self._monitor.log_paths[g] if self._monitor else None
            )
            self.close()
            how = (f"died with exitcode {rc}" if rc
                   else "exited cleanly (exitcode 0) while replies were "
                        "still pending")
            raise WorkerDiedError(g, f"{how} (reply pipe closed)", tail)
        if kind == "fault":
            self.close()
            raise _rebuild_fault(g, payload)
        return kind, payload

    def _recv(self, g: int, timeout: float | None = None,
              progress: bool = False, hang_check: bool = True):
        """Await one reply.  ``progress=True`` (run commands): no absolute
        deadline — the ProcessMonitor's heartbeat watchdog converts a
        worker that stops making *epoch progress* for ``timeout`` seconds
        (dead, hung, or deadlocked on a ring) into a WorkerDiedError.
        ``hang_check=False`` (startup): workers emit no heartbeats before
        their first command, so only exitcodes are polled and the
        absolute deadline governs."""
        conn = self._conns[g]
        deadline = (None if progress
                    else time.monotonic() + (timeout or self.timeout))
        while not conn.poll(0.02):
            self._check_workers(waiting_on=(g,) if hang_check else None)
            if deadline is not None and time.monotonic() > deadline:
                tail = read_log_tail(self._monitor.log_paths[g])
                self.close()
                raise WorkerDiedError(
                    g, f"no reply within {timeout or self.timeout:.0f}s", tail
                )
        return self._recv_raw(g)

    def _command(self, g: int, cmd: tuple, timeout: float | None = None):
        self._send(g, cmd)
        kind, payload = self._recv(g, timeout)
        if kind == "err":
            self.close()
            raise RuntimeError(f"worker {g} command {cmd[0]!r} failed:\n{payload}")
        return payload

    def _broadcast(self, cmd: tuple, progress: bool = False) -> list:
        """Send to every worker, then collect every reply — the workers run
        the command concurrently (free-running; no barrier inside).

        Replies are consumed READY-FIRST, not in worker order: a typed
        fault reply (ring corruption, worker-side timeout) surfaces the
        moment it lands even while earlier-numbered workers are wedged by
        that same fault — detection latency is one poll interval, and the
        monitor's fleet-wide stall diagnosis reasons over exactly the
        still-pending set."""
        for g in range(self.NW):
            self._send(g, cmd)
        out: list = [None] * self.NW
        pending = set(range(self.NW))
        deadline = (None if progress
                    else time.monotonic() + self.timeout)
        while pending:
            ready = [g for g in sorted(pending) if self._conns[g].poll(0)]
            for g in ready:
                kind, payload = self._recv_raw(g)
                if kind == "err":
                    self.close()
                    raise RuntimeError(
                        f"worker {g} command {cmd[0]!r} failed:\n{payload}"
                    )
                out[g] = payload
                pending.discard(g)
            if not pending:
                break
            if ready:
                if deadline is not None:  # any reply rearms the deadline
                    deadline = time.monotonic() + self.timeout
                continue
            self._check_workers(waiting_on=tuple(sorted(pending)))
            if deadline is not None and time.monotonic() > deadline:
                g = min(pending)
                tail = read_log_tail(self._monitor.log_paths[g])
                self.close()
                raise WorkerDiedError(
                    g, f"no reply within {self.timeout:.0f}s", tail
                )
            time.sleep(0.02)
        return out

    # ------------------------------------------------------ engine protocol
    def init(self, key, group_params: dict[int, PyTree] | None = None) -> ProcsState:
        import jax

        self.launch()
        self._generation += 1
        self._recovery.note_reset()
        for ring in self._rings.values():
            ring.reset()
        self._seed_credit_rings()
        import jax.numpy as jnp

        key = jnp.asarray(key)
        if not jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
            key = jax.random.wrap_key_data(key)  # legacy raw uint32 keys
        key_data = np.asarray(jax.device_get(jax.random.key_data(key)))
        per_granule_params: list[list | None] = [None] * self.G
        if group_params is not None:
            for g in range(self.G):
                sliced: list = [None] * len(self.graph.groups)
                for gi, p in group_params.items():
                    mo = self.lowering.member_of[gi][g]
                    sliced[gi] = _tree_np(p, mo)
                per_granule_params[g] = sliced
        for w, members in enumerate(self._worker_members):
            if group_params is None:
                payload = None
            elif self._is_batch[w]:
                payload = [per_granule_params[g] for g in members]
            else:
                payload = per_granule_params[members[0]]
            self._send(w, ("init", key_data, payload))
        for g in range(self.NW):
            kind, payload = self._recv(g)
            if kind == "err":
                self.close()
                raise RuntimeError(f"worker {g} init failed:\n{payload}")
        return ProcsState(
            cycle=np.zeros((), np.int32), epoch=np.zeros((), np.int32),
            generation=self._generation,
        )

    def _require(self, state: ProcsState) -> ProcsState:
        if not isinstance(state, ProcsState):
            raise TypeError(f"expected ProcsState, got {type(state).__name__}")
        if state.generation != self._generation:
            raise RuntimeError(
                "stale ProcsState: the engine was re-initialized (reset) "
                "after this handle was issued"
            )
        return state

    def run_epochs(self, state: ProcsState, n_epochs: int, *,
                   donate: bool = True) -> ProcsState:
        """Free-run ``n_epochs`` on every worker.  Returns when the slowest
        worker reaches the target epoch — the only global synchronization
        is this *observation* at the command boundary; during the run each
        worker is gated solely by its own channels' credits.

        With ``on_fault="recover"`` the run goes through the recovery
        controller: coordinated snapshots on the ``snapshot_every`` epoch
        grid, and any recoverable fleet fault (dead / hung / corrupted /
        deadlocked) is healed by respawn + restore + replay instead of
        raised."""
        state = self._require(state)
        if n_epochs <= 0:
            return state
        if self.on_fault == "recover":
            return self._recovery.run_epochs(state, int(n_epochs))
        return self._run_epochs_raw(state, int(n_epochs))

    def _run_epochs_raw(self, state: ProcsState, n_epochs: int) -> ProcsState:
        epochs = self._broadcast(("run", int(n_epochs)), progress=True)
        done = epochs[0]
        assert all(e == done for e in epochs), epochs
        return state.replace(
            cycle=np.int32(done * self.cycles_per_epoch),
            epoch=np.int32(done),
        )

    def run_cycles(self, state: ProcsState, n_cycles: int) -> ProcsState:
        return self.run_epochs(
            state, -(-int(n_cycles) // self.cycles_per_epoch)
        )

    def _done_view(self, view):
        return view

    def _np_tables(self, g: int):
        """This granule's GraphTables as numpy (the launcher-side copy the
        lightweight ``view`` replies are rejoined with — tables are
        constant, so they never ride the per-epoch pickle)."""
        if not hasattr(self, "_np_tables_cache"):
            self._np_tables_cache: dict[int, Any] = {}
        if g not in self._np_tables_cache:
            from ..core.distributed import GraphTables

            spec = self._specs[g]
            self._np_tables_cache[g] = GraphTables(
                rx_idx=tuple(gs.rx_idx for gs in spec.groups),
                tx_idx=tuple(gs.tx_idx for gs in spec.groups),
                active=tuple(gs.active for gs in spec.groups),
                send_idx=tuple(t.egress_lqids for t in spec.tiers),
                send_mask=tuple(np.ones(len(t.egress_chans), bool)
                                for t in spec.tiers),
                recv_idx=tuple(t.ingress_lqids for t in spec.tiers),
                recv_mask=tuple(np.ones(len(t.ingress_chans), bool)
                                for t in spec.tiers),
            )
        return self._np_tables_cache[g]

    def _views(self) -> list:
        """Per-GRANULE state views in granule order (batched workers reply
        with the stacked batch; each member's row is sliced back out)."""
        import jax

        out: list = [None] * self.G
        for w, v in enumerate(self._broadcast(("view",))):
            for r, g in enumerate(self._worker_members[w]):
                vv = (jax.tree.map(lambda x: x[r], v) if self._is_batch[w]
                      else v)
                out[g] = vv.replace(tables=self._np_tables(g))
        return out

    def eval_done(self, state: ProcsState, done_fn: Callable) -> bool:
        """Evaluate a granule-local predicate on every worker's state view
        (host-side — predicates are arbitrary closures, which do not cross
        process boundaries)."""
        self._require(state)
        return all(bool(np.asarray(done_fn(self._done_view(v))).all())
                   for v in self._views())

    def run_until(self, state: ProcsState, done_fn: Callable,
                  max_epochs: int, *, cache_key: Any = None,
                  donate: bool = True) -> ProcsState:
        """Run until ``done_fn`` holds on every granule (checked at epoch
        boundaries, the engines' cadence), at most ``max_epochs`` more."""
        state = self._require(state)
        ran = 0
        while ran < max_epochs and not self.eval_done(state, done_fn):
            state = self.run_epochs(state, 1)
            ran += 1
        return state

    def run_until_done(self, state: ProcsState, max_epochs: int, **kw) -> ProcsState:
        return self.run_until(
            state, lambda v: np.asarray(True), max_epochs, **kw
        )

    # ------------------------------------------------------------- probing
    def group_state(self, state: ProcsState, inst) -> PyTree:
        """One instance's (unstacked) live state — mirrors the in-process
        engines' ``group_state``."""
        self._require(state)
        inst_id = inst if isinstance(inst, int) else inst.inst_id
        gi, slot_g = self.graph.locate(inst_id)
        g = int(self.lowering.member_granule[gi][slot_g])
        slot = int(self.lowering.member_slot[gi][slot_g])
        w = self._worker_of[g]
        if self._is_batch[w]:
            return self._command(w, ("probe", gi, slot, self._row_of[g]))
        return self._command(w, ("probe", gi, slot))

    def gather_group(self, state: ProcsState, gi: int) -> PyTree:
        """Group ``gi``'s member states in global instantiation order."""
        self._require(state)
        views = self._views()
        low = self.lowering
        import jax

        def pick(*leaves):
            stacked = np.stack(
                [leaves[g][low.member_slot[gi][m]]
                 for m, g in enumerate(low.member_granule[gi])]
            ) if len(low.member_granule[gi]) else np.zeros((0,))
            return stacked

        per_worker = [v.block_states[gi] for v in views]
        return jax.tree.map(pick, *per_worker)

    def worker_stats(self, state: ProcsState | None = None) -> list[dict]:
        """One record per GRANULE (batched workers reply with a list, one
        per batch row — flattened here so the schema is engine-invariant)."""
        if state is not None:
            self._require(state)
        out: list[dict] = []
        for payload in self._broadcast(("stats",)):
            if isinstance(payload, list):
                out.extend(payload)
            else:
                out.append(payload)
        return out

    def port_stats(self, state: ProcsState) -> dict[str, dict]:
        """Per external port: shm-ring occupancy (packets the host can pop /
        has parked) plus the owning worker's device-queue occupancy — the
        uniform ``Simulation.stats()["ports"]`` schema, nested by
        direction so a name serving BOTH directions reports each
        channel's own ring/queue."""
        self._require(state)
        wstats = {s["granule"]: s for s in self.worker_stats()}

        def rec(cid, name, is_in):
            ring = self._rings[ext_ring_name(self._ring_prefix, cid)]
            g = int(self._chan_owner[cid])
            dev = wstats[g]["ports"].get(name, {})
            return {
                "occupancy": ring.size() + int(dev.get("occupancy", 0)),
                "credit": (self.capacity - 1 - int(dev.get("occupancy", 0)))
                if is_in else ring.free(),
                "ring": ring.size(),
                "home": g,
            }

        return {
            "tx": {n: rec(c, n, True) for n, c in self.graph.ext_in.items()},
            "rx": {n: rec(c, n, False) for n, c in self.graph.ext_out.items()},
        }

    # ---------------------- host-side external ports (PySbTx/PySbRx surface)
    def _ext_ring(self, table: dict, name: str) -> ShmRing:
        if name not in table:
            raise KeyError(name)
        return self._rings[ext_ring_name(self._ring_prefix, table[name])]

    def host_push(self, state: ProcsState, name: str, payload):
        state = self._require(state)
        self._recovery.note_ext_io(state)
        arr = np.asarray(payload, self.dtype).reshape(1, self.W)
        n = self._ext_ring(self.graph.ext_in, name).push_packets(arr)
        return state, np.bool_(n == 1)

    def host_pop(self, state: ProcsState, name: str):
        state = self._require(state)
        self._recovery.note_ext_io(state)
        got = self._ext_ring(self.graph.ext_out, name).pop_packets(
            1, self.dtype, self.W
        )
        if len(got):
            return state, got[0], np.bool_(True)
        return state, np.zeros((self.W,), self.dtype), np.bool_(False)

    def host_push_many(self, state: ProcsState, name: str, payloads):
        state = self._require(state)
        self._recovery.note_ext_io(state)
        arr = np.asarray(payloads, self.dtype).reshape(-1, self.W)
        arr = arr[: self.capacity - 1]
        n = self._ext_ring(self.graph.ext_in, name).push_packets(arr)
        return state, np.int32(n)

    def host_pop_many(self, state: ProcsState, name: str, max_n: int):
        state = self._require(state)
        self._recovery.note_ext_io(state)
        got = self._ext_ring(self.graph.ext_out, name).pop_packets(
            max_n, self.dtype, self.W
        )
        out = np.zeros((max_n, self.W), self.dtype)
        out[: len(got)] = got
        return state, out, np.int32(len(got))

    # ------------------------------------------------- checkpoint (gather)
    def gather_state(self, state: ProcsState) -> PyTree:
        """Full-fleet state as one pytree: every worker's granule state,
        every boundary channel's in-flight credit record, every external
        ring's resident packets (fixed-size buffers + counts, so the
        checkpoint template is shape-stable)."""
        import jax

        state = self._require(state)
        gathered = self._broadcast(("gather",))
        workers: list = [None] * self.G
        for w, tree_w in enumerate(gathered):
            for r, g in enumerate(self._worker_members[w]):
                workers[g] = (jax.tree.map(lambda x: x[r], tree_w)
                              if self._is_batch[w] else tree_w)
        credits = {}
        for (t, s, d), chans in sorted(self.lowering.routes.items()):
            for c in chans:
                ring = self._rings[credit_ring_name(self._ring_prefix, c)]
                snap = ring.snapshot()
                # at a command boundary exactly one credit is in flight
                assert len(snap) == 1, (c, len(snap))
                credits[f"c{c}"] = snap[0].copy()
        return {
            "cycle": np.asarray(state.cycle),
            "epoch": np.asarray(state.epoch),
            "workers": {f"g{g}": w for g, w in enumerate(workers)},
            "credits": credits,
            "ext": self._gather_ext(),
        }

    def _gather_ext(self) -> dict:
        """External rings' resident packets + seq counters (also used by
        the recovery controller to refresh a snapshot after host I/O at an
        unchanged epoch).  Checked rings snapshot WITH their headers, and
        the (producer, consumer) sequence counters ride along so a restore
        into a FRESH segment resumes the exact seq timeline — the bit-
        identical-recovery requirement."""
        ext = {}
        for name, (cid, is_in) in self.graph.ext_ports().items():
            ring = self._rings[ext_ring_name(self._ring_prefix, cid)]
            snap = ring.snapshot()
            buf = np.zeros((self.capacity - 1, ring.stride), np.uint8)
            buf[: len(snap)] = snap
            ext[name] = {"buf": buf, "count": np.int32(len(snap)),
                         "seq": np.asarray(ring.seq_state(), np.int64)}
        return ext

    def scatter_state(self, state: ProcsState, tree: PyTree) -> ProcsState:
        """Restore a ``gather_state`` tree into the running fleet."""
        import jax

        state = self._require(state)
        self._recovery.note_scatter()
        tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        for (t, s, d), chans in sorted(self.lowering.routes.items()):
            for c in chans:
                ring = self._rings[credit_ring_name(self._ring_prefix, c)]
                ring.restore(np.asarray(tree["credits"][f"c{c}"])[None])
        for (t, s, d), chans in sorted(self.lowering.routes.items()):
            for c in chans:
                self._rings[data_ring_name(self._ring_prefix, c)].reset()
        for name, (cid, is_in) in self.graph.ext_ports().items():
            ring = self._rings[ext_ring_name(self._ring_prefix, cid)]
            rec = tree["ext"][name]
            seq = (tuple(int(x) for x in np.asarray(rec["seq"]).ravel())
                   if "seq" in rec else None)
            ring.restore(np.asarray(rec["buf"])[: int(rec["count"])], seq=seq)
        epoch = int(np.asarray(tree["epoch"]).ravel()[0])
        for w, members in enumerate(self._worker_members):
            if self._is_batch[w]:
                rows = [tree["workers"][f"g{g}"] for g in members]
                payload = jax.tree.map(lambda *xs: np.stack(xs), *rows)
            else:
                payload = tree["workers"][f"g{members[0]}"]
            self._send(w, ("scatter", payload, epoch))
        for g in range(self.NW):
            kind, payload = self._recv(g)
            if kind == "err":
                self.close()
                raise RuntimeError(f"worker {g} scatter failed:\n{payload}")
        return state.replace(
            cycle=np.int32(np.asarray(tree["cycle"]).ravel()[0]),
            epoch=np.int32(epoch),
        )

    # -------------------------------------------------------- fault surface
    def fault_stats(self) -> dict:
        """Recovery/fault counters — ``Simulation.stats()["faults"]``."""
        return self._recovery.stats()

    def _handle_at(self, epoch: int) -> ProcsState:
        """A fresh state handle pinned at ``epoch`` — the recovery restore
        path's replacement for the handle that rode into the fault."""
        return ProcsState(
            cycle=np.int32(int(epoch) * self.cycles_per_epoch),
            epoch=np.int32(int(epoch)),
            generation=self._generation,
        )


def _rebuild_fault(worker: int, payload: dict) -> Exception:
    """Rebuild a worker's typed ``("fault", ...)`` reply into its original
    exception (ring corruption / ring timeout) so the recovery controller
    sees the same type it would from a launcher-side detection."""
    if payload.get("error") == "RingCorruptionError":
        return RingCorruptionError(**payload["args"])
    return RingTimeout(
        f"worker {worker}: {payload.get('message', 'ring timeout')}"
    )


def _tree_np(tree: PyTree, idx: np.ndarray) -> PyTree:
    import jax

    return jax.tree.map(lambda x: np.asarray(x)[np.asarray(idx)], tree)


def _child_env() -> dict[str, str | None]:
    """Point spawned workers at a single CPU device: strip the parent's
    fake-device XLA flag and force the CPU platform.  Returns the saved
    parent values for ``_restore_env``."""
    saved = {k: os.environ.get(k) for k in ("XLA_FLAGS", "JAX_PLATFORMS")}
    flags = [
        f for f in os.environ.get("XLA_FLAGS", "").split()
        if "xla_force_host_platform_device_count" not in f
    ]
    if flags:
        os.environ["XLA_FLAGS"] = " ".join(flags)
    else:
        os.environ.pop("XLA_FLAGS", None)
    os.environ["JAX_PLATFORMS"] = "cpu"
    return saved


def _restore_env(saved: dict[str, str | None]) -> None:
    for k, v in saved.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed on the
single-pod 16x16 mesh AND the 2x16x16 multi-pod mesh for every assigned
cell, plus the paper's own manycore grid.  memory_analysis() proves the
working set fits; cost_analysis() + HLO collective parsing feed the
roofline table (EXPERIMENTS.md §Roofline).

Usage:
    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    python -m repro.launch.dryrun --all [--mesh single|multi|both]
    python -m repro.launch.dryrun --arch manycore

Results are written to experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from ..configs import ALIASES, ARCH_IDS, SHAPES, get_config, skip_reason
from ..sharding.partition import Strategy
from . import hlo_analysis as HA
from .mesh import make_grid_mesh, make_production_mesh
from .steps import lower_cell

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D prefill, 2·N_active·B decode."""
    n_active = cfg.active_param_count()
    if shape.step == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.step == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # one token per request


# Per-(arch, shape-kind) strategy overrides found by the §Perf hillclimb.
# key: (arch_id, step) with None wildcards; first match wins.
STRATEGY_OVERRIDES: list[tuple[str | None, str | None, dict]] = [
    # xlstm-125m (§Perf): tp=16 with 4 heads forced per-layer activation
    # all-gathers (iter-1); FSDP over both axes put weight shards on
    # contraction dims -> per-scan-step partial-sum all-reduces (iter-2,
    # refuted).  A 125M model is small enough to REPLICATE: pure 256-way DP,
    # one gradient all-reduce per step (iter-3, confirmed).
    ("xlstm_125m", None, dict(tp=None, dp_all=True, fsdp=False)),
]

# §Perf iteration (llama3.2-3b prefill): sequence-sharding activations over
# the model axis lets GSPMD distribute attention by (batch x seq x kv-shard)
# instead of replicating head-indivisible activations: 241s -> 1.6s
# collective on llama3.2-3b prefill_32k, and 2.4-2.7x on train for odd-head
# archs.  Applied to every pure-attention family; recurrent/hybrid archs
# keep SP off (a sequential recurrence cannot shard its scan axis).
_SP_FAMILIES = {"dense", "moe", "vlm", "audio"}


def default_strategy(cfg, shape, mesh) -> Strategy:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    arch = getattr(cfg, "name", "").replace(".", "_").replace("-", "_")
    for a, s, kw in STRATEGY_OVERRIDES:
        if (a is None or arch == a or arch.startswith(a)) and (
            s is None or s == shape.step
        ):
            kw = dict(kw)
            if kw.pop("dp_all", False):
                # grow the DP axis set greedily while the global batch still
                # divides it (batch=256 divides 16x16 but not 2x16x16 —
                # the pod axis then stays replicated at 50% scaling, which
                # beats a non-divisible sharding collapse; see §Perf).
                dp = ()
                for ax in ("data", "model", "pod"):
                    if ax in mesh.axis_names:
                        size = 1
                        for a in dp + (ax,):
                            size *= mesh.shape[a]
                        if shape.global_batch % size == 0:
                            dp = dp + (ax,)
            return Strategy(dp=dp, tp=kw.pop("tp", "model"),
                            fsdp=kw.pop("fsdp", True),
                            seq_shard=kw.pop("seq_shard", False))
    sp = (
        getattr(cfg, "family", "") in _SP_FAMILIES
        and shape.step in ("train", "prefill")
    )
    return Strategy(dp=dp, tp="model", fsdp=True, seq_shard=sp)


def run_lm_cell(arch: str, shape_name: str, mesh_kind: str, strategy: Strategy | None = None) -> dict:
    arch_id = ALIASES.get(arch, arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind}
    reason = skip_reason(arch_id, shape_name)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec
    cfg = get_config(arch_id)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    strategy = strategy or default_strategy(cfg, shape, mesh)
    t0 = time.time()
    try:
        lowered, kind = lower_cell(cfg, shape, mesh, strategy)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        terms = HA.roofline_terms(cost, hlo, n_chips)
        mf = model_flops(cfg, shape)
        rec.update(
            status="ok",
            step_kind=kind,
            n_chips=n_chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            model_flops=mf,
            useful_ratio=mf / terms["hlo_flops"] if terms.get("hlo_flops") else None,
            dominant=HA.dominant_term(terms),
            memory_analysis=_mem_dict(mem),
            **{k: v for k, v in terms.items()},
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def run_manycore(mesh_kind: str, k_epoch: int | None = None) -> dict:
    """Lower+compile the million-core systolic epoch on the device grid."""
    import jax.numpy as jnp
    from ..configs.manycore import CONFIG
    from ..core.distributed import GridEngine
    from ..hw.systolic import SystolicCell, make_cell_params

    rec = {"arch": "manycore", "shape": f"grid{CONFIG.grid_rows}x{CONFIG.grid_cols}",
           "mesh": mesh_kind}
    # 512 devices as 32x16 (multi) or 256 as 16x16 (single pod)
    rows, cols = (32, 16) if mesh_kind == "multi" else (16, 16)
    mesh = make_grid_mesh(rows, cols)
    try:
        eng = GridEngine(
            SystolicCell(m_stream=CONFIG.m_stream),
            CONFIG.grid_rows, CONFIG.grid_cols, mesh,
            K=k_epoch or CONFIG.k_epoch, capacity=CONFIG.queue_capacity,
        )
        params = jax.eval_shape(
            lambda: make_cell_params(
                np.zeros((CONFIG.m_stream, CONFIG.grid_rows), np.float32),
                np.zeros((CONFIG.grid_rows, CONFIG.grid_cols), np.float32),
            )
        )
        state_shapes = jax.eval_shape(
            lambda p: eng.init(jax.random.key(0), p), params
        )
        fn = jax.jit(eng.epoch_fn())
        t0 = time.time()
        lowered = fn.lower(state_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        terms = HA.roofline_terms(cost, hlo, mesh.size)
        rec.update(
            status="ok", step_kind="epoch(K=%d)" % (k_epoch or CONFIG.k_epoch),
            n_chips=mesh.size,
            cores=CONFIG.grid_rows * CONFIG.grid_cols,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            dominant=HA.dominant_term(terms),
            memory_analysis=_mem_dict(compiled.memory_analysis()),
            **terms,
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    return out


def _save(rec: dict) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def _summ(rec: dict) -> str:
    if rec["status"] == "ok":
        per_dev = rec.get("memory_analysis", {}).get("argument_size_in_bytes", 0) / 1e9
        return (f"OK   {rec['arch']:26s} {rec['shape']:12s} {rec['mesh']:6s} "
                f"dom={rec['dominant'][:-2]:10s} comp={rec['compute_s']:.3e}s "
                f"mem={rec['memory_s']:.3e}s coll={rec['collective_s']:.3e}s "
                f"args/dev={per_dev:.2f}GB compile={rec['compile_s']:.0f}s")
    if rec["status"] == "skipped":
        return f"SKIP {rec['arch']:26s} {rec['shape']:12s} {rec['mesh']:6s} ({rec['reason'][:60]})"
    return f"FAIL {rec['arch']:26s} {rec['shape']:12s} {rec['mesh']:6s} {rec['error'][:100]}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    jobs: list = []
    if args.all:
        for arch in ARCH_IDS:
            if arch == "manycore":
                continue
            for shape in SHAPES:
                for mk in meshes:
                    jobs.append((arch, shape, mk))
        for mk in meshes:
            jobs.append(("manycore", None, mk))
    else:
        arch = args.arch or "llama3.2-1b"
        if ALIASES.get(arch, arch) == "manycore":
            jobs = [("manycore", None, mk) for mk in meshes]
        else:
            shapes = [args.shape] if args.shape else list(SHAPES)
            jobs = [(arch, s, mk) for s in shapes for mk in meshes]

    for arch, shape, mk in jobs:
        if arch == "manycore":
            rec = run_manycore(mk)
        else:
            rec = run_lm_cell(arch, shape, mk)
        _save(rec)
        print(_summ(rec), flush=True)


if __name__ == "__main__":
    main()

"""Step builders shared by dryrun.py, train.py and benchmarks.

Builds jitted train/prefill/decode steps for an (arch config, shape,
mesh, strategy) cell, with all in/out shardings resolved from
``sharding.partition`` rules.  Everything here works on either concrete
arrays or ShapeDtypeStructs (dry-run).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import model as M
from ..models.config import ModelConfig
from ..optim.optimizer import AdamW
from ..sharding import partition as SP
from ..configs.registry import ShapeSpec

PyTree = Any


# ------------------------------------------------------------- abstractions
def abstract_params(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(lambda: M.init_params(cfg, jax.random.key(0)))


def abstract_opt_state(cfg: ModelConfig, opt: AdamW, params_shapes: PyTree) -> PyTree:
    return jax.eval_shape(opt.init, params_shapes)


def abstract_batch(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_mode == "embeddings":
        inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return {"inputs": inputs, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def abstract_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> PyTree:
    return jax.eval_shape(lambda: M.init_decode_state(cfg, batch, max_seq))


# ------------------------------------------------------------- train step
def make_train_step(cfg: ModelConfig, opt: AdamW, constrain):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(M.loss_fn, has_aux=True)(
            params, cfg, batch, constrain
        )
        new_params, new_opt_state, om = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return new_params, new_opt_state, metrics

    return train_step


def jit_train_step(
    cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, strategy: SP.Strategy,
    opt: AdamW | None = None, donate: bool = True,
):
    opt = opt or AdamW()
    constrain = SP.make_constrain(strategy, mesh, seq_len=shape.seq_len)
    step = make_train_step(cfg, opt, constrain)

    p_shapes = abstract_params(cfg)
    o_shapes = abstract_opt_state(cfg, opt, p_shapes)
    p_sh = SP.named_shardings(p_shapes, strategy, mesh)
    o_sh = _opt_shardings(o_shapes, p_sh, mesh)
    b_specs = SP.batch_specs(cfg, shape, strategy, mesh)
    b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                        is_leaf=lambda x: isinstance(x, P))

    jitted = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1) if donate else (),
    )
    abstract = (p_shapes, o_shapes, abstract_batch(cfg, shape))
    return jitted, abstract


def _opt_shardings(opt_shapes, param_shardings, mesh):
    """Adam moments shard like their params; step counter replicated."""
    from ..optim.optimizer import AdamWState

    rep = NamedSharding(mesh, P())
    return AdamWState(step=rep, mu=param_shardings, nu=param_shardings)


# ------------------------------------------------------------- serve steps
def jit_prefill(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, strategy: SP.Strategy):
    constrain = SP.make_constrain(strategy, mesh, seq_len=shape.seq_len)
    b, s = shape.global_batch, shape.seq_len

    def prefill_step(params, inputs):
        return M.prefill(params, cfg, inputs, max_seq=s, constrain=constrain)

    p_shapes = abstract_params(cfg)
    p_sh = SP.named_shardings(p_shapes, strategy, mesh)
    st_shapes = abstract_decode_state(cfg, b, s)
    st_specs = SP.decode_state_specs(st_shapes, cfg, strategy, mesh)
    st_sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), st_specs,
                         is_leaf=lambda x: isinstance(x, P))
    dpb = SP._div(b, strategy.dp, mesh)
    if cfg.input_mode == "embeddings":
        in_sh = NamedSharding(mesh, P(dpb, None, None))
        inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        in_sh = NamedSharding(mesh, P(dpb, None))
        inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)

    jitted = jax.jit(
        prefill_step,
        in_shardings=(p_sh, in_sh),
        out_shardings=(st_sh, None),
    )
    return jitted, (p_shapes, inputs)


def jit_decode_step(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, strategy: SP.Strategy):
    constrain = SP.make_constrain(strategy, mesh)
    b, s = shape.global_batch, shape.seq_len

    def serve_step(params, states, token, pos):
        return M.decode_step(params, cfg, states, token, pos, constrain=constrain)

    p_shapes = abstract_params(cfg)
    p_sh = SP.named_shardings(p_shapes, strategy, mesh)
    st_shapes = abstract_decode_state(cfg, b, s)
    st_specs = SP.decode_state_specs(st_shapes, cfg, strategy, mesh)
    st_sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), st_specs,
                         is_leaf=lambda x: isinstance(x, P))
    dpb = SP._div(b, strategy.dp, mesh)
    tok_sh = NamedSharding(mesh, P(dpb))

    jitted = jax.jit(
        serve_step,
        in_shardings=(p_sh, st_sh, tok_sh, NamedSharding(mesh, P())),
        out_shardings=(st_sh, None),
        donate_argnums=(1,),
    )
    abstract = (
        p_shapes,
        st_shapes,
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return jitted, abstract


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, strategy: SP.Strategy):
    """Lower the right step for a cell; returns (lowered, kind)."""
    with jax.default_device(jax.devices()[0]):
        if shape.step == "train":
            jitted, abstract = jit_train_step(cfg, shape, mesh, strategy)
            return jitted.lower(*abstract), "train_step"
        if shape.step == "prefill":
            jitted, abstract = jit_prefill(cfg, shape, mesh, strategy)
            return jitted.lower(*abstract), "prefill"
        jitted, abstract = jit_decode_step(cfg, shape, mesh, strategy)
        return jitted.lower(*abstract), "serve_step"

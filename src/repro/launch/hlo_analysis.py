"""Roofline-term extraction from compiled XLA artifacts.

``compiled.cost_analysis()`` counts each while-loop *body once*, which makes
it useless for scan-over-layers models (a 94-layer scan would be
undercounted 94x).  So this module implements a static analyzer over the
optimized HLO text that:

  1. parses every computation and the shapes of its instructions,
  2. walks the call graph (fusion/call/while/conditional) from ENTRY,
     multiplying while bodies by their ``known_trip_count``,
  3. accumulates
       * matmul FLOPs (2*M*N*K from dot shapes + contracting dims),
       * HBM traffic at fusion granularity (inputs + outputs of top-level
         fusions/dots/copies — the same model XLA's own cost analysis uses),
       * collective wire bytes with ring-model multipliers:
           all-gather          out_bytes * (g-1)/g
           reduce-scatter      out_bytes * g * (g-1)/g  (input is g*out)
           all-reduce          2 * bytes * (g-1)/g
           all-to-all          bytes * (g-1)/g
           collective-permute  bytes

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (values from the assignment).

All sizes in the optimized SPMD module are *per device*.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# Result types are either a tuple `( ... )` (no nested parens, but may
# contain `/*index=N*/` comments) or a single `dtype[dims]{layout}` token.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+([\w\-]+)\("
)
# Header like: `%wide.region_2.15_spmd.clone (wide_param: (s32[], ...)) -> (...) {`
# Parameter signatures can nest parens/tuples arbitrarily, so only anchor on
# the leading name and the trailing '{'.
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count["\s:{]+n["\s:]+"?(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """(elements, bytes) of a possibly-tuple type string."""
    elems = 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire: float = 0.0
    coll_raw: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_wire += other.coll_wire * mult
        for k, v in other.coll_raw.items():
            self.coll_raw[k] = self.coll_raw.get(k, 0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(text)

    def _parse(self, text: str) -> None:
        cur: list[Instr] | None = None
        for line in text.splitlines():
            stripped = line.strip()
            if (
                stripped.endswith("{")
                and " = " not in stripped
                and (stripped.startswith("ENTRY") or stripped.startswith("%"))
            ):
                m = _COMP_RE.match(stripped)
                if m:
                    cur = []
                    self.computations[m.group(1)] = cur
                    if stripped.startswith("ENTRY"):
                        self.entry = m.group(1)
                    continue
            if stripped == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, type_str, op = m.groups()
                cur.append(Instr(name=name, type_str=type_str, op=op, line=line))

    # -------------------------------------------------------------- analysis
    def analyze(self) -> Totals:
        self._var_types = {
            i.name: i.type_str
            for comp in self.computations.values()
            for i in comp
        }
        self._memo: dict[str, Totals] = {}
        if self.entry is None:
            # fall back: analyze all computations flat (no call graph)
            t = Totals()
            for name in self.computations:
                t.add(self._comp_totals(name, set()))
            return t
        return self._comp_totals(self.entry, set())

    def _callees(self, instr: Instr) -> list[str]:
        names: list[str] = []
        for m in _CALL_ATTR_RE.finditer(instr.line):
            n = m.group(1)
            if n in self.computations:
                names.append(n)
        for m in _BRANCHES_RE.finditer(instr.line):
            for n in m.group(1).split(","):
                n = n.strip().lstrip("%")
                if n in self.computations:
                    names.append(n)
        return names

    def _operand_names(self, instr: Instr) -> list[str]:
        inner = instr.line.split("(", 1)[1]
        return [m.group(1) for m in re.finditer(r"%([\w\.\-]+)", inner.split(")")[0])]

    def _comp_totals(self, name: str, stack: set) -> Totals:
        if name in self._memo:
            return self._memo[name]
        if name in stack:
            return Totals()
        stack = stack | {name}
        total = Totals()
        for instr in self.computations.get(name, []):
            op = instr.op
            _, out_bytes = _shape_elems_bytes(instr.type_str)

            if op == "dot":
                total.flops += self._dot_flops(instr)
                total.bytes += out_bytes + self._operand_bytes(instr)
            elif op == "convolution":
                total.bytes += out_bytes + self._operand_bytes(instr)
            elif op in ("dynamic-slice", "gather", "slice"):
                # only the extracted window moves, not the whole operand
                total.bytes += 2 * out_bytes
            elif op in ("dynamic-update-slice", "scatter"):
                # in-place update: the written window moves (operand 1)
                ops_ = self._operand_names(instr)
                upd = (
                    _shape_elems_bytes(self._var_types.get(ops_[1], ""))[1]
                    if len(ops_) > 1 else out_bytes
                )
                total.bytes += 2 * min(upd, out_bytes)
                if op == "scatter":
                    for callee in self._callees(instr):
                        total.flops += self._comp_totals(callee, stack).flops
            elif op in COLLECTIVES or any(
                op == c + "-start" for c in COLLECTIVES
            ):
                base = op.replace("-start", "")
                g = max(self._group_size(instr.line), 2)
                ring = (g - 1) / g
                total.coll_counts[base] = total.coll_counts.get(base, 0) + 1
                total.coll_raw[base] = total.coll_raw.get(base, 0) + out_bytes
                if base == "all-gather":
                    total.coll_wire += out_bytes * ring
                elif base == "reduce-scatter":
                    total.coll_wire += out_bytes * g * ring
                elif base == "all-reduce":
                    total.coll_wire += 2 * out_bytes * ring
                elif base == "all-to-all":
                    total.coll_wire += out_bytes * ring
                else:  # collective-permute
                    total.coll_wire += out_bytes
            elif op == "fusion":
                # kLoop/kOutput fusions stream elementwise; an operand larger
                # than the output is being *sliced* (scan xs, embedding rows),
                # so cap its contribution at the output size.  kInput fusions
                # are reductions: they really read whole operands.
                if "dynamic-update-slice" in instr.name or "dynamic_update_slice" in instr.name:
                    # In-place window writes: the big buffers are aliased.
                    # Resolve the true update sizes from the fusion body's
                    # dynamic-update-slice instructions (multi-output safe).
                    upd = 0
                    for callee in self._callees(instr):
                        for bi in self.computations.get(callee, []):
                            if bi.op == "dynamic-update-slice":
                                ons = self._operand_names(bi)
                                if len(ons) > 1:
                                    upd += _shape_elems_bytes(
                                        self._var_types.get(ons[1], "")
                                    )[1]
                    total.bytes += 2 * upd if upd else 2 * min(
                        out_bytes,
                        sum(
                            _shape_elems_bytes(self._var_types.get(n, ""))[1]
                            for n in self._operand_names(instr)
                        ),
                    )
                elif "kind=kInput" in instr.line:
                    total.bytes += out_bytes + self._operand_bytes(instr)
                else:
                    for n in self._operand_names(instr):
                        ob = _shape_elems_bytes(self._var_types.get(n, ""))[1]
                        total.bytes += min(ob, max(out_bytes, 1))
                    total.bytes += out_bytes
                # dots inside fusions still do MXU work:
                for callee in self._callees(instr):
                    sub = self._comp_totals(callee, stack)
                    total.flops += sub.flops
            elif op == "while":
                callees = self._callees(instr)
                trip = 1
                m = _TRIP_RE.search(instr.line)
                if m:
                    trip = int(m.group(1))
                for callee in callees:
                    total.add(self._comp_totals(callee, stack), mult=trip)
            elif op in ("call", "conditional", "custom-call", "async-start", "map",
                        "reduce", "reduce-window", "sort", "scatter", "select-and-scatter"):
                if op not in ("call", "conditional"):
                    total.bytes += out_bytes + self._operand_bytes(instr)
                for callee in self._callees(instr):
                    total.add(self._comp_totals(callee, stack))
            elif op in _SKIP_BYTES_OPS:
                pass
            else:
                # unfused top-level elementwise / copy / dynamic-slice etc.
                total.bytes += out_bytes + self._operand_bytes(instr)
        self._memo[name] = total
        return total

    def _operand_bytes(self, instr: Instr) -> int:
        total = 0
        for n in self._operand_names(instr):
            t = self._var_types.get(n)
            if t:
                total += _shape_elems_bytes(t)[1]
        return total

    def _dot_flops(self, instr: Instr) -> float:
        out_elems, _ = _shape_elems_bytes(instr.type_str)
        m = _CONTRACT_RE.search(instr.line)
        k = 1
        ops_ = self._operand_names(instr)
        if m and ops_:
            lhs_t = self._var_types.get(ops_[0], "")
            sm = _SHAPE_RE.search(lhs_t)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
        return 2.0 * out_elems * k

    def _group_size(self, line: str) -> int:
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            return int(m.group(2))
        m = _GROUPS_RE.search(line)
        if m:
            return len(m.group(1).split(","))
        return 2


def roofline_terms(cost: dict, hlo_text: str, n_chips: int, **_) -> dict:
    """The three roofline terms in seconds (per step, per chip).

    The optimized HLO is the per-device program, so analyzer totals are
    already per chip.  ``cost_analysis`` values are reported alongside for
    reference (with the loop-body-once caveat).
    """
    mod = HloModule(hlo_text)
    t = mod.analyze()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per computation
        cost = cost[0] if cost else None
    return {
        "compute_s": t.flops / PEAK_FLOPS,
        "memory_s": t.bytes / HBM_BW,
        "collective_s": t.coll_wire / ICI_BW,
        "hlo_flops": t.flops * n_chips,          # global, loop-corrected
        "hlo_flops_per_chip": t.flops,
        "hlo_bytes_per_chip": t.bytes,
        "collective_wire_bytes": t.coll_wire,
        "collective_counts": {k: round(v, 1) for k, v in t.coll_counts.items()},
        "collective_raw_bytes": {k: float(v) for k, v in t.coll_raw.items()},
        "xla_cost_flops_bodyonce": float(cost.get("flops", 0.0)) if cost else None,
        "xla_cost_bytes_bodyonce": float(cost.get("bytes accessed", 0.0)) if cost else None,
    }


def dominant_term(terms: dict) -> str:
    trio = {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")}
    return max(trio, key=trio.get)

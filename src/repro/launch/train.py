"""Fault-tolerant training driver.

Composes every substrate: config registry -> model -> sharding rules ->
AdamW -> synthetic data pipeline -> watchdog -> checkpoint/restore loop.
Runs on whatever devices exist (1 CPU here; the production mesh on TPU).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

The same entry point is exercised end-to-end (including crash/restore) by
examples/train_pipeline.py and tests/test_system.py.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import checkpointing as ckpt
from ..configs import get_config
from ..data.pipeline import PipelineConfig, TokenPipeline
from ..models import model as M
from ..optim.optimizer import AdamW
from ..runtime.fault_tolerance import FailureInjector, Watchdog, run_resumable
from ..sharding import partition as SP
from .mesh import make_host_mesh


def make_trainer(cfg, opt, mesh=None, strategy=None):
    constrain = (
        SP.make_constrain(strategy, mesh) if (mesh and strategy) else (lambda a, k: a)
    )

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(M.loss_fn, has_aux=True)(
            params, cfg, batch, constrain
        )
        params, opt_state, om = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train(
    arch: str = "llama3.2-1b",
    smoke: bool = True,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-3,
    ckpt_dir: str | None = None,
    ckpt_every: int = 25,
    fail_at: tuple[int, ...] = (),
    log_every: int = 10,
    seed: int = 0,
    verbose: bool = True,
) -> dict:
    """Returns {'final_loss', 'losses', 'restarts', 'steps_run'}."""
    cfg = get_config(arch, smoke=smoke)
    opt = AdamW(lr=lr, warmup_steps=max(steps // 20, 2), total_steps=steps)
    pipe_cfg = PipelineConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=seed,
        embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else None,
    )
    train_step = make_trainer(cfg, opt)
    injector = FailureInjector(fail_at=fail_at)
    watchdog = Watchdog()
    losses: list[float] = []
    stats = {"restarts": 0, "steps_run": 0}

    def make_state():
        params = M.init_params(cfg, jax.random.key(seed))
        pipe = TokenPipeline(pipe_cfg)
        return {"params": params, "opt": opt.init(params), "pipe": pipe}

    def restore_state():
        if ckpt_dir is None or ckpt.latest_step(ckpt_dir) is None:
            return None
        stats["restarts"] += 1 if stats["steps_run"] else 0
        template = make_state()
        tree = {"params": template["params"], "opt": template["opt"]}
        restored, meta = ckpt.restore(ckpt_dir, tree)
        pipe = TokenPipeline(pipe_cfg)
        pipe.restore(meta["pipe"])
        return (
            {"params": restored["params"], "opt": restored["opt"], "pipe": pipe},
            meta["step"],
        )

    def train_one(state, step):
        injector.maybe_fail(step)
        batch_np = state["pipe"].batch()
        batch_dev = {
            "inputs": jnp.asarray(batch_np["inputs"]),
            "labels": jnp.asarray(batch_np["labels"]),
        }
        state["params"], state["opt"], metrics = train_step(
            state["params"], state["opt"], batch_dev
        )
        loss = float(metrics["loss"])
        losses.append(loss)
        stats["steps_run"] += 1
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(
                f"step {step:5d}  loss {loss:.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"lr {float(metrics['lr']):.2e}",
                flush=True,
            )
        return state

    def save_state(state, step):
        if ckpt_dir is None:
            return
        ckpt.save(
            ckpt_dir, step,
            {"params": state["params"], "opt": state["opt"]},
            meta={"step": step, "pipe": state["pipe"].state()},
        )

    run_resumable(
        total_steps=steps, make_state=make_state, restore_state=restore_state,
        train_one=train_one, save_state=save_state, ckpt_every=ckpt_every,
        watchdog=watchdog,
    )
    return {
        "final_loss": losses[-1] if losses else float("nan"),
        "losses": losses,
        "restarts": stats["restarts"],
        "steps_run": stats["steps_run"],
        "stragglers": watchdog.stragglers,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=False)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()
    out = train(
        arch=args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, fail_at=tuple(args.fail_at),
    )
    print(
        f"done: final_loss={out['final_loss']:.4f} "
        f"restarts={out['restarts']} steps_run={out['steps_run']}"
    )


if __name__ == "__main__":
    main()

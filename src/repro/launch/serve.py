"""Batched serving driver: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import model as M


def serve(arch: str = "llama3.2-1b", smoke: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, seed: int = 0,
          verbose: bool = True) -> dict:
    cfg = get_config(arch, smoke=smoke)
    if not cfg.causal:
        raise ValueError(f"{arch} is encoder-only; no decode step")
    params = M.init_params(cfg, jax.random.key(seed))
    prompts = jax.random.randint(
        jax.random.key(seed + 1), (batch, prompt_len), 2, cfg.vocab
    )
    max_seq = prompt_len + gen

    t0 = time.perf_counter()
    states, logits = M.prefill(params, cfg, prompts, max_seq=max_seq)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(lambda st, tok, pos: M.decode_step(params, cfg, st, tok, pos))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [np.asarray(tok)]
    # compile once, then time steady-state decode
    states, logits = decode(states, tok, jnp.int32(prompt_len))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out.append(np.asarray(tok))
    t0 = time.perf_counter()
    for t in range(1, gen - 1):
        states, logits = decode(states, tok, jnp.int32(prompt_len + t))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    tokens = np.stack(out, axis=1)  # (batch, gen)
    tps = batch * (gen - 2) / max(t_decode, 1e-9)
    if verbose:
        print(f"prefill({batch}x{prompt_len}): {t_prefill*1e3:.1f} ms")
        print(f"decode steady-state: {tps:.1f} tok/s ({t_decode/(gen-2)*1e3:.1f} ms/step)")
        print(f"first generated tokens: {tokens[:, :8].tolist()}")
    return {"tokens": tokens, "prefill_s": t_prefill, "tok_per_s": tps}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve(arch=args.arch, smoke=args.smoke, batch=args.batch,
          prompt_len=args.prompt_len, gen=args.gen)


if __name__ == "__main__":
    main()

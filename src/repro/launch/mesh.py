"""Production mesh construction.

Axis semantics (DESIGN.md §2): 'data' and 'model' are intra-pod ICI axes;
'pod' is the inter-pod DCI tier (the paper's TCP-bridge layer).  Defined as
functions so importing this module never touches jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from ..core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_grid_mesh(rows: int, cols: int) -> Mesh:
    """Device grid for the manycore simulation (granule tiling)."""
    return make_mesh((rows, cols), ("gr", "gc"))


def make_host_mesh() -> Mesh:
    """Whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))

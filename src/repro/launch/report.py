"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")
SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(mesh: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if mesh is None or r.get("mesh") == mesh:
            recs.append(r)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9), r["mesh"]))
    return recs


def _fmt_s(x) -> str:
    if x is None:
        return "-"
    x = float(x)
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}us"
    if x < 0.1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def roofline_table(mesh: str = "single") -> str:
    rows = [
        "| arch | shape | step | compute | memory | collective | dominant | "
        "MODEL_FLOPs | HLO/MODEL | peak-frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if r["arch"] == "manycore":
            continue
        if r["status"] == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | SKIP | — | — | "
                f"{r['reason'][:48]} |"
            )
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | {r['error'][:60]} |")
            continue
        total = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / total if total else 0.0
        ratio = (
            f"{r['hlo_flops']/r['model_flops']:.2f}" if r.get("model_flops") else "-"
        )
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['step_kind']} | "
            f"{_fmt_s(r['compute_s'])} | {_fmt_s(r['memory_s'])} | "
            f"{_fmt_s(r['collective_s'])} | {r['dominant'][:-2]} | "
            f"{r.get('model_flops', 0):.2e} | {ratio} | {frac*100:.1f}% |"
        )
    return "\n".join(rows)


def dryrun_table() -> str:
    rows = [
        "| arch | shape | mesh | status | chips | args/dev | compile | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load():
        if r["status"] == "ok":
            gb = r.get("memory_analysis", {}).get("argument_size_in_bytes", 0) / 1e9
            coll = ", ".join(
                f"{k}:{int(v)}" for k, v in sorted(r.get("collective_counts", {}).items())
            )
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
                f"{r.get('n_chips','-')} | {gb:.2f} GB | {r.get('compile_s','-')}s | {coll} |"
            )
        elif r["status"] == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | - | - | - | "
                f"{r['reason'][:52]} |"
            )
        else:
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** | - | - | - | "
                f"{r['error'][:52]} |"
            )
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--table", default="both", choices=["roofline", "dryrun", "both"])
    args = ap.parse_args()
    if args.table in ("dryrun", "both"):
        print("## Dry-run matrix\n")
        print(dryrun_table())
        print()
    if args.table in ("roofline", "both"):
        print(f"## Roofline ({args.mesh}-pod)\n")
        print(roofline_table(args.mesh))


if __name__ == "__main__":
    main()

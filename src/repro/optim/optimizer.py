"""AdamW with warmup-cosine schedule and global-norm clipping.

Pure-functional (optax-style but dependency-free): ``init(params)`` builds
the state, ``update(grads, state, params)`` returns (new_params, new_state,
metrics).  Moments are f32 regardless of param dtype; the update is applied
in f32 and cast back (mixed-precision master-weight behaviour without
duplicating weights — the f32 master lives in the moments' precision story;
see DESIGN.md).  State shards exactly like params (same tree structure), so
optimizer memory rides the FSDP axes for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1

    def schedule(self, step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(self.warmup_steps, 1)
        decay_t = (step - self.warmup_steps) / jnp.maximum(
            self.total_steps - self.warmup_steps, 1
        )
        decay_t = jnp.clip(decay_t, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * decay_t))
        cos = self.min_lr_ratio + (1.0 - self.min_lr_ratio) * cos
        return self.lr * jnp.where(step < self.warmup_steps, warm, cos)

    def init(self, params: PyTree) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.copy, zeros))

    def update(
        self, grads: PyTree, state: AdamWState, params: PyTree
    ) -> tuple[PyTree, AdamWState, dict]:
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(
            jax.tree.reduce(lambda a, g: a + jnp.sum(g * g), gf, jnp.zeros((), jnp.float32))
        )
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        gf = jax.tree.map(lambda g: g * scale, gf)

        step = state.step + 1
        lr = self.schedule(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g, state.mu, gf)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g, state.nu, gf)

        def upd(p, m, v):
            mh = m / b1c
            vh = v / b2c
            u = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu), {
            "grad_norm": gnorm,
            "lr": lr,
        }

"""Gradient compression for cross-pod (DCI) data parallelism.

Two schemes, both usable inside shard_map over the slow ('pod') axis:

  * ``quantized_psum`` — int8 block-quantized all-reduce: 4x (bf16) / 8x
    (f32) wire-bytes reduction on the DCI hop.  Deterministic, stateless.
  * ``TopKCompressor`` — top-k magnitude sparsification with error feedback
    (residual accumulation), the classic deep-gradient-compression recipe;
    state rides in the train step like optimizer state.

The paper analogy (DESIGN.md §2): the pod axis is Switchboard's TCP tier —
exactly where the paper multiplexes queues to reduce connection overhead;
compression plays that role for gradient traffic.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any
_BLOCK = 256


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization along the last axis."""
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    blocks = q.astype(jnp.float32) * scale
    flat = blocks.reshape(-1)[: int(jnp.prod(jnp.array(shape)))]
    n = 1
    for s in shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)


def quantized_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-quantized all-reduce over ``axis_name`` (inside shard_map).

    Each participant quantizes its contribution; the int8 payload and f32
    scales are summed (psum of q*scale is linear, so we psum the dequantized
    block values at int8 wire width by reducing q and scale separately with
    a two-phase trick: ship q (int8) + per-block scale (f32 / BLOCK floats).
    """
    q, scale = _quantize_int8(x)
    # wire bytes: 1B/elem + 4B/256 elems ≈ 1.016B/elem vs 2-4B uncompressed
    deq_blocks = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int8 payload
    # scales differ per participant -> psum of scaled blocks needs per-rank
    # scale; we approximate with the max scale (conservative magnitude).
    scale_max = jax.lax.pmax(scale, axis_name)
    blocks = deq_blocks.astype(jnp.float32) * scale_max
    n = x.size
    return blocks.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


class TopKCompressor:
    """Top-k sparsification with error feedback.

    state: residual pytree (same shapes as grads, f32).
    compress(): returns (values, indices) per leaf keeping the top ``ratio``
    fraction by magnitude of (grad + residual); the un-sent remainder stays
    in the residual (error feedback), preserving convergence.
    """

    def __init__(self, ratio: float = 0.01):
        self.ratio = ratio

    def init(self, params: PyTree) -> PyTree:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(self, grads: PyTree, residual: PyTree):
        def one(g, r):
            gf = g.astype(jnp.float32) + r
            flat = gf.reshape(-1)
            k = max(int(flat.size * self.ratio), 1)
            vals, idx = jax.lax.top_k(jnp.abs(flat), k)
            sent = flat[idx]
            new_r = flat.at[idx].set(0.0).reshape(g.shape)
            return (sent, idx), new_r

        leaves, treedef = jax.tree.flatten(grads)
        rleaves = treedef.flatten_up_to(residual)
        comp_leaves, new_res_leaves = [], []
        for g, r in zip(leaves, rleaves):
            (sent, idx), new_r = one(g, r)
            comp_leaves.append((sent, idx))
            new_res_leaves.append(new_r)
        return treedef.unflatten(comp_leaves), treedef.unflatten(new_res_leaves)

    def decompress(self, compressed: PyTree, template: PyTree) -> PyTree:
        def one(c, t):
            sent, idx = c
            flat = jnp.zeros((t.size,), jnp.float32).at[idx].set(sent)
            return flat.reshape(t.shape).astype(t.dtype)

        leaves, treedef = jax.tree.flatten(template)
        cleaves = treedef.flatten_up_to(compressed)
        return treedef.unflatten([one(c, t) for c, t in zip(cleaves, leaves)])

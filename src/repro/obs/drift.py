"""Perfmodel drift detection: measured phase times vs predictions.

``benchmarks/timing_breakdown.py`` historically *inferred* procs phase
costs by differencing compiled variants; the telemetry ring now measures
them directly.  This module closes the loop: fold the measured per-phase
means back into ``core/perfmodel``'s epoch-time predictions and surface
the relative error as the ``perfmodel.model_drift`` gauge — a large
drift means the analytic model (used to pick worker counts and overlap
mode) no longer describes the machine the fleet is actually running on.
"""
from __future__ import annotations

from ..core import perfmodel

#: phases folded into the communication term of the perfmodel.
COMM_PHASES = ("exchange_issue", "exchange_commit")
#: phases folded into the residual (per-epoch fixed work).
RESIDUAL_PHASES = ("ingest", "flush")


def _mean(snapshot: dict, name: str) -> float:
    m = snapshot.get(name)
    if isinstance(m, dict):
        return float(m.get("mean", 0.0))
    return 0.0


def phase_means(snapshot: dict, prefix: str = "procs") -> dict:
    """Per-epoch mean seconds per phase from a registry snapshot.

    ``exchange_issue``/``exchange_commit`` histograms record one sample
    per (tier, epoch), so their per-epoch cost is ``mean * samples /
    epoch_samples``; ``step``/``ingest``/``flush``/``epoch`` record one
    sample per epoch.
    """
    out: dict = {}
    epoch_h = snapshot.get(f"{prefix}.phase.epoch.s")
    n_epochs = int(epoch_h.get("count", 0)) if isinstance(epoch_h, dict) \
        else 0
    for phase in ("step", "ingest", "flush", "epoch"):
        out[phase] = _mean(snapshot, f"{prefix}.phase.{phase}.s")
    for phase in COMM_PHASES:
        h = snapshot.get(f"{prefix}.phase.{phase}.s")
        if isinstance(h, dict) and n_epochs > 0:
            out[phase] = float(h.get("sum", 0.0)) / n_epochs
        else:
            out[phase] = _mean(snapshot, f"{prefix}.phase.{phase}.s")
    return out


def compute_drift(snapshot: dict, *, overlap: bool = False,
                  prefix: str = "procs", registry=None) -> dict:
    """Compare measured epoch time against the perfmodel prediction.

    Returns ``{t_step, t_comm, t_residual, predicted_s, measured_s,
    model_drift}`` (empty dict when the snapshot holds no epoch
    samples).  When ``registry`` is given, also publishes
    ``perfmodel.model_drift`` / ``perfmodel.predicted_epoch.s`` /
    ``perfmodel.measured_epoch.s`` gauges.
    """
    means = phase_means(snapshot, prefix)
    measured = means.get("epoch", 0.0)
    if measured <= 0.0:
        return {}
    t_step = means.get("step", 0.0)
    t_comm = sum(means.get(p, 0.0) for p in COMM_PHASES)
    t_residual = sum(means.get(p, 0.0) for p in RESIDUAL_PHASES)
    if overlap:
        predicted = perfmodel.overlapped_epoch_time(t_step, t_comm,
                                                    t_residual)
    else:
        predicted = perfmodel.serial_epoch_time(t_step, t_comm, t_residual)
    drift = abs(measured - predicted) / measured
    out = {
        "t_step": t_step,
        "t_comm": t_comm,
        "t_residual": t_residual,
        "predicted_s": predicted,
        "measured_s": measured,
        "model_drift": drift,
    }
    if registry is not None:
        registry.set("perfmodel.model_drift", drift)
        registry.set("perfmodel.predicted_epoch.s", predicted)
        registry.set("perfmodel.measured_epoch.s", measured)
    return out


__all__ = ["COMM_PHASES", "RESIDUAL_PHASES", "compute_drift", "phase_means"]

"""Process-global metrics registry (DESIGN.md §Observability).

Counters, gauges and histograms under stable dotted names
(``layer.component.metric``, e.g. ``procs.phase.step.s`` or
``bridge.0.bytes_tx``).  Every layer publishes into ONE process-global
``REGISTRY``; ``Simulation.stats()["metrics"]`` is a snapshot view of it.

Cost model: publishing is a dict lookup plus a float add.  With the
registry *disabled* every ``inc``/``set``/``observe`` is a single
attribute check and an immediate return — the ≤1.02x tracing-off budget
of ISSUE 10 (gated by ``benchmarks/obs_overhead.py``).
"""
from __future__ import annotations

import re

_NAME_RE = re.compile(r"^[a-z0-9_]+(\.[a-z0-9_-]+)+$")


class Counter:
    """Monotonic counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins sample."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded summary (count/sum/min/max) — no per-sample storage, so a
    free-running worker can observe millions of times without growth."""

    __slots__ = ("count", "sum", "min", "max")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def summary(self) -> dict:
        mean = (self.sum / self.count) if self.count else 0.0
        return {
            "count": int(self.count),
            "sum": float(self.sum),
            "mean": float(mean),
            "min": float(self.min) if self.count else 0.0,
            "max": float(self.max) if self.count else 0.0,
        }


class MetricsRegistry:
    """Dotted-name -> metric map.  Creation validates the name once;
    the hot publishing paths never re-validate."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    # ------------------------------------------------------------- creation
    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            if not _NAME_RE.match(name):
                raise ValueError(
                    f"metric name {name!r} is not dotted lowercase "
                    "(layer.component.metric)"
                )
            m = self._metrics[name] = cls()
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    # ------------------------------------------------------ hot-path verbs
    def inc(self, name: str, n: float = 1.0) -> None:
        if not self.enabled:
            return
        self.counter(name).inc(n)

    def set(self, name: str, v: float) -> None:
        if not self.enabled:
            return
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        if not self.enabled:
            return
        self.histogram(name).observe(v)

    # -------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """``{name: number}`` for counters/gauges, ``{name: summary
        dict}`` for histograms — the ``stats()["metrics"]`` view."""
        out: dict = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                out[name] = m.summary()
            else:
                out[name] = float(m.value)
        return out

    def clear(self) -> None:
        self._metrics.clear()


#: The process-global registry every layer publishes into.
REGISTRY = MetricsRegistry()

__all__ = ["REGISTRY", "MetricsRegistry", "Counter", "Gauge", "Histogram"]

"""Structured trace layer: bounded span/instant buffers + Perfetto export.

Events follow the Chrome trace-event JSON format (loadable in Perfetto /
``chrome://tracing``): ``ph="X"`` complete spans with microsecond
``ts``/``dur``, ``ph="i"`` instants, ``ph="M"`` track-naming metadata.
One track per worker / bridge / launcher: ``pid`` groups a host process,
``tid`` is the member (worker index, ``NW+i`` for bridge ``i``, and
``TID_SESSION`` for the launcher/session track).

Timestamps are ``time.monotonic()`` microseconds — CLOCK_MONOTONIC is
system-wide on Linux, so spans recorded by worker processes (shipped
through the shm telemetry ring) land on the same timeline as the
launcher's own spans.

The recorder is process-global and bounded: past ``max_events`` new
events are dropped and counted (``trace.dropped`` in the export), never
grown — a free-running fleet can trace indefinitely.  When disabled
(default) ``span``/``instant`` return after one flag check.
"""
from __future__ import annotations

import atexit
import contextlib
import json
import os
import time

ENV_TRACE = "REPRO_TRACE"

#: tid of the launcher/session track within a host pid.
TID_SESSION = 1000

_PH_ALLOWED = {"X", "i", "M", "C"}


class TraceRecorder:
    """Bounded in-memory event buffer, Chrome-trace-format export."""

    def __init__(self, max_events: int = 400_000):
        self.enabled = False
        self.max_events = int(max_events)
        self.events: list[dict] = []
        self.dropped = 0
        self._tracks: dict[tuple[int, int], str] = {}
        self._procs: dict[int, str] = {}

    # ------------------------------------------------------------ recording
    def _append(self, ev: dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def set_process(self, pid: int, name: str) -> None:
        self._procs[int(pid)] = str(name)

    def set_track(self, pid: int, tid: int, name: str) -> None:
        self._tracks[(int(pid), int(tid))] = str(name)

    def span(self, name: str, t0: float, dur: float, *, pid: int = 0,
             tid: int = TID_SESSION, cat: str = "sim",
             args: dict | None = None) -> None:
        """One complete span; ``t0`` is monotonic seconds, ``dur`` seconds."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": t0 * 1e6, "dur": max(dur, 0.0) * 1e6,
              "pid": int(pid), "tid": int(tid)}
        if args:
            ev["args"] = args
        self._append(ev)

    def instant(self, name: str, *, pid: int = 0, tid: int = TID_SESSION,
                cat: str = "sim", args: dict | None = None,
                ts: float | None = None) -> None:
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "i", "s": "p",
              "ts": (time.monotonic() if ts is None else ts) * 1e6,
              "pid": int(pid), "tid": int(tid)}
        if args:
            ev["args"] = args
        self._append(ev)

    @contextlib.contextmanager
    def span_ctx(self, name: str, *, pid: int = 0, tid: int = TID_SESSION,
                 cat: str = "sim", args: dict | None = None):
        """Time the body as one span (no-op when disabled)."""
        if not self.enabled:
            yield
            return
        t0 = time.monotonic()
        try:
            yield
        finally:
            self.span(name, t0, time.monotonic() - t0, pid=pid, tid=tid,
                      cat=cat, args=args)

    # -------------------------------------------------------------- export
    def to_dict(self) -> dict:
        meta: list[dict] = []
        for pid, name in sorted(self._procs.items()):
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0, "args": {"name": name}})
        for (pid, tid), name in sorted(self._tracks.items()):
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": tid, "args": {"name": name}})
        return {
            "traceEvents": meta + self.events,
            "displayTimeUnit": "ms",
            "otherData": {"recorder": "repro.obs", "dropped": self.dropped},
        }

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
            f.write("\n")
        return path

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0
        self._tracks.clear()
        self._procs.clear()


_RECORDER = TraceRecorder()
_env_armed = False


def recorder() -> TraceRecorder:
    return _RECORDER


def enabled() -> bool:
    return _RECORDER.enabled


def span(name: str, t0: float, dur: float, **kw) -> None:
    _RECORDER.span(name, t0, dur, **kw)


def instant(name: str, **kw) -> None:
    _RECORDER.instant(name, **kw)


def _flush_engines() -> None:
    """Pull any undrained worker telemetry into the recorder before an
    export (live procs engines hold it in their shm rings)."""
    try:
        from ..runtime.launcher import _live_engines
    except Exception:  # pragma: no cover - runtime not imported
        return
    for eng in list(_live_engines):
        try:
            flush = getattr(eng, "flush_telemetry", None)
            if flush is not None:
                flush()
        except Exception:  # pragma: no cover - stats stay best-effort
            pass


def _atexit_export() -> None:  # pragma: no cover - interpreter exit
    path = os.environ.get(ENV_TRACE)
    if path and _RECORDER.enabled and (_RECORDER.events or _RECORDER._tracks):
        _flush_engines()
        _RECORDER.export(path)


def maybe_enable_from_env() -> bool:
    """Arm the recorder from ``REPRO_TRACE=<path>`` (idempotent): enable
    now, export to the named path at interpreter exit.  Returns whether
    tracing is enabled after the call."""
    global _env_armed
    path = os.environ.get(ENV_TRACE)
    if path and not _env_armed:
        _env_armed = True
        _RECORDER.enabled = True
        atexit.register(_atexit_export)
    return _RECORDER.enabled


__all__ = [
    "ENV_TRACE", "TID_SESSION", "TraceRecorder", "enabled", "instant",
    "maybe_enable_from_env", "recorder", "span",
]

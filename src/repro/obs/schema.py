"""The ONE ``Simulation.stats()`` schema + Perfetto trace validator.

Every engine family returns the same top-level stats layout
(``repro-stats-v1``) so a consumer can switch engines without code
changes (ISSUE 10 satellite):

    schema   "repro-stats-v1"
    engine   "single" | "graph" | "fused" | "register" | "procs"
    cycle    int
    epoch    int
    ports    {"tx": {port: {sent,pending,occupancy,credit}},
              "rx": {port: {received,occupancy,credit}}}
    detail   optional engine-specific extras (e.g. single's
             push_count/pop_count arrays) — the ONLY place engines may
             diverge
    metrics  optional registry snapshot ({dotted-name: number|summary})
    faults   optional fault/recovery stats dict
    bridges  optional list of bridge stat rows
    workers  optional list of per-worker stat rows (procs)

CLI::

    python -m repro.obs.schema trace.json      # validate a trace file
"""
from __future__ import annotations

import json
import numbers
import sys

STATS_SCHEMA = "repro-stats-v1"

_ENGINES = {"single", "graph", "fused", "register", "procs"}
_TOP_REQUIRED = {"schema", "engine", "cycle", "epoch", "ports"}
_TOP_OPTIONAL = {"detail", "metrics", "faults", "bridges", "workers"}
_TX_KEYS = {"sent", "pending", "occupancy", "credit"}
_RX_KEYS = {"received", "occupancy", "credit"}

_BRIDGE_REQUIRED = {"link", "bytes_tx", "bytes_rx", "wait_fraction",
                    "connect_s"}

_PH_ALLOWED = {"X", "i", "M", "C"}


def _fail(msg: str) -> None:
    raise ValueError(f"stats schema: {msg}")


def validate_stats(stats: dict) -> dict:
    """Assert ``stats`` conforms to ``repro-stats-v1``; returns it."""
    if not isinstance(stats, dict):
        _fail(f"expected dict, got {type(stats).__name__}")
    keys = set(stats)
    missing = _TOP_REQUIRED - keys
    if missing:
        _fail(f"missing keys {sorted(missing)}")
    extra = keys - _TOP_REQUIRED - _TOP_OPTIONAL
    if extra:
        _fail(f"unknown top-level keys {sorted(extra)}")
    if stats["schema"] != STATS_SCHEMA:
        _fail(f"schema {stats['schema']!r} != {STATS_SCHEMA!r}")
    if stats["engine"] not in _ENGINES:
        _fail(f"unknown engine {stats['engine']!r}")
    for k in ("cycle", "epoch"):
        if not isinstance(stats[k], numbers.Integral):
            _fail(f"{k} must be an int, got {type(stats[k]).__name__}")
    ports = stats["ports"]
    if not isinstance(ports, dict):
        _fail("ports must be a dict")
    if set(ports) != {"tx", "rx"}:
        _fail(f"ports keys {sorted(ports)} != ['rx', 'tx']")
    for direction, want in (("tx", _TX_KEYS), ("rx", _RX_KEYS)):
        side = ports[direction]
        if not isinstance(side, dict):
            _fail(f"ports[{direction!r}] must be a dict of port rows")
        for port, rec in side.items():
            if set(rec) != want:
                _fail(f"ports[{direction!r}][{port!r}] keys "
                      f"{sorted(rec)} != {sorted(want)}")
    if "metrics" in stats and not isinstance(stats["metrics"], dict):
        _fail("metrics must be a dict snapshot")
    if "bridges" in stats:
        rows = stats["bridges"]
        if not isinstance(rows, list):
            _fail("bridges must be a list of rows")
        for row in rows:
            missing = _BRIDGE_REQUIRED - set(row)
            if missing:
                _fail(f"bridge row missing {sorted(missing)}")
    if "workers" in stats and not isinstance(stats["workers"], list):
        _fail("workers must be a list of rows")
    return stats


def _tfail(msg: str) -> None:
    raise ValueError(f"trace format: {msg}")


def validate_trace(doc: dict) -> dict:
    """Assert ``doc`` is a Perfetto/Chrome-loadable trace document
    (the JSON object format with a ``traceEvents`` array)."""
    if not isinstance(doc, dict):
        _tfail(f"expected JSON object, got {type(doc).__name__}")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        _tfail("missing traceEvents array")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            _tfail(f"event {i} is not an object")
        ph = ev.get("ph")
        if ph not in _PH_ALLOWED:
            _tfail(f"event {i} has ph {ph!r} (allowed {sorted(_PH_ALLOWED)})")
        if not isinstance(ev.get("name"), str):
            _tfail(f"event {i} missing string name")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), numbers.Integral):
                _tfail(f"event {i} missing integer {k}")
        if ph == "M":
            args = ev.get("args")
            if not (isinstance(args, dict) and isinstance(args.get("name"),
                                                         str)):
                _tfail(f"metadata event {i} missing args.name")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, numbers.Real) or ts < 0:
            _tfail(f"event {i} has bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, numbers.Real) or dur < 0:
                _tfail(f"span event {i} has bad dur {dur!r}")
    return doc


def validate_trace_file(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    return validate_trace(doc)


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        print("usage: python -m repro.obs.schema TRACE.json [...]",
              file=sys.stderr)
        return 2
    for path in argv:
        doc = validate_trace_file(path)
        events = doc["traceEvents"]
        spans = sum(1 for e in events if e.get("ph") == "X")
        instants = sum(1 for e in events if e.get("ph") == "i")
        tracks = {(e.get("pid"), e.get("tid")) for e in events
                  if e.get("ph") != "M"}
        print(f"{path}: ok — {len(events)} events "
              f"({spans} spans, {instants} instants, {len(tracks)} tracks)")
    return 0


if __name__ == "__main__":  # pragma: no cover - CLI
    raise SystemExit(main())


__all__ = ["STATS_SCHEMA", "main", "validate_stats", "validate_trace",
           "validate_trace_file"]

"""Flight recorder (ISSUE 10; DESIGN.md §Observability).

One observability spine shared by all five engines:

  * ``registry`` — process-global metrics registry (counters / gauges /
    histograms under stable dotted names; near-zero-cost when disabled);
  * ``trace`` — bounded structured trace buffers (span / instant events)
    exported as Chrome/Perfetto ``trace.json``, driven by
    ``Simulation.trace(path)`` or the ``REPRO_TRACE`` env knob;
  * ``telemetry`` — the per-worker shm telemetry ring: fixed-size phase
    records the procs workers publish and the launcher drains (same SPSC
    machinery as ``runtime/shmem.py``; the credit rings stay untouched);
  * ``schema`` — the ONE validated ``Simulation.stats()`` schema every
    engine shares, plus the Perfetto trace-format validator (CLI:
    ``python -m repro.obs.schema trace.json``);
  * ``drift`` — feeds measured phase times back into ``core/perfmodel``
    and surfaces the ``perfmodel.model_drift`` metric;
  * ``report`` — ``python -m repro.obs.report trace.json``: top stalls,
    straggler ranking, per-phase breakdown from a trace file.
"""
from . import drift, registry, schema, telemetry, trace  # noqa: F401
from .registry import REGISTRY, MetricsRegistry  # noqa: F401
from .trace import TraceRecorder  # noqa: F401

__all__ = [
    "REGISTRY", "MetricsRegistry", "TraceRecorder",
    "drift", "registry", "schema", "telemetry", "trace",
]

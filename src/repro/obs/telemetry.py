"""Per-worker shm telemetry ring: fixed-size phase records.

Each procs worker gets ONE extra SPSC ring (``{prefix}t{w}``, same
``runtime/shmem.py`` machinery as the data/credit rings — those stay
untouched).  The worker is the producer: every traced phase emits one
48-byte record, non-blocking — when the launcher falls behind and the
ring fills, records are *dropped and counted*, never awaited, so
telemetry can never deadlock or slow the simulation beyond the push.
The launcher is the consumer: it drains at command boundaries and from
the monitor thread while the fleet free-runs.

Record layout (6 little-endian f64, ``TELEM_RECORD_BYTES`` = 48)::

    [code, arg, ts, dur, v0, v1]

``ts`` is ``time.monotonic()`` seconds at phase start (CLOCK_MONOTONIC
is system-wide on Linux, so worker records align with launcher spans),
``dur`` is the phase wall time in seconds.  ``arg`` and ``v0``/``v1``
are per-code (see the ``TEV_*`` table below).
"""
from __future__ import annotations

import struct
import time

import numpy as np

TELEM_RECORD_F64 = 6
TELEM_RECORD_BYTES = TELEM_RECORD_F64 * 8
#: default ring capacity in records (the SPSC ring holds capacity-1).
TELEM_RING_RECORDS = 4096

_PACK = struct.Struct("<6d")

# Event codes.  arg / v0 / v1 meanings:
TEV_INGEST = 1.0   # ext-port ingest; arg unused
TEV_STEP = 2.0     # compiled step; arg = cycles advanced
TEV_ISSUE = 3.0    # exchange issue (credit wait + pack + push); arg = tier
TEV_COMMIT = 4.0   # exchange commit (slab wait + unpack); arg = tier
TEV_FLUSH = 5.0    # ext-port flush; arg unused
TEV_EPOCH = 6.0    # whole epoch; arg = epoch index, v0 = wait_s delta
TEV_OCC = 7.0      # occupancy sample; v0 = data-ring size sum, v1 = chans

_NAMES = {
    TEV_INGEST: "ingest",
    TEV_STEP: "step",
    TEV_ISSUE: "exchange_issue",
    TEV_COMMIT: "exchange_commit",
    TEV_FLUSH: "flush",
    TEV_EPOCH: "epoch",
    TEV_OCC: "occupancy",
}

#: codes rendered as spans (the rest become counters/instants).
_SPAN_CODES = (TEV_INGEST, TEV_STEP, TEV_ISSUE, TEV_COMMIT, TEV_FLUSH,
               TEV_EPOCH)


def telemetry_ring_name(prefix: str, worker: int) -> str:
    """Ring name for worker ``worker`` under launcher prefix ``prefix``
    (sits beside ``{prefix}d{c}`` / ``{prefix}c{c}`` / ``{prefix}hb``)."""
    return f"{prefix}t{worker}"


def code_name(code: float) -> str:
    return _NAMES.get(float(code), f"tev_{int(code)}")


class TelemetryWriter:
    """Producer side: non-blocking emit into the worker's shm ring."""

    __slots__ = ("ring", "enabled", "dropped", "emitted")

    def __init__(self, ring):
        self.ring = ring
        self.enabled = False
        self.dropped = 0
        self.emitted = 0

    def emit(self, code: float, arg: float, ts: float, dur: float,
             v0: float = 0.0, v1: float = 0.0) -> None:
        if not self.ring.push_record(_PACK.pack(code, arg, ts, dur, v0, v1)):
            self.dropped += 1
        else:
            self.emitted += 1

    def phase(self, code: float, arg: float, t0: float,
              v0: float = 0.0, v1: float = 0.0) -> None:
        """Emit a span record for a phase that started at ``t0``."""
        self.emit(code, arg, t0, time.monotonic() - t0, v0, v1)


def drain(ring, max_records: int = 1 << 20) -> np.ndarray:
    """Consumer side: pop every pending record, return an ``(n, 6)``
    float64 array (columns ``code, arg, ts, dur, v0, v1``)."""
    rows = []
    for _ in range(max_records):
        rec = ring.pop_record()
        if rec is None:
            break
        rows.append(_PACK.unpack(rec))
    if not rows:
        return np.empty((0, TELEM_RECORD_F64), dtype=np.float64)
    return np.asarray(rows, dtype=np.float64)


def records_to_events(records: np.ndarray, *, worker: int, pid: int = 0,
                      recorder=None, registry=None,
                      prefix: str = "procs") -> int:
    """Fold drained records into the trace recorder (one span per phase
    record, track ``tid=worker``) and the metrics registry (per-phase
    histograms ``{prefix}.phase.<name>.s`` plus per-worker wait/epoch
    tallies).  Returns the number of records consumed."""
    n = int(records.shape[0])
    if n == 0:
        return 0
    rec_spans = recorder is not None and recorder.enabled
    for i in range(n):
        code, arg, ts, dur, v0, v1 = records[i]
        name = code_name(code)
        if registry is not None and registry.enabled:
            if code == TEV_OCC:
                registry.observe(f"{prefix}.ring.occupancy", v0)
            else:
                registry.observe(f"{prefix}.phase.{name}.s", dur)
                if code == TEV_EPOCH:
                    registry.observe(f"{prefix}.worker.{worker}.epoch.s", dur)
                    registry.observe(f"{prefix}.worker.{worker}.wait.s", v0)
        if rec_spans and code in _SPAN_CODES:
            args = None
            if code in (TEV_ISSUE, TEV_COMMIT):
                args = {"tier": int(arg)}
            elif code == TEV_STEP:
                args = {"cycles": int(arg)}
            elif code == TEV_EPOCH:
                args = {"epoch": int(arg), "wait_s": float(v0)}
            recorder.span(name, float(ts), float(dur), pid=pid, tid=worker,
                          cat="worker", args=args)
    return n


__all__ = [
    "TELEM_RECORD_BYTES", "TELEM_RECORD_F64", "TELEM_RING_RECORDS",
    "TEV_COMMIT", "TEV_EPOCH", "TEV_FLUSH", "TEV_INGEST", "TEV_ISSUE",
    "TEV_OCC", "TEV_STEP", "TelemetryWriter", "code_name", "drain",
    "records_to_events", "telemetry_ring_name",
]

"""Flight-recorder text report: ``python -m repro.obs.report trace.json``.

Renders, from an exported Perfetto trace file:

  * **phase breakdown** — total span seconds per event name, across all
    tracks (where does the wall time go?);
  * **straggler ranking** — per-worker busy seconds, slowest first
    (which worker gates the barrier-less fleet?);
  * **top stalls** — the longest individual wait-like spans (credit
    waits, slab waits, pump waits), with track and timestamp so the
    window can be inspected in the Perfetto UI.
"""
from __future__ import annotations

import argparse
import collections
import json

from . import schema

#: span names treated as stalls for the top-stalls table.
STALL_NAMES = {"exchange_issue", "exchange_commit", "host_wait", "pump_wait",
               "barrier_wait"}


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    return schema.validate_trace(doc)


def _track_names(events: list) -> dict:
    names: dict = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    return names


def _track_label(names: dict, pid: int, tid: int) -> str:
    return names.get((pid, tid), f"pid{pid}/tid{tid}")


def summarize(doc: dict, *, top: int = 10) -> str:
    events = doc["traceEvents"]
    names = _track_names(events)
    spans = [e for e in events if e.get("ph") == "X"]
    instants = [e for e in events if e.get("ph") == "i"]

    by_phase: dict = collections.defaultdict(lambda: [0, 0.0])
    busy: dict = collections.defaultdict(float)
    stalls = []
    for ev in spans:
        dur_s = ev["dur"] / 1e6
        rec = by_phase[ev["name"]]
        rec[0] += 1
        rec[1] += dur_s
        key = (ev["pid"], ev["tid"])
        if ev["name"] != "epoch":  # epoch spans contain the phase spans
            busy[key] += dur_s
        wait = (ev.get("args") or {}).get("wait_s")
        if ev["name"] in STALL_NAMES or wait is not None:
            stalls.append((wait if wait is not None else dur_s, ev))

    lines = [f"trace: {len(spans)} spans, {len(instants)} instants, "
             f"{len(names) or len(busy)} tracks"]

    lines.append("")
    lines.append("phase breakdown (total seconds per event name):")
    total = sum(rec[1] for rec in by_phase.values()) or 1.0
    for name, (count, secs) in sorted(by_phase.items(),
                                      key=lambda kv: -kv[1][1]):
        lines.append(f"  {name:<18} {secs:10.4f}s  x{count:<7d} "
                     f"{100.0 * secs / total:5.1f}%")

    lines.append("")
    lines.append("straggler ranking (busy seconds per track, slowest first):")
    for (pid, tid), secs in sorted(busy.items(), key=lambda kv: -kv[1]):
        lines.append(f"  {_track_label(names, pid, tid):<24} {secs:10.4f}s")

    lines.append("")
    lines.append(f"top stalls (longest {top}):")
    stalls.sort(key=lambda x: -x[0])
    for secs, ev in stalls[:top]:
        lines.append(f"  {secs * 1e3:9.3f}ms  {ev['name']:<18} "
                     f"{_track_label(names, ev['pid'], ev['tid']):<24} "
                     f"@{ev['ts'] / 1e6:.4f}s")
    if not stalls:
        lines.append("  (none recorded)")

    if instants:
        lines.append("")
        lines.append("incidents:")
        for ev in instants:
            args = ev.get("args") or {}
            extra = " ".join(f"{k}={v}" for k, v in sorted(args.items()))
            lines.append(f"  @{ev['ts'] / 1e6:.4f}s  {ev['name']} "
                         f"[{_track_label(names, ev['pid'], ev['tid'])}]"
                         f"{('  ' + extra) if extra else ''}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a text summary from a flight-recorder trace.")
    ap.add_argument("trace", help="trace.json exported by repro.obs.trace")
    ap.add_argument("--top", type=int, default=10,
                    help="rows in the top-stalls table (default 10)")
    args = ap.parse_args(argv)
    print(summarize(load(args.trace), top=args.top))
    return 0


if __name__ == "__main__":  # pragma: no cover - CLI
    raise SystemExit(main())


__all__ = ["STALL_NAMES", "load", "main", "summarize"]

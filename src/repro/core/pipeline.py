"""Pipeline parallelism as a Switchboard network (DESIGN.md §3).

The paper's modular-decomposition idea applied to model execution: pipeline
*stages are blocks*, the stage-to-stage activation stream is a
*latency-insensitive channel*, and the schedule is the same epoch-batched
dataflow as ``core.distributed`` — a GPipe-style fill/drain wavefront where
each tick moves one microbatch one hop via ``ppermute`` (the channel) and
computes where a microbatch is present (the ready/valid handshake; idle
stages are masked, which is exactly a de-asserted ``valid``).

Intended placement: the ``pod`` axis (DCI) — stage cuts are where the paper
put its TCP bridges, because the channel tolerates the extra latency.

The backward schedule needs no extra code: ``jax.grad`` through the
``shard_map``-ed tick scan reverses the permutes, yielding the mirrored
drain/fill wavefront automatically (verified equal to the unpipelined
reference in tests/test_pipeline.py).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import shard_map

PyTree = Any


class Pipeline:
    """Run ``stage_fn`` as an S-stage pipeline over mesh axis ``axis``.

    stage_fn(stage_params, h) -> h' must be shape-preserving across stages
    (the classic homogeneous-stage pipeline; embed/head live outside).
    Stage s holds ``params[s]`` (leaves stacked on a leading S dim).
    """

    def __init__(self, stage_fn: Callable, mesh: Mesh, axis: str = "stage"):
        self.stage_fn = stage_fn
        self.mesh = mesh
        self.axis = axis
        self.S = mesh.shape[axis]

    def __call__(self, stage_params: PyTree, x: jax.Array) -> jax.Array:
        """x: (M, mb, d) microbatches; returns (M, mb, d) outputs."""
        S, axis = self.S, self.axis
        M = x.shape[0]
        n_ticks = M + S - 1
        fwd_perm = [(s, s + 1) for s in range(S - 1)]

        def run(params, x):
            params = jax.tree.map(lambda p: p[0], params)  # local stage params
            sid = jax.lax.axis_index(axis)
            mb_shape = x.shape[1:]

            def tick(carry, t):
                h, outbuf = carry
                # channel hop: previous stage's output arrives (stage 0
                # receives zeros = invalid, and instead loads microbatch m).
                h_in = jax.lax.ppermute(h, axis, fwd_perm) if fwd_perm else h
                m = t - sid  # microbatch index at this stage this tick
                feed = jnp.clip(t, 0, M - 1)
                h_in = jnp.where(sid == 0, x[feed], h_in)
                active = (m >= 0) & (m < M)
                h_out = self.stage_fn(params, h_in)
                h_out = jnp.where(active, h_out, jnp.zeros_like(h_out))
                # last stage collects finished microbatches
                collect = active & (sid == S - 1)
                outbuf = jnp.where(
                    collect,
                    jax.lax.dynamic_update_index_in_dim(
                        outbuf, h_out, jnp.clip(m, 0, M - 1), axis=0
                    ),
                    outbuf,
                )
                return (h_out, outbuf), None

            h0 = jnp.zeros(mb_shape, x.dtype)
            out0 = jnp.zeros((M,) + mb_shape, x.dtype)
            (_, outbuf), _ = jax.lax.scan(
                tick, (h0, out0), jnp.arange(n_ticks)
            )
            # only stage S-1 holds real outputs; psum broadcasts them.
            outbuf = jnp.where(sid == S - 1, outbuf, jnp.zeros_like(outbuf))
            return jax.lax.psum(outbuf, axis)

        return shard_map(
            run,
            mesh=self.mesh,
            in_specs=(P(self.axis), P()),
            out_specs=P(),
            check_vma=False,
        )(stage_params, x)


def stage_shardings(mesh: Mesh, params_stacked: PyTree, axis: str = "stage") -> PyTree:
    sh = NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda _: sh, params_stacked)

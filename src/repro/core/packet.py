"""Switchboard packets (paper §III-A).

An SB packet is 64 bytes: 4B flags, 4B destination, 52B data payload, 4B
reserved.  Inside the JAX simulation engine a packet is simply a flat vector
of ``payload_words`` 32-bit words; this module provides the paper-layout view
(16 uint32 words: [flags, dest, data0..data12, reserved]) plus pack/unpack
helpers so host-side code can speak the same format as the paper's
``PySbPacket``.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Paper layout: 64B packet = 16 x uint32 words.
SB_PACKET_WORDS = 16
FLAGS_WORD = 0
DEST_WORD = 1
DATA_WORDS = slice(2, 15)  # 13 words = 52 bytes
RESERVED_WORD = 15

# `last` flag: bit 0 of flags (mirrors switchboard's umi/sb `last`).
FLAG_LAST = np.uint32(1)


def make_packet(dest: int = 0, flags: int = 1, data: np.ndarray | None = None) -> np.ndarray:
    """Host-side constructor for a paper-layout SB packet (numpy uint32[16])."""
    pkt = np.zeros(SB_PACKET_WORDS, dtype=np.uint32)
    pkt[FLAGS_WORD] = np.uint32(flags)
    pkt[DEST_WORD] = np.uint32(dest)
    if data is not None:
        raw = np.asarray(data).tobytes()
        if len(raw) > 52:
            raise ValueError(f"SB packet payload is 52 bytes max, got {len(raw)}")
        buf = np.zeros(52, dtype=np.uint8)
        buf[: len(raw)] = np.frombuffer(raw, dtype=np.uint8)
        pkt[DATA_WORDS] = buf.view(np.uint32)
    return pkt


def packet_data(pkt: np.ndarray, dtype=np.uint8, count: int | None = None) -> np.ndarray:
    """Extract the data payload of a paper-layout packet as ``dtype``."""
    pkt = np.asarray(pkt, dtype=np.uint32)
    raw = pkt[DATA_WORDS].tobytes()
    out = np.frombuffer(raw, dtype=dtype)
    return out[:count] if count is not None else out


def packet_dest(pkt) -> int:
    return int(np.asarray(pkt)[DEST_WORD])


def packet_flags(pkt) -> int:
    return int(np.asarray(pkt)[FLAGS_WORD])


def zeros_payload(payload_words: int, dtype=jnp.float32):
    """Device-side empty payload vector (the engine's packet representation)."""
    return jnp.zeros((payload_words,), dtype=dtype)

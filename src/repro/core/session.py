"""Simulation sessions — ONE user-facing lifecycle over every engine
(paper §III-E/§IV-A; DESIGN.md §4).

Switchboard's user surface is not "build a netlist and scan it": it is
host-side queue handles (``PySbTx``/``PySbRx``) pushing and popping packets
into a *running* simulation, plus monitors — that is what makes the
paper's interactive chiplet web app possible.  This module is that
surface.  ``Network.build(engine=...)`` returns a ``Simulation``:

    sim = net.build(engine="fused", mesh=mesh, partition=part, K=8)
    sim.reset(jax.random.key(0))          # engine state, placed + owned
    tx, rx = sim.tx("cmd.q"), sim.rx("resp.q")
    tx.send([41.0, 1.0])                  # host -> network queue handle
    sim.run(cycles=1000)                  # donation/de-aliasing inside
    print(rx.recv(), sim.cycle)
    sim.save("/tmp/ckpt")                 # checkpoint; sim.load() resumes

The same five lines drive all five engines — ``single`` | ``graph`` |
``fused`` | ``register`` | ``procs`` — because the facade speaks only the
uniform engine protocol (``engine_kind``, ``init``, ``run_epochs``/
``run``, ``run_until``, ``group_state``, ``host_push*``/``host_pop*``,
``cycles_per_epoch``).  The ``procs`` engine (the free-running
multiprocess runtime, DESIGN.md §Runtime) holds its state in worker
processes, so its "state" is a handle; the facade's save/load and
until-predicates route through the engine's ``gather_state``/
``scatter_state``/``eval_done`` hooks when present.

**The host is the outermost tier.**  Host packets enter and leave at
*boundaries* — every ``cycles_per_epoch`` simulated cycles, i.e. exactly
when the engines' tiered exchange already synchronizes (DESIGN.md §3) —
through the same SPSC ring machinery the inter-granule slabs use
(``queue.fill_single``/``drain_single`` batch ops on the external
channel's queue, homed on its owning granule per
``ChannelGraph.ext_home``).  A ``TxPort`` therefore never drops traffic:
packets that do not fit the device queue stay in a host-side buffer (the
host tier's credit) and are flushed at subsequent boundaries during
``run``.  Because boundaries land on the same cycles for every engine,
a host send/recv script produces bit-identical traffic on all of them
(property-tested in ``tests/test_session.py``).

**State ownership.**  The session owns the engine state: ``run`` donates
buffers into the compiled loops (``donate_argnums=0``), de-aliases
tied buffers first, and re-places distributed states at ``reset`` — the
sharp edges of the raw engine surface.  The legacy engine-state-threading
surface (``init(key)`` / ``run(state, n)`` / ``run_epochs(state, n)`` /
``push_external``) keeps working through deprecation shims on the facade,
and a state donated through a shim is *poisoned*: touching it afterwards
raises ``DonatedStateError`` instead of an opaque XLA deleted-buffer
crash.

**Probes and monitors** (the paper's PyMonitor): ``sim.probe(inst)``
returns one instance's live state on any engine; ``sim.stats()`` reports
cycle/epoch plus per-port handshake counters (and the single engine's
per-channel push/pop counts); ``sim.add_monitor(fn, every=...)`` samples a
host callback at epoch boundaries during ``run``.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import queue as qmod
from ..obs import trace as _trace
from ..obs.registry import REGISTRY
from ..obs.schema import STATS_SCHEMA

PyTree = Any

_ENGINE_KINDS = ("single", "graph", "fused", "register", "procs")
_DEFAULT_MAX_EPOCHS = 100_000


class DonatedStateError(RuntimeError):
    """A state whose buffers were donated into a compiled loop was reused."""


class _Donated:
    """Poison sentinel installed over a donated state's fields."""

    __slots__ = ("_api",)

    def __init__(self, api: str):
        object.__setattr__(self, "_api", api)

    def _fail(self, *a, **k):
        raise DonatedStateError(
            f"state was donated to {object.__getattribute__(self, '_api')}; "
            "use Simulation (which owns its state) or pass donate=False"
        )

    __getattr__ = __array__ = __iter__ = __len__ = __bool__ = _fail
    __getitem__ = __add__ = __mul__ = _fail

    def __repr__(self):
        return f"<donated state ({object.__getattribute__(self, '_api')})>"


def poison_donated(state: PyTree, api: str) -> None:
    """Overwrite a donated state's fields with a guard that raises a clear
    ``DonatedStateError`` on any later use (instead of XLA's deleted-buffer
    crash).  Mutates ``state`` in place; no-op for non-dataclass states."""
    if not dataclasses.is_dataclass(state):
        return
    guard = _Donated(api)
    for f in dataclasses.fields(state):
        object.__setattr__(state, f.name, guard)


class TxPort:
    """Host -> network queue handle for one ``external_in`` port (PySbTx).

    ``send``/``send_many`` never drop packets: what does not fit the
    device-side SPSC queue is buffered host-side (``pending``) and flushed
    at the next epoch boundary during ``Simulation.run`` — the host tier's
    credit protocol.
    """

    def __init__(self, sim: "Simulation", name: str):
        self._sim = sim
        self.name = name
        self.sent = 0  # handshakes into the device queue
        self._pending: collections.deque = collections.deque()

    @property
    def pending(self) -> int:
        """Packets buffered host-side, awaiting queue space."""
        return len(self._pending)

    def send(self, payload) -> bool:
        """Queue one packet.  Returns True if it landed in the device queue
        immediately (False: buffered until the next run boundary)."""
        return self.send_many([payload]) == 1

    def send_many(self, payloads) -> int:
        """Queue a batch (k, W).  Returns how many landed in the device
        queue now; the remainder is buffered and flushed during ``run``."""
        arr = np.atleast_2d(np.asarray(payloads, np.float64))
        for row in arr:
            self._pending.append(np.asarray(row))
        before = self.sent
        self._sim._flush_tx(self)
        return self.sent - before

    def __repr__(self):
        return (f"TxPort({self.name!r}, sent={self.sent}, "
                f"pending={self.pending})")


class RxPort:
    """Network -> host queue handle for one ``external_out`` port (PySbRx)."""

    def __init__(self, sim: "Simulation", name: str):
        self._sim = sim
        self.name = name
        self.received = 0

    def recv(self):
        """Pop one packet; returns its (W,) payload or None when empty."""
        out = self.drain(max_n=1)
        return out[0] if len(out) else None

    def drain(self, max_n: int | None = None) -> np.ndarray:
        """Pop up to ``max_n`` packets (all available by default).
        Returns a (k, W) array, k possibly 0."""
        return self._sim._drain_rx(self, max_n)

    def __repr__(self):
        return f"RxPort({self.name!r}, received={self.received})"


class Monitor:
    """A host callback sampled at epoch boundaries during ``run``.

    Cadence is counted on the GLOBAL boundary index (simulated cycle /
    period), not per ``run`` call — ten ``run(epochs=1)`` calls sample
    exactly like one ``run(epochs=10)``.
    """

    def __init__(self, sim: "Simulation", fn: Callable[["Simulation"], None],
                 every: int):
        self._sim = sim
        self.fn = fn
        self.every = max(int(every), 1)  # boundary cadence, in epochs
        self.samples = 0
        self._last = 0  # last global boundary index fired at

    def remove(self) -> None:
        if self in self._sim._monitors:
            self._sim._monitors.remove(self)

    def _fire(self):
        self.samples += 1
        self.fn(self._sim)


class Simulation:
    """One session facade over any engine (DESIGN.md §4).

    Lifecycle:  ``reset(key)`` -> [``tx``/``rx``/``probe``/``run``]* ->
    ``save``/``load``.  The raw engine stays reachable as ``.engine``;
    unknown attributes delegate to it, and the legacy state-threading
    surface keeps working via deprecation shims (with donated inputs
    poisoned — see ``DonatedStateError``).
    """

    def __init__(self, engine, *, period: int | None = None):
        kind = getattr(engine, "engine_kind", None)
        if kind not in _ENGINE_KINDS:
            raise TypeError(
                f"Simulation needs an engine with engine_kind in "
                f"{_ENGINE_KINDS}, got {type(engine).__name__}"
            )
        self.engine = engine
        self.kind = kind
        if period is not None and kind != "single":
            cpe = int(engine.cycles_per_epoch)
            if period % cpe:
                raise ValueError(
                    f"period={period} must be a multiple of the engine's "
                    f"epoch ({cpe} cycles)"
                )
        self._period = period
        self._state: PyTree | None = None
        self._tx_ports: dict[str, TxPort] = {}
        self._rx_ports: dict[str, RxPort] = {}
        self._monitors: list[Monitor] = []
        self._done_cache: dict[int, tuple] = {}  # anchor id -> (ref, jitted)
        graph = getattr(engine, "graph", None)
        self._ext_in = dict(graph.ext_in) if graph is not None else {}
        self._ext_out = dict(graph.ext_out) if graph is not None else {}
        # flight recorder: REPRO_TRACE=<path> arms the process-global
        # recorder (exported at interpreter exit); engines that carry
        # worker telemetry switch it on too
        if _trace.maybe_enable_from_env():
            st = getattr(engine, "set_tracing", None)
            if st is not None:
                st(True)

    # ------------------------------------------------------------- lifecycle
    @property
    def period(self) -> int:
        """Cycles between host boundaries (epoch length; the host tier's
        sync period).  Every engine's boundaries land on multiples of this,
        which is what makes host traffic engine-invariant."""
        if self._period is not None:
            return self._period
        return int(self.engine.cycles_per_epoch)

    def reset(self, key: int | jax.Array = 0, **init_kw) -> "Simulation":
        """(Re)initialize and take ownership of the engine state.

        ``key`` seeds per-block ``init_state`` (identically across engines;
        ignored by the register engine, whose operands live in the IR).
        Extra kwargs go to ``engine.init`` (e.g. ``cell_params=``,
        ``group_params=``).  Distributed states are placed on the mesh.
        """
        if self.kind == "register":
            state = self.engine.init(**init_kw)
        else:
            if isinstance(key, int):
                key = jax.random.key(key)
            state = self.engine.init(key, **init_kw)
        if hasattr(self.engine, "place"):
            state = self.engine.place(state)
        self._state = state
        for p in self._tx_ports.values():
            p.sent = 0
            p._pending.clear()
        for p in self._rx_ports.values():
            p.received = 0
        for m in self._monitors:
            m.samples = 0
            m._last = 0
        return self

    @property
    def state(self) -> PyTree:
        """The live engine state.  Read-only by convention: the session
        donates these buffers into the next ``run``, so hold results (e.g.
        from ``probe``), not this object."""
        return self._require_state()

    def _require_state(self) -> PyTree:
        if self._state is None:
            raise RuntimeError("call reset(key) before using the session")
        if isinstance(getattr(self._state, "cycle", None), _Donated):
            self._state.cycle._fail()  # raises DonatedStateError
        return self._state

    @property
    def cycle(self) -> int:
        """Current simulated cycle (identical on every granule at a
        boundary, which is the only time the host observes it)."""
        st = self._require_state()
        return int(np.asarray(jax.device_get(st.cycle)).ravel()[0])

    @property
    def epoch(self) -> int:
        st = self._require_state()
        if hasattr(st, "epoch"):
            return int(np.asarray(jax.device_get(st.epoch)).ravel()[0])
        return self.cycle // max(self.period, 1)

    def block_until_ready(self) -> "Simulation":
        jax.block_until_ready(self._require_state())
        return self

    # ----------------------------------------------------------------- ports
    def tx(self, name: str) -> TxPort:
        """Host Tx queue handle for external-in port ``name``."""
        if name not in self._ext_in:
            have = sorted(self._ext_in) or "none (graph has no external-in)"
            raise KeyError(f"no external-in port {name!r}; available: {have}")
        if name not in self._tx_ports:
            self._tx_ports[name] = TxPort(self, name)
        return self._tx_ports[name]

    def rx(self, name: str) -> RxPort:
        """Host Rx queue handle for external-out port ``name``."""
        if name not in self._ext_out:
            have = sorted(self._ext_out) or "none (graph has no external-out)"
            raise KeyError(f"no external-out port {name!r}; available: {have}")
        if name not in self._rx_ports:
            self._rx_ports[name] = RxPort(self, name)
        return self._rx_ports[name]

    def _flush_tx(self, port: TxPort) -> int:
        """Push as many of ``port``'s pending packets as fit (host tier
        credit = the external queue's free space)."""
        st = self._require_state()
        cap = int(self.engine.capacity)
        moved = 0
        while port._pending:
            batch = [port._pending[i]
                     for i in range(min(len(port._pending), cap - 1))]
            st, n = self.engine.host_push_many(st, port.name, np.stack(batch))
            n = int(n)
            for _ in range(n):
                port._pending.popleft()
            port.sent += n
            moved += n
            if n < len(batch):
                break  # queue full — the rest waits for the next boundary
        self._state = st
        return moved

    def _flush_all_tx(self) -> None:
        for port in self._tx_ports.values():
            if port._pending:
                self._flush_tx(port)

    def _drain_rx(self, port: RxPort, max_n: int | None) -> np.ndarray:
        st = self._require_state()
        cap = int(self.engine.capacity)
        W = int(self.engine.W if hasattr(self.engine, "W")
                else self.engine.payload_words)
        out: list[np.ndarray] = []
        while max_n is None or len(out) < max_n:
            ask = cap - 1 if max_n is None else min(cap - 1, max_n - len(out))
            st, pays, cnt = self.engine.host_pop_many(st, port.name, ask)
            cnt = int(cnt)
            out.extend(np.asarray(jax.device_get(pays))[:cnt])
            port.received += cnt
            if cnt < ask:
                break
        self._state = st
        if not out:
            return np.zeros((0, W), np.float32)
        return np.stack(out)

    # ------------------------------------------------------ probes / monitors
    def probe(self, inst) -> PyTree:
        """One instance's live (unstacked) state — uniform across engines.
        ``inst`` is an ``Instance`` or a global instance id."""
        return self.engine.group_state(self._require_state(), inst)

    def stats(self) -> dict:
        """Cycle/epoch counters plus per-port state, behind the ONE
        validated schema on every engine (``repro-stats-v1``; see
        ``repro.obs.schema.validate_stats``): each tx/rx entry nests the
        session counters (sent/pending resp. received) AND the port's
        live queue occupancy/credit — device-queue occupancy on the
        in-process engines, shm-ring + owning-worker occupancy on the
        ``procs`` runtime.  Engine-specific extras (e.g. the single
        engine's per-channel push/pop handshake counts) live under
        ``"detail"`` — the only key allowed to diverge per engine — and
        ``"metrics"`` is a snapshot of the process-global registry."""
        st = self._require_state()
        ps = getattr(self.engine, "port_stats", None)
        occ = ps(st) if ps is not None else {}

        def _occ(direction: str, name: str) -> dict:
            rec = occ.get(direction, {}).get(name, {})
            return {"occupancy": int(rec.get("occupancy", 0)),
                    "credit": int(rec.get("credit", 0))}

        d: dict[str, Any] = {
            "schema": STATS_SCHEMA,
            "engine": self.kind,
            "cycle": self.cycle,
            "epoch": self.epoch,
            "ports": {
                "tx": {n: {"sent": p.sent, "pending": p.pending,
                           **_occ("tx", n)}
                       for n, p in self._tx_ports.items()},
                "rx": {n: {"received": p.received, **_occ("rx", n)}
                       for n, p in self._rx_ports.items()},
            },
        }
        REGISTRY.set("session.tx.sent",
                     float(sum(p.sent for p in self._tx_ports.values())))
        REGISTRY.set("session.rx.received",
                     float(sum(p.received for p in self._rx_ports.values())))
        if self.kind == "single":
            d["detail"] = {
                "push_count": np.asarray(jax.device_get(st.push_count)),
                "pop_count": np.asarray(jax.device_get(st.pop_count)),
            }
        fs = getattr(self.engine, "fault_stats", None)
        if fs is not None:
            # the procs runtime's self-healing surface (ISSUE 8): policy,
            # restart count, snapshot cadence/epoch, replayed epochs
            d["faults"] = fs()
        bs = getattr(self.engine, "bridge_stats", None)
        if bs is not None:
            # multi-host fleets (ISSUE 9): one row per TCP ring bridge —
            # bytes/slabs/credits each way, credit RTT, wait fraction
            # (steady-state pump only; cold-start under "connect_s")
            rows = bs()
            if rows:
                d["bridges"] = rows
        d["metrics"] = REGISTRY.snapshot()
        return d

    @contextlib.contextmanager
    def trace(self, path: str):
        """Flight-recorder window: record span/instant events (and, on the
        procs engine, per-worker phase telemetry) for the body, then
        export a Perfetto/Chrome-loadable ``trace.json`` to ``path``::

            with sim.trace("/tmp/trace.json"):
                sim.run(epochs=200)

        Tracing changes no simulated behavior — final state and host Rx
        traffic stay bit-identical to an untraced run (tested in
        ``tests/test_obs.py``).  The ``REPRO_TRACE=<path>`` env knob is
        the non-contextual variant (exports at interpreter exit)."""
        rec = _trace.recorder()
        prev = rec.enabled
        rec.enabled = True
        st = getattr(self.engine, "set_tracing", None)
        if st is not None:
            st(True)
        try:
            yield self
        finally:
            try:
                flush = getattr(self.engine, "flush_telemetry", None)
                if flush is not None:
                    flush()
                if st is not None:
                    st(False)
            finally:
                rec.export(path)
                rec.enabled = prev

    def add_monitor(self, fn: Callable[["Simulation"], None],
                    every: int = 1) -> Monitor:
        """Register a host callback fired every ``every`` epoch boundaries
        during ``run`` (the paper's PyMonitor).  Returns a removable
        handle."""
        mon = Monitor(self, fn, every)
        self._monitors.append(mon)
        return mon

    # ------------------------------------------------------------------- run
    def _advance_epochs(self, n_epochs: int) -> None:
        """``n_epochs`` boundary periods through the engine's compiled
        loop, donating the owned state."""
        if n_epochs <= 0:
            return
        st = self._require_state()
        rec = _trace.recorder()
        t0 = time.monotonic() if rec.enabled else 0.0
        if self.kind == "single":
            self._state = self.engine.run(st, n_epochs * self.period,
                                          donate=True)
        else:
            per = self.period // int(self.engine.cycles_per_epoch)
            self._state = self.engine.run_epochs(st, n_epochs * per,
                                                 donate=True)
        REGISTRY.inc("session.epochs", float(n_epochs))
        if rec.enabled:
            rec.span("epoch_window", t0, time.monotonic() - t0,
                     cat="session", args={"epochs": int(n_epochs)})

    def _advance_cycles_single(self, n_cycles: int) -> None:
        if n_cycles > 0:
            rec = _trace.recorder()
            t0 = time.monotonic() if rec.enabled else 0.0
            self._state = self.engine.run(self._require_state(), n_cycles,
                                          donate=True)
            REGISTRY.inc("session.cycles", float(n_cycles))
            if rec.enabled:
                rec.span("epoch_window", t0, time.monotonic() - t0,
                         cat="session", args={"cycles": int(n_cycles)})

    def _host_done(self, done_fn, cache_key=None) -> bool:
        """Evaluate an engine-view predicate on the host (between chunks).

        The predicate sees exactly what the engine's compiled ``run_until``
        would show it: the full state (single), the granule-local state
        via ``_done_view`` (graph family), or the cell dict (register).
        The evaluator is jitted once per predicate (anchor-keyed like the
        engines' compiled loops), so per-epoch checks don't retrace.
        """
        st = self._require_state()
        if self.kind == "procs":
            # worker states never enter this process's jit: the engine
            # gathers each granule's view and evaluates host-side
            return bool(self.engine.eval_done(st, done_fn))
        anchor = cache_key if cache_key is not None else done_fn
        key = id(anchor)
        if key not in self._done_cache:
            if self.kind == "single":
                def ev(s):
                    return done_fn(s)
            elif self.kind == "register":
                G = self.engine.Dr * self.engine.Dc

                def ev(s):
                    flat = jax.tree.map(
                        lambda x: jnp.reshape(x, (G,) + jnp.shape(x)[2:]),
                        s.cell,
                    )
                    return jax.vmap(done_fn)(flat).all()
            else:
                nd, G = self.engine.nd, self.engine.G

                def ev(s):
                    local = jax.tree.map(
                        lambda x: jnp.reshape(x, (G,) + jnp.shape(x)[nd:]), s
                    )
                    return jax.vmap(
                        lambda g: done_fn(self.engine._done_view(g))
                    )(local).all()
            self._done_cache[key] = (anchor, jax.jit(ev))
        return bool(jax.device_get(self._done_cache[key][1](st)))

    def _session_run(
        self,
        cycles: int | None = None,
        *,
        epochs: int | None = None,
        until: Callable | None = None,
        max_cycles: int | None = None,
        max_epochs: int | None = None,
        cache_key: Any = None,
    ) -> "Simulation":
        """Advance the simulation (the one lifecycle verb) — this is the
        implementation behind ``run(cycles=... | epochs=... | until=...)``
        (``run`` itself also dispatches the legacy ``run(state, n)`` shim).

        cycles / epochs:  advance at least this far (cycles round UP to
            whole boundary periods on epoch-batched engines).
        until:  run until a predicate holds everywhere, within the
            ``max_cycles``/``max_epochs`` budget (relative to now; default
            100k epochs).  The predicate sees the engine's ``run_until``
            view.  ``cache_key`` pins the engine's compiled-loop cache
            when the predicate is a fresh lambda per call.

        Pending Tx packets are flushed and monitors sampled at every
        boundary (``period`` cycles); with no monitors and no pending
        traffic the whole run is a single compiled call.
        """
        if (cycles is None) + (epochs is None) + (until is None) < 2:
            raise TypeError("run() takes exactly one of cycles/epochs/until")
        self._require_state()
        self._flush_all_tx()

        if until is not None:
            return self._run_until(until, max_cycles, max_epochs, cache_key)
        if cycles is None and epochs is None:
            raise TypeError("run() needs cycles=, epochs= or until=")

        per = self.period
        n_ep = int(epochs) if epochs is not None else -(-int(cycles) // per)
        exact_cycles = (
            int(cycles) if (cycles is not None and self.kind == "single")
            else None
        )

        chunk = self._boundary_chunk()
        if chunk is None:  # no boundary work: one compiled call
            if exact_cycles is not None:
                self._advance_cycles_single(exact_cycles)
            else:
                self._advance_epochs(n_ep)
            return self

        total_c = exact_cycles if exact_cycles is not None else n_ep * per
        done_c = 0
        while done_c < total_c:
            if chunk == 1:
                step_c = min(per, total_c - done_c)
            else:
                # align chunks to the GLOBAL boundary grid so monitor
                # cadences are invariant to how runs are sliced
                cur_b = self.cycle // per
                step_c = min((chunk - cur_b % chunk) * per, total_c - done_c)
            if exact_cycles is not None:
                self._advance_cycles_single(step_c)
            else:
                self._advance_epochs(step_c // per)
            done_c += step_c
            self._boundary()
        return self

    def _boundary_chunk(self) -> int | None:
        """Epochs between host boundaries, or None when nothing needs
        them (single compiled call).  The gcd of the monitor cadences, so
        boundaries land on every multiple of every monitor's ``every``
        (min would silently skip non-dividing cadences)."""
        import math

        cadences = [m.every for m in self._monitors]
        if any(p._pending for p in self._tx_ports.values()):
            cadences.append(1)
        if not cadences:
            return None
        g = cadences[0]
        for c in cadences[1:]:
            g = math.gcd(g, c)
        return g

    def _boundary(self) -> None:
        self._flush_all_tx()
        if not self._monitors:
            return
        cyc = self.cycle
        if cyc % self.period:
            return  # mid-period (single-engine exact-cycle remainder)
        b = cyc // self.period  # global boundary index
        for mon in list(self._monitors):
            if b and b % mon.every == 0 and b != mon._last:
                mon._last = b
                mon._fire()
                REGISTRY.inc("session.monitor.fired")

    def _run_until(self, done_fn, max_cycles, max_epochs, cache_key):
        per = self.period
        if max_cycles is not None and max_epochs is not None:
            raise TypeError("pass max_cycles or max_epochs, not both")
        if max_epochs is None:
            max_epochs = (
                -(-int(max_cycles) // per) if max_cycles is not None
                else _DEFAULT_MAX_EPOCHS
            )
        chunk = self._boundary_chunk()
        if chunk is None:
            # straight to the engine's compiled while-loop; the budget is
            # relative, so repeated interactive calls share one compilation
            st = self._require_state()
            if self.kind == "single":
                self._state = self.engine.run_until(
                    st, done_fn, max_cycles=max_epochs * per,
                    cache_key=cache_key, donate=True,
                )
            else:
                per_engine = per // int(self.engine.cycles_per_epoch)
                self._state = self.engine.run_until(
                    st, done_fn, max_epochs=max_epochs * per_engine,
                    cache_key=cache_key, donate=True,
                )
            return self
        # chunked: cached one-epoch runs + the host-side predicate, checked
        # every epoch — the same cadence as the compiled while-loop, so an
        # attached monitor never changes where an until-run stops
        ran = 0
        while ran < max_epochs and not self._host_done(done_fn, cache_key):
            self._advance_epochs(1)
            ran += 1
            self._boundary()
        return self

    # ---------------------------------------------------------- checkpoints
    def save(self, path: str, step: int | None = None, *,
             keep_last: int = 3) -> str:
        """Checkpoint the session (engine state + host-port buffers) under
        ``path`` via ``checkpoint.checkpointing`` (atomic tmp+rename).
        Returns the written directory."""
        from ..checkpoint import checkpointing

        st = self._require_state()
        if hasattr(self.engine, "gather_state"):
            # engines whose state lives elsewhere (the multiprocess
            # runtime) hand the facade a shape-stable gathered tree
            st = self.engine.gather_state(st)
        if step is None:
            step = self.cycle
        meta = {
            "engine_kind": self.kind,
            "cycle": self.cycle,
            "ports": {
                "tx": {
                    n: {"sent": p.sent,
                        "pending": [np.asarray(r).tolist()
                                    for r in p._pending]}
                    for n, p in self._tx_ports.items()
                },
                "rx": {n: {"received": p.received}
                       for n, p in self._rx_ports.items()},
            },
        }
        return checkpointing.save(path, step, st, meta=meta,
                                  keep_last=keep_last)

    def load(self, path: str, step: int | None = None) -> "Simulation":
        """Restore a checkpoint into this session (elastic resharding: the
        current state is the template, so a different mesh works).  Call
        ``reset`` first so a template exists."""
        from ..checkpoint import checkpointing

        template = self._require_state()
        gathered = hasattr(self.engine, "gather_state")
        if gathered:
            template = self.engine.gather_state(template)
        tree, meta = checkpointing.restore(path, template, step)
        if meta.get("engine_kind") not in (None, self.kind):
            raise ValueError(
                f"checkpoint was saved from engine "
                f"{meta['engine_kind']!r}, this session is {self.kind!r}"
            )
        if gathered:
            self._state = self.engine.scatter_state(self._require_state(), tree)
        else:
            self._state = tree
        for n, rec in meta.get("ports", {}).get("tx", {}).items():
            port = self.tx(n)
            port.sent = int(rec.get("sent", 0))
            port._pending = collections.deque(
                np.asarray(r) for r in rec.get("pending", [])
            )
        for n, rec in meta.get("ports", {}).get("rx", {}).items():
            self.rx(n).received = int(rec.get("received", 0))
        return self

    # ------------------------------------------------------ deprecation shims
    # The pre-session surface: explicit engine-state threading.  Each shim
    # warns, delegates to the engine, and poisons donated inputs so stale
    # reuse raises DonatedStateError instead of an XLA crash.
    def _shim(self, old: str, new: str) -> None:
        warnings.warn(
            f"Simulation.{old} is the legacy engine-state-threading surface;"
            f" use {new} (see DESIGN.md §4 migration notes)",
            DeprecationWarning, stacklevel=3,
        )

    def init(self, *args, **kw):
        self._shim("init(...)", "reset(key)")
        return self.engine.init(*args, **kw)

    def run_epochs(self, state, n_epochs, **kw):
        self._shim("run_epochs(state, n)", "run(epochs=n)")
        out = self.engine.run_epochs(state, n_epochs, **kw)
        if kw.get("donate", True):
            poison_donated(state, "run_epochs")
        return out

    def run_cycles(self, state, n_cycles):
        self._shim("run_cycles(state, n)", "run(cycles=n)")
        out = self.engine.run_cycles(state, n_cycles)
        poison_donated(state, "run_cycles")  # run_cycles always donates
        return out

    def run_until(self, state, done_fn, max_epochs, **kw):
        self._shim("run_until(state, ...)", "run(until=...)")
        out = self.engine.run_until(state, done_fn, max_epochs, **kw)
        if kw.get("donate", True):
            poison_donated(state, "run_until")
        return out

    def run_until_done(self, state, max_epochs, **kw):
        self._shim("run_until_done(state, ...)", "run(until=...)")
        out = self.engine.run_until_done(state, max_epochs, **kw)
        if kw.get("donate", True):
            poison_donated(state, "run_until_done")
        return out

    def push_external(self, state, name, payload):
        self._shim("push_external(state, ...)", "tx(name).send(...)")
        return self.engine.host_push(state, name, payload)

    def pop_external(self, state, name):
        self._shim("pop_external(state, ...)", "rx(name).recv()")
        return self.engine.host_pop(state, name)

    def run(self, *args, **kw):
        """``run(cycles=... | epochs=... | until=...)`` — see
        ``_session_run``.  Also accepts the legacy ``run(state, n_cycles)``
        call shape as a deprecation shim."""
        if args and not isinstance(args[0], (int, np.integer)):
            # legacy: run(state, n_cycles) on the single engine
            self._shim("run(state, n)", "run(cycles=n)")
            out = self.engine.run(*args, **kw)
            if kw.get("donate", False):
                poison_donated(args[0], "run")
            return out
        if args:
            kw.setdefault("cycles", int(args[0]))
        return self._session_run(**kw)

    def __getattr__(self, name: str):
        # Anything the facade does not define delegates to the engine
        # (group_state, gather_group, classes, place, step, graph, ...).
        if name.startswith("__") or name == "engine":
            raise AttributeError(name)
        return getattr(self.engine, name)

    def __repr__(self):
        st = "reset" if self._state is not None else "unreset"
        return (f"Simulation(engine={type(self.engine).__name__}, "
                f"kind={self.kind!r}, {st})")

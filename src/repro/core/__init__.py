"""repro.core — the paper's primary contribution in JAX.

Switchboard's modular-simulation model (blocks + latency-insensitive
channels + SPSC queues + unsynchronized scale-out + rate-controlled
performance measurement), adapted to the TPU execution model.  See
DESIGN.md §2 for the mechanism-by-mechanism mapping.

  packet      SB packet layout (§III-A)
  queue       SPSC ring buffers, single-cycle + epoch bulk ops (§III-B)
  block       ready/valid Block protocol + bridge semantics (§II-A)
  network     SbNetwork analogue; single-netlist simulator (§III-F)
  distributed epoch-batched shard_map grid engine (§II, §IV-B)
  perfmodel   rate control + N_meas error model (§II-C)
  fastgrid    kernel-fused register-channel engine (§Perf optimized backend)
  pipeline    LM pipeline parallelism on the same channel semantics
"""
from .block import Block
from .network import Network, NetworkSim, NetworkState
from .queue import QueueArray, make_queues, DEFAULT_CAPACITY
from .distributed import GridEngine, GridState
from .fastgrid import RegisterGridEngine
from .pipeline import Pipeline
from . import packet, perfmodel

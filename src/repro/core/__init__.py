"""repro.core — the paper's primary contribution in JAX.

Switchboard's modular-simulation model (blocks + latency-insensitive
channels + SPSC queues + unsynchronized scale-out + rate-controlled
performance measurement), adapted to the TPU execution model.  See
DESIGN.md for the mechanism-by-mechanism mapping and the three-layer
architecture: Network description -> channel-graph IR + partition ->
engine backend.

  packet      SB packet layout (§III-A)
  queue       SPSC ring buffers, single-cycle + epoch bulk ops (§III-B)
  block       ready/valid Block protocol + bridge semantics (§II-A)
  network     SbNetwork analogue; build(engine=...) entry point (§III-F)
  session     Simulation facade: one reset/run/probe/tx/rx/save lifecycle
              over every engine, host TxPort/RxPort queue handles,
              monitors, checkpoints (DESIGN.md §4)
  graph       channel-graph IR + PartitionTree shared by every backend
              (DESIGN.md §1, §3)
  distributed epoch-batched shard_map GraphEngine (tiered per-tier sync
              rates, batched per-tier exchange) + GridEngine preset
  fused       fused-epoch fast path for ANY topology: depth-1 register
              channels + one compiled K-cycle epoch body (§Perf)
  perfmodel   rate control + N_meas error model (§II-C)
  fastgrid    hand-specialized systolic Pallas preset of the fused family
  pipeline    LM pipeline parallelism on the same channel semantics
  compat      version-tolerant jax.make_mesh / jax.shard_map wrappers
"""
from .compat import tune_cpu_runtime as _tune_cpu_runtime

_tune_cpu_runtime()  # before any backend init — see compat.tune_cpu_runtime

from .block import Block
from .network import Network, NetworkSim, NetworkState
from .graph import (
    ChannelGraph, PartitionLowering, PartitionTree, Tier, grid_partition,
    lower_partition, normalize_partition, normalize_tiers,
    tiered_grid_partition,
)
from .queue import QueueArray, make_queues, DEFAULT_CAPACITY
from .distributed import (
    GraphEngine, GraphState, GridEngine, edge_color_routes,
    merge_compatible_classes, route_shift_groups,
)
from .fastgrid import RegisterGridEngine
from .fused import FusedEngine, FusedState
from .session import (
    DonatedStateError, Monitor, RxPort, Simulation, TxPort,
)
from .pipeline import Pipeline
from . import packet, perfmodel

"""repro.core — the paper's primary contribution in JAX.

Switchboard's modular-simulation model (blocks + latency-insensitive
channels + SPSC queues + unsynchronized scale-out + rate-controlled
performance measurement), adapted to the TPU execution model.  See
DESIGN.md for the mechanism-by-mechanism mapping and the three-layer
architecture: Network description -> channel-graph IR + partition ->
engine backend.

  packet      SB packet layout (§III-A)
  queue       SPSC ring buffers, single-cycle + epoch bulk ops (§III-B)
  block       ready/valid Block protocol + bridge semantics (§II-A)
  network     SbNetwork analogue; build(engine=...) entry point (§III-F)
  graph       channel-graph IR + PartitionTree shared by every backend
              (DESIGN.md §1, §3)
  distributed epoch-batched shard_map GraphEngine (tiered per-tier sync
              rates) + GridEngine preset
  perfmodel   rate control + N_meas error model (§II-C)
  fastgrid    kernel-fused register-channel engine (§Perf optimized backend)
  pipeline    LM pipeline parallelism on the same channel semantics
  compat      version-tolerant jax.make_mesh / jax.shard_map wrappers
"""
from .block import Block
from .network import Network, NetworkSim, NetworkState
from .graph import (
    ChannelGraph, PartitionTree, Tier, grid_partition, normalize_partition,
    normalize_tiers, tiered_grid_partition,
)
from .queue import QueueArray, make_queues, DEFAULT_CAPACITY
from .distributed import GraphEngine, GraphState, GridEngine, edge_color_routes
from .fastgrid import RegisterGridEngine
from .pipeline import Pipeline
from . import packet, perfmodel

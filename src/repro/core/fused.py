"""Fused-epoch engine — the kernel-fused fast path for ANY channel graph
(§Perf; the generalization of ``fastgrid`` promised by DESIGN.md).

``GraphEngine`` interprets a granule cycle over deep SPSC queues: every
cycle peeks, steps, pushes and pops a ``(n_local, capacity, W)`` buffer —
~10 XLA ops of full-buffer traffic per simulated cycle.  This engine
lowers the same partitioned ``ChannelGraph`` to a *fused* per-granule
epoch instead:

  * **intra-granule channels are depth-1 elastic registers** — a
    (value, valid) pair per channel, the same legal latency-insensitive
    refinement ``fastgrid`` uses.  The per-cycle state shrinks from
    ``(n_local, capacity, W)`` to ``(n_reg, W)`` — 8-62x less data
    touched per cycle — and the ring arithmetic disappears;
  * **boundary + external channels stay real queues** (a small
    ``(n_q, capacity, W)`` array, typically ~10% of channels for a good
    partition), so the batched tier exchange, slab depths and credit
    protocol are *bit-identical* to ``GraphEngine`` — the two engines
    interoperate with the same sync schedule and the same partition tree;
  * the whole ``K_inner``-cycle tier-inner epoch executes as ONE fused
    body (``kernels.granule_step.epoch_loop``): fully unrolled straight-
    line XLA for small K, a ``fori_loop`` for large K, or one Pallas
    kernel with the granule state resident in VMEM on TPU.

Correctness contract (property-tested in ``tests/test_fused.py``):

  * handshaked results are **bit-exact** vs ``GraphEngine``/``NetworkSim``
    for any topology, any hierarchical partition and any per-tier rates —
    channel depth is latency the handshakes tolerate by construction;
  * with ``capacity=2`` the depth-1 registers are *cycle-identical* to the
    SPSC queues (a capacity-2 ring holds exactly one packet with the same
    pre-cycle snapshot semantics), so at K=(1,1) the fused engine is
    additionally cycle-accurate vs the single netlist;
  * the network must be deadlock-free at channel depth 1 (true for every
    latency-insensitive design shipped here; a design that *requires*
    deeper elastic buffering should run on ``GraphEngine``).

Select it with ``Network.build(engine="fused", ...)``; ``FusedEngine.grid``
is the uniform-grid preset (the ``GridEngine`` analogue).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import queue as qmod
from .block import Block
from .distributed import GraphEngine, _rank_within
from .graph import ChannelGraph, grid_partition
from .struct import pytree_dataclass
from ..kernels import granule_step

PyTree = Any


@pytree_dataclass
class FusedTables:
    """Fused-engine lookup tables (device-varying, constant over time).

    Extends the ``GraphTables`` port/exchange tables with the *inverse*
    port maps: because channels are SPSC, every combined channel id has at
    most one local producer and one local consumer, so the per-cycle
    drive/commit step is three static **gathers** (producer payload,
    producer valid, consumer ready) instead of scatters — the XLA-CPU/TPU
    friendly formulation.
    """

    rx_idx: tuple  # per group: (dev..., n_slot, n_in) int32 combined ids
    tx_idx: tuple  # per group: (dev..., n_slot, n_out) int32 combined ids
    active: tuple  # per group: (dev..., n_slot) bool
    send_idx: tuple  # per tier: (dev..., S_t) int32 queue rows
    send_mask: tuple  # per tier: (dev..., S_t) bool
    recv_idx: tuple  # per tier: (dev..., S_t) int32 queue rows
    recv_mask: tuple  # per tier: (dev..., S_t) bool
    inv_tx: jax.Array  # (dev..., n_reg + n_q) int32 flat producer index
    inv_tx_mask: jax.Array  # (dev..., n_reg + n_q) bool
    inv_rx: jax.Array  # (dev..., n_reg + n_q) int32 flat consumer index
    inv_rx_mask: jax.Array  # (dev..., n_reg + n_q) bool


@pytree_dataclass
class FusedState:
    """All leaves carry leading device dims, sharded over the granule axes.

    ``reg_val``/``reg_v`` are the depth-1 intra-granule channel registers
    (ids 0/1 are the NULL_RX / NULL_TX sentinels: ``reg_v`` is pinned
    False there, so 0 never reads valid and 1 always looks free).
    ``queues`` holds only boundary egress/ingress + external channels.
    """

    reg_val: jax.Array  # (dev..., n_reg, W)
    reg_v: jax.Array  # (dev..., n_reg) bool
    queues: qmod.QueueArray  # (dev..., n_q, capacity, W)
    block_states: tuple  # per group: leaves (dev..., n_slot, ...)
    credits: tuple  # per tier: (dev..., S_t) int32 send credits
    cycle: jax.Array  # (dev...,) int32
    epoch: jax.Array  # (dev...,) int32
    tables: FusedTables


class FusedEngine(GraphEngine):
    """Fused-epoch distributed engine over an arbitrary partitioned graph.

    Accepts everything ``GraphEngine`` accepts, plus:

    fuse:    epoch-body strategy — "auto" (one Pallas kernel on TPU, one
             ``fori_loop`` body elsewhere), or explicitly "xla" |
             "unroll" | "pallas" (see ``kernels.granule_step``).
    pallas_interpret: run the Pallas path in interpret mode (CPU CI).
    """

    engine_kind = "fused"

    def __init__(
        self,
        graph: ChannelGraph,
        partition,
        mesh: Mesh,
        K: int = 1,
        axes: Sequence[str] | None = None,
        tiers: Sequence | None = None,
        *,
        fuse: str = "auto",
        pallas_interpret: bool = False,
    ):
        self.fuse = fuse
        self.pallas_interpret = bool(pallas_interpret)
        super().__init__(graph, partition, mesh, K=K, axes=axes, tiers=tiers)
        self._build_fused_tables()

    # ---------------------------------------------------- uniform-grid preset
    @classmethod
    def grid(
        cls,
        cell: Block,
        R: int,
        C: int,
        mesh: Mesh,
        K: int,
        payload_words: int = 2,
        capacity: int = qmod.DEFAULT_CAPACITY,
        dtype: Any = jnp.float32,
        axis_r: str = "gr",
        axis_c: str = "gc",
        **kw,
    ) -> "FusedEngine":
        """Uniform R×C grid preset — the fused ``GridEngine`` analogue."""
        Dr, Dc = mesh.shape[axis_r], mesh.shape[axis_c]
        graph = ChannelGraph.grid(
            cell, R, C, payload_words=payload_words, dtype=dtype,
            capacity=capacity,
        )
        return cls(
            graph, grid_partition(R, C, Dr, Dc), mesh, K=K,
            axes=(axis_r, axis_c), **kw,
        )

    # ------------------------------------------------- host-side compilation
    def _build_fused_tables(self) -> None:
        """Re-lower the granule-local queue id space onto registers + queues.

        Every (granule, local queue) entity becomes either a depth-1
        register (intra-granule channels) or a row of the small boundary
        queue array (egress/ingress/external).  Combined addressing keeps
        one flat id space for the port tables: ids ``[0, n_reg)`` are
        registers (0/1 the sentinels), ``[n_reg, n_reg + n_q)`` queues.
        """
        G = self.G
        g = self.graph
        ent_g, ent_c, ent_kind, lid = self._ent
        # external channels (host-facing) need real multi-packet queues
        ext = (g.chan_src[ent_c] < 0) | (g.chan_dst[ent_c] < 0)
        is_reg = (ent_kind == 0) & ~ext

        reg_rank, reg_counts = _rank_within(ent_g[is_reg], G)
        q_rank, q_counts = _rank_within(ent_g[~is_reg], G)
        self.n_reg = int(2 + (reg_counts.max() if reg_counts.size else 0))
        # queue row 0 is a scratch sentinel: exchange-table *padding* points
        # there, so masked slots can never scatter stale head/tail/buf
        # copies over a real channel's row (rows are written back whole)
        self.n_q = int(1 + (q_counts.max() if q_counts.size else 0))

        lid2comb = np.zeros((G, self.n_local), np.int64)
        lid2comb[:, 1] = 1
        lid2comb[ent_g[is_reg], lid[is_reg]] = 2 + reg_rank
        lid2comb[ent_g[~is_reg], lid[~is_reg]] = self.n_reg + 1 + q_rank
        self._lid2comb = lid2comb

        gi = np.arange(G)[:, None, None]
        self._rx_tables_f = [
            lid2comb[gi, rxm].astype(np.int32) for rxm in self._rx_tables
        ]
        self._tx_tables_f = [
            lid2comb[gi, txm].astype(np.int32) for txm in self._tx_tables
        ]

        # exchange tables move from local-queue-id space to queue-row space
        gq = np.arange(G)[:, None]

        def to_qrow(idx, mask):
            comb = lid2comb[gq, idx]
            assert (comb[mask] >= self.n_reg).all(), (
                "boundary channel lowered to a register"
            )
            return np.where(mask, comb - self.n_reg, 0).astype(np.int32)

        self._send_idx_f = [
            to_qrow(si, sm) for si, sm in zip(self._send_idx, self._send_mask)
        ]
        self._recv_idx_f = [
            to_qrow(ri, rm) for ri, rm in zip(self._recv_idx, self._recv_mask)
        ]

        # Inverse port maps: channel -> (unique) flat producer/consumer slot.
        # SPSC guarantees uniqueness for real channels; the sentinels (many
        # writers/readers, all dropped) and remotely-driven channels
        # (ingress: producer on the peer granule; egress: consumer there)
        # are masked out.
        n_tot = self.n_reg + self.n_q
        inv_tx = np.zeros((G, n_tot), np.int64)
        inv_tx_m = np.zeros((G, n_tot), bool)
        inv_rx = np.zeros((G, n_tot), np.int64)
        inv_rx_m = np.zeros((G, n_tot), bool)
        garange = np.arange(G)[:, None]
        off = 0
        for txm in self._tx_tables_f:
            _, n_slot, n_out = txm.shape
            flat = np.broadcast_to(
                off + np.arange(n_slot * n_out), (G, n_slot * n_out)
            )
            inv_tx[garange, txm.reshape(G, -1)] = flat
            inv_tx_m[garange, txm.reshape(G, -1)] = True
            off += n_slot * n_out
        off = 0
        for rxm in self._rx_tables_f:
            _, n_slot, n_in = rxm.shape
            flat = np.broadcast_to(
                off + np.arange(n_slot * n_in), (G, n_slot * n_in)
            )
            inv_rx[garange, rxm.reshape(G, -1)] = flat
            inv_rx_m[garange, rxm.reshape(G, -1)] = True
            off += n_slot * n_in
        inv_tx_m[:, :2] = False  # sentinels never drive/commit anything
        inv_rx_m[:, :2] = False
        self._inv_tx, self._inv_tx_mask = inv_tx.astype(np.int32), inv_tx_m
        self._inv_rx, self._inv_rx_mask = inv_rx.astype(np.int32), inv_rx_m

    def tables(self) -> FusedTables:
        return FusedTables(
            rx_idx=tuple(self._dev(t) for t in self._rx_tables_f),
            tx_idx=tuple(self._dev(t) for t in self._tx_tables_f),
            active=tuple(self._dev(t) for t in self._act_tables),
            send_idx=tuple(self._dev(t) for t in self._send_idx_f),
            send_mask=tuple(self._dev(t) for t in self._send_mask),
            recv_idx=tuple(self._dev(t) for t in self._recv_idx_f),
            recv_mask=tuple(self._dev(t) for t in self._recv_mask),
            inv_tx=self._dev(self._inv_tx),
            inv_tx_mask=self._dev(self._inv_tx_mask),
            inv_rx=self._dev(self._inv_rx),
            inv_rx_mask=self._dev(self._inv_rx_mask),
        )

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array, group_params: dict[int, PyTree] | None = None) -> FusedState:
        """Initial state — same per-member block init as every other engine
        (bit-identical results), fused channel representation."""
        states = self._init_block_states(key, group_params)
        q = qmod.make_queues(self.n_q, self.W, self.capacity, self.dtype)
        queues = jax.tree.map(
            lambda x: jnp.broadcast_to(x, self.dev_shape + x.shape), q
        )
        cap1 = self.capacity - 1
        credits = tuple(
            jnp.full(self.dev_shape + (si.shape[1],), cap1, jnp.int32)
            for si in self._send_idx
        )
        return FusedState(
            reg_val=jnp.zeros(self.dev_shape + (self.n_reg, self.W), self.dtype),
            reg_v=jnp.zeros(self.dev_shape + (self.n_reg,), bool),
            queues=queues,
            block_states=tuple(states),
            credits=credits,
            cycle=jnp.zeros(self.dev_shape, jnp.int32),
            epoch=jnp.zeros(self.dev_shape, jnp.int32),
            tables=self.tables(),
        )

    # ----------------------------------------------------------- local cycle
    @staticmethod
    def _tables6(tb: FusedTables):
        """The (loop-invariant) table leaves the cycle body actually reads —
        passed to the epoch kernel as read-only consts, NOT loop carry."""
        return (
            tb.rx_idx, tb.tx_idx,
            tb.inv_tx, tb.inv_tx_mask, tb.inv_rx, tb.inv_rx_mask,
        )

    def _local_cycle(self, st: FusedState) -> FusedState:
        """One granule-local cycle on registers + boundary queues."""
        carry = (st.reg_val, st.reg_v, st.queues, st.block_states, st.cycle)
        out = self._cycle_body(carry, self._tables6(st.tables))
        return st.replace(
            reg_val=out[0], reg_v=out[1], queues=out[2],
            block_states=out[3], cycle=out[4],
        )

    def _cycle_body(self, carry, tables6):
        """One granule-local cycle on registers + boundary queues.

        Same pre-cycle snapshot semantics as ``NetworkSim.step`` /
        ``GraphEngine._local_cycle`` — fronts, valids and readies are all
        taken before any block steps — with channel storage split between
        the register file and the small boundary queue array.  Pure in
        its explicit arguments (no captured engine state), so the epoch
        kernel can run it inside ``pallas_call``.
        """
        reg_val_in, reg_v_in, q, block_states, cycle = carry
        rx_tbl, tx_tbl, inv_tx, inv_tx_mask, inv_rx, inv_rx_mask = tables6
        n_reg, W = self.n_reg, self.W
        # n_q == 1 means only the scratch row exists: this granule set has no
        # boundary/external channels, so the queue machinery vanishes from
        # the compiled body entirely (host-static decision).
        have_q = self.n_q > 1

        if have_q:
            qsize = (q.head - q.tail) % q.capacity
            qfronts = jnp.take_along_axis(
                q.buf, q.tail[:, None, None], axis=1
            )[:, 0, :]
            # combined channel views: registers first, queue rows after
            fronts = jnp.concatenate([reg_val_in, qfronts], axis=0)
            valids = jnp.concatenate([reg_v_in, qsize > 0], axis=0)
            readies = jnp.concatenate([~reg_v_in, qsize < q.capacity - 1], axis=0)
        else:
            fronts, valids, readies = reg_val_in, reg_v_in, ~reg_v_in

        new_states = []
        pay_parts, val_parts, rr_parts = [], [], []
        for gi, grp in enumerate(self.graph.groups):
            blk = grp.block
            rxm, txm = rx_tbl[gi], tx_tbl[gi]
            f_all = fronts[rxm]  # (n_slot, n_in, W) — one gather per group
            v_all = valids[rxm]
            r_all = readies[txm]
            rx = {
                port: (f_all[:, p], v_all[:, p])
                for p, port in enumerate(blk.in_ports)
            }
            tx_ready = {port: r_all[:, p] for p, port in enumerate(blk.out_ports)}
            bst = block_states[gi]
            new_st, rx_ready, tx = jax.vmap(blk.step)(bst, rx, tx_ready)

            if blk.clock_divider > 1:
                en = (cycle % blk.clock_divider) == 0
                new_st = jax.tree.map(lambda n, o: jnp.where(en, n, o), new_st, bst)
                rx_ready = {k: v & en for k, v in rx_ready.items()}
                tx = {k: (p, v & en) for k, (p, v) in tx.items()}
            new_states.append(new_st)

            if blk.in_ports:
                rr_parts.append(
                    jnp.stack([rx_ready[p] for p in blk.in_ports], 1).reshape(-1)
                )
            if blk.out_ports:
                pay_parts.append(
                    jnp.stack([tx[p][0] for p in blk.out_ports], 1)
                    .reshape(-1, W).astype(self.dtype)
                )
                val_parts.append(
                    jnp.stack([tx[p][1] for p in blk.out_ports], 1).reshape(-1)
                )

        def _cat(parts, empty):
            if not parts:
                return empty
            return parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)

        pay_all = _cat(pay_parts, jnp.zeros((1, W), self.dtype))
        val_all = _cat(val_parts, jnp.zeros((1,), bool))
        rr_all = _cat(rr_parts, jnp.zeros((1,), bool))

        # SPSC: the static inverse maps pick each channel's unique producer
        # and consumer — gathers only, no scatters anywhere in the cycle.
        # Gather straight into the register/queue halves (no full-width
        # intermediate to slice).
        inv_tx_r, inv_rx_r = inv_tx[:n_reg], inv_rx[:n_reg]

        # registers: depth-1 elastic commit (push into empty, pop drains)
        do_push_r = val_all[inv_tx_r] & inv_tx_mask[:n_reg] & ~reg_v_in
        do_pop_r = rr_all[inv_rx_r] & inv_rx_mask[:n_reg] & reg_v_in
        reg_val = jnp.where(do_push_r[:, None], pay_all[inv_tx_r], reg_val_in)
        reg_v = (reg_v_in & ~do_pop_r) | do_push_r

        if have_q:
            # boundary/external queues: the standard ring handshake
            q2, _, _ = qmod.cycle(
                q,
                pay_all[inv_tx[n_reg:]],
                val_all[inv_tx[n_reg:]] & inv_tx_mask[n_reg:],
                rr_all[inv_rx[n_reg:]] & inv_rx_mask[n_reg:],
            )
        else:
            q2 = q
        return (reg_val, reg_v, q2, tuple(new_states), cycle + 1)

    # ------------------------------------------------------------ fused epoch
    def _inner_cycles(self, st: FusedState, K: int) -> FusedState:
        """The K_inner hot loop as ONE fused epoch body (the tentpole).

        Only the mutating leaves ride the loop carry; port tables enter as
        read-only consts, and the exchange tables/credits/epoch counter
        never touch the kernel at all.
        """
        carry = (st.reg_val, st.reg_v, st.queues, st.block_states, st.cycle)
        out = granule_step.epoch_loop(
            self._cycle_body, carry, K,
            consts=self._tables6(st.tables),
            mode=self.fuse, interpret=self.pallas_interpret,
        )
        return st.replace(
            reg_val=out[0], reg_v=out[1], queues=out[2],
            block_states=out[3], cycle=out[4],
        )

    # ------------------------------------------------- host-side external I/O
    def _ext_loc(self, cid: int) -> tuple[tuple[int, ...], int]:
        gid = int(self._chan_owner[cid])
        didx = tuple(int(i) for i in np.unravel_index(gid, self.dev_shape))
        lid = int(max(self._rx_local[cid], self._tx_local[cid]))
        return didx, int(self._lid2comb[gid, lid]) - self.n_reg

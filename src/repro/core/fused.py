"""Fused-epoch engine — the kernel-fused fast path for ANY channel graph
(§Perf; the generalization of ``fastgrid`` promised by DESIGN.md).

``GraphEngine`` interprets a granule cycle over deep SPSC queues: every
cycle peeks, steps, pushes and pops a ``(n_local, capacity, W)`` buffer —
~10 XLA ops of full-buffer traffic per simulated cycle.  This engine
lowers the same partitioned ``ChannelGraph`` to a *fused* per-granule
epoch instead:

  * **intra-granule channels are depth-1 elastic registers** — a
    (value, valid) pair per channel, the same legal latency-insensitive
    refinement ``fastgrid`` uses.  The per-cycle state shrinks from
    ``(n_local, capacity, W)`` to ``(n_reg, W)`` — 8-62x less data
    touched per cycle — and the ring arithmetic disappears;
  * **boundary + external channels stay real queues** (a small
    ``(n_q, capacity, W)`` array, typically ~10% of channels for a good
    partition), so the batched tier exchange, slab depths and credit
    protocol are *bit-identical* to ``GraphEngine`` — the two engines
    interoperate with the same sync schedule and the same partition tree;
  * the whole ``K_inner``-cycle tier-inner epoch executes as ONE fused
    body (``kernels.granule_step.epoch_loop``): fully unrolled straight-
    line XLA for small K, a ``fori_loop`` for large K, or one Pallas
    kernel with the granule state resident in VMEM on TPU.

Correctness contract (property-tested in ``tests/test_fused.py``):

  * handshaked results are **bit-exact** vs ``GraphEngine``/``NetworkSim``
    for any topology, any hierarchical partition and any per-tier rates —
    channel depth is latency the handshakes tolerate by construction;
  * with ``capacity=2`` the depth-1 registers are *cycle-identical* to the
    SPSC queues (a capacity-2 ring holds exactly one packet with the same
    pre-cycle snapshot semantics), so at K=(1,1) the fused engine is
    additionally cycle-accurate vs the single netlist;
  * the network must be deadlock-free at channel depth 1 (true for every
    latency-insensitive design shipped here; a design that *requires*
    deeper elastic buffering should run on ``GraphEngine``).

Select it with ``Network.build(engine="fused", ...)``; ``FusedEngine.grid``
is the uniform-grid preset (the ``GridEngine`` analogue).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import queue as qmod
from ..obs.registry import REGISTRY
from .block import Block
from .distributed import GraphEngine, _dealias_for_donation, _rank_within
from .graph import ChannelGraph, grid_partition
from .struct import pytree_dataclass
from ..kernels import granule_step

PyTree = Any


@pytree_dataclass
class FusedTables:
    """Fused-engine lookup tables (device-varying, constant over time).

    Extends the ``GraphTables`` port/exchange tables with the *inverse*
    port maps: because channels are SPSC, every combined channel id has at
    most one local producer and one local consumer, so the per-cycle
    drive/commit step is three static **gathers** (producer payload,
    producer valid, consumer ready) instead of scatters — the XLA-CPU/TPU
    friendly formulation.
    """

    rx_idx: tuple  # per group: (dev..., n_slot, n_in) int32 combined ids
    tx_idx: tuple  # per group: (dev..., n_slot, n_out) int32 combined ids
    active: tuple  # per group: (dev..., n_slot) bool
    send_idx: tuple  # per tier: (dev..., S_t) int32 queue rows
    send_mask: tuple  # per tier: (dev..., S_t) bool
    recv_idx: tuple  # per tier: (dev..., S_t) int32 queue rows
    recv_mask: tuple  # per tier: (dev..., S_t) bool
    inv_tx: jax.Array  # (dev..., n_reg + n_q) int32 flat producer index
    inv_tx_mask: jax.Array  # (dev..., n_reg + n_q) bool
    inv_rx: jax.Array  # (dev..., n_reg + n_q) int32 flat consumer index
    inv_rx_mask: jax.Array  # (dev..., n_reg + n_q) bool
    # signature-batched exchange gather maps (see GraphTables.bat_fwd/
    # bat_rev) — empty tuples when the engine runs unbatched
    bat_fwd: tuple = ()
    bat_rev: tuple = ()


@pytree_dataclass
class FusedState:
    """All leaves carry leading device dims, sharded over the granule axes.

    ``reg_val``/``reg_v`` are the depth-1 intra-granule channel registers
    (ids 0/1 are the NULL_RX / NULL_TX sentinels: ``reg_v`` is pinned
    False there, so 0 never reads valid and 1 always looks free).
    ``queues`` holds only boundary egress/ingress + external channels.
    """

    reg_val: jax.Array  # (dev..., n_reg, W)
    reg_v: jax.Array  # (dev..., n_reg) bool
    queues: qmod.QueueArray  # (dev..., n_q, capacity, W)
    block_states: tuple  # per group: leaves (dev..., n_slot, ...)
    credits: tuple  # per tier: (dev..., S_t) int32 send credits
    cycle: jax.Array  # (dev...,) int32
    epoch: jax.Array  # (dev...,) int32
    tables: FusedTables


class FusedEngine(GraphEngine):
    """Fused-epoch distributed engine over an arbitrary partitioned graph.

    Accepts everything ``GraphEngine`` accepts, plus:

    fuse:    epoch-body strategy — "auto" (one Pallas kernel on TPU, one
             ``fori_loop`` body elsewhere; overridable via the
             ``REPRO_EPOCH_MODE`` env var), or explicitly "xla" |
             "unroll" | "pallas" (see ``kernels.granule_step``).
    pallas_interpret: run the Pallas path in interpret mode.  "auto"
             (default) interprets everywhere but TPU, so ``fuse="pallas"``
             is live on CPU CI; booleans force either way
             (``REPRO_PALLAS_INTERPRET`` overrides both).
    batch_axes: signature batching — see ``GraphEngine``.  On the fused
             engine a batched granule axis additionally unlocks the
             *resident multi-epoch kernel*: every tier whose exchanges
             stay on-device (trailing batched tiers) folds into the fused
             epoch body, so one dispatch — one ``pallas_call`` under
             ``fuse="pallas"`` — runs the whole K_outer x K_inner span
             with registers, queues and credits resident.
    """

    engine_kind = "fused"

    def __init__(
        self,
        graph: ChannelGraph,
        partition,
        mesh: Mesh,
        K: int = 1,
        axes: Sequence[str] | None = None,
        tiers: Sequence | None = None,
        *,
        fuse: str = "auto",
        pallas_interpret: Any = "auto",
        batch_axes=None,
        overlap: Any = "auto",
    ):
        self.fuse = fuse
        self.pallas_interpret = pallas_interpret
        super().__init__(
            graph, partition, mesh, K=K, axes=axes, tiers=tiers,
            batch_axes=batch_axes, overlap=overlap,
        )
        self._build_fused_tables()
        # First tier index from which EVERY exchange is on-device (batched
        # classes with an empty real_perm; exchange-free tiers trivially
        # qualify): tiers [_resident_from:] run as ONE epoch_program — the
        # resident multi-epoch kernel.  Unbatched engines keep the plain
        # fold region (real_perm is None there, never ()).
        r = len(self.tiers)
        while r > 0 and all(
            cl.real_perm == () for cl in self.tier_classes[r - 1]
        ):
            r -= 1
        self._resident_from = min(r, self._fold_from)
        self._program_cache: dict[int, tuple] = {}
        self._t6_rows_cache: tuple | None = None

    # ---------------------------------------------------- uniform-grid preset
    @classmethod
    def grid(
        cls,
        cell: Block,
        R: int,
        C: int,
        mesh: Mesh,
        K: int,
        payload_words: int = 2,
        capacity: int = qmod.DEFAULT_CAPACITY,
        dtype: Any = jnp.float32,
        axis_r: str = "gr",
        axis_c: str = "gc",
        **kw,
    ) -> "FusedEngine":
        """Uniform R×C grid preset — the fused ``GridEngine`` analogue."""
        Dr, Dc = mesh.shape[axis_r], mesh.shape[axis_c]
        graph = ChannelGraph.grid(
            cell, R, C, payload_words=payload_words, dtype=dtype,
            capacity=capacity,
        )
        return cls(
            graph, grid_partition(R, C, Dr, Dc), mesh, K=K,
            axes=(axis_r, axis_c), **kw,
        )

    # ------------------------------------------------- host-side compilation
    def _build_fused_tables(self) -> None:
        """Re-lower the granule-local queue id space onto registers + queues.

        Every (granule, local queue) entity becomes either a depth-1
        register (intra-granule channels) or a row of the small boundary
        queue array (egress/ingress/external).  Combined addressing keeps
        one flat id space for the port tables: ids ``[0, n_reg)`` are
        registers (0/1 the sentinels), ``[n_reg, n_reg + n_q)`` queues.
        """
        G = self.G
        g = self.graph
        ent_g, ent_c, ent_kind, lid = self._ent
        # external channels (host-facing) need real multi-packet queues
        ext = (g.chan_src[ent_c] < 0) | (g.chan_dst[ent_c] < 0)
        is_reg = (ent_kind == 0) & ~ext

        reg_rank, reg_counts = _rank_within(ent_g[is_reg], G)
        q_rank, q_counts = _rank_within(ent_g[~is_reg], G)
        self.n_reg = int(2 + (reg_counts.max() if reg_counts.size else 0))
        # queue row 0 is a scratch sentinel: exchange-table *padding* points
        # there, so masked slots can never scatter stale head/tail/buf
        # copies over a real channel's row (rows are written back whole)
        self.n_q = int(1 + (q_counts.max() if q_counts.size else 0))

        lid2comb = np.zeros((G, self.n_local), np.int64)
        lid2comb[:, 1] = 1
        lid2comb[ent_g[is_reg], lid[is_reg]] = 2 + reg_rank
        lid2comb[ent_g[~is_reg], lid[~is_reg]] = self.n_reg + 1 + q_rank
        self._lid2comb = lid2comb

        gi = np.arange(G)[:, None, None]
        self._rx_tables_f = [
            lid2comb[gi, rxm].astype(np.int32) for rxm in self._rx_tables
        ]
        self._tx_tables_f = [
            lid2comb[gi, txm].astype(np.int32) for txm in self._tx_tables
        ]

        # exchange tables move from local-queue-id space to queue-row space
        gq = np.arange(G)[:, None]

        def to_qrow(idx, mask):
            comb = lid2comb[gq, idx]
            assert (comb[mask] >= self.n_reg).all(), (
                "boundary channel lowered to a register"
            )
            return np.where(mask, comb - self.n_reg, 0).astype(np.int32)

        self._send_idx_f = [
            to_qrow(si, sm) for si, sm in zip(self._send_idx, self._send_mask)
        ]
        self._recv_idx_f = [
            to_qrow(ri, rm) for ri, rm in zip(self._recv_idx, self._recv_mask)
        ]

        # Inverse port maps: channel -> (unique) flat producer/consumer slot.
        # SPSC guarantees uniqueness for real channels; the sentinels (many
        # writers/readers, all dropped) and remotely-driven channels
        # (ingress: producer on the peer granule; egress: consumer there)
        # are masked out.
        n_tot = self.n_reg + self.n_q
        inv_tx = np.zeros((G, n_tot), np.int64)
        inv_tx_m = np.zeros((G, n_tot), bool)
        inv_rx = np.zeros((G, n_tot), np.int64)
        inv_rx_m = np.zeros((G, n_tot), bool)
        garange = np.arange(G)[:, None]
        off = 0
        for txm in self._tx_tables_f:
            _, n_slot, n_out = txm.shape
            flat = np.broadcast_to(
                off + np.arange(n_slot * n_out), (G, n_slot * n_out)
            )
            inv_tx[garange, txm.reshape(G, -1)] = flat
            inv_tx_m[garange, txm.reshape(G, -1)] = True
            off += n_slot * n_out
        off = 0
        for rxm in self._rx_tables_f:
            _, n_slot, n_in = rxm.shape
            flat = np.broadcast_to(
                off + np.arange(n_slot * n_in), (G, n_slot * n_in)
            )
            inv_rx[garange, rxm.reshape(G, -1)] = flat
            inv_rx_m[garange, rxm.reshape(G, -1)] = True
            off += n_slot * n_in
        inv_tx_m[:, :2] = False  # sentinels never drive/commit anything
        inv_rx_m[:, :2] = False
        self._inv_tx, self._inv_tx_mask = inv_tx.astype(np.int32), inv_tx_m
        self._inv_rx, self._inv_rx_mask = inv_rx.astype(np.int32), inv_rx_m
        if self._batched:
            self._build_flat_tables()

    def _build_flat_tables(self) -> None:
        """Flatten the batch of B same-device granules into ONE granule.

        ``jax.vmap`` of the cycle body turns every port-table lookup into a
        gather with a *batching dimension* — which XLA:CPU lowers to a
        scalar loop (measured ~5x off linear scaling).  Instead the batch
        is folded into the channel/slot axes: row r's registers live at
        ``r*n_reg + c``, its queue rows at ``B*n_reg + r*n_q + k``, its
        group slots at ``r*n_slot + s`` — and the cycle body runs
        UNVMAPPED on (B*n,)-shaped arrays with ordinary (fast) gathers.
        Rows need not share table *values*: each row's window gets its own
        granule's table, so heterogeneous same-signature members batch
        exactly.  Tier exchange keeps the (B, n_q) vmap layout — the local
        view bridges with free reshapes at tier boundaries only."""
        G, B = self.G, self.B
        G_real = G // B
        n_reg, n_q = self.n_reg, self.n_q

        def fmap(t: np.ndarray) -> np.ndarray:
            # (G_real, B, ...) combined ids -> flat combined ids
            r = np.arange(B).reshape((1, B) + (1,) * (t.ndim - 2))
            return np.where(
                t < n_reg, r * n_reg + t, B * n_reg + r * n_q + (t - n_reg)
            )

        def flat_ports(tbls):
            out = []
            for tbl in tbls:
                _, n_slot, n_p = tbl.shape
                t = fmap(tbl.reshape(G_real, B, n_slot, n_p))
                out.append(t.reshape(G_real, B * n_slot, n_p).astype(np.int32))
            return out

        self._rx_flat = flat_ports(self._rx_tables_f)
        self._tx_flat = flat_ports(self._tx_tables_f)

        # Inverse maps over the flat id space — same construction as the
        # per-granule inverses (SPSC uniqueness holds per row, and rows map
        # into disjoint flat windows), with every row's sentinels masked.
        n_tot = B * (n_reg + n_q)
        inv_tx = np.zeros((G_real, n_tot), np.int64)
        inv_tx_m = np.zeros((G_real, n_tot), bool)
        inv_rx = np.zeros((G_real, n_tot), np.int64)
        inv_rx_m = np.zeros((G_real, n_tot), bool)
        grange = np.arange(G_real)[:, None]
        off = 0
        for txm in self._tx_flat:
            _, n_fs, n_out = txm.shape
            flat = np.broadcast_to(
                off + np.arange(n_fs * n_out), (G_real, n_fs * n_out)
            )
            inv_tx[grange, txm.reshape(G_real, -1)] = flat
            inv_tx_m[grange, txm.reshape(G_real, -1)] = True
            off += n_fs * n_out
        off = 0
        for rxm in self._rx_flat:
            _, n_fs, n_in = rxm.shape
            flat = np.broadcast_to(
                off + np.arange(n_fs * n_in), (G_real, n_fs * n_in)
            )
            inv_rx[grange, rxm.reshape(G_real, -1)] = flat
            inv_rx_m[grange, rxm.reshape(G_real, -1)] = True
            off += n_fs * n_in
        sent = (np.arange(B)[:, None] * n_reg + np.array([0, 1])).ravel()
        inv_tx_m[:, sent] = False
        inv_rx_m[:, sent] = False
        self._inv_tx_flat = inv_tx.astype(np.int32)
        self._inv_tx_mask_flat = inv_tx_m
        self._inv_rx_flat = inv_rx.astype(np.int32)
        self._inv_rx_mask_flat = inv_rx_m

    def _dev_flat(self, arr: np.ndarray) -> jax.Array:
        """(G_real, ...) flat table -> (real_shape..., ...) device array."""
        return jnp.asarray(arr.reshape(self.real_shape + arr.shape[1:]))

    def tables(self) -> FusedTables:
        # Batched engines carry the FLAT port/inverse tables (real_shape
        # leading dims; the batch is folded into the slot/channel axes) —
        # exchange tables keep the per-granule (dev_shape) layout the tier
        # exchange consumes.
        if self._batched:
            port = dict(
                rx_idx=tuple(self._dev_flat(t) for t in self._rx_flat),
                tx_idx=tuple(self._dev_flat(t) for t in self._tx_flat),
                inv_tx=self._dev_flat(self._inv_tx_flat),
                inv_tx_mask=self._dev_flat(self._inv_tx_mask_flat),
                inv_rx=self._dev_flat(self._inv_rx_flat),
                inv_rx_mask=self._dev_flat(self._inv_rx_mask_flat),
            )
        else:
            port = dict(
                rx_idx=tuple(self._dev(t) for t in self._rx_tables_f),
                tx_idx=tuple(self._dev(t) for t in self._tx_tables_f),
                inv_tx=self._dev(self._inv_tx),
                inv_tx_mask=self._dev(self._inv_tx_mask),
                inv_rx=self._dev(self._inv_rx),
                inv_rx_mask=self._dev(self._inv_rx_mask),
            )
        return FusedTables(
            active=tuple(self._dev(t) for t in self._act_tables),
            send_idx=tuple(self._dev(t) for t in self._send_idx_f),
            send_mask=tuple(self._dev(t) for t in self._send_mask),
            recv_idx=tuple(self._dev(t) for t in self._recv_idx_f),
            recv_mask=tuple(self._dev(t) for t in self._recv_mask),
            bat_fwd=tuple(self._dev_bat(t) for t in self._bat_fwd),
            bat_rev=tuple(self._dev_bat(t) for t in self._bat_rev),
            **port,
        )

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array, group_params: dict[int, PyTree] | None = None) -> FusedState:
        """Initial state — same per-member block init as every other engine
        (bit-identical results), fused channel representation."""
        states = self._init_block_states(key, group_params)
        q = qmod.make_queues(self.n_q, self.W, self.capacity, self.dtype)
        queues = jax.tree.map(
            lambda x: jnp.broadcast_to(x, self.dev_shape + x.shape), q
        )
        cap1 = self.capacity - 1
        credits = tuple(
            jnp.full(self.dev_shape + (si.shape[1],), cap1, jnp.int32)
            for si in self._send_idx
        )
        return FusedState(
            reg_val=jnp.zeros(self.dev_shape + (self.n_reg, self.W), self.dtype),
            reg_v=jnp.zeros(self.dev_shape + (self.n_reg,), bool),
            queues=queues,
            block_states=tuple(states),
            credits=credits,
            cycle=jnp.zeros(self.dev_shape, jnp.int32),
            epoch=jnp.zeros(self.dev_shape, jnp.int32),
            tables=self.tables(),
        )

    # ----------------------------------------------------------- local cycle
    @staticmethod
    def _tables6(tb: FusedTables):
        """The (loop-invariant) table leaves the cycle body actually reads —
        passed to the epoch kernel as read-only consts, NOT loop carry."""
        return (
            tb.rx_idx, tb.tx_idx,
            tb.inv_tx, tb.inv_tx_mask, tb.inv_rx, tb.inv_rx_mask,
        )

    # ------------------------------------------------ flat-batch local views
    def _local_view(self, state: FusedState) -> FusedState:
        """Batched fused engines run the FLAT layout: the batch axes fold
        into the register/queue/slot axes (matching the flat port tables),
        so the cycle body runs unvmapped with ordinary gathers.  Exchange
        state (credits + exchange tables) keeps the (B, S_t) layout the
        tier exchange consumes; ``queues`` bridge by reshape at tier
        boundaries.  A scratch-only queue array ((B, 1) rows, no boundary
        channels anywhere) drops to its first row so the queue machinery
        still vanishes from the compiled body."""
        if not self._batched:
            return super()._local_view(state)
        B, nd, nd_r = self.B, self.nd, self.nd_real

        fold = lambda x: x.reshape(  # noqa: E731 — batch into first data dim
            (B * x.shape[nd],) + x.shape[nd + 1:]
        )
        bat = lambda x: x.reshape((B,) + x.shape[nd:])  # noqa: E731
        q_fold = fold if self.n_q > 1 else lambda x: bat(x)[0]
        tb = state.tables
        tables = tb.replace(
            rx_idx=jax.tree.map(lambda x: x.reshape(x.shape[nd_r:]), tb.rx_idx),
            tx_idx=jax.tree.map(lambda x: x.reshape(x.shape[nd_r:]), tb.tx_idx),
            inv_tx=tb.inv_tx.reshape(tb.inv_tx.shape[nd_r:]),
            inv_tx_mask=tb.inv_tx_mask.reshape(tb.inv_tx_mask.shape[nd_r:]),
            inv_rx=tb.inv_rx.reshape(tb.inv_rx.shape[nd_r:]),
            inv_rx_mask=tb.inv_rx_mask.reshape(tb.inv_rx_mask.shape[nd_r:]),
            active=jax.tree.map(fold, tb.active),
            send_idx=jax.tree.map(bat, tb.send_idx),
            send_mask=jax.tree.map(bat, tb.send_mask),
            recv_idx=jax.tree.map(bat, tb.recv_idx),
            recv_mask=jax.tree.map(bat, tb.recv_mask),
            bat_fwd=jax.tree.map(bat, tb.bat_fwd),
            bat_rev=jax.tree.map(bat, tb.bat_rev),
        )
        return state.replace(
            reg_val=fold(state.reg_val),
            reg_v=fold(state.reg_v),
            queues=jax.tree.map(q_fold, state.queues),
            block_states=jax.tree.map(fold, state.block_states),
            credits=jax.tree.map(bat, state.credits),
            cycle=bat(state.cycle)[0],  # lockstep rows share one counter
            epoch=bat(state.epoch),
            tables=tables,
        )

    def _global_view(self, local: FusedState) -> FusedState:
        if not self._batched:
            return super()._global_view(local)
        B, nd_r = self.B, self.nd_real
        lead = (1,) * nd_r + self.batch_shape

        unfold = lambda x: x.reshape(  # noqa: E731
            lead + (x.shape[0] // B,) + x.shape[1:]
        )
        unbat = lambda x: x.reshape(lead + x.shape[1:])  # noqa: E731
        q_unfold = (
            unfold if self.n_q > 1
            else lambda x: jnp.broadcast_to(x, lead + x.shape)
        )
        tb = local.tables
        readd = lambda x: x.reshape((1,) * nd_r + x.shape)  # noqa: E731
        tables = tb.replace(
            rx_idx=jax.tree.map(readd, tb.rx_idx),
            tx_idx=jax.tree.map(readd, tb.tx_idx),
            inv_tx=readd(tb.inv_tx),
            inv_tx_mask=readd(tb.inv_tx_mask),
            inv_rx=readd(tb.inv_rx),
            inv_rx_mask=readd(tb.inv_rx_mask),
            active=jax.tree.map(unfold, tb.active),
            send_idx=jax.tree.map(unbat, tb.send_idx),
            send_mask=jax.tree.map(unbat, tb.send_mask),
            recv_idx=jax.tree.map(unbat, tb.recv_idx),
            recv_mask=jax.tree.map(unbat, tb.recv_mask),
            bat_fwd=jax.tree.map(unbat, tb.bat_fwd),
            bat_rev=jax.tree.map(unbat, tb.bat_rev),
        )
        return local.replace(
            reg_val=unfold(local.reg_val),
            reg_v=unfold(local.reg_v),
            queues=jax.tree.map(q_unfold, local.queues),
            block_states=jax.tree.map(unfold, local.block_states),
            credits=jax.tree.map(unbat, local.credits),
            cycle=jnp.broadcast_to(local.cycle, self.dev_shape[:0] + lead),
            epoch=unbat(local.epoch),
            tables=tables,
        )

    def _q_batch_view(self, q):
        """Flat (B*n_q, ...) queue leaves -> (B, n_q, ...) for the exchange."""
        return jax.tree.map(
            lambda x: x.reshape((self.B, self.n_q) + x.shape[1:]), q
        )

    def _q_flat_view(self, q):
        return jax.tree.map(
            lambda x: x.reshape((self.B * self.n_q,) + x.shape[2:]), q
        )

    def _exchange_issue_batched(self, st: FusedState, t: int):
        """Exchange halves on the flat layout: reshape the queue block to
        the (B, n_q) batch layout, run the inherited slab staging, flatten
        back — free reshapes at tier boundaries only."""
        st2, pending = super()._exchange_issue_batched(
            st.replace(queues=self._q_batch_view(st.queues)), t
        )
        return st2.replace(queues=self._q_flat_view(st2.queues)), pending

    def _exchange_commit_batched(self, st: FusedState, t: int, pending):
        st2 = super()._exchange_commit_batched(
            st.replace(queues=self._q_batch_view(st.queues)), t, pending
        )
        return st2.replace(queues=self._q_flat_view(st2.queues))

    # ------------------------------------------------- per-row resident rows
    def _t6_row(self, r: int):
        """Row r's port/inverse tables in its OWN combined id space — the
        consts for one batch row's cycle body.  Per-row tables (not one
        shared set) so heterogeneous same-signature members batch exactly;
        XLA sees each row's tables as ordinary constants."""
        if self._t6_rows_cache is None:
            # host-side numpy, NOT jnp: the cache is built lazily — possibly
            # under a jit trace, where a jnp constant would be a tracer that
            # must not outlive that trace.  numpy consts embed per-trace.
            rows = []
            for g in range(self.B):
                rows.append((
                    tuple(np.asarray(t[g]) for t in self._rx_tables_f),
                    tuple(np.asarray(t[g]) for t in self._tx_tables_f),
                    np.asarray(self._inv_tx[g]),
                    np.asarray(self._inv_tx_mask[g]),
                    np.asarray(self._inv_rx[g]),
                    np.asarray(self._inv_rx_mask[g]),
                ))
            self._t6_rows_cache = tuple(rows)
        return self._t6_rows_cache[r]

    def _rows_split(self, st: FusedState) -> tuple:
        """Flat local state -> per-row cycle carries.

        Each row's registers/queues/block slots become SEPARATE buffers:
        XLA:CPU keeps a <=granule-sized working set cache-resident through
        a whole exchange-free cycle window, where the fused flat arrays
        fall off a sharp elementwise-cost cliff (measured ~4x above ~512
        rows on one core).  Split once per epoch, not per cycle."""
        B, n_reg, n_q = self.B, self.n_reg, self.n_q
        rows = []
        for r in range(B):
            q_r = (
                jax.tree.map(
                    lambda x: x[r * n_q:(r + 1) * n_q], st.queues
                )
                if n_q > 1 else st.queues  # shared scratch row: never read
            )
            bst_r = tuple(
                jax.tree.map(
                    lambda x, nsg=jax.tree.leaves(bs)[0].shape[0] // B:
                        x[r * nsg:(r + 1) * nsg],
                    bs,
                )
                for bs in st.block_states
            )
            rows.append((
                st.reg_val[r * n_reg:(r + 1) * n_reg],
                st.reg_v[r * n_reg:(r + 1) * n_reg],
                q_r,
                bst_r,
                st.cycle,
            ))
        return tuple(rows)

    def _rows_join(self, st: FusedState, rows: tuple, credits) -> FusedState:
        """Per-row carries -> the flat local layout (inverse of
        ``_rows_split``; rows run in lockstep so row 0's cycle counter
        stands for all)."""
        cat = lambda xs: jnp.concatenate(xs, axis=0)  # noqa: E731
        queues = (
            jax.tree.map(lambda *xs: cat(xs), *(r[2] for r in rows))
            if self.n_q > 1 else rows[0][2]
        )
        return st.replace(
            reg_val=cat([r[0] for r in rows]),
            reg_v=cat([r[1] for r in rows]),
            queues=queues,
            block_states=tuple(
                jax.tree.map(lambda *xs: cat(xs), *(r[3][g] for r in rows))
                for g in range(len(st.block_states))
            ),
            cycle=rows[0][4],
            credits=credits,
        )

    def _rows_exchange_issue(self, rows: tuple, credits, t: int, tb):
        """ISSUE half of the per-row on-device exchange: credit-bounded
        ``stage_drain`` per row, one tiny (B, S_t, E_t, W) slab moved by
        the ``bat_fwd`` batch-row gather.  Only the staged slab is ever
        materialized across rows — the queue buffers stay per-row."""
        sidx, smask = tb.send_idx[t], tb.send_mask[t]  # (B, S_t)
        rmask = tb.recv_mask[t]
        bfw = tb.bat_fwd[t]
        limit = jnp.where(smask, credits[t], 0)
        new_rows, slabs, cnts = [], [], []
        for r in range(self.B):
            q2, slab, cnt = qmod.stage_drain(
                rows[r][2], sidx[r], self.E_tiers[t], limit=limit[r]
            )
            rv, rb, _, bs, cyc = rows[r]
            new_rows.append((rv, rb, q2, bs, cyc))
            slabs.append(slab)
            cnts.append(cnt)
        slab = jnp.stack(slabs)  # (B, S_t, E_t, W)
        cnt = jnp.stack(cnts)    # (B, S_t)
        slab_in = self._bat_move(slab, bfw, t)
        cnt_in = jnp.where(rmask, self._bat_move(cnt, bfw, t), 0)
        return tuple(new_rows), (slab_in, cnt_in)

    def _rows_exchange_commit(self, rows: tuple, credits, t: int, tb,
                              pending):
        """COMMIT half: ``stage_fill`` per row + the ``bat_rev`` credit
        return."""
        ridx, rmask = tb.recv_idx[t], tb.recv_mask[t]
        slab_in, cnt_in = pending
        new_rows, frees = [], []
        for r in range(self.B):
            q3 = qmod.stage_fill(rows[r][2], ridx[r], slab_in[r], cnt_in[r])
            rv, rb, _, bs, cyc = rows[r]
            new_rows.append((rv, rb, q3, bs, cyc))
            frees.append(qmod.free(q3))
        cred = jnp.where(
            rmask, jnp.take_along_axis(jnp.stack(frees), ridx, axis=1), 0
        )
        credits = (credits[:t] + (self._bat_move(cred, tb.bat_rev[t], t),)
                   + credits[t + 1:])
        return tuple(new_rows), credits

    def _rows_exchange(self, rows: tuple, credits, t: int, tb) -> tuple:
        """Tier t's on-device exchange on per-row queues — literally
        commit∘issue, so the serial and overlapped schedules share every
        instruction and differ only in ordering."""
        rows, pending = self._rows_exchange_issue(rows, credits, t, tb)
        return self._rows_exchange_commit(rows, credits, t, tb, pending)

    def _local_cycle(self, st: FusedState) -> FusedState:
        """One granule-local cycle on registers + boundary queues."""
        carry = (st.reg_val, st.reg_v, st.queues, st.block_states, st.cycle)
        out = self._cycle_body(carry, self._tables6(st.tables))
        return st.replace(
            reg_val=out[0], reg_v=out[1], queues=out[2],
            block_states=out[3], cycle=out[4],
        )

    def _cycle_body(self, carry, tables6):
        """One granule-local cycle on registers + boundary queues.

        Same pre-cycle snapshot semantics as ``NetworkSim.step`` /
        ``GraphEngine._local_cycle`` — fronts, valids and readies are all
        taken before any block steps — with channel storage split between
        the register file and the small boundary queue array.  Pure in
        its explicit arguments (no captured engine state), so the epoch
        kernel can run it inside ``pallas_call``.
        """
        reg_val_in, reg_v_in, q, block_states, cycle = carry
        rx_tbl, tx_tbl, inv_tx, inv_tx_mask, inv_rx, inv_rx_mask = tables6
        # Dims come from the carry, not the engine: the SAME body then serves
        # the per-granule layout (n_reg rows) and the signature-batched flat
        # layout (B*n_reg rows with per-row offset tables) unchanged.
        n_reg, W = reg_val_in.shape
        # A 1-row queue array is only the scratch sentinel: this granule set
        # has no boundary/external channels, so the queue machinery vanishes
        # from the compiled body entirely (host-static decision).
        have_q = q.buf.shape[0] > 1

        if have_q:
            qsize = (q.head - q.tail) % q.capacity
            qfronts = jnp.take_along_axis(
                q.buf, q.tail[:, None, None], axis=1
            )[:, 0, :]
            # combined channel views: registers first, queue rows after
            fronts = jnp.concatenate([reg_val_in, qfronts], axis=0)
            valids = jnp.concatenate([reg_v_in, qsize > 0], axis=0)
            readies = jnp.concatenate([~reg_v_in, qsize < q.capacity - 1], axis=0)
        else:
            fronts, valids, readies = reg_val_in, reg_v_in, ~reg_v_in

        new_states = []
        pay_parts, val_parts, rr_parts = [], [], []
        for gi, grp in enumerate(self.graph.groups):
            blk = grp.block
            rxm, txm = rx_tbl[gi], tx_tbl[gi]
            f_all = fronts[rxm]  # (n_slot, n_in, W) — one gather per group
            v_all = valids[rxm]
            r_all = readies[txm]
            rx = {
                port: (f_all[:, p], v_all[:, p])
                for p, port in enumerate(blk.in_ports)
            }
            tx_ready = {port: r_all[:, p] for p, port in enumerate(blk.out_ports)}
            bst = block_states[gi]
            new_st, rx_ready, tx = jax.vmap(blk.step)(bst, rx, tx_ready)

            if blk.clock_divider > 1:
                en = (cycle % blk.clock_divider) == 0
                new_st = jax.tree.map(lambda n, o: jnp.where(en, n, o), new_st, bst)
                rx_ready = {k: v & en for k, v in rx_ready.items()}
                tx = {k: (p, v & en) for k, (p, v) in tx.items()}
            new_states.append(new_st)

            if blk.in_ports:
                rr_parts.append(
                    jnp.stack([rx_ready[p] for p in blk.in_ports], 1).reshape(-1)
                )
            if blk.out_ports:
                pay_parts.append(
                    jnp.stack([tx[p][0] for p in blk.out_ports], 1)
                    .reshape(-1, W).astype(self.dtype)
                )
                val_parts.append(
                    jnp.stack([tx[p][1] for p in blk.out_ports], 1).reshape(-1)
                )

        def _cat(parts, empty):
            if not parts:
                return empty
            return parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)

        pay_all = _cat(pay_parts, jnp.zeros((1, W), self.dtype))
        val_all = _cat(val_parts, jnp.zeros((1,), bool))
        rr_all = _cat(rr_parts, jnp.zeros((1,), bool))

        # SPSC: the static inverse maps pick each channel's unique producer
        # and consumer — gathers only, no scatters anywhere in the cycle.
        # Gather straight into the register/queue halves (no full-width
        # intermediate to slice).
        inv_tx_r, inv_rx_r = inv_tx[:n_reg], inv_rx[:n_reg]

        # registers: depth-1 elastic commit (push into empty, pop drains)
        do_push_r = val_all[inv_tx_r] & inv_tx_mask[:n_reg] & ~reg_v_in
        do_pop_r = rr_all[inv_rx_r] & inv_rx_mask[:n_reg] & reg_v_in
        reg_val = jnp.where(do_push_r[:, None], pay_all[inv_tx_r], reg_val_in)
        reg_v = (reg_v_in & ~do_pop_r) | do_push_r

        if have_q:
            # boundary/external queues: the standard ring handshake
            q2, _, _ = qmod.cycle(
                q,
                pay_all[inv_tx[n_reg:]],
                val_all[inv_tx[n_reg:]] & inv_tx_mask[n_reg:],
                rr_all[inv_rx[n_reg:]] & inv_rx_mask[n_reg:],
            )
        else:
            q2 = q
        return (reg_val, reg_v, q2, tuple(new_states), cycle + 1)

    # ------------------------------------------------------------ fused epoch
    def _inner_cycles(self, st: FusedState, K: int) -> FusedState:
        """The K_inner hot loop as ONE fused epoch body (the tentpole).

        Only the mutating leaves ride the loop carry; port tables enter as
        read-only consts, and the exchange tables/credits/epoch counter
        never touch the kernel at all.  Batched engines step the whole
        granule batch in this same single dispatch (flat layout).
        """
        carry = (st.reg_val, st.reg_v, st.queues, st.block_states, st.cycle)
        # Batched engines run the same UNVMAPPED body on the flat layout —
        # one dispatch per epoch AND plain gathers (vmap would lower every
        # table lookup to a batched gather, a scalar loop on XLA:CPU).
        out = granule_step.epoch_loop(
            self._cycle_body, carry, K,
            consts=self._tables6(st.tables),
            mode=self.fuse, interpret=self.pallas_interpret,
        )
        return st.replace(
            reg_val=out[0], reg_v=out[1], queues=out[2],
            block_states=out[3], cycle=out[4],
        )

    # -------------------------------------------- resident multi-epoch kernel
    def _resident_program(self, t0: int) -> tuple:
        """The ("C", n)/("X", t) op list realizing tiers [t0:] — the same
        recursion as ``_tier_round``, flattened so the whole span executes
        as ONE ``epoch_program`` body (adjacent cycle blocks merged,
        exchange-free tiers elided).  Under ``overlap`` every boundary's
        run of ("X", t) ops is rewritten to all-issues-then-all-commits
        (``granule_step.overlap_program``) so transfers are in flight
        across the sync point — inside the pallas lowering that is the
        double-buffered DMA staging."""
        if t0 not in self._program_cache:

            def prog(t):
                if t >= self._fold_from:
                    return [("C", int(np.prod(self.K_tiers[t:])))]
                if t == len(self.tiers) - 1:
                    ops = [("C", self.tiers[t].K)]
                else:
                    ops = prog(t + 1) * self.tiers[t].K
                if self.tier_classes[t]:
                    ops = ops + [("X", t)]
                return ops

            merged: list[tuple] = []
            for op, arg in prog(t0):
                if op == "C" and merged and merged[-1][0] == "C":
                    merged[-1] = ("C", merged[-1][1] + arg)
                else:
                    merged.append((op, arg))
            program = tuple(merged)
            if self.overlap:
                program = granule_step.overlap_program(program)
            self._program_cache[t0] = program
        return self._program_cache[t0]

    def _resident_cycle(self, carry, consts):
        """Cycle body on the resident carry (the 5-leaf cycle carry plus
        the per-tier credit tuple, which only exchanges touch)."""
        return self._cycle_body(carry[:5], consts[0]) + (carry[5],)

    def _resident_exchange_issue(self, carry, t: int, consts):
        """ISSUE half of tier t's exchange *inside* the resident body.

        Every class of a resident tier has an empty ``real_perm`` (that is
        what admitted it), so the issue is slab staging on the local fused
        queue rows: credit-bounded ``stage_drain`` into the
        (B, S_t, E_t, W) slab + the ``bat_fwd`` batch-row gather.  Under
        ``fuse="pallas"`` the returned pending pair is what the kernel
        parks in the double-buffered VMEM staging slots (async DMA started
        at issue, waited at commit)."""
        reg_val, reg_v, q, block_states, cycle, credits = carry
        sidx, smask, _, rmask, bfw, _ = (x[t] for x in consts[1])
        q = self._q_batch_view(q)  # flat rows -> (B, n_q) for the slab move
        limit = jnp.where(smask, credits[t], 0)
        q, slab, cnt = jax.vmap(
            lambda qb, si, lim: qmod.stage_drain(
                qb, si, self.E_tiers[t], limit=lim
            )
        )(q, sidx, limit)
        slab_in = self._bat_move(slab, bfw, t)
        cnt_in = jnp.where(rmask, self._bat_move(cnt, bfw, t), 0)
        carry = (reg_val, reg_v, self._q_flat_view(q), block_states, cycle,
                 credits)
        return carry, (slab_in, cnt_in)

    def _resident_exchange_commit(self, carry, t: int, pending, consts):
        """COMMIT half: ``stage_fill`` the in-flight slab + the ``bat_rev``
        credit return."""
        reg_val, reg_v, q, block_states, cycle, credits = carry
        _, _, ridx, rmask, _, brv = (x[t] for x in consts[1])
        slab_in, cnt_in = pending
        q = self._q_batch_view(q)
        q = jax.vmap(qmod.stage_fill)(q, ridx, slab_in, cnt_in)
        cred = jnp.where(
            rmask, jnp.take_along_axis(qmod.free(q), ridx, axis=1), 0
        )
        credits = credits[:t] + (self._bat_move(cred, brv, t),) + credits[t + 1:]
        return (reg_val, reg_v, self._q_flat_view(q), block_states, cycle,
                credits)

    def _resident_exchange(self, carry, t: int, consts):
        """Tier t's serial exchange inside the resident body — commit∘issue
        (see the halves above); under ``fuse="pallas"`` this runs between
        the kernel's in-VMEM epoch loops, the slab never leaves the
        kernel."""
        carry, pending = self._resident_exchange_issue(carry, t, consts)
        return self._resident_exchange_commit(carry, t, pending, consts)

    def _rows_program(self, rows: tuple, credits, tb, t0: int) -> tuple:
        """Walk tiers [t0:] on the per-row carries: each ("C", n) op runs
        every row's n-cycle window as its own ``epoch_loop`` over that
        row's private buffers, each ("X", t) op is ``_rows_exchange``'s
        slab staging (split into the ("XI", t)/("XC", t) halves under
        ``overlap``).  Rows are independent between exchanges, so running
        row r's whole window before row r+1 is legal — and keeps one
        granule's working set cache-resident per window."""
        pending: dict[int, tuple] = {}
        for op, arg in self._resident_program(t0):
            if op == "C":
                rows = tuple(
                    granule_step.epoch_loop(
                        self._cycle_body, c_r, arg,
                        consts=self._t6_row(r),
                        mode=self.fuse, interpret=self.pallas_interpret,
                    )
                    for r, c_r in enumerate(rows)
                )
            elif op == "XI":
                rows, pending[arg] = self._rows_exchange_issue(
                    rows, credits, arg, tb
                )
            elif op == "XC":
                rows, credits = self._rows_exchange_commit(
                    rows, credits, arg, tb, pending.pop(arg)
                )
            else:
                rows, credits = self._rows_exchange(rows, credits, arg, tb)
        assert not pending, f"uncommitted exchanges: {sorted(pending)}"
        return rows, credits

    def run_epochs(
        self, state: FusedState, n_epochs: int, *, donate: bool = True
    ) -> FusedState:
        """Pure-batch engines scan whole epochs on the per-row carries —
        split once per ``run_epochs`` call, not once per epoch.  Keeping
        the row structure in the scan carry lets XLA update each row's
        queue buffers in place across every epoch instead of copying the
        flat state apart and back together ``n_epochs`` times.  Mixed
        real+batch and unbatched engines take the inherited path."""
        if not (self._batched and not self.real_axes):
            return super().run_epochs(state, n_epochs, donate=donate)
        key = ("run_rows", n_epochs, donate)
        if key not in self._jit_cache:
            REGISTRY.inc("fused.compile.count")

            def run(state):
                local = self._local_view(state)
                tb = local.tables

                def one(carry, _):
                    rows, credits, epoch = carry
                    rows, credits = self._rows_program(rows, credits, tb, 0)
                    return (rows, credits, epoch + 1), None

                carry = (self._rows_split(local), local.credits, local.epoch)
                rows, credits, epoch = jax.lax.scan(
                    one, carry, None, length=n_epochs
                )[0]
                out = self._rows_join(local, rows, credits)
                return self._global_view(out.replace(epoch=epoch))

            self._jit_cache[key] = jax.jit(
                self._wrap(run), donate_argnums=(0,) if donate else ()
            )
        if donate:
            state = _dealias_for_donation(state)
        REGISTRY.inc("fused.dispatch.count")
        REGISTRY.inc("fused.epochs", float(n_epochs))
        return self._jit_cache[key](state)

    def _tier_round(self, st: FusedState, t: int) -> FusedState:
        """Batched engines run every all-on-device span of the tier tree
        resident — registers, queues and credits never leave the kernel
        between its inner epochs and tier boundaries — falling back to the
        inherited loop-and-exchange recursion above ``_resident_from``.

        Pure-batch engines (every mesh axis a batch axis) take the per-row
        blocked walk: each ("C", n) op runs every row's n-cycle window as
        its own ``epoch_loop`` over that row's private buffers (see
        ``_rows_split``), and each ("X", t) op is the slab exchange of
        ``_rows_exchange``.  Mixed real+batch engines keep the flat-carry
        ``epoch_program`` (one body under shard_map)."""
        if not (self._batched and t >= self._resident_from):
            return super()._tier_round(st, t)
        tb = st.tables
        if not self.real_axes:
            rows, credits = self._rows_program(
                self._rows_split(st), st.credits, tb, t
            )
            return self._rows_join(st, rows, credits)
        carry = (
            st.reg_val, st.reg_v, st.queues, st.block_states, st.cycle,
            st.credits,
        )
        consts = (
            self._tables6(tb),
            (tb.send_idx, tb.send_mask, tb.recv_idx, tb.recv_mask,
             tb.bat_fwd, tb.bat_rev),
        )
        out = granule_step.epoch_program(
            self._resident_cycle, carry, self._resident_program(t),
            exchange_fn=self._resident_exchange,
            issue_fn=self._resident_exchange_issue,
            commit_fn=self._resident_exchange_commit,
            consts=consts,
            mode=self.fuse, interpret=self.pallas_interpret,
        )
        return st.replace(
            reg_val=out[0], reg_v=out[1], queues=out[2],
            block_states=out[3], cycle=out[4], credits=out[5],
        )

    def _pend_tiers(self, t0: int) -> tuple:
        """Resident spans commit their own split exchanges inside the
        ``epoch_program`` (pallas pendings live in kernel-local staging
        buffers and cannot cross the kernel boundary), so they contribute
        nothing to the caller's pending chain."""
        if self._batched and t0 >= self._resident_from:
            return ()
        return super()._pend_tiers(t0)

    def _round_split(self, st: FusedState, t: int):
        """The overlapped round: resident spans run their (overlapped)
        op-list program as one body — split ops committed internally —
        and the tiers above take the inherited split recursion."""
        if self._batched and t >= self._resident_from:
            return self._tier_round(st, t), ()
        return super()._round_split(st, t)

    # ------------------------------------------------- host-side external I/O
    def _ext_loc(self, cid: int) -> tuple[tuple[int, ...], int]:
        gid = int(self._chan_owner[cid])
        didx = tuple(int(i) for i in np.unravel_index(gid, self.dev_shape))
        lid = int(max(self._rx_local[cid], self._tx_local[cid]))
        return didx, int(self._lid2comb[gid, lid]) - self.n_reg

"""SPSC queues as functional ring buffers (paper §III-B).

The paper's queue is a 4KB page: 4B head (next write), 4B tail (next read),
and 62 slots of 64B packets.  Semantics reproduced exactly:

  * write: ``next_head = (head+1) % capacity``; FULL if ``next_head == tail``;
    otherwise write slot ``head`` and advance.
  * read:  EMPTY if ``tail == head``; otherwise read slot ``tail`` and advance.

so a queue of capacity C holds at most C-1 packets — property-tested against
a Python deque oracle in ``tests/test_queue.py``.

The paper's *memory* optimizations (cached head/tail, separate cache lines,
acquire/release) are host-CPU coherence tricks with no TPU analogue; their
role — avoiding synchronization traffic on every packet — is played here by
*epoch batching*: queue state lives in device memory and producer/consumer
exchange head/tail information once per epoch, not per packet (DESIGN.md §2).

All operations are masked and batched: a ``QueueArray`` stores N queues with
stacked buffers so that a whole network's channels update in a handful of
fused XLA ops (the TPU-native equivalent of "queues are fast").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .struct import pytree_dataclass, static_field

# Paper default: 62 packet slots per queue (4KB page / 64B packets).
DEFAULT_CAPACITY = 62


@pytree_dataclass
class QueueArray:
    """``n`` SPSC ring buffers with a shared capacity and payload width.

    buf:  (n, capacity, payload_words) payload storage
    head: (n,) int32 — next slot to write
    tail: (n,) int32 — next slot to read
    """

    buf: jax.Array
    head: jax.Array
    tail: jax.Array
    capacity: int = static_field(default=DEFAULT_CAPACITY)

    @property
    def n(self) -> int:
        return self.buf.shape[0]

    @property
    def payload_words(self) -> int:
        return self.buf.shape[2]


def make_queues(
    n: int,
    payload_words: int,
    capacity: int = DEFAULT_CAPACITY,
    dtype=jnp.float32,
) -> QueueArray:
    return QueueArray(
        buf=jnp.zeros((n, capacity, payload_words), dtype=dtype),
        head=jnp.zeros((n,), dtype=jnp.int32),
        tail=jnp.zeros((n,), dtype=jnp.int32),
        capacity=capacity,
    )


# --------------------------------------------------------------------------
# Occupancy queries (pre-cycle snapshot reads).
# --------------------------------------------------------------------------

def size(q: QueueArray) -> jax.Array:
    """(n,) number of packets currently enqueued."""
    return (q.head - q.tail) % q.capacity


def free(q: QueueArray) -> jax.Array:
    """(n,) number of packets that can still be pushed (capacity-1 max)."""
    return (q.capacity - 1) - size(q)


def empty(q: QueueArray) -> jax.Array:
    return q.head == q.tail


def full(q: QueueArray) -> jax.Array:
    return (q.head + 1) % q.capacity == q.tail


def peek(q: QueueArray) -> tuple[jax.Array, jax.Array]:
    """Front packet of every queue: ((n, W) payload, (n,) valid)."""
    payload = jnp.take_along_axis(q.buf, q.tail[:, None, None], axis=1)[:, 0, :]
    return payload, ~empty(q)


# --------------------------------------------------------------------------
# Single-cycle handshake update (paper §II-A bridge semantics).
# --------------------------------------------------------------------------

def _push_one(buf, head, payload, do_push):
    """Write ``payload`` at slot ``head`` of one queue's buffer if do_push."""
    cur = jax.lax.dynamic_index_in_dim(buf, head, axis=0, keepdims=False)
    row = jnp.where(do_push, payload, cur)
    return jax.lax.dynamic_update_index_in_dim(buf, row, head, axis=0)


def cycle(
    q: QueueArray,
    push_payload: jax.Array,
    push_valid: jax.Array,
    pop_ready: jax.Array,
) -> tuple[QueueArray, jax.Array, jax.Array]:
    """Apply one simulation cycle of handshakes to all queues at once.

    Per queue: the producer drives ``(push_payload, push_valid)`` and sees
    ``ready = ~full`` (pre-cycle); the consumer sees ``(front, ~empty)``
    (pre-cycle) and drives ``pop_ready``.  Both handshakes may fire in the
    same cycle — SPSC push touches ``head``, pop touches ``tail``, so they
    commute, exactly as in the shared-memory implementation.

    Returns (new_queues, did_push, did_pop).
    """
    do_push = push_valid & ~full(q)
    do_pop = pop_ready & ~empty(q)

    buf = jax.vmap(_push_one)(q.buf, q.head, push_payload, do_push)
    head = jnp.where(do_push, (q.head + 1) % q.capacity, q.head)
    tail = jnp.where(do_pop, (q.tail + 1) % q.capacity, q.tail)
    return q.replace(buf=buf, head=head, tail=tail), do_push, do_pop


# --------------------------------------------------------------------------
# Single-queue host-side handshakes (external-port I/O). Same ring
# conventions as ``cycle`` but for one queue's raw (capacity, W) storage, so
# engines never re-implement the head/tail arithmetic.
# --------------------------------------------------------------------------

def push_single(buf, head, tail, capacity, payload):
    """Push ``payload`` into one queue. Returns (buf, head, did_push)."""
    ok = (head + 1) % capacity != tail
    buf = _push_one(buf, head, payload, ok)
    return buf, jnp.where(ok, (head + 1) % capacity, head), ok


def pop_single(buf, head, tail, capacity):
    """Pop one queue's front. Returns (front, tail, did_pop)."""
    valid = head != tail
    front = jax.lax.dynamic_index_in_dim(buf, tail, axis=0, keepdims=False)
    return front, jnp.where(valid, (tail + 1) % capacity, tail), valid


def fill_single(buf, head, tail, capacity, payloads, limit=None):
    """Push up to ``len(payloads)`` packets into one queue (host batch I/O).

    payloads: (k, W) with k <= capacity-1.  Packets beyond the queue's free
    space are NOT written (the host-side caller keeps them buffered — the
    session's host-tier "credit").  ``limit`` optionally caps the count
    further (a traced scalar: the multiprocess runtime passes the shm
    ring's record count so padding rows never land).  Returns
    (buf, head, n_pushed).
    """
    k = payloads.shape[0]
    if k > capacity - 1:
        raise ValueError(f"fill_single: {k} packets > capacity-1={capacity - 1}")
    n_free = (capacity - 1) - (head - tail) % capacity
    count = jnp.minimum(jnp.int32(k), n_free.astype(jnp.int32))
    if limit is not None:
        count = jnp.minimum(count, jnp.asarray(limit, jnp.int32))
    offs = jnp.arange(k, dtype=jnp.int32)
    idx = (head + offs) % capacity
    cur = buf[idx]
    rows = jnp.where((offs < count)[:, None], payloads, cur)
    buf = buf.at[idx].set(rows, mode="promise_in_bounds", unique_indices=True)
    return buf, (head + count) % capacity, count


def drain_single(buf, head, tail, capacity, max_n: int, limit=None):
    """Pop up to ``max_n`` packets from one queue (host batch I/O).

    ``limit`` optionally caps the count further (a traced scalar: the shm
    ring's free space in the multiprocess runtime, so a flush never
    overruns the host-facing ring).  Returns (payloads (max_n, W), tail,
    count); rows beyond ``count`` are stale and must be masked by the
    caller.
    """
    n_avail = (head - tail) % capacity
    count = jnp.minimum(n_avail, max_n).astype(jnp.int32)
    if limit is not None:
        count = jnp.minimum(count, jnp.asarray(limit, jnp.int32))
    offs = jnp.arange(max_n, dtype=jnp.int32)
    idx = (tail + offs) % capacity
    return buf[idx], (tail + count) % capacity, count


# --------------------------------------------------------------------------
# Host-port operations on one queue of a QueueArray, addressed by ``idx``
# (an int row for the single netlist, a (dev..., local) tuple for the
# distributed engines).  Every engine's external-port surface routes
# through these four, so the ring/truncation logic lives exactly once.
# --------------------------------------------------------------------------

def host_push(q: QueueArray, idx, payload):
    """Push one packet into queue ``idx``.  Returns (queues, did_push)."""
    buf, head, ok = push_single(
        q.buf[idx], q.head[idx], q.tail[idx], q.capacity, payload
    )
    return q.replace(
        buf=q.buf.at[idx].set(buf), head=q.head.at[idx].set(head)
    ), ok


def host_pop(q: QueueArray, idx):
    """Pop queue ``idx``'s front.  Returns (queues, front, valid)."""
    front, tail, valid = pop_single(
        q.buf[idx], q.head[idx], q.tail[idx], q.capacity
    )
    return q.replace(tail=q.tail.at[idx].set(tail)), front, valid


def host_push_many(q: QueueArray, idx, payloads):
    """Batched push into queue ``idx``: what fits lands, the rest is
    refused (count returned) — oversize batches are truncated to the ring
    maximum of capacity-1, never an error.  Returns (queues, n_pushed)."""
    payloads = payloads[: q.capacity - 1]
    buf, head, n = fill_single(
        q.buf[idx], q.head[idx], q.tail[idx], q.capacity, payloads
    )
    return q.replace(
        buf=q.buf.at[idx].set(buf), head=q.head.at[idx].set(head)
    ), n


def host_pop_many(q: QueueArray, idx, max_n: int):
    """Batched pop from queue ``idx``.  Returns (queues, payloads
    (max_n, W), count); rows beyond count are stale."""
    pays, tail, cnt = drain_single(
        q.buf[idx], q.head[idx], q.tail[idx], q.capacity, max_n
    )
    return q.replace(tail=q.tail.at[idx].set(tail)), pays, cnt


# --------------------------------------------------------------------------
# Epoch (bulk) operations — used by the distributed exchange. These move up
# to ``max_n`` packets in one fused op, amortizing inter-device traffic over
# many packets (the paper's "queues are unlikely to be a bottleneck" claim,
# restated for ICI).
# --------------------------------------------------------------------------

def drain(q: QueueArray, max_n: int, limit: jax.Array | None = None):
    """Pop up to ``max_n`` packets from each queue.

    limit: optional (n,) per-queue cap (credit count from the receiver).
    Returns (new_queues, payloads (n, max_n, W), count (n,)).
    Slots beyond ``count`` contain stale data; consumers must mask by count.
    """
    n_avail = size(q)
    count = jnp.minimum(n_avail, max_n).astype(jnp.int32)
    if limit is not None:
        count = jnp.minimum(count, limit.astype(jnp.int32))
    offs = jnp.arange(max_n, dtype=jnp.int32)  # (max_n,)
    idx = (q.tail[:, None] + offs[None, :]) % q.capacity  # (n, max_n)
    payloads = jnp.take_along_axis(q.buf, idx[:, :, None], axis=1)  # (n,max_n,W)
    tail = (q.tail + count) % q.capacity
    return q.replace(tail=tail), payloads, count


def _fill_one(buf, head, payloads, count, capacity):
    """Push ``count`` rows of ``payloads`` into one queue at ``head``."""
    max_n = payloads.shape[0]
    offs = jnp.arange(max_n, dtype=jnp.int32)
    idx = (head + offs) % capacity  # (max_n,)
    mask = offs < count
    cur = buf[idx]  # gather (max_n, W)
    rows = jnp.where(mask[:, None], payloads, cur)
    return buf.at[idx].set(rows, mode="promise_in_bounds", unique_indices=max_n <= capacity)


def fill(q: QueueArray, payloads: jax.Array, count: jax.Array) -> QueueArray:
    """Push ``count[i]`` packets from ``payloads[i]`` into queue i.

    Caller must guarantee ``count <= free(q)`` (the credit protocol in
    ``distributed.py`` does).  Counts are clamped defensively anyway.
    """
    max_n = payloads.shape[1]
    if max_n > q.capacity - 1:
        # A wrap-around of the scatter index window could alias masked
        # (write-back) slots onto real writes, whose ordering is unspecified.
        raise ValueError(
            f"fill: max_n={max_n} must be <= capacity-1={q.capacity - 1}"
        )
    count = jnp.minimum(count.astype(jnp.int32), free(q))
    buf = jax.vmap(lambda b, h, p, c: _fill_one(b, h, p, c, q.capacity))(
        q.buf, q.head, payloads, count
    )
    head = (q.head + count) % q.capacity
    return q.replace(buf=buf, head=head)


def stage_drain(
    q: QueueArray, idx: jax.Array, max_n: int,
    limit: jax.Array | None = None,
):
    """Drain up to ``max_n`` packets from queue rows ``idx`` into a slab.

    The tier-exchange staging primitive: one gather selects the egress
    rows, one bulk :func:`drain` empties them into a contiguous
    ``(len(idx), max_n, W)`` slab (credit-bounded when ``limit`` is
    given), and only the selected rows' tails advance.  Rows whose count
    resolves to 0 write back their original tail, so padding ``idx``
    entries (masked by a 0 ``limit``) are harmless even when duplicated.
    Returns ``(new_q, slab, count)``.
    """
    sub = QueueArray(
        buf=q.buf[idx], head=q.head[idx], tail=q.tail[idx],
        capacity=q.capacity,
    )
    sub2, slab, count = drain(sub, max_n, limit=limit)
    return q.replace(tail=q.tail.at[idx].set(sub2.tail)), slab, count


def stage_fill(
    q: QueueArray, idx: jax.Array, payloads: jax.Array, count: jax.Array,
) -> QueueArray:
    """Land a slab into queue rows ``idx`` — the inverse of
    :func:`stage_drain`.

    ``payloads``: (len(idx), max_n, W); ``count``: (len(idx),).  Rows with
    ``count == 0`` are written back unchanged, so duplicate padding
    indices are harmless.
    """
    sub = QueueArray(
        buf=q.buf[idx], head=q.head[idx], tail=q.tail[idx],
        capacity=q.capacity,
    )
    sub2 = fill(sub, payloads, count)
    return q.replace(
        buf=q.buf.at[idx].set(sub2.buf),
        head=q.head.at[idx].set(sub2.head),
    )

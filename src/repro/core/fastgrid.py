"""Register-channel grid engine — the hand-specialized systolic preset of
the fused-backend family (§Perf).

The general fused fast path is ``core.fused.FusedEngine``: it lowers ANY
partitioned channel graph to depth-1 register channels + a fused K-cycle
epoch, and subsumes this engine — on XLA:CPU it now measures *faster*
than this preset (BENCH_PR3 ``engine_speedup``).  What this preset keeps
is the hand-written Pallas kernel that fuses the MAC *block semantics*
(not just the channel plumbing) for TPU.  Use ``engine="fused"`` for
arbitrary topologies; ``engine="register"`` remains the systolic-grid
Pallas-kernel reference.

The queue engine (``distributed.GridEngine``) is paper-faithful: 62-slot
SPSC queues updated cycle by cycle with ~10 XLA ops per cycle.  This engine
is the beyond-paper optimized backend for the manycore app:

  * intra-tile channels are **depth-1 elastic registers** (a valid/value
    pair per hop) — a legal latency-insensitive implementation, so the final
    result is unchanged (property-tested vs the queue engine);
  * the whole K-cycle epoch of a granule runs inside ONE Pallas kernel
    (``kernels/systolic_step``) with the tile state resident in VMEM —
    HBM sees the state once per epoch instead of ~10 times per cycle;
  * tile boundaries remain epoch slabs exchanged with ``ppermute`` and
    credit flow control — identical distribution semantics to the paper
    engine, so granule counts/partitioning stay invariant.

This is the paper's own Table-I move (same behaviour, faster backend behind
the same interface) applied to its own flagship experiment.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ops as kops
from ..obs.registry import REGISTRY
from .compat import shard_map
from .graph import ChannelGraph
from .struct import pytree_dataclass

PyTree = Any


@pytree_dataclass
class RegGridState:
    """All leaves carry leading (Dr, Dc) device dims."""

    cell: dict          # b, a_reg, a_v, p_reg, p_v, a_idx, y_idx, a_buf, y_buf, flags
    west_slab: jax.Array   # (Dr, Dc, Tr, 2K) ingress (east-bound data)
    west_cnt: jax.Array    # (Dr, Dc, Tr)
    north_slab: jax.Array  # (Dr, Dc, Tc, 2K)
    north_cnt: jax.Array   # (Dr, Dc, Tc)
    credit_e: jax.Array    # (Dr, Dc, Tr) packets we may send east next epoch
    credit_s: jax.Array    # (Dr, Dc, Tc)
    cycle: jax.Array       # (Dr, Dc)
    epoch: jax.Array       # (Dr, Dc)


def _sq(tree):
    return jax.tree.map(lambda x: x.reshape(x.shape[2:]), tree)


def _unsq(tree):
    return jax.tree.map(lambda x: x.reshape((1, 1) + x.shape), tree)


def _compact(slab, cnt, consumed, arrived, arrived_cnt):
    """Drop ``consumed`` leading packets, append ``arrived``; per row.

    slab: (R, W); arrived: (R, A). Returns (slab', cnt').
    """
    R, W = slab.shape
    A = arrived.shape[1]
    idx = jnp.arange(W)[None, :] + consumed[:, None]  # shift left
    shifted = jnp.take_along_axis(
        jnp.concatenate([slab, jnp.zeros_like(slab)], axis=1), idx, axis=1
    )
    left = cnt - consumed  # leftovers
    # insert arrived at position `left` per row
    pos = jnp.arange(W)[None, :] - left[:, None]  # index into arrived
    can = (pos >= 0) & (pos < A) & (pos < arrived_cnt[:, None])
    from_arrived = jnp.take_along_axis(
        arrived, jnp.clip(pos, 0, A - 1), axis=1
    )
    new_slab = jnp.where(can, from_arrived, shifted)
    return new_slab, left + jnp.minimum(arrived_cnt, W - left)


class RegisterGridEngine:
    """Drop-in alternative to GridEngine for the systolic app."""

    engine_kind = "register"

    def __init__(self, R: int, C: int, mesh: Mesh, K: int, m_stream: int,
                 axis_r: str = "gr", axis_c: str = "gc"):
        self.R, self.C = R, C
        self.mesh = mesh
        self.axis_r, self.axis_c = axis_r, axis_c
        self.Dr = mesh.shape[axis_r]
        self.Dc = mesh.shape[axis_c]
        if R % self.Dr or C % self.Dc:
            raise ValueError("grid not divisible by device grid")
        self.Tr, self.Tc = R // self.Dr, C // self.Dc
        self.K = K
        self.W = 2 * K  # ingress slab capacity (credit-bounded)
        self.M = m_stream
        self._spec = P(axis_r, axis_c)
        self._cache: dict = {}
        self._graph_ab: tuple[np.ndarray, np.ndarray] | None = None

    # ------------------------------------------------------- IR entry point
    @classmethod
    def from_graph(
        cls,
        graph: ChannelGraph,
        mesh: Mesh,
        K: int,
        axis_r: str = "gr",
        axis_c: str = "gc",
    ) -> "RegisterGridEngine":
        """Build the register engine from the channel-graph IR.

        This backend is specialized: the kernel fuses the systolic-matmul
        cell semantics, so the IR must describe exactly the §IV-B topology —
        one group of ``SystolicCell`` instances wired as a row-major R×C
        east/south grid with stacked ``SystolicParams``.  The shape is
        *verified* against a freshly generated reference grid IR; anything
        else raises, steering the caller to engine="graph".
        """
        from ..hw.systolic import SystolicCell, SystolicParams

        if len(graph.groups) != 1 or not isinstance(graph.groups[0].block, SystolicCell):
            raise ValueError(
                "engine='register' requires a single-group SystolicCell "
                f"network, got {graph.summary()}"
            )
        grp = graph.groups[0]
        if not isinstance(grp.params, SystolicParams):
            raise ValueError("engine='register' requires stacked SystolicParams")
        is_north = np.asarray(grp.params.is_north).astype(bool)
        C = int(is_north.sum())
        if C == 0 or grp.n_members % C:
            raise ValueError("IR is not a rectangular systolic grid")
        R = grp.n_members // C
        ref = ChannelGraph.grid(
            grp.block, R, C,
            payload_words=graph.payload_words, dtype=graph.dtype,
            capacity=graph.capacity,
        )
        # Compare channel structure up to channel *renumbering*: every
        # channel is identified by its (src instance, dst instance) pair,
        # which is unique in a grid.
        def endpoint_map(g):
            return {
                (int(s), int(d)): cid
                for cid, (s, d) in enumerate(zip(g.chan_src, g.chan_dst))
                if cid >= 2
            }

        ref_map, act_map = endpoint_map(ref), endpoint_map(graph)
        same = (
            not graph.ext_in and not graph.ext_out
            and graph.n_channels == ref.n_channels
            and set(ref_map) == set(act_map)
        )
        if same:
            renum = np.arange(ref.n_channels, dtype=np.int64)
            for pair, rc in ref_map.items():
                renum[rc] = act_map[pair]
            same = np.array_equal(renum[ref.rx_idx[0]], graph.rx_idx[0]) and (
                np.array_equal(renum[ref.tx_idx[0]], graph.tx_idx[0])
            )
        if not same:
            raise ValueError(
                "IR channel table is not the row-major east/south grid the "
                "register backend is specialized for; use engine='graph'"
            )
        a_buf = np.asarray(grp.params.a_buf)  # (R*C, M)
        M = a_buf.shape[-1]
        A = a_buf.reshape(R, C, M)[:, 0, :].T  # west cells stream A[:, r]
        B = np.asarray(grp.params.b).reshape(R, C)
        eng = cls(R, C, mesh, K=K, m_stream=M, axis_r=axis_r, axis_c=axis_c)
        eng._graph_ab = (A, B)
        return eng

    # ------------------------------------------------------------------ init
    def init(self, A: np.ndarray | None = None, B: np.ndarray | None = None) -> RegGridState:
        if A is None and B is None and self._graph_ab is not None:
            A, B = self._graph_ab  # engine came from the IR; operands stacked there
        R, C, M = self.R, self.C, self.M
        Dr, Dc, Tr, Tc = self.Dr, self.Dc, self.Tr, self.Tc
        rr, cc = np.meshgrid(np.arange(R), np.arange(C), indexing="ij")
        a_buf = np.zeros((R, C, M), np.float32)
        a_buf[:, 0, :] = np.asarray(A, np.float32).T

        def tile(x):
            x = jnp.asarray(x)
            return x.reshape((Dr, Tr, Dc, Tc) + x.shape[2:]).transpose(
                (0, 2, 1, 3) + tuple(range(4, x.ndim + 2))
            )

        z = jnp.zeros
        cell = dict(
            b=tile(jnp.asarray(B, jnp.float32)),
            a_reg=z((Dr, Dc, Tr, Tc)), a_v=z((Dr, Dc, Tr, Tc), bool),
            p_reg=z((Dr, Dc, Tr, Tc)), p_v=z((Dr, Dc, Tr, Tc), bool),
            a_idx=z((Dr, Dc, Tr, Tc), jnp.int32),
            y_idx=z((Dr, Dc, Tr, Tc), jnp.int32),
            a_buf=tile(a_buf), y_buf=z((Dr, Dc, Tr, Tc, M)),
            is_west=tile(jnp.asarray(cc == 0)),
            is_north=tile(jnp.asarray(rr == 0)),
            is_south=tile(jnp.asarray(rr == R - 1)),
            is_east=tile(jnp.asarray(cc == C - 1)),
        )
        return RegGridState(
            cell=cell,
            west_slab=z((Dr, Dc, Tr, self.W)), west_cnt=z((Dr, Dc, Tr), jnp.int32),
            north_slab=z((Dr, Dc, Tc, self.W)), north_cnt=z((Dr, Dc, Tc), jnp.int32),
            credit_e=jnp.full((Dr, Dc, Tr), self.W, jnp.int32),
            credit_s=jnp.full((Dr, Dc, Tc), self.W, jnp.int32),
            cycle=z((Dr, Dc), jnp.int32), epoch=z((Dr, Dc), jnp.int32),
        )

    def place(self, state: RegGridState) -> RegGridState:
        sh = NamedSharding(self.mesh, self._spec)
        return jax.tree.map(lambda x: jax.device_put(x, sh), state)

    @property
    def cycles_per_epoch(self) -> int:
        return self.K

    # ----------------------------------------------------------------- epoch
    def _epoch(self, st: RegGridState) -> RegGridState:
        Tr, Tc, K = self.Tr, self.Tc, self.K
        kstate = dict(
            st.cell,
            west_slab=st.west_slab, west_cnt=st.west_cnt,
            north_slab=st.north_slab, north_cnt=st.north_cnt,
            widx=jnp.zeros((Tr,), jnp.int32), nidx=jnp.zeros((Tc,), jnp.int32),
            east_slab=jnp.zeros((Tr, K)), east_cnt=jnp.zeros((Tr,), jnp.int32),
            south_slab=jnp.zeros((Tc, K)), south_cnt=jnp.zeros((Tc,), jnp.int32),
            east_limit=jnp.minimum(st.credit_e, K),
            south_limit=jnp.minimum(st.credit_s, K),
        )
        out = kops.systolic_step(kstate, K)

        Dr, Dc = self.Dr, self.Dc
        perm_e = [(j, j + 1) for j in range(Dc - 1)]
        perm_w = [(j + 1, j) for j in range(Dc - 1)]
        perm_s = [(i, i + 1) for i in range(Dr - 1)]
        perm_n = [(i + 1, i) for i in range(Dr - 1)]

        def pshift(x, axis_name, perm):
            if not perm:
                return jnp.zeros_like(x)
            return jax.lax.ppermute(x, axis_name, perm)

        # emission was credit-bounded inside the kernel; send everything.
        e_cnt = out["east_cnt"]
        s_cnt = out["south_cnt"]
        slab_e_in = pshift(out["east_slab"], self.axis_c, perm_e)
        cnt_e_in = pshift(e_cnt, self.axis_c, perm_e)
        slab_s_in = pshift(out["south_slab"], self.axis_r, perm_s)
        cnt_s_in = pshift(s_cnt, self.axis_r, perm_s)

        west_slab, west_cnt = _compact(
            out["west_slab"], out["west_cnt"], out["widx"], slab_e_in, cnt_e_in
        )
        north_slab, north_cnt = _compact(
            out["north_slab"], out["north_cnt"], out["nidx"], slab_s_in, cnt_s_in
        )
        credit_e = pshift(self.W - west_cnt, self.axis_c, perm_w)
        credit_s = pshift(self.W - north_cnt, self.axis_r, perm_n)

        cell = {k: out[k] for k in st.cell}
        return st.replace(
            cell=cell,
            west_slab=west_slab, west_cnt=west_cnt,
            north_slab=north_slab, north_cnt=north_cnt,
            credit_e=credit_e, credit_s=credit_s,
            cycle=st.cycle + K, epoch=st.epoch + 1,
        )

    # ------------------------------------------------------------------- run
    def epoch_fn(self):
        def run(state):
            return _unsq(self._epoch(_sq(state)))

        return shard_map(run, mesh=self.mesh, in_specs=self._spec,
                         out_specs=self._spec, check_vma=False)

    def run_epochs(
        self, state: RegGridState, n_epochs: int, *, donate: bool = True
    ) -> RegGridState:
        """Advance ``n_epochs`` epochs (K cycles each) — the uniform engine
        entry point the ``Simulation`` session drives.

        ``donate=True`` (default) donates the state into the compiled loop
        (no per-call state copy); the input must not be reused after.
        """
        key = ("epochs", n_epochs, donate)
        if key not in self._cache:
            REGISTRY.inc("register.compile.count")

            def run(state):
                local = _sq(state)
                out = jax.lax.scan(
                    lambda s, _: (self._epoch(s), None), local, None,
                    length=n_epochs,
                )[0]
                return _unsq(out)

            self._cache[key] = jax.jit(
                shard_map(run, mesh=self.mesh, in_specs=self._spec,
                          out_specs=self._spec, check_vma=False),
                donate_argnums=(0,) if donate else (),
            )
        if donate:
            from .distributed import _dealias_for_donation

            state = _dealias_for_donation(state)
        REGISTRY.inc("register.dispatch.count")
        REGISTRY.inc("register.epochs", float(n_epochs))
        return self._cache[key](state)

    def run_until(
        self,
        state: RegGridState,
        done_fn,
        max_epochs: int,
        *,
        cache_key=None,
        donate: bool = True,
    ) -> RegGridState:
        """Run epochs until ``done_fn(cell)`` holds on every granule (the
        predicate sees the granule-local cell dict, leaves (Tr, Tc, ...)),
        or at most ``max_epochs`` MORE epochs from the input state — the
        same relative-budget contract as ``GraphEngine.run_until``.  An
        already-done state runs zero epochs, so chunked (session) callers
        can re-enter."""
        anchor = cache_key if cache_key is not None else done_fn
        key = ("until", id(anchor), max_epochs, donate)
        if key not in self._cache:

            def run(state):
                local = _sq(state)
                e0 = local.epoch

                def pending_of(s):
                    not_done = 1 - done_fn(s.cell).astype(jnp.int32)
                    return jax.lax.psum(
                        jax.lax.psum(not_done, self.axis_r), self.axis_c
                    )

                def cond(carry):
                    s, pending = carry
                    return (pending > 0) & (s.epoch - e0 < max_epochs)

                def body(carry):
                    s, _ = carry
                    s = self._epoch(s)
                    return s, pending_of(s)

                out, _ = jax.lax.while_loop(cond, body, (local, pending_of(local)))
                return _unsq(out)

            self._cache[key] = (
                anchor,  # strong ref: keeps the keyed id alive
                jax.jit(
                    shard_map(run, mesh=self.mesh, in_specs=self._spec,
                              out_specs=self._spec, check_vma=False),
                    donate_argnums=(0,) if donate else (),
                ),
            )
        if donate:
            from .distributed import _dealias_for_donation

            state = _dealias_for_donation(state)
        return self._cache[key][1](state)

    def run_until_done(
        self, state: RegGridState, max_epochs: int, *, donate: bool = True
    ) -> RegGridState:
        """Run epochs until every south cell collected all M outputs."""
        M = self.M
        return self.run_until(
            state,
            lambda cell: ((~cell["is_south"]) | (cell["y_idx"] >= M)).all(),
            max_epochs,
            cache_key="y_done",
            donate=donate,
        )

    # -------------------------------------------------------- host utilities
    def group_state(self, state: RegGridState, inst) -> dict:
        """One cell's (unstacked) state leaves — the uniform probe surface
        (``Simulation.probe``).  ``inst`` is the row-major instance id of
        the cell (or an ``Instance``), matching the IR numbering every
        other engine uses for the same grid."""
        inst_id = inst if isinstance(inst, int) else inst.inst_id
        r, c = divmod(int(inst_id), self.C)
        didx = (r // self.Tr, c // self.Tc)
        lr, lc = r % self.Tr, c % self.Tc
        cell = jax.device_get(state.cell)
        return {
            k: v[didx + (lr, lc)]
            for k, v in cell.items()
            if np.ndim(v) >= 4  # per-cell leaves carry (Dr, Dc, Tr, Tc, ...)
        }

    def result(self, state: RegGridState) -> np.ndarray:
        """Gather Y (M, C) from south-edge cells."""
        Dr, Dc, Tr, Tc = self.Dr, self.Dc, self.Tr, self.Tc
        y = np.asarray(jax.device_get(state.cell["y_buf"]))
        y = y.transpose(0, 2, 1, 3, 4).reshape(self.R, self.C, self.M)
        return y[self.R - 1].transpose(1, 0)  # (M, C)

"""Version-tolerant wrappers over fast-moving JAX APIs.

The repo targets the JAX the container ships; newer call signatures
(``jax.make_mesh(axis_types=...)``, ``jax.shard_map(check_vma=...)``) are
accepted here and degraded gracefully so engines, tests, and benchmarks
share one spelling:

    from repro.core.compat import make_mesh, shard_map

Both helpers are pure call-forwarders — no behavioural shimming beyond
dropping/renaming keywords the installed JAX does not know about.
"""
from __future__ import annotations

import inspect
import os
from typing import Any, Callable, Sequence

import jax

__all__ = ["make_mesh", "shard_map", "tune_cpu_runtime"]


def tune_cpu_runtime() -> None:
    """Disable the XLA:CPU *thunk* runtime for this process (perf, §Perf).

    The thunk runtime this jaxlib ships pays a per-op dispatch cost inside
    compiled while-loops that dwarfs the actual work of cycle-stepped
    simulation (tiny tensors, many ops per cycle): the single-netlist
    engine ran ~4x slower than with the legacy emitter — the
    "compiled backend at 0x speedup" regression in BENCH_PR2.json.
    Measured on ``benchmarks.backend_speedup``: 30.2 -> 7.5 us/cycle.

    Must run before the CPU backend initializes — XLA reads the flags at
    client creation, so if user code ran a jax computation before
    importing ``repro.core`` the mutation is set but has NO effect for
    that process (import ``repro.core`` first, or export the flag in the
    environment).  Called at ``repro.core`` import; a no-op if the user
    already pinned the flag in ``XLA_FLAGS`` (either value).  TPU/GPU
    lowering ignores the flag entirely.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_cpu_use_thunk_runtime" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        flags + " --xla_cpu_use_thunk_runtime=false"
    ).strip()


def _supports_kwarg(fn: Callable, name: str) -> bool:
    try:
        return name in inspect.signature(fn).parameters
    except (TypeError, ValueError):  # builtins / C callables
        return False


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Any = None,
    axis_types: Any = None,
):
    """``jax.make_mesh`` that tolerates JAX versions without ``axis_types``.

    ``axis_types`` (an explicit Auto/Manual marker in newer JAX) is dropped
    when unsupported — older versions treat every axis as Auto, which is the
    only mode this repo uses.
    """
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and _supports_kwarg(jax.make_mesh, "axis_types"):
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f: Callable, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` across JAX versions.

    Newer JAX exposes ``jax.shard_map(..., check_vma=)``; older versions only
    have ``jax.experimental.shard_map.shard_map(..., check_rep=)``.  The
    engines always disable the replication/VMA check: their bodies mix
    per-granule state with collectives in ways the checker rejects.
    """
    impl = getattr(jax, "shard_map", None)
    if impl is not None:
        kwargs: dict[str, Any] = {}
        if _supports_kwarg(impl, "check_vma"):
            kwargs["check_vma"] = False if check_vma is None else check_vma
        return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)

"""Hardware-block protocol (paper §II, Fig. 1).

A *block* is a cycle-stepped state machine whose only connection to the rest
of the system is a set of latency-insensitive ports carrying ready/valid
handshakes.  Mirroring the paper's bridge semantics (§II-A):

  * On each cycle the RX bridge presents the front packet of the inbound
    queue as ``(payload, valid)`` (from the pre-cycle queue snapshot);
    the block answers with ``ready``; ``valid & ready`` pops the queue.
  * The TX bridge presents ``ready = ~full`` (pre-cycle snapshot); the block
    answers with ``(payload, valid)``; ``valid & ready`` pushes.

Because queue snapshots are taken before any block steps, every block in the
network steps from a consistent view and the whole-network cycle is one pure
function — this is the "single-netlist" composition.  Bridges therefore add
exactly one cycle of latency each (N_TX = N_RX = 1), matching the paper's
observation that bridge latency "cannot generally be better than one cycle".

Blocks declare ``in_ports`` / ``out_ports`` (names) and implement
``init_state`` and ``step``.  ``step`` must be vmappable: a network
instantiates a block type many times and steps all instances with one
compiled body (the paper's "prebuilt simulator per unique block").

Heterogeneous model types (paper Fig. 3 — RTL / FPGA / SW / analog) are all
just Blocks with different ``step`` implementations; see ``repro.hw``.
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

import jax

PyTree = Any


class Block:
    """Base class for hardware blocks.

    Subclasses define:
      in_ports:  sequence of input-port names
      out_ports: sequence of output-port names
      payload_words / payload_dtype: packet payload signature
      init_state(key, **inst_params) -> state pytree
      step(state, rx, tx_ready) -> (state, rx_ready, tx)
        rx:       {port: (payload (W,), valid ())} — pre-cycle queue fronts
        tx_ready: {port: ready ()}                — pre-cycle queue fullness
        rx_ready: {port: ready ()}                — pop enables
        tx:       {port: (payload (W,), valid ())} — push requests
    ``clock_divider``: this block's simulated clock runs 1/divider as fast
    as the network base clock (rate control, §II-C) — the block is only
    stepped on cycles where ``cycle % divider == 0``.
    """

    in_ports: Sequence[str] = ()
    out_ports: Sequence[str] = ()
    payload_words: int = 1
    payload_dtype: Any = None  # default float32, set in network
    clock_divider: int = 1

    # -- required overrides -------------------------------------------------
    def init_state(self, key: jax.Array) -> PyTree:
        raise NotImplementedError

    def step(
        self,
        state: PyTree,
        rx: Mapping[str, tuple[jax.Array, jax.Array]],
        tx_ready: Mapping[str, jax.Array],
    ) -> tuple[PyTree, Mapping[str, jax.Array], Mapping[str, tuple[jax.Array, jax.Array]]]:
        raise NotImplementedError

    # -- identity -----------------------------------------------------------
    @property
    def type_name(self) -> str:
        return type(self).__name__

"""Network construction — the ``SbNetwork`` analogue (paper §III-F).

Usage mirrors the paper's Listing 5::

    net = Network(payload_words=2)
    a = net.instantiate(MyBlock(), name="a")
    b = net.instantiate(MyBlock(), name="b")
    net.connect(a["out"], b["in"])          # internal channel
    host_in = net.external_in(a["in"])      # host -> network
    host_out = net.external_out(b["out"])   # network -> host
    sim = net.build()                       # "single-netlist" simulator
    state = sim.init(jax.random.key(0))
    state = sim.run(state, 1000)            # jitted lax.scan over cycles

Key properties carried over from the paper:

  * **One compiled step per unique block type.**  Instances of the same
    ``Block`` object are stacked and stepped with a single ``vmap``-ed body;
    build (trace+compile) cost is O(#unique block types), not O(#instances).
  * **Channels are SPSC queues** with the §III-B ring semantics; bridges add
    one cycle each (N_TX = N_RX = 1).
  * **Rate control** (§II-C): each block type has a ``clock_divider``; a
    block steps only on cycles divisible by its divider, so simulated-clock
    ratios are matched *exactly* (deterministic analogue of the paper's
    sleep-based controller).

``build()`` returns a single-netlist simulator (paper §III-F-2) — the whole
network as one pure ``step`` function, suitable for ``lax.scan`` and used as
the cycle-accurate ground truth for accuracy studies (Fig. 15).  The
distributed epoch-batched engine lives in ``repro.core.distributed``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import queue as qmod
from .block import Block
from .struct import pytree_dataclass, static_field

PyTree = Any


@dataclasses.dataclass(frozen=True)
class PortRef:
    inst_id: int
    port: str
    is_output: bool


@dataclasses.dataclass
class Instance:
    inst_id: int
    block: Block
    name: str
    params: PyTree  # per-instance parameters (un-stacked pytree) or None

    def __getitem__(self, port: str) -> PortRef:
        if port in self.block.out_ports:
            return PortRef(self.inst_id, port, True)
        if port in self.block.in_ports:
            return PortRef(self.inst_id, port, False)
        raise KeyError(f"{self.block.type_name} has no port {port!r}")


@pytree_dataclass
class NetworkState:
    queues: qmod.QueueArray
    block_states: tuple[PyTree, ...]  # stacked per block group
    cycle: jax.Array  # () int32
    push_count: jax.Array  # (n_channels,) int32 — handshakes, for perf stats
    pop_count: jax.Array  # (n_channels,) int32


class Network:
    """Builder: instantiate blocks, wire channels, produce a simulator."""

    def __init__(
        self,
        payload_words: int = 2,
        dtype: Any = jnp.float32,
        capacity: int = qmod.DEFAULT_CAPACITY,
    ):
        self.payload_words = payload_words
        self.dtype = dtype
        self.capacity = capacity
        self._instances: list[Instance] = []
        self._connections: list[tuple[PortRef, PortRef]] = []
        self._external_in: dict[str, PortRef] = {}
        self._external_out: dict[str, PortRef] = {}

    # -- construction API ---------------------------------------------------
    def instantiate(self, block: Block, name: str | None = None, params: PyTree = None) -> Instance:
        inst = Instance(len(self._instances), block, name or f"i{len(self._instances)}", params)
        self._instances.append(inst)
        return inst

    def connect(self, tx: PortRef, rx: PortRef) -> None:
        if not tx.is_output or rx.is_output:
            raise ValueError("connect(tx, rx) needs an output then an input port")
        self._connections.append((tx, rx))

    def external_in(self, rx: PortRef, name: str | None = None) -> str:
        """Expose an input port to the host; returns the external-port name."""
        name = name or f"ext_in{len(self._external_in)}"
        self._external_in[name] = rx
        return name

    def external_out(self, tx: PortRef, name: str | None = None) -> str:
        name = name or f"ext_out{len(self._external_out)}"
        self._external_out[name] = tx
        return name

    # -- build ---------------------------------------------------------------
    def build(self) -> "NetworkSim":
        return NetworkSim(self)


class NetworkSim:
    """Single-netlist simulator for a built Network.

    The step function is pure; ``run`` wraps it in ``jax.jit(lax.scan)``.
    """

    def __init__(self, net: Network):
        self.net = net
        insts = net._instances

        # Group instances by block object identity (one group per unique
        # "prebuilt simulator").
        groups: dict[int, list[Instance]] = {}
        order: list[int] = []
        for inst in insts:
            key = id(inst.block)
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(inst)
        self.groups: list[list[Instance]] = [groups[k] for k in order]
        self.group_blocks: list[Block] = [g[0].block for g in self.groups]

        # Channel table. Two sentinel channels:
        #   0: NULL_RX — never written, reads always invalid.
        #   1: NULL_TX — auto-drained every cycle, writes always ready.
        self.NULL_RX, self.NULL_TX = 0, 1
        n_channels = 2
        chan_of_tx: dict[tuple[int, str], int] = {}
        chan_of_rx: dict[tuple[int, str], int] = {}
        for tx, rx in net._connections:
            cid = n_channels
            n_channels += 1
            if (tx.inst_id, tx.port) in chan_of_tx:
                raise ValueError(f"output port {tx} connected twice (SPSC)")
            if (rx.inst_id, rx.port) in chan_of_rx:
                raise ValueError(f"input port {rx} connected twice (SPSC)")
            chan_of_tx[(tx.inst_id, tx.port)] = cid
            chan_of_rx[(rx.inst_id, rx.port)] = cid
        self.ext_in_chan: dict[str, int] = {}
        for name, rx in net._external_in.items():
            cid = n_channels
            n_channels += 1
            chan_of_rx[(rx.inst_id, rx.port)] = cid
            self.ext_in_chan[name] = cid
        self.ext_out_chan: dict[str, int] = {}
        for name, tx in net._external_out.items():
            cid = n_channels
            n_channels += 1
            chan_of_tx[(tx.inst_id, tx.port)] = cid
            self.ext_out_chan[name] = cid
        self.n_channels = n_channels

        # Per-group port->channel index arrays.
        self.rx_idx: list[np.ndarray] = []  # (n_inst, n_in)
        self.tx_idx: list[np.ndarray] = []  # (n_inst, n_out)
        for g in self.groups:
            blk = g[0].block
            rxm = np.full((len(g), len(blk.in_ports)), self.NULL_RX, np.int32)
            txm = np.full((len(g), len(blk.out_ports)), self.NULL_TX, np.int32)
            for i, inst in enumerate(g):
                for p, port in enumerate(blk.in_ports):
                    rxm[i, p] = chan_of_rx.get((inst.inst_id, port), self.NULL_RX)
                for p, port in enumerate(blk.out_ports):
                    txm[i, p] = chan_of_tx.get((inst.inst_id, port), self.NULL_TX)
            self.rx_idx.append(rxm)
            self.tx_idx.append(txm)

    # -- state ---------------------------------------------------------------
    def init(self, key: jax.Array) -> NetworkState:
        states = []
        for g, blk in zip(self.groups, self.group_blocks):
            keys = jax.random.split(jax.random.fold_in(key, id(blk) % (2**31)), len(g))
            if any(inst.params is not None for inst in g):
                params = jax.tree.map(lambda *xs: jnp.stack(xs), *[inst.params for inst in g])
                st = jax.vmap(blk.init_state)(keys, params)
            else:
                st = jax.vmap(blk.init_state)(keys)
            states.append(st)
        queues = qmod.make_queues(
            self.n_channels, self.net.payload_words, self.net.capacity, self.net.dtype
        )
        zero = jnp.zeros((self.n_channels,), jnp.int32)
        return NetworkState(
            queues=queues,
            block_states=tuple(states),
            cycle=jnp.zeros((), jnp.int32),
            push_count=zero,
            pop_count=zero,
        )

    # -- one network cycle ----------------------------------------------------
    def step(self, state: NetworkState) -> NetworkState:
        q = state.queues
        fronts, valids = qmod.peek(q)  # (N,W), (N,)
        readies = ~qmod.full(q)  # (N,)
        # Sentinels: NULL_RX never valid; NULL_TX always ready.
        valids = valids.at[self.NULL_RX].set(False)
        readies = readies.at[self.NULL_TX].set(True)

        push_payload = jnp.zeros((self.n_channels, self.net.payload_words), self.net.dtype)
        push_valid = jnp.zeros((self.n_channels,), bool)
        pop_ready = jnp.zeros((self.n_channels,), bool)

        new_states = []
        for gi, (g, blk) in enumerate(zip(self.groups, self.group_blocks)):
            rxm, txm = self.rx_idx[gi], self.tx_idx[gi]
            rx = {
                port: (fronts[rxm[:, p]], valids[rxm[:, p]])
                for p, port in enumerate(blk.in_ports)
            }
            tx_ready = {port: readies[txm[:, p]] for p, port in enumerate(blk.out_ports)}
            st = state.block_states[gi]
            new_st, rx_ready, tx = jax.vmap(blk.step)(st, rx, tx_ready)

            if blk.clock_divider > 1:
                en = (state.cycle % blk.clock_divider) == 0
                new_st = jax.tree.map(lambda n, o: jnp.where(en, n, o), new_st, st)
                rx_ready = {k: v & en for k, v in rx_ready.items()}
                tx = {k: (p, v & en) for k, (p, v) in tx.items()}
            new_states.append(new_st)

            for p, port in enumerate(blk.in_ports):
                pop_ready = pop_ready.at[rxm[:, p]].max(rx_ready[port])
            for p, port in enumerate(blk.out_ports):
                pay, val = tx[port]
                push_payload = push_payload.at[txm[:, p]].set(
                    pay.astype(self.net.dtype), mode="drop"
                )
                push_valid = push_valid.at[txm[:, p]].max(val)

        # Sentinel writes are dropped: never push to NULL_TX's storage, and
        # NULL_RX is never popped.
        push_valid = push_valid.at[self.NULL_TX].set(False)
        pop_ready = pop_ready.at[self.NULL_RX].set(False)

        q2, did_push, did_pop = qmod.cycle(q, push_payload, push_valid, pop_ready)
        return NetworkState(
            queues=q2,
            block_states=tuple(new_states),
            cycle=state.cycle + 1,
            push_count=state.push_count + did_push.astype(jnp.int32),
            pop_count=state.pop_count + did_pop.astype(jnp.int32),
        )

    def run(self, state: NetworkState, n_cycles: int) -> NetworkState:
        """Advance ``n_cycles`` with a jitted scan."""
        return _run_scan(self, state, n_cycles)

    # -- host-side external port access (PySbTx / PySbRx analogue) -----------
    def push_external(self, state: NetworkState, name: str, payload) -> tuple[NetworkState, jax.Array]:
        cid = self.ext_in_chan[name]
        q = state.queues
        pp = jnp.zeros((self.n_channels, self.net.payload_words), self.net.dtype)
        pp = pp.at[cid].set(jnp.asarray(payload, self.net.dtype))
        pv = jnp.zeros((self.n_channels,), bool).at[cid].set(True)
        pr = jnp.zeros((self.n_channels,), bool)
        q2, did_push, _ = qmod.cycle(q, pp, pv, pr)
        return state.replace(queues=q2), did_push[cid]

    def pop_external(self, state: NetworkState, name: str):
        cid = self.ext_out_chan[name]
        q = state.queues
        fronts, valids = qmod.peek(q)
        pr = jnp.zeros((self.n_channels,), bool).at[cid].set(True)
        pp = jnp.zeros((self.n_channels, self.net.payload_words), self.net.dtype)
        pv = jnp.zeros((self.n_channels,), bool)
        q2, _, did_pop = qmod.cycle(q, pp, pv, pr)
        return state.replace(queues=q2), fronts[cid], did_pop[cid]

    def group_state(self, state: NetworkState, inst: Instance):
        """Extract one instance's (unstacked) state from the network state."""
        for gi, g in enumerate(self.groups):
            for i, cand in enumerate(g):
                if cand.inst_id == inst.inst_id:
                    return jax.tree.map(lambda x: x[i], state.block_states[gi])
        raise KeyError(inst.name)


def _run_scan_impl(sim: NetworkSim, state: NetworkState, n_cycles: int) -> NetworkState:
    def body(st, _):
        return sim.step(st), None

    out, _ = jax.lax.scan(body, state, None, length=n_cycles)
    return out


_jitted_cache: dict[tuple[int, int], Callable] = {}


def _run_scan(sim: NetworkSim, state: NetworkState, n_cycles: int) -> NetworkState:
    key = (id(sim), n_cycles)
    if key not in _jitted_cache:
        _jitted_cache[key] = jax.jit(lambda st: _run_scan_impl(sim, st, n_cycles))
    return _jitted_cache[key](state)

"""Network construction — the ``SbNetwork`` analogue (paper §III-F).

Usage mirrors the paper's Listing 5::

    net = Network(payload_words=2)
    a = net.instantiate(MyBlock(), name="a")
    b = net.instantiate(MyBlock(), name="b")
    net.connect(a["out"], b["in"])          # internal channel
    host_in = net.external_in(a["in"])      # host -> network
    host_out = net.external_out(b["out"])   # network -> host
    sim = net.build()                       # Simulation session (single engine)
    sim.reset(jax.random.key(0))
    sim.tx(host_in).send([1.0, 0.0])        # host queue handles (PySbTx/PySbRx)
    sim.run(cycles=1000)                    # session owns + donates the state
    print(sim.rx(host_out).recv())

Key properties carried over from the paper:

  * **One compiled step per unique block type.**  Instances of the same
    ``Block`` object are stacked and stepped with a single ``vmap``-ed body;
    build (trace+compile) cost is O(#unique block types), not O(#instances).
  * **Channels are SPSC queues** with the §III-B ring semantics; bridges add
    one cycle each (N_TX = N_RX = 1).
  * **Rate control** (§II-C): each block type has a ``clock_divider``; a
    block steps only on cycles divisible by its divider, so simulated-clock
    ratios are matched *exactly* (deterministic analogue of the paper's
    sleep-based controller).

The builder lowers to the **channel-graph IR** (``repro.core.graph``), and
``build(engine=...)`` hands that IR to any backend (DESIGN.md §1, §4):

    sim = net.build()                          # single-netlist session
    sim = net.build(engine="graph",            # distributed GraphEngine
                    mesh=mesh, partition=part, K=8)
    sim = net.build(engine="register", ...)    # kernel-fused fast backend

Every variant returns a ``session.Simulation`` facade with ONE lifecycle
(``reset`` / ``run`` / ``probe`` / ``tx`` / ``rx`` / ``save`` / ``load``)
regardless of the engine; the raw engine stays reachable as
``sim.engine`` (or ``build(..., session=False)``), and the legacy
``init(key)``/``run(state, n)``/``push_external`` surface keeps working
through deprecation shims on the facade.

``NetworkSim`` (engine="single") interprets the whole IR as one pure
``step`` function, suitable for ``lax.scan`` and used as the cycle-accurate
ground truth for accuracy studies (Fig. 15).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import queue as qmod
from ..obs.registry import REGISTRY
from .block import Block
from .graph import ChannelGraph
from .struct import pytree_dataclass, static_field

PyTree = Any


@dataclasses.dataclass(frozen=True)
class PortRef:
    inst_id: int
    port: str
    is_output: bool


@dataclasses.dataclass
class Instance:
    inst_id: int
    block: Block
    name: str
    params: PyTree  # per-instance parameters (un-stacked pytree) or None

    def __getitem__(self, port: str) -> PortRef:
        if port in self.block.out_ports:
            return PortRef(self.inst_id, port, True)
        if port in self.block.in_ports:
            return PortRef(self.inst_id, port, False)
        raise KeyError(f"{self.block.type_name} has no port {port!r}")


@pytree_dataclass
class NetworkState:
    queues: qmod.QueueArray
    block_states: tuple[PyTree, ...]  # stacked per block group
    cycle: jax.Array  # () int32
    push_count: jax.Array  # (n_channels,) int32 — handshakes, for perf stats
    pop_count: jax.Array  # (n_channels,) int32


class Network:
    """Builder: instantiate blocks, wire channels, produce a simulator."""

    def __init__(
        self,
        payload_words: int = 2,
        dtype: Any = jnp.float32,
        capacity: int = qmod.DEFAULT_CAPACITY,
    ):
        self.payload_words = payload_words
        self.dtype = dtype
        self.capacity = capacity
        self._instances: list[Instance] = []
        self._connections: list[tuple[PortRef, PortRef]] = []
        self._external_in: dict[str, PortRef] = {}
        self._external_out: dict[str, PortRef] = {}

    # -- construction API ---------------------------------------------------
    def instantiate(self, block: Block, name: str | None = None, params: PyTree = None) -> Instance:
        inst = Instance(len(self._instances), block, name or f"i{len(self._instances)}", params)
        self._instances.append(inst)
        return inst

    def connect(self, tx: PortRef, rx: PortRef) -> None:
        if not tx.is_output or rx.is_output:
            raise ValueError("connect(tx, rx) needs an output then an input port")
        self._connections.append((tx, rx))

    def external_in(self, rx: PortRef, name: str | None = None) -> str:
        """Expose an input port to the host; returns the external-port name."""
        name = name or f"ext_in{len(self._external_in)}"
        self._external_in[name] = rx
        return name

    def external_out(self, tx: PortRef, name: str | None = None) -> str:
        name = name or f"ext_out{len(self._external_out)}"
        self._external_out[name] = tx
        return name

    # -- lowering ------------------------------------------------------------
    def graph(self) -> ChannelGraph:
        """Lower the builder state to the engine-agnostic channel-graph IR."""
        return ChannelGraph.from_network(self)

    def build(self, engine: str = "single", session: bool = True, **kw):
        """Lower to the IR and construct the selected backend (DESIGN.md §4).

        Returns a ``session.Simulation`` facade over the engine (the
        uniform ``reset``/``run``/``probe``/``tx``/``rx``/``save``/``load``
        lifecycle); pass ``session=False`` for the raw engine object.

        engine="single"    -> NetworkSim (this module); no extra kwargs.
        engine="graph"     -> distributed.GraphEngine; kwargs: mesh, K,
                              partition (instance->granule map or a
                              graph.PartitionTree), axes, tiers (per-tier
                              (axes, K) pairs or graph.Tier, outermost
                              first — hierarchical sync, DESIGN.md §3),
                              batch_axes (signature-batched stepping:
                              axis names, or {name: size} for batch-only
                              axes off the mesh — DESIGN.md §Perf),
                              overlap (split issue/commit exchange —
                              bit-identical pipelining of tier transfers
                              with compute; "auto"/bool, REPRO_OVERLAP
                              env override — DESIGN.md §Perf).
        engine="fused"     -> fused.FusedEngine — the kernel-fused fast
                              path for arbitrary topologies (§Perf):
                              same kwargs as "graph" plus fuse /
                              pallas_interpret (epoch-body strategy).
        engine="register"  -> fastgrid.RegisterGridEngine (systolic-grid
                              networks only); kwargs: mesh, K.
        engine="procs"     -> runtime.launcher.ProcsEngine — the free-
                              running multiprocess runtime (DESIGN.md
                              §Runtime): one prebuilt granule simulator
                              per OS process over shared-memory queues,
                              no mesh needed; kwargs: partition (flat map
                              or PartitionTree), n_workers, K, ring_depth,
                              timeout, prebuild, cache_dir, log_dir,
                              batch_signatures, overlap (send-early/
                              receive-late worker exchanges), on_fault
                              ("raise"|"recover" self-healing policy,
                              REPRO_ON_FAULT env override), snapshot_every,
                              max_restarts, backoff_s, fault_plan
                              (deterministic drills, REPRO_FAULT_PLAN),
                              hosts (multi-host fleet: granule->host
                              placement, DESIGN.md §Multi-host fleet;
                              REPRO_HOSTS env), host (which host this
                              launcher serves), base_port
                              (REPRO_BRIDGE_PORT; 0 = ephemeral).

        (The uniform-grid presets ``distributed.GridEngine`` and
        ``fused.FusedEngine.grid`` are constructed directly — they build
        their own grid IR without a Network.)
        """
        graph = self.graph()
        eng = self._build_engine(graph, engine, kw)
        if session:
            from .session import Simulation

            return Simulation(eng)
        return eng

    def _build_engine(self, graph: ChannelGraph, engine: str, kw: dict):
        if engine == "single":
            if kw:
                raise TypeError(f"engine='single' takes no kwargs, got {sorted(kw)}")
            return NetworkSim(graph)
        if engine in ("graph", "fused"):
            if engine == "graph":
                from .distributed import GraphEngine as Engine

                extra = {}
            else:
                from .fused import FusedEngine as Engine

                extra = {
                    k: kw.pop(k)
                    for k in ("fuse", "pallas_interpret")
                    if k in kw
                }
            mesh = kw.pop("mesh")
            K = kw.pop("K", 1)
            tiers = kw.pop("tiers", None)
            axes = kw.pop("axes", None)  # engine defaults to mesh.axis_names
            partition = kw.pop("partition", None)
            if "batch_axes" in kw:  # signature-batched stepping (§Perf)
                extra["batch_axes"] = kw.pop("batch_axes")
            if "overlap" in kw:  # split issue/commit exchange (ISSUE 7)
                extra["overlap"] = kw.pop("overlap")
            if kw:
                raise TypeError(
                    f"unknown build kwargs for engine={engine!r}: {sorted(kw)}"
                )
            return Engine(
                graph, partition, mesh, K=K, axes=axes, tiers=tiers, **extra
            )
        if engine == "register":
            from .fastgrid import RegisterGridEngine

            return RegisterGridEngine.from_graph(graph, **kw)
        if engine == "procs":
            from ..runtime.launcher import ProcsEngine

            return ProcsEngine(graph, kw.pop("partition", None), **kw)
        raise ValueError(
            f"unknown engine {engine!r} "
            "(single | graph | fused | register | procs)"
        )


class NetworkSim:
    """Single-netlist simulator: a thin interpreter of the channel-graph IR.

    The step function is pure; ``run`` wraps it in ``jax.jit(lax.scan)``.
    """

    engine_kind = "single"
    cycles_per_epoch = 1  # host-sync granularity: every cycle is a boundary

    def __init__(self, graph: ChannelGraph):
        self.graph = graph
        self.group_blocks: list[Block] = [g.block for g in graph.groups]
        self.NULL_RX, self.NULL_TX = graph.NULL_RX, graph.NULL_TX
        self.n_channels = graph.n_channels
        self.rx_idx = graph.rx_idx
        self.tx_idx = graph.tx_idx
        self.ext_in_chan = graph.ext_in
        self.ext_out_chan = graph.ext_out
        self.payload_words = graph.payload_words
        self.dtype = graph.dtype
        self.capacity = graph.capacity
        # Compiled-run cache lives on the instance (keyed by n_cycles and
        # donation), so a collected simulator releases its executables and a
        # recycled id can never alias a stale compilation.
        self._jit_cache: dict[tuple[int, bool], Callable] = {}

    # -- state ---------------------------------------------------------------
    def init(self, key: jax.Array) -> NetworkState:
        states = []
        for gi, (g, blk) in enumerate(zip(self.graph.groups, self.group_blocks)):
            # Fold in the group *index* (deterministic, identical across
            # engine backends and process runs) — never id(blk), which is
            # allocation-dependent and would break cross-engine bit-equality
            # for blocks whose init_state consumes the key.
            keys = jax.random.split(jax.random.fold_in(key, gi), g.n_members)
            if g.params is not None:
                params = jax.tree.map(jnp.asarray, g.params)
                st = jax.vmap(blk.init_state)(keys, params)
            else:
                st = jax.vmap(blk.init_state)(keys)
            states.append(st)
        queues = qmod.make_queues(
            self.n_channels, self.payload_words, self.capacity, self.dtype
        )
        # distinct buffers (not one shared `zero`): donation-friendly
        return NetworkState(
            queues=queues,
            block_states=tuple(states),
            cycle=jnp.zeros((), jnp.int32),
            push_count=jnp.zeros((self.n_channels,), jnp.int32),
            pop_count=jnp.zeros((self.n_channels,), jnp.int32),
        )

    # -- one network cycle ----------------------------------------------------
    def step(self, state: NetworkState) -> NetworkState:
        q = state.queues
        fronts, valids = qmod.peek(q)  # (N,W), (N,)
        readies = ~qmod.full(q)  # (N,)
        # Sentinels: NULL_RX never valid; NULL_TX always ready.
        valids = valids.at[self.NULL_RX].set(False)
        readies = readies.at[self.NULL_TX].set(True)

        push_payload = jnp.zeros((self.n_channels, self.payload_words), self.dtype)
        push_valid = jnp.zeros((self.n_channels,), bool)
        pop_ready = jnp.zeros((self.n_channels,), bool)

        new_states = []
        for gi, blk in enumerate(self.group_blocks):
            rxm, txm = self.rx_idx[gi], self.tx_idx[gi]
            rx = {
                port: (fronts[rxm[:, p]], valids[rxm[:, p]])
                for p, port in enumerate(blk.in_ports)
            }
            tx_ready = {port: readies[txm[:, p]] for p, port in enumerate(blk.out_ports)}
            st = state.block_states[gi]
            new_st, rx_ready, tx = jax.vmap(blk.step)(st, rx, tx_ready)

            if blk.clock_divider > 1:
                en = (state.cycle % blk.clock_divider) == 0
                new_st = jax.tree.map(lambda n, o: jnp.where(en, n, o), new_st, st)
                rx_ready = {k: v & en for k, v in rx_ready.items()}
                tx = {k: (p, v & en) for k, (p, v) in tx.items()}
            new_states.append(new_st)

            for p, port in enumerate(blk.in_ports):
                pop_ready = pop_ready.at[rxm[:, p]].max(rx_ready[port])
            for p, port in enumerate(blk.out_ports):
                pay, val = tx[port]
                push_payload = push_payload.at[txm[:, p]].set(
                    pay.astype(self.dtype), mode="drop"
                )
                push_valid = push_valid.at[txm[:, p]].max(val)

        # Sentinel writes are dropped: never push to NULL_TX's storage, and
        # NULL_RX is never popped.
        push_valid = push_valid.at[self.NULL_TX].set(False)
        pop_ready = pop_ready.at[self.NULL_RX].set(False)

        q2, did_push, did_pop = qmod.cycle(q, push_payload, push_valid, pop_ready)
        return NetworkState(
            queues=q2,
            block_states=tuple(new_states),
            cycle=state.cycle + 1,
            push_count=state.push_count + did_push.astype(jnp.int32),
            pop_count=state.pop_count + did_pop.astype(jnp.int32),
        )

    def run(
        self, state: NetworkState, n_cycles: int, *, donate: bool = False
    ) -> NetworkState:
        """Advance ``n_cycles`` with a jitted scan (compiled once per length).

        ``donate=True`` reuses the input state's buffers for the output
        (no copy through HBM); the input must not be used afterwards.
        """
        key = (n_cycles, donate)
        if key not in self._jit_cache:
            REGISTRY.inc("single.compile.count")

            def impl(st):
                return jax.lax.scan(
                    lambda s, _: (self.step(s), None), st, None, length=n_cycles
                )[0]

            self._jit_cache[key] = jax.jit(
                impl, donate_argnums=(0,) if donate else ()
            )
        if donate:
            from .distributed import _dealias_for_donation

            state = _dealias_for_donation(state)
        REGISTRY.inc("single.dispatch.count")
        REGISTRY.inc("single.cycles", float(n_cycles))
        return self._jit_cache[key](state)

    def run_until(
        self,
        state: NetworkState,
        done_fn: Callable[[NetworkState], jax.Array],
        max_cycles: int,
        *,
        cache_key: Any = None,
        donate: bool = True,
    ) -> NetworkState:
        """Step until ``done_fn(state)`` holds, or at most ``max_cycles``
        MORE cycles from the input state (a relative budget, mirroring the
        engines' relative ``max_epochs`` — the compiled loop is reusable
        from any starting cycle).  An already-done state runs zero cycles,
        so chunked callers (the session's monitor cadence) can re-enter
        safely.  Donation defaults on, matching ``GraphEngine.run_until``
        (uniform engine protocol — ``run`` keeps its legacy donate=False).
        Cache keying follows ``GraphEngine.run_until``: pass ``cache_key``
        when the predicate is a fresh lambda per call."""
        anchor = cache_key if cache_key is not None else done_fn
        key = ("until", id(anchor), max_cycles, donate)
        if key not in self._jit_cache:

            def impl(st):
                c0 = st.cycle

                def cond(carry):
                    s, pending = carry
                    return (pending > 0) & (s.cycle - c0 < max_cycles)

                def body(carry):
                    s, _ = carry
                    s = self.step(s)
                    return s, 1 - done_fn(s).astype(jnp.int32)

                pending0 = 1 - done_fn(st).astype(jnp.int32)
                return jax.lax.while_loop(cond, body, (st, pending0))[0]

            self._jit_cache[key] = (
                anchor,  # strong ref: keeps the keyed id alive
                jax.jit(impl, donate_argnums=(0,) if donate else ()),
            )
        if donate:
            from .distributed import _dealias_for_donation

            state = _dealias_for_donation(state)
        return self._jit_cache[key][1](state)

    # -- host-side external port access (PySbTx / PySbRx analogue) -----------
    # ``host_push``/``host_pop`` (+ batched ``_many``) are the engine-level
    # primitives the session Tx/Rx ports drive; the historical
    # ``push_external``/``pop_external`` names remain as deprecation shims.
    def host_push(self, state: NetworkState, name: str, payload) -> tuple[NetworkState, jax.Array]:
        q2, ok = qmod.host_push(
            state.queues, self.ext_in_chan[name],
            jnp.asarray(payload, self.dtype),
        )
        return state.replace(queues=q2), ok

    def host_pop(self, state: NetworkState, name: str):
        q2, front, valid = qmod.host_pop(state.queues, self.ext_out_chan[name])
        return state.replace(queues=q2), front, valid

    def host_push_many(self, state: NetworkState, name: str, payloads):
        """Batched push: up to ``free`` packets land, the rest are refused
        (count returned).  payloads: (k, W)."""
        payloads = jnp.asarray(payloads, self.dtype).reshape(-1, self.payload_words)
        q2, n = qmod.host_push_many(
            state.queues, self.ext_in_chan[name], payloads
        )
        return state.replace(queues=q2), n

    def host_pop_many(self, state: NetworkState, name: str, max_n: int):
        """Batched pop: returns (state, payloads (max_n, W), count)."""
        q2, pays, cnt = qmod.host_pop_many(
            state.queues, self.ext_out_chan[name], max_n
        )
        return state.replace(queues=q2), pays, cnt

    def push_external(self, state: NetworkState, name: str, payload):
        warnings.warn(
            "push_external is deprecated; use the Simulation session's "
            "tx(name).send(...) (or engine.host_push)",
            DeprecationWarning, stacklevel=2,
        )
        return self.host_push(state, name, payload)

    def pop_external(self, state: NetworkState, name: str):
        warnings.warn(
            "pop_external is deprecated; use the Simulation session's "
            "rx(name).recv() (or engine.host_pop)",
            DeprecationWarning, stacklevel=2,
        )
        return self.host_pop(state, name)

    def group_state(self, state: NetworkState, inst: Instance | int):
        """Extract one instance's (unstacked) state from the network state."""
        inst_id = inst if isinstance(inst, int) else inst.inst_id
        gi, slot = self.graph.locate(inst_id)
        return jax.tree.map(lambda x: x[slot], state.block_states[gi])

    def port_stats(self, state: NetworkState) -> dict:
        """Per external port: live queue occupancy + remaining credit —
        the uniform ``Simulation.stats()["ports"]`` schema (one shape on
        every engine, shm-backed or in-process).  Nested by direction so
        a name serving BOTH directions reports each channel's own queue."""
        import numpy as np

        q = state.queues
        size = np.asarray(jax.device_get((q.head - q.tail) % q.capacity))

        def rec(cid):
            return {"occupancy": int(size[cid]),
                    "credit": int(q.capacity - 1 - size[cid])}

        return {
            "tx": {n: rec(c) for n, c in self.graph.ext_in.items()},
            "rx": {n: rec(c) for n, c in self.graph.ext_out.items()},
        }

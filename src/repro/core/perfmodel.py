"""Performance-measurement model (paper §II-C) and rate control.

The paper measures performance *approximately* in an unsynchronized modular
simulation by (a) matching wall-clock rate ratios to simulated-clock rate
ratios, and (b) keeping wall rates low enough that inter-simulator latency
T_comm is negligible:

    N_meas = N * (F_A_wall / F_B_wall)
           + 2 * T_comm * F_A_wall
           + (N_RX + N_TX) * (1 + F_A_wall / F_B_wall)

In our bulk-synchronous adaptation, rate control is **deterministic**: block
i is stepped on cycles divisible by ``divider_i``, so
``F_i_sim = F_base / divider_i`` holds *exactly* (the paper's sleep-based
controller only achieves this in expectation).  The T_comm nonideality maps
to the epoch length K: a packet crossing a granule boundary waits up to K
cycles, so for a round trip ``T_comm ≈ K / F_wall`` and the error term
``2*T_comm*F_A_wall`` becomes ``≈ 2*K`` cycles per boundary crossing — a
*bound*, not a distribution.  ``benchmarks/accuracy_vs_rate.py`` sweeps K to
reproduce Fig. 15.
"""
from __future__ import annotations

import math
from typing import Sequence


def n_meas_ideal(n_cycles: float, f_a_sim: float, f_b_sim: float) -> float:
    """Ideal measured processing delay (cycles of A's clock)."""
    return n_cycles * f_a_sim / f_b_sim


def n_meas_actual(
    n_cycles: float,
    f_a_wall: float,
    f_b_wall: float,
    t_comm: float,
    n_rx: int = 1,
    n_tx: int = 1,
) -> float:
    """Paper §II-C equation for the *observed* processing delay."""
    ratio = f_a_wall / f_b_wall
    return n_cycles * ratio + 2.0 * t_comm * f_a_wall + (n_rx + n_tx) * (1.0 + ratio)


def max_wall_rate(n_meas_ideal_cycles: float, t_comm: float, rel_err: float = 0.05) -> float:
    """Largest F_A_wall for which the T_comm term stays under ``rel_err``.

    From F_A_wall << N_ideal / (2*T_comm): we return the rate at which the
    communication term equals ``rel_err * N_ideal``.
    """
    return rel_err * n_meas_ideal_cycles / (2.0 * t_comm)


def bsp_error_bound(k_epoch: int, boundary_crossings: int, n_ideal_cycles: float) -> float:
    """Deterministic relative-error bound for epoch-batched simulation.

    Each granule-boundary crossing on the measured path adds at most
    ``k_epoch`` cycles of waiting (the packet arrives just after an
    exchange); backpressure can reflect it once more, hence the factor 2
    (the paper's 2*T_comm term).
    """
    return 2.0 * k_epoch * boundary_crossings / max(n_ideal_cycles, 1.0)


# -- tiered (hierarchical-partition) accounting, DESIGN.md §3/§5 -------------

def tier_periods(k_tiers: Sequence[int]) -> list[int]:
    """Cycles between tier-t synchronizations for a nested epoch schedule.

    ``k_tiers`` lists per-tier rates outermost first (matching
    ``graph.Tier``): the innermost rate is local cycles per innermost
    round, each outer rate is sub-rounds per round.  Tier t's boundary
    channels are exchanged every ``prod(k_tiers[t:])`` cycles — its T_comm.
    """
    periods, acc = [], 1
    for k in reversed(list(k_tiers)):
        acc *= int(k)
        periods.append(acc)
    return list(reversed(periods))


def tiered_comm_cycles(
    k_tiers: Sequence[int], crossings_per_tier: Sequence[int]
) -> float:
    """Total communication-nonideality cycles on a measured path.

    A tier-t crossing waits up to ``period_t`` cycles for its exchange and
    backpressure can reflect it once (the paper's 2*T_comm term), so each
    contributes ``<= 2 * period_t`` cycles.
    """
    periods = tier_periods(k_tiers)
    if len(crossings_per_tier) != len(periods):
        raise ValueError(
            f"{len(periods)} tiers but {len(crossings_per_tier)} crossing counts"
        )
    return sum(2.0 * p * x for p, x in zip(periods, crossings_per_tier))


def n_meas_actual_tiered(
    n_cycles: float,
    f_a_wall: float,
    f_b_wall: float,
    k_tiers: Sequence[int],
    crossings_per_tier: Sequence[int],
    n_rx: int = 1,
    n_tx: int = 1,
) -> float:
    """§II-C observed delay with the T_comm term split per partition tier.

    The flat model folds all boundary latency into one ``2*T_comm*F_wall``
    term; under a hierarchical partition a path may cross both fast (ICI)
    and slow (DCI) tiers, and the slow tier's longer sync period dominates.
    Feeding the per-tier sum through the same equation keeps the flat
    single-tier case identical to ``n_meas_actual``.
    """
    ratio = f_a_wall / f_b_wall
    comm = tiered_comm_cycles(k_tiers, crossings_per_tier)
    return n_cycles * ratio + comm + (n_rx + n_tx) * (1.0 + ratio)


def bsp_error_bound_tiered(
    k_tiers: Sequence[int],
    crossings_per_tier: Sequence[int],
    n_ideal_cycles: float,
) -> float:
    """Per-tier generalization of ``bsp_error_bound``: each tier-t crossing
    adds at most ``2 * period_t`` cycles.  Reduces to the flat bound for a
    single tier."""
    return tiered_comm_cycles(k_tiers, crossings_per_tier) / max(
        n_ideal_cycles, 1.0
    )


# -- signature-batched dispatch accounting (ISSUE 6, DESIGN.md §Perf) --------

def batched_epoch_time(
    batch: int, t_step: float, t_dispatch: float, pad_factor: float = 1.0
) -> float:
    """Wall time for ONE vmapped dispatch stepping ``batch`` same-signature
    granules: the per-dispatch overhead (trace/launch/coordination) is paid
    once and the per-granule compute ``batch`` times.  ``pad_factor >= 1``
    models heterogeneous batching, where every member is padded to the
    largest signature in the stack and steps ``pad_factor * t_step``."""
    return t_dispatch + batch * t_step * pad_factor


def unbatched_epoch_time(batch: int, t_step: float, t_dispatch: float) -> float:
    """Wall time for ``batch`` separate per-granule dispatches."""
    return batch * (t_dispatch + t_step)


def dispatch_amortization(
    batch: int, t_step: float, t_dispatch: float, pad_factor: float = 1.0
) -> float:
    """Predicted speedup of signature-batched over per-granule dispatch:

        S(B) = B * (t_disp + t_step) / (t_disp + B * t_step * pad)

    S(1) = 1 for pad = 1 (batching a single granule is free), and
    S -> (t_disp + t_step) / (t_step * pad) as B -> inf: the per-dispatch
    overhead amortizes away and only the padding waste remains."""
    return unbatched_epoch_time(batch, t_step, t_dispatch) / batched_epoch_time(
        batch, t_step, t_dispatch, pad_factor
    )


def fit_dispatch_overhead(
    t_unbatched: float, t_batched: float, batch: int
) -> tuple[float, float]:
    """Recover ``(t_step, t_dispatch)`` from ONE measured A/B pair.

    Inverts the two-equation model ``t_unbatched = B*(t_disp + t_step)``,
    ``t_batched = t_disp + B*t_step`` (pad = 1) — the fit
    ``benchmarks/run.py`` applies to the wafer rows to validate the model
    against a second, differently-shaped measured pair.  Degenerate
    measurements (batched slower than unbatched) clamp to t_disp = 0."""
    if batch < 2:
        raise ValueError("need batch >= 2 to separate t_step from t_dispatch")
    t_disp = max((t_unbatched - t_batched) / (batch - 1), 0.0)
    t_step = max((t_batched - t_disp) / batch, 0.0)
    return t_step, t_disp


def batching_crossover(
    t_step: float, t_dispatch: float, pad_factor: float
) -> float:
    """Smallest batch size B at which batching WINS (S(B) > 1) despite a
    ``pad_factor`` padding waste; ``inf`` when padding always loses.

    From B*(t_disp + t_step) > t_disp + B*t_step*pad:
    batching wins iff the amortized dispatch saving outruns the padding
    waste — when ``t_step * pad >= t_disp + t_step`` it never does."""
    gain = t_dispatch + t_step - t_step * pad_factor
    if gain <= 0.0:
        return math.inf
    return max(t_dispatch / gain, 1.0)


# -- overlapped exchange accounting (ISSUE 7, DESIGN.md §Perf) ---------------

def serial_epoch_time(t_step: float, t_comm: float,
                      t_residual: float = 0.0) -> float:
    """Wall time for one epoch under the serial schedule: the exchange
    (drain + transfer + fill) strictly follows the window's compute, so
    the two costs add.  ``t_residual`` is the schedule-independent part
    (dispatch, host work) paid either way."""
    return t_step + t_comm + t_residual


def overlapped_epoch_time(t_step: float, t_comm: float,
                          t_residual: float = 0.0) -> float:
    """Wall time for one epoch under the split issue/commit schedule.

    Transfers issued at window end complete under the next window's
    compute, so the additive ``t_step + t_comm`` becomes
    ``max(t_step, t_comm)``: whichever of compute and communication is
    longer sets the pace and fully hides the other.  ``t_residual``
    collects what neither phase can hide — the drain/fill bookkeeping at
    the sync point and per-dispatch overhead — and is what
    ``fit_overlap_residual`` recovers from a measured row."""
    return max(t_step, t_comm) + t_residual


def overlap_fraction(t_step: float, t_comm: float) -> float:
    """Fraction of the serial epoch the split schedule can hide:
    ``min(T_step, T_comm) / (T_step + T_comm)`` — 0 when either phase is
    empty (nothing to overlap), 1/2 at perfect balance (the best case:
    half the serial time disappears)."""
    tot = t_step + t_comm
    if tot <= 0.0:
        return 0.0
    return min(t_step, t_comm) / tot


def overlap_speedup(t_step: float, t_comm: float,
                    t_residual: float = 0.0) -> float:
    """Predicted serial/overlapped epoch-time ratio (>= 1; equals
    ``1 / (1 - overlap_fraction)`` when ``t_residual`` is 0)."""
    over = overlapped_epoch_time(t_step, t_comm, t_residual)
    if over <= 0.0:
        return 1.0
    return serial_epoch_time(t_step, t_comm, t_residual) / over


def fit_overlap_residual(t_step: float, t_comm: float,
                         t_overlapped_meas: float) -> float:
    """Recover ``t_residual`` from ONE measured overlapped epoch time and
    the serial run's phase split (step vs drain+transfer+fill).

    Inverts ``t_meas = max(t_step, t_comm) + residual``; clamped at 0 for
    a measurement faster than the model floor (timer noise).  The fit
    ``benchmarks/run.py`` applies: fit the residual on one wafer row,
    predict the other rows' overlapped times with it, and report the
    worst relative error (the acceptance gate is <= 15%) — the residual
    absorbs whatever fraction of the exchange the backend's scheduler
    failed to hide, so the VALIDATED claim is that the residual is a
    stable per-configuration constant, not that overlap is perfect."""
    return max(t_overlapped_meas - max(t_step, t_comm), 0.0)


def dividers_for_rates(f_sims: Sequence[float]) -> list[int]:
    """Clock dividers that realize simulated-frequency ratios exactly.

    Given per-block simulated frequencies, returns integer dividers
    ``d_i`` with ``F_i = F_base / d_i`` where ``F_base = lcm-normalized``.
    Frequencies must be rationally related; we scale to integers first.
    """
    if not f_sims:
        return []
    # Scale to integers (handle floats like 2.5 GHz by rationalizing).
    scaled = [int(round(f * 1_000_000)) for f in f_sims]
    g = 0
    for s in scaled:
        g = math.gcd(g, s)
    units = [s // g for s in scaled]
    l = 1
    for u in units:
        l = l * u // math.gcd(l, u)
    return [l // u for u in units]

"""Distributed epoch-batched simulation of a partitioned channel graph
(paper §II, §IV-B; DESIGN.md §2-§3).

This is the TPU-native adaptation of Switchboard's scale-out story,
generalized from a uniform grid to **any** topology the channel-graph IR
(``repro.core.graph``) can describe.  A **hierarchical partition**
(``graph.PartitionTree``) assigns every block instance to a *granule* (the
paper's network-of-networks node, here one device of a mesh) and groups the
granule axes into **tiers** — fast intra-pod ICI axes, the slow inter-pod
DCI axis — each with its own sync rate.  Each granule advances cycles of
pure local simulation (a ``lax.scan`` touching only granule-local state)
and exchanges the contents of boundary queues with its peers via
``lax.ppermute`` inside ``shard_map``:

    paper                      | here
    ---------------------------+---------------------------------
    single-netlist granule     | device, vmapped per-group step
    shm queue between granules | egress queue -> ppermute slab -> ingress
    free-running processes     | K-cycle epochs (bounded staleness)
    TCP bridge between hosts   | outer (slow) tier of the same ppermute,
                               | synchronized every K_outer * K_inner cycles
    ready/valid backpressure   | credit return on the reverse ppermute

**Tiered sync** (the paper's scale-out economics, §II-B/§IV): a boundary
channel is classified by the *outermost* tier it crosses.  The epoch loop
is nested — one epoch = ``K_0`` rounds of tier 1, each ``K_1`` rounds of
tier 2, ..., the innermost tier running ``K_inner`` granule-local cycles —
and tier t's exchange fires once per tier-t round, i.e. every
``prod(K_t .. K_inner)`` local cycles (its *period*).  Slow-tier channels
simply present deeper elastic buffering; the flat single-K engine is the
one-tier special case.

Functional correctness is *independent of every tier's K* for handshaked
dataflow because every cross-granule channel is latency-insensitive — the
exchange cadence only adds latency, which the channels tolerate by
construction.  This is property-tested against the single-netlist ground
truth (``tests/test_graph.py``, ``tests/test_tiered.py``); with every
K = 1 the exchanges run each cycle and the distributed simulation is
additionally *cycle-accurate*.

Arbitrary granule adjacency: each tier's boundary channels are grouped
into **routes** (one per directed granule pair) and routes are edge-colored
into **exchange classes**, each a partial permutation (every granule sends
on at most one route and receives on at most one route per class).  One
``ppermute`` moves a whole class's packet slabs.  The coloring uses the
König construction (regularize to a Δ-regular bipartite multigraph, peel
off Δ perfect matchings), so the class count *equals* the maximum granule
in/out-degree of the tier — property-tested in ``tests/test_tiered.py``.
``merge_compatible_classes`` then guards the invariant that the class
count never exceeds the number of distinct granule shifts of the tier (a
fixed coordinate delta is injective, hence one ``ppermute``) — a no-op on
König's optimal output, load-bearing for any other decomposition fed
through the table builder.  A
nearest-neighbor grid needs exactly two classes (east, south) — the
historical ``GridEngine`` schedule falls out as a special case, and
``GridEngine`` below is now just a partition-map preset over
``GraphEngine``.

**Batched tier exchange** (§Perf): a tier's classes are concatenated into
one ``(slots, E_t, W)`` slab table at build time, so an exchange is ONE
bulk ``drain`` of every egress queue in the tier, one ``ppermute`` per
remaining class (= per distinct shift), and ONE bulk ``fill`` of every
ingress queue — instead of a drain/permute/fill/credit chain per class.
Credits are carried per tier over the same concatenated slot axis.  Since
every egress/ingress queue belongs to exactly one channel of exactly one
class, the batched schedule is bit-identical to the per-class chain.
``run_epochs``/``run_until`` donate the engine state into the compiled
loop (``jax.jit(..., donate_argnums=0)``), so an epoch updates the wafer
state in place instead of copying it through HBM.

Credit protocol (DESIGN.md §3): the receiver of a boundary channel
advertises ``free(ingress)`` after each fill; the sender drains at most
that many packets at its tier's next exchange.  Safety: only the sender
fills the ingress queue, so the advertised credit can only be consumed by
the sender's own future sends.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import queue as qmod
from ..obs.registry import REGISTRY
from .block import Block
from .compat import shard_map
from .graph import (
    ChannelGraph, PartitionTree, Tier, _rank_within, grid_partition,
    lower_partition, normalize_partition, normalize_tiers,
)
from .struct import pytree_dataclass, static_field
from ..kernels import granule_step

PyTree = Any


@pytree_dataclass
class GraphTables:
    """Per-granule lookup tables (device-varying, constant over time).

    All leaves carry the leading device dims; index values are *local*
    queue ids (0 = NULL_RX sentinel, 1 = NULL_TX sentinel).  The exchange
    tables are concatenated per *tier* (batched exchange): slot ``j`` of
    tier ``t`` belongs to the class whose ``[col0, col0+cmax)`` column
    window contains ``j``.
    """

    rx_idx: tuple  # per group: (dev..., n_slot, n_in) int32
    tx_idx: tuple  # per group: (dev..., n_slot, n_out) int32
    active: tuple  # per group: (dev..., n_slot) bool — padding slots False
    send_idx: tuple  # per tier: (dev..., S_t) int32 local egress queue ids
    send_mask: tuple  # per tier: (dev..., S_t) bool
    recv_idx: tuple  # per tier: (dev..., S_t) int32 local ingress queue ids
    recv_mask: tuple  # per tier: (dev..., S_t) bool
    # Signature-batched exchange (PR 6, ``batch_axes``): per tier, the
    # batch-row gather maps of the on-device slab move.  Empty when the
    # engine runs unbatched.  ``bat_fwd[t][dev..., bd, col] = bs`` — on the
    # *source* device, send-buffer row ``bd`` (the receiver's batch row)
    # reads slab row ``bs``; ``bat_rev[t][dev..., bs, col] = bd`` — on the
    # *dest* device, the credit-return row ``bs`` reads credit row ``bd``.
    # 0-padded; garbage rows are killed by the send/recv masks downstream.
    bat_fwd: tuple = ()
    bat_rev: tuple = ()


@pytree_dataclass
class GraphState:
    """All leaves carry leading device dims, sharded over the granule axes."""

    queues: qmod.QueueArray  # (dev..., n_local, ...) granule-local queues
    block_states: tuple  # per group: leaves (dev..., n_slot, ...)
    credits: tuple  # per tier: (dev..., S_t) int32 send credits
    cycle: jax.Array  # (dev...,) int32 local cycle counters
    epoch: jax.Array  # (dev...,) int32
    tables: GraphTables


@pytree_dataclass
class _ExchangeClass:
    """One partial permutation of boundary routes (static aux data)."""

    perm: tuple = static_field(default=())  # ((src_granule, dst_granule), ...)
    cmax: int = static_field(default=0)  # max channels on any route
    tier: int = static_field(default=0)  # which tier's exchange runs this class
    depth: int = static_field(default=1)  # slab depth E = min(period, cap-1)
    col0: int = static_field(default=0)  # column offset in the tier slab
    # batched engines only: the deduped ((src_device, dst_device), ...)
    # ppermute over the *real* mesh axes; () = the whole class moves
    # between batch rows of one device (no collective at all).  None on
    # unbatched engines (where ``perm`` itself is the ppermute).
    real_perm: tuple | None = static_field(default=None)


def _dealias_for_donation(tree: PyTree) -> PyTree:
    """Copy pytree leaves that share a device buffer with an earlier leaf.

    XLA refuses to donate the same buffer twice, and block ``init_state``
    implementations legitimately reuse one array for several state fields
    (e.g. ``CoreState(value=v, own=v, acc=v)``).  Donating entry points
    route their input through this first; leaves already distinct (the
    steady state, since compiled-loop *outputs* never alias) pass through
    untouched.
    """
    seen: set[int] = set()

    def fix(x):
        if isinstance(x, jax.Array):
            try:
                key = x.unsafe_buffer_pointer()
            except Exception:  # sharded: key on the first local shard
                try:
                    key = x.addressable_shards[0].data.unsafe_buffer_pointer()
                except Exception:
                    key = id(x)
            if key in seen:
                return jnp.copy(x)
            seen.add(key)
        return x

    return jax.tree.map(fix, tree)


def _sq(tree: PyTree, nd: int) -> PyTree:
    """Strip the leading (1,) * nd device dims inside shard_map."""
    return jax.tree.map(lambda x: x.reshape(x.shape[nd:]), tree)


def _unsq(tree: PyTree, nd: int) -> PyTree:
    return jax.tree.map(lambda x: x.reshape((1,) * nd + x.shape), tree)


def _first(x: jax.Array) -> jax.Array:
    """Scalar view of a per-granule counter: the leaf itself when the local
    view is one granule (unbatched), row 0 of the (B,) batch otherwise
    (every batched granule steps in lockstep, so the rows agree)."""
    return x if x.ndim == 0 else x.reshape(-1)[0]


def _perfect_matching(adj: np.ndarray) -> np.ndarray:
    """Perfect matching in a regular bipartite multigraph (Kuhn's algorithm).

    adj[s, d] = remaining parallel-edge count.  Returns match[s] = d.
    A Δ-regular bipartite multigraph always has one (Hall's theorem), so
    failure here means the caller's regularization is broken.
    """
    G = adj.shape[0]
    match_r = np.full((G,), -1, np.int64)  # right node -> matched left node

    def augment(s: int, visited: np.ndarray) -> bool:
        for d in range(G):
            if adj[s, d] > 0 and not visited[d]:
                visited[d] = True
                if match_r[d] < 0 or augment(int(match_r[d]), visited):
                    match_r[d] = s
                    return True
        return False

    for s in range(G):
        if not augment(s, np.zeros((G,), bool)):
            raise AssertionError("regular bipartite graph lost its matching")
    match = np.full((G,), -1, np.int64)
    match[match_r] = np.arange(G, dtype=np.int64)
    return match


def edge_color_routes(
    pairs: Sequence[tuple[int, int]], n_granules: int
) -> list[list[tuple[int, int]]]:
    """Partition directed granule pairs into partial permutations.

    König construction: pad the route digraph (a bipartite graph senders ->
    receivers) with dummy edges until it is Δ-regular, then peel off Δ
    perfect matchings.  The number of classes therefore *equals*
    Δ = max over granules of (out-degree, in-degree) — the optimum, since
    some granule must appear in Δ distinct classes.  Deterministic.
    """
    if not pairs:
        return []
    G = n_granules
    real = np.zeros((G, G), np.int64)
    for s, d in pairs:
        real[s, d] += 1
    out_deg, in_deg = real.sum(axis=1), real.sum(axis=0)
    delta = int(max(out_deg.max(), in_deg.max()))

    # Regularize: total left deficiency == total right deficiency, so the
    # two-pointer pairing below always terminates with both sides at Δ.
    total = real.copy()
    od, idg = out_deg.copy(), in_deg.copy()
    si = di = 0
    while si < G:
        if od[si] >= delta:
            si += 1
            continue
        while idg[di] >= delta:
            di += 1
        add = min(delta - od[si], delta - idg[di])
        total[si, di] += add
        od[si] += add
        idg[di] += add

    classes: list[list[tuple[int, int]]] = []
    for _ in range(delta):
        match = _perfect_matching(total)
        cls: list[tuple[int, int]] = []
        for s in range(G):
            d = int(match[s])
            total[s, d] -= 1
            if real[s, d] > 0:  # prefer consuming a real route over a dummy
                real[s, d] -= 1
                cls.append((s, d))
        if cls:
            classes.append(cls)
    assert real.sum() == 0, "edge coloring failed to cover every route"
    return classes


def merge_compatible_classes(
    classes: Sequence[Sequence[tuple[int, int]]]
) -> list[list[tuple[int, int]]]:
    """Merge exchange classes that compose into one granule permutation.

    Two classes are *compatible* when no granule sends in both and no
    granule receives in both — their union is then still a partial
    permutation, i.e. one ``ppermute``.  Identical (duplicate) classes are
    collapsed outright: exchanging the same permutation twice per sync is
    never needed, the slab depth already covers the traffic.  Greedy,
    deterministic, order-preserving.

    NOTE: on the König coloring the engine uses this is a *guard*, not an
    optimization — König already emits the optimal Δ classes, and the
    granule realizing Δ appears in every one of them, so nothing merges.
    It exists so ANY class decomposition fed through the table builder
    (hand-written schedules, future colorings) keeps the invariant that
    the class count never exceeds the distinct granule shifts
    (``route_shift_groups``) — asserted at build time.
    """
    merged: list[dict[int, int]] = []  # src -> dst maps
    for cls in classes:
        cmap = dict(cls)
        for m in merged:
            if m == cmap:  # duplicate permutation: plain dedup
                break
            if not (m.keys() & cmap.keys()) and not (
                set(m.values()) & set(cmap.values())
            ):
                m.update(cmap)
                break
        else:
            merged.append(cmap)
    return [sorted(m.items()) for m in merged]


def route_shift_groups(
    pairs: Sequence[tuple[int, int]], dev_shape: Sequence[int]
) -> dict[tuple[int, ...], list[tuple[int, int]]]:
    """Group directed granule routes by their coordinate *shift*.

    The shift of a route is the plain per-axis difference of the granule
    coordinates (no modular wrap), so a 2-D torus tiling has exactly four:
    east, east-wrap, south, south-wrap.  A fixed shift is injective, hence
    every group is automatically a partial permutation — one ``ppermute``.
    The distinct-shift count therefore upper-bounds the class count any
    decomposition needs, and lower-bounds nothing: König (max in/out
    degree) is always <= it, which ``GraphEngine`` asserts at build time.
    """
    dev_shape = tuple(int(s) for s in dev_shape)
    groups: dict[tuple[int, ...], list[tuple[int, int]]] = {}
    for s, d in pairs:
        sc = np.unravel_index(int(s), dev_shape)
        dc = np.unravel_index(int(d), dev_shape)
        shift = tuple(int(b) - int(a) for a, b in zip(sc, dc))
        groups.setdefault(shift, []).append((int(s), int(d)))
    return groups


def granule_local_cycle(groups, n_local: int, W: int, dtype, st):
    """One cycle of a granule-local network.

    Identical semantics to ``NetworkSim.step`` — same pre-cycle queue
    snapshot, same sentinel handling, same clock-divider rate control —
    but driven by granule-local tables read from the state
    (``st.tables.rx_idx/tx_idx`` per group, local-queue-id space).

    ``st`` is any pytree with ``queues`` (n_local rows), ``tables``,
    ``block_states`` (per group, n_slot-leading) and ``cycle``; the
    leading device dims must already be squeezed.  Shared by
    ``GraphEngine._local_cycle`` (inside shard_map) and the multiprocess
    workers (``repro.runtime.worker``): because the tables are runtime
    inputs, every same-shaped granule traces to the same jaxpr — the
    prebuilt-simulator-cache property — and both engine families step
    granules with literally the same code.
    """
    from .graph import NULL_RX as NRX, NULL_TX as NTX

    q = st.queues
    tb = st.tables
    fronts, valids = qmod.peek(q)
    readies = ~qmod.full(q)
    valids = valids.at[NRX].set(False)
    readies = readies.at[NTX].set(True)

    push_payload = jnp.zeros((n_local, W), dtype)
    push_valid = jnp.zeros((n_local,), bool)
    pop_ready = jnp.zeros((n_local,), bool)

    new_states = []
    for gi, grp in enumerate(groups):
        blk = grp.block
        rxm, txm = tb.rx_idx[gi], tb.tx_idx[gi]
        rx = {
            port: (fronts[rxm[:, p]], valids[rxm[:, p]])
            for p, port in enumerate(blk.in_ports)
        }
        tx_ready = {port: readies[txm[:, p]] for p, port in enumerate(blk.out_ports)}
        bst = st.block_states[gi]
        new_st, rx_ready, tx = jax.vmap(blk.step)(bst, rx, tx_ready)

        if blk.clock_divider > 1:
            en = (st.cycle % blk.clock_divider) == 0
            new_st = jax.tree.map(lambda n, o: jnp.where(en, n, o), new_st, bst)
            rx_ready = {k: v & en for k, v in rx_ready.items()}
            tx = {k: (p, v & en) for k, (p, v) in tx.items()}
        new_states.append(new_st)

        for p, port in enumerate(blk.in_ports):
            pop_ready = pop_ready.at[rxm[:, p]].max(rx_ready[port])
        for p, port in enumerate(blk.out_ports):
            pay, val = tx[port]
            push_payload = push_payload.at[txm[:, p]].set(
                pay.astype(dtype), mode="drop"
            )
            push_valid = push_valid.at[txm[:, p]].max(val)

    push_valid = push_valid.at[NTX].set(False)
    pop_ready = pop_ready.at[NRX].set(False)
    q2, _, _ = qmod.cycle(q, push_payload, push_valid, pop_ready)
    return st.replace(
        queues=q2, block_states=tuple(new_states), cycle=st.cycle + 1
    )


class GraphEngine:
    """Epoch-batched distributed interpreter of a partitioned ChannelGraph.

    graph:     the channel-graph IR (``Network.graph()`` or a builder).
    partition: a ``graph.PartitionTree`` (hierarchical: carries both the
               instance -> granule map and the tier structure), or any flat
               instance -> granule map ``normalize_partition`` accepts;
               granules are the devices of ``mesh`` along ``axes``,
               flattened row-major (outermost tier first).
    K:         innermost sync rate — cycles of local simulation per
               innermost exchange (the paper's "max simulation rate"
               analogue, swept in Fig. 15).  Ignored when ``partition`` is
               a PartitionTree or ``tiers`` is given.
    tiers:     optional per-tier spec (``graph.Tier`` or ``(axes, K)``
               pairs, outermost first) grouping the mesh axes into sync
               tiers; tier t's boundary channels are exchanged every
               ``prod(K_t .. K_inner)`` cycles.  Default: one tier spanning
               ``axes`` with rate ``K`` — the flat engine.
    batch_axes: signature batching (PR 6).  Names an innermost suffix of
               the granule axes to run as an on-device *batch* dimension
               instead of mesh shards: all granules along those axes stack
               on one leading axis and step with a single vmapped dispatch
               per cycle, and their tier exchanges become local slab
               gathers (no collective).  Pass a sequence of axis names
               (sizes from the mesh / PartitionTree) or a ``{name: size}``
               mapping for axes that are not mesh axes at all — e.g.
               ``mesh=Mesh(1 device), batch_axes={"g": 8}`` folds an
               8-granule wafer onto one device.  Granules batched together
               should share ``granule_signature`` (one traced stepper);
               the engine works regardless (tables are runtime inputs) but
               the speedup argument is per-signature.
    overlap:   overlapped exchange (ISSUE 7).  When on, every tier exchange
               splits into an *issue* phase (drain + start the transfer, at
               the end of an epoch window) and a *commit* phase (finish the
               transfer + fill, at the start of the NEXT window), so XLA's
               latency-hiding scheduler can overlap the collective with the
               intervening compute.  Bit-identical to the serial schedule
               by construction: a slab drained at the end of window ``w``
               is only consumed from the ingress queue at the start of
               window ``w+1``, and issue/commit touch disjoint queue rows
               (egress vs ingress) and per-tier credit windows.  "auto"
               (default off) — the ``REPRO_OVERLAP`` env var overrides
               auto, an explicit bool overrides both (the ``resolve_mode``
               precedence from PR 6).
    """

    engine_kind = "graph"

    def __init__(
        self,
        graph: ChannelGraph,
        partition,
        mesh: Mesh,
        K: int = 1,
        axes: Sequence[str] | None = None,
        tiers: Sequence | None = None,
        batch_axes=None,
        overlap: Any = "auto",
    ):
        self.graph = graph
        self.mesh = mesh
        # resolved at build time (env read once): explicit arg > env > auto
        self.overlap = granule_step.resolve_overlap(overlap)
        if batch_axes is None:
            bmap: dict[str, int | None] = {}
        elif isinstance(batch_axes, dict):
            bmap = {str(a): int(s) for a, s in batch_axes.items()}
        else:
            bmap = {str(a): None for a in batch_axes}

        def axis_size(a: str) -> int:
            s = bmap.get(a)
            if s is not None:
                return s
            if a not in mesh.shape:
                raise ValueError(
                    f"axis {a!r} is not a mesh axis; pass its size via "
                    f"batch_axes={{{a!r}: size}}"
                )
            return int(mesh.shape[a])

        if isinstance(partition, PartitionTree):
            if tiers is not None:
                raise ValueError("pass tiers via the PartitionTree or the "
                                 "tiers kwarg, not both")
            if axes is not None:
                raise ValueError(
                    "axes is derived from the PartitionTree's tiers — "
                    "pass the axis order there"
                )
            ptree = partition
            mesh_shape = tuple(
                sz if (a in bmap and bmap[a] is None) else axis_size(a)
                for a, sz in zip(ptree.axes, ptree.dev_shape)
            )
            if mesh_shape != ptree.dev_shape:
                raise ValueError(
                    f"PartitionTree device shape {ptree.dev_shape} does not "
                    f"match mesh/batch axes {ptree.axes} = {mesh_shape}"
                )
            if ptree.part.shape != (graph.n_instances,):
                raise ValueError(
                    f"PartitionTree covers {ptree.part.size} instances, "
                    f"graph has {graph.n_instances}"
                )
        else:
            if tiers is not None:
                if axes is not None:
                    raise ValueError(
                        "axes is derived from the tier spec when tiers is "
                        "given — pass the axis order via the tiers entries"
                    )
                tspec = normalize_tiers(tiers)
            else:
                t_axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
                tspec = (Tier(axes=t_axes, K=int(K)),)
            all_axes = tuple(a for t in tspec for a in t.axes)
            n_gran = int(np.prod([axis_size(a) for a in all_axes]))
            part = normalize_partition(graph, partition, n_gran)
            ptree = PartitionTree(
                part, tspec, {a: axis_size(a) for a in all_axes}
            )
        self.ptree = ptree
        self.tiers = ptree.tiers
        self.axes = ptree.axes
        self.dev_shape = ptree.dev_shape
        self.nd = len(self.dev_shape)
        unknown = set(bmap) - set(ptree.axes)
        if unknown:
            raise ValueError(f"batch_axes {sorted(unknown)} are not "
                             f"granule axes {ptree.axes}")
        self.batch_axes = tuple(a for a in ptree.axes if a in bmap)
        self.nd_real = self.nd - len(self.batch_axes)
        if self.batch_axes != tuple(ptree.axes[self.nd_real:]):
            raise ValueError(
                f"batch_axes {self.batch_axes} must be a contiguous "
                f"innermost suffix of the granule axes {ptree.axes} (state "
                f"leaves shard on the leading real axes)"
            )
        self.real_axes = tuple(ptree.axes[: self.nd_real])
        self.real_shape = ptree.dev_shape[: self.nd_real]
        self.batch_shape = ptree.dev_shape[self.nd_real:]
        self.B = int(np.prod(self.batch_shape)) if self.batch_shape else 1
        self._batched = bool(self.batch_axes)
        self.G = ptree.n_granules
        self.K_tiers = ptree.K_tiers
        self.periods = ptree.periods()
        self.cycles_per_epoch = ptree.cycles_per_epoch
        self.K = self.K_tiers[-1]  # innermost rate (back-compat accessor)
        # max packets per boundary channel per *its tier's* exchange
        self.E_tiers = tuple(
            min(p, graph.capacity - 1) for p in self.periods
        )
        self.E = self.E_tiers[-1]
        self.W = graph.payload_words
        self.capacity = graph.capacity
        self.dtype = graph.dtype
        self.part = ptree.part
        self._spec = P(*self.real_axes)
        self._jit_cache: dict[Any, Callable] = {}
        self._build_tables()

    # ------------------------------------------------- host-side compilation
    def _build_tables(self) -> None:
        """Lower (graph, partition) to per-granule tables — all vectorized.

        The mesh-independent half (queue-id assignment, per-group member
        placement, boundary routes) is ``graph.lower_partition`` — shared
        with the multiprocess runtime, so both families simulate the same
        granule-local state layout.  This method adds the shard_map
        specifics: per-tier exchange-class coloring and the concatenated
        slab tables the batched ppermute exchange consumes.
        """
        g, G = self.graph, self.G
        low = lower_partition(g, self.ptree)
        self.lowering = low
        tx_local, rx_local = low.tx_local, low.rx_local
        self.n_local = low.n_local
        self._tx_local, self._rx_local = tx_local, rx_local
        self._chan_owner = low.chan_owner
        self._ent = low.ent
        self._rx_tables, self._tx_tables = low.rx_tables, low.tx_tables
        self._act_tables = low.act_tables
        self._member_of = low.member_of
        self._member_granule = low.member_granule
        self._member_slot = low.member_slot
        self._n_slot = low.n_slot
        routes = low.routes  # (tier, src granule, dst granule) -> channels

        # Per tier: König classes, then compatible-permutation merging, then
        # concatenation into ONE (G, S_t) slab table — the batched exchange.
        # Under ``batch_axes`` the coloring is refined per *real-axis* shift
        # first: all routes of one class then share a single injective
        # device->device map (its ``real_perm`` ppermute, () when the class
        # never leaves the device), and the within-device move becomes the
        # ``bat_fwd``/``bat_rev`` batch-row gathers.
        G_real = int(np.prod(self.real_shape)) if self.real_shape else 1
        self.classes: list[_ExchangeClass] = []
        self.tier_classes: list[list[_ExchangeClass]] = []
        send_i, send_m, recv_i, recv_m = [], [], [], []
        bat_f, bat_r = [], []
        for t in range(len(self.tiers)):
            pairs = sorted((s, d) for tt, s, d in routes if tt == t)
            if self._batched:
                shift_groups: dict[tuple, list[tuple[int, int]]] = {}
                for s, d in pairs:
                    sc = np.unravel_index(s, self.dev_shape)
                    dc = np.unravel_index(d, self.dev_shape)
                    shift = tuple(
                        int(dc[i]) - int(sc[i]) for i in range(self.nd_real)
                    )
                    shift_groups.setdefault(shift, []).append((s, d))
                colors, rperms = [], []
                for shift in sorted(shift_groups):
                    for color in merge_compatible_classes(
                        edge_color_routes(shift_groups[shift], G)
                    ):
                        colors.append(color)
                        if any(shift):
                            rperms.append(tuple(sorted(
                                {(s // self.B, d // self.B) for s, d in color}
                            )))
                        else:
                            rperms.append(())
            else:
                colors = merge_compatible_classes(edge_color_routes(pairs, G))
                rperms = [None] * len(colors)
                if pairs:
                    # a fixed shift is one permutation, so no decomposition
                    # ever needs more classes than distinct shifts (König:
                    # fewer)
                    n_shifts = len(route_shift_groups(pairs, self.dev_shape))
                    assert len(colors) <= n_shifts, (len(colors), n_shifts)
            cmaxes = [
                max(len(routes[(t, s, d)]) for s, d in color) for color in colors
            ]
            S_t = sum(cmaxes)
            si = np.zeros((G, S_t), np.int64)
            sm = np.zeros((G, S_t), bool)
            ri = np.zeros((G, S_t), np.int64)
            rm = np.zeros((G, S_t), bool)
            bf = np.zeros((G_real, self.B, S_t), np.int64)
            br = np.zeros((G_real, self.B, S_t), np.int64)
            cls_t: list[_ExchangeClass] = []
            col0 = 0
            for color, cmax, rperm in zip(colors, cmaxes, rperms):
                for s, d in color:
                    chans = routes[(t, s, d)]
                    k = len(chans)
                    si[s, col0:col0 + k] = tx_local[chans]
                    sm[s, col0:col0 + k] = True
                    ri[d, col0:col0 + k] = rx_local[chans]
                    rm[d, col0:col0 + k] = True
                    if self._batched:
                        rs, bs = divmod(s, self.B)
                        rd, bd = divmod(d, self.B)
                        bf[rs, bd, col0:col0 + k] = bs
                        br[rd, bs, col0:col0 + k] = bd
                cls = _ExchangeClass(
                    perm=tuple(color), cmax=cmax, tier=t,
                    depth=self.E_tiers[t], col0=col0, real_perm=rperm,
                )
                cls_t.append(cls)
                self.classes.append(cls)
                col0 += cmax
            self.tier_classes.append(cls_t)
            send_i.append(si.astype(np.int32))
            send_m.append(sm)
            recv_i.append(ri.astype(np.int32))
            recv_m.append(rm)
            bat_f.append(bf.astype(np.int32))
            bat_r.append(br.astype(np.int32))
        self._send_idx, self._send_mask = send_i, send_m
        self._recv_idx, self._recv_mask = recv_i, recv_m
        self._bat_fwd = bat_f if self._batched else []
        self._bat_rev = bat_r if self._batched else []

        # Trailing tiers with NO exchange classes never synchronize, so
        # their loop nesting is pure overhead: tiers >= _fold_from run as
        # one contiguous inner-cycle block of prod(K_t..K_inner) cycles.
        # (A single-granule engine folds the whole epoch into one loop.)
        f = len(self.tiers)
        while f > 0 and not self.tier_classes[f - 1]:
            f -= 1
        self._fold_from = f

    def _dev(self, arr: np.ndarray) -> jax.Array:
        """(G, ...) host table -> (dev_shape..., ...) device array."""
        return jnp.asarray(arr.reshape(self.dev_shape + arr.shape[1:]))

    def _dev_bat(self, arr: np.ndarray) -> jax.Array:
        """(G_real, B, S_t) batch-gather table -> (dev_shape..., S_t).

        The batch-row axis unflattens into the batch axes so every
        GraphTables leaf carries the same ``dev_shape`` leading dims (the
        local view flattens them back to one (B, S_t))."""
        return jnp.asarray(
            arr.reshape(self.real_shape + self.batch_shape + arr.shape[2:])
        )

    def tables(self) -> GraphTables:
        return GraphTables(
            rx_idx=tuple(self._dev(t) for t in self._rx_tables),
            tx_idx=tuple(self._dev(t) for t in self._tx_tables),
            active=tuple(self._dev(t) for t in self._act_tables),
            send_idx=tuple(self._dev(t) for t in self._send_idx),
            send_mask=tuple(self._dev(t) for t in self._send_mask),
            recv_idx=tuple(self._dev(t) for t in self._recv_idx),
            recv_mask=tuple(self._dev(t) for t in self._recv_mask),
            bat_fwd=tuple(self._dev_bat(t) for t in self._bat_fwd),
            bat_rev=tuple(self._dev_bat(t) for t in self._bat_rev),
        )

    # ------------------------------------------------------------------ init
    def _init_block_states(
        self, key: jax.Array, group_params: dict[int, PyTree] | None
    ) -> list[PyTree]:
        """Per-group stacked block states in granule layout (shared by
        ``FusedEngine.init`` so per-member init stays engine-invariant)."""
        states = []
        for gi, grp in enumerate(self.graph.groups):
            blk = grp.block
            params = grp.params
            if group_params is not None and gi in group_params:
                params = group_params[gi]
            # Same key derivation as NetworkSim.init (group index + global
            # member order), so per-member init is bit-identical across
            # engines even for key-consuming blocks.
            keys = jax.random.split(jax.random.fold_in(key, gi), grp.n_members)
            mo = self._member_of[gi].reshape(self.dev_shape + (self._n_slot[gi],))
            keys_l = keys[mo]
            init = blk.init_state
            for _ in range(self.nd + 1):
                init = jax.vmap(init)
            if params is not None:
                params_l = jax.tree.map(lambda x: jnp.asarray(x)[mo], params)
                st = init(keys_l, params_l)
            else:
                st = init(keys_l)
            states.append(st)
        return states

    def init(self, key: jax.Array, group_params: dict[int, PyTree] | None = None) -> GraphState:
        """Initial state.  ``group_params[gi]`` overrides the IR's stacked
        per-member params for group ``gi`` (leading dim = n_members, in
        global instantiation order — the same order ``NetworkSim`` uses, so
        per-member init is bit-identical across engines)."""
        states = self._init_block_states(key, group_params)
        q = qmod.make_queues(self.n_local, self.W, self.capacity, self.dtype)
        queues = jax.tree.map(
            lambda x: jnp.broadcast_to(x, self.dev_shape + x.shape), q
        )
        cap1 = self.capacity - 1
        credits = tuple(
            jnp.full(self.dev_shape + (si.shape[1],), cap1, jnp.int32)
            for si in self._send_idx
        )
        return GraphState(
            queues=queues,
            block_states=tuple(states),
            credits=credits,
            cycle=jnp.zeros(self.dev_shape, jnp.int32),
            epoch=jnp.zeros(self.dev_shape, jnp.int32),
            tables=self.tables(),
        )

    def shardings(self):
        """Sharding for every GraphState leaf (granule-major).

        When EVERY granule axis is batched there is nothing to shard —
        ``NamedSharding(mesh, P())`` would *replicate* the state over the
        whole mesh and make each jit redundantly re-execute the batch on
        every device (an 8-device mesh pays 8x the work for identical
        answers).  The all-batch engine therefore pins state to one
        device."""
        if self._batched and not self.real_axes:
            return jax.sharding.SingleDeviceSharding(
                self.mesh.devices.flat[0]
            )
        return NamedSharding(self.mesh, self._spec)

    def place(self, state: GraphState) -> GraphState:
        sh = self.shardings()
        return jax.tree.map(lambda x: jax.device_put(x, sh), state)

    # -------------------------------------------------- local <-> global view
    def _local_view(self, state: PyTree) -> PyTree:
        """Per-device view of the state: strip the (1,)*nd_real shard dims
        and flatten the batch axes into ONE leading (B,) axis (no-op
        reshape when unbatched — then this is plain ``_sq``)."""
        if not self._batched:
            return _sq(state, self.nd)
        return jax.tree.map(
            lambda x: x.reshape((self.B,) + x.shape[self.nd:]), state
        )

    def _global_view(self, local: PyTree) -> PyTree:
        if not self._batched:
            return _unsq(local, self.nd)
        return jax.tree.map(
            lambda x: x.reshape(
                (1,) * self.nd_real + self.batch_shape + x.shape[1:]
            ),
            local,
        )

    def _wrap(self, fn: Callable) -> Callable:
        """shard_map over the real mesh axes — or ``fn`` unwrapped when
        every granule axis is batched (single-device: no collectives at
        all, the whole epoch is one local computation)."""
        if not self.real_axes:
            return fn
        return shard_map(
            fn, mesh=self.mesh, in_specs=self._spec, out_specs=self._spec
        )

    # ----------------------------------------------------------- local cycle
    def _local_cycle(self, st: GraphState) -> GraphState:
        """One cycle of the granule-local network (pre-squeezed state) —
        the shared ``granule_local_cycle`` body (also the multiprocess
        workers' stepper, so the two families stay bit-identical)."""
        return granule_local_cycle(
            self.graph.groups, self.n_local, self.W, self.dtype, st
        )

    # ---------------------------------------------------------------- epoch
    def _pshift(self, x: jax.Array, perm) -> jax.Array:
        if not perm:
            return jnp.zeros_like(x)
        return jax.lax.ppermute(x, self.axes, list(perm))

    def _class_shift(self, x: jax.Array, t: int, rev: bool = False):
        """Move the tier-t slab columns class by class — one ``ppermute``
        per class (each a partial permutation of granules); ``rev`` runs
        the reverse permutations (the credit return)."""
        parts = []
        for cl in self.tier_classes[t]:
            perm = tuple((d, s) for s, d in cl.perm) if rev else cl.perm
            parts.append(self._pshift(x[cl.col0:cl.col0 + cl.cmax], perm))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)

    def _bat_move(self, x, tbl, t: int, rev: bool = False):
        """The batched slab move: within-device share of every class is a
        ``bat_fwd``/``bat_rev`` batch-row gather instead of a collective;
        only classes whose ``real_perm`` is non-empty pay a ppermute (none
        do when every granule axis is batched).  Garbage rows from the
        0-padded gather tables are killed by the same send/recv masks that
        already guard slab padding."""
        parts = []
        for cl in self.tier_classes[t]:
            w = x[:, cl.col0:cl.col0 + cl.cmax]
            g = tbl[:, cl.col0:cl.col0 + cl.cmax]
            g = g.reshape(g.shape + (1,) * (w.ndim - 2))
            part = jnp.take_along_axis(w, g, axis=0)
            perm = cl.real_perm
            if perm:
                if rev:
                    perm = tuple((d, s) for s, d in perm)
                part = jax.lax.ppermute(part, self.real_axes, list(perm))
            parts.append(part)
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, 1)

    def _exchange_issue(self, st: GraphState, t: int):
        """Tier t's exchange, ISSUE half: drain every egress queue of the
        tier (credit-bounded) and start the transfer — the forward
        ``ppermute`` per class.  Returns ``(st, pending)`` where pending
        is the in-flight ``(slab_in, cnt_in)`` pair (``None`` when the
        tier has no exchange classes).  Reads egress queues + this tier's
        credit window only, so it commutes bit-exactly with other tiers'
        commits (disjoint queue rows, per-tier credits)."""
        if self._batched:
            return self._exchange_issue_batched(st, t)
        cls_t = self.tier_classes[t]
        if not cls_t:
            return st, None
        q = st.queues
        tb = st.tables
        sidx, smask = tb.send_idx[t], tb.send_mask[t]
        # drain all egress queues of the tier, bounded by receiver credit
        sub = qmod.QueueArray(
            buf=q.buf[sidx], head=q.head[sidx], tail=q.tail[sidx],
            capacity=q.capacity,
        )
        limit = jnp.where(smask, st.credits[t], 0)
        sub2, slab, cnt = qmod.drain(sub, self.E_tiers[t], limit=limit)
        q = q.replace(tail=q.tail.at[sidx].set(sub2.tail))
        slab_in = self._class_shift(slab, t)
        cnt_in = jnp.where(tb.recv_mask[t], self._class_shift(cnt, t), 0)
        return st.replace(queues=q), (slab_in, cnt_in)

    def _exchange_commit(self, st: GraphState, t: int, pending) -> GraphState:
        """Tier t's exchange, COMMIT half: land the in-flight slab in the
        ingress queues (ONE bulk ``fill``) and return fresh credits to the
        senders on the reverse permutations.  Writes ingress queues + this
        tier's credit window only."""
        if self._batched:
            return self._exchange_commit_batched(st, t, pending)
        if pending is None:
            return st
        slab_in, cnt_in = pending
        tb = st.tables
        ridx, rmask = tb.recv_idx[t], tb.recv_mask[t]
        q = qmod_fill_at(st.queues, ridx, slab_in, cnt_in)
        # receivers advertise new free space; returns to the senders on the
        # reverse permutations
        cred = jnp.where(rmask, jnp.take(qmod.free(q), ridx), 0)
        new_credits = list(st.credits)
        new_credits[t] = self._class_shift(cred, t, rev=True)
        return st.replace(queues=q, credits=tuple(new_credits))

    def _exchange_issue_batched(self, st: GraphState, t: int):
        """ISSUE half with the granules stacked on a (B,) batch axis —
        credit-bounded ``stage_drain`` per row + the forward ``bat_fwd``
        slab move (collective only for classes with a real shift)."""
        cls_t = self.tier_classes[t]
        if not cls_t:
            return st, None
        tb = st.tables
        sidx, smask = tb.send_idx[t], tb.send_mask[t]  # (B, S_t)
        limit = jnp.where(smask, st.credits[t], 0)
        q, slab, cnt = jax.vmap(
            lambda qb, si, lim: qmod.stage_drain(
                qb, si, self.E_tiers[t], limit=lim
            )
        )(st.queues, sidx, limit)
        slab_in = self._bat_move(slab, tb.bat_fwd[t], t)
        cnt_in = jnp.where(
            tb.recv_mask[t], self._bat_move(cnt, tb.bat_fwd[t], t), 0
        )
        return st.replace(queues=q), (slab_in, cnt_in)

    def _exchange_commit_batched(self, st: GraphState, t: int, pending):
        """COMMIT half on the batch layout: ``stage_fill`` per row + the
        ``bat_rev`` credit return."""
        if pending is None:
            return st
        slab_in, cnt_in = pending
        tb = st.tables
        ridx, rmask = tb.recv_idx[t], tb.recv_mask[t]
        q = jax.vmap(qmod.stage_fill)(st.queues, ridx, slab_in, cnt_in)
        cred = jnp.where(
            rmask, jnp.take_along_axis(qmod.free(q), ridx, axis=1), 0
        )
        new_credits = list(st.credits)
        new_credits[t] = self._bat_move(cred, tb.bat_rev[t], t, rev=True)
        return st.replace(queues=q, credits=tuple(new_credits))

    def _exchange_tier(self, st: GraphState, t: int) -> GraphState:
        """Run tier t's batched exchange (runs inside shard_map).

        ONE bulk ``drain`` empties every egress queue of the tier into the
        concatenated ``(S_t, E_t, W)`` slab (each slot bounded by the
        receiver's advertised credit), one ``ppermute`` per class moves
        that class's column window, ONE bulk ``fill`` lands everything in
        the ingress queues, and fresh credits return to the senders on the
        reverse permutations.  Egress/ingress queues are disjoint across
        classes, so this is bit-identical to the historical per-class
        drain/permute/fill chain — with ~1/#classes of the gather/scatter
        traffic.  Other tiers' queues and credit windows are untouched.

        The serial schedule is literally commit∘issue — the overlapped
        schedule (``overlap=True``) runs the same two halves with compute
        in between, which is why the two are bit-identical.
        """
        st, pending = self._exchange_issue(st, t)
        return self._exchange_commit(st, t, pending)

    def _inner_cycles(self, st: GraphState, K: int) -> GraphState:
        """K granule-local cycles — the innermost hot loop.  ``FusedEngine``
        overrides this with the fused-epoch kernel."""
        cyc = (jax.vmap(self._local_cycle) if self._batched
               else self._local_cycle)
        return jax.lax.scan(
            lambda s, _: (cyc(s), None), st, None, length=K
        )[0]

    def _tier_round(self, st: GraphState, t: int) -> GraphState:
        """One round of tier t: K_t sub-rounds (granule-local cycles at the
        innermost tier, tier-(t+1) rounds otherwise), then tier t's
        exchange — so tier t synchronizes every ``periods[t]`` cycles.
        Exchange-free trailing tiers are folded into one contiguous
        inner-cycle block (no loop nesting, no no-op exchanges)."""
        if t >= self._fold_from:
            return self._inner_cycles(st, int(np.prod(self.K_tiers[t:])))
        if t == len(self.tiers) - 1:
            st = self._inner_cycles(st, self.tiers[t].K)
        else:
            body = lambda s, _: (self._tier_round(s, t + 1), None)  # noqa: E731
            st = jax.lax.scan(body, st, None, length=self.tiers[t].K)[0]
        return self._exchange_tier(st, t)

    # --------------------------------------------- overlapped (split) schedule
    def _pend_tiers(self, t0: int) -> tuple:
        """Static tier order of the pending chain ``_round_split(st, t0)``
        returns: the suffix of tiers whose exchanges fire *at the end* of a
        tier-t0 round, deepest first — issued there, committed by the
        caller at the start of its next window (``_commit_chain``)."""
        if t0 >= self._fold_from:
            return ()
        inner = () if t0 == len(self.tiers) - 1 else self._pend_tiers(t0 + 1)
        return inner + ((t0,) if self.tier_classes[t0] else ())

    def _commit_chain(self, st: GraphState, t0: int, pend: tuple) -> GraphState:
        """Commit a pending chain from ``_round_split(·, t0)`` — fills land
        deepest tier first, the same order the serial schedule fills them
        (they are disjoint across tiers either way)."""
        tiers = self._pend_tiers(t0)
        assert len(tiers) == len(pend), (tiers, len(pend))
        for t, p in zip(tiers, pend):
            st = self._exchange_commit(st, t, p)
        return st

    def _round_split(self, st: GraphState, t: int):
        """One round of tier t with *split* exchanges: every sub-round's
        boundary transfers are ISSUED at its window end and COMMITTED at
        the start of the next sub-round's window (inside the scan body:
        commit-previous, then compute — so the in-flight data crosses a
        loop iteration and XLA's scheduler can overlap the transfer with
        the next window's compute).  The final boundary's chain — tier t's
        own exchange stacked on the inner tiers that fired with it — is
        returned *pending* for the caller to commit at ITS next window.

        Bit-identity with ``_tier_round``: issue reads egress queues +
        credits[t] only, commit writes ingress queues + credits[t] only,
        and those row sets are disjoint across all tiers — so hoisting
        commits past later issues reorders nothing; and every commit still
        precedes the first cycle that could consume the filled packets
        (the start of window ``w+1`` for a slab drained at the end of
        ``w``), which is exactly where the serial schedule fills them
        relative to the dataflow."""
        if t >= self._fold_from:
            return self._inner_cycles(st, int(np.prod(self.K_tiers[t:]))), ()
        if t == len(self.tiers) - 1:
            st, pend = self._inner_cycles(st, self.tiers[t].K), ()
        else:
            st, pend = self._round_split(st, t + 1)
            if self.tiers[t].K > 1:

                def body(carry, _):
                    s, p = carry
                    s = self._commit_chain(s, t + 1, p)
                    return self._round_split(s, t + 1), None

                (st, pend), _ = jax.lax.scan(
                    body, (st, pend), None, length=self.tiers[t].K - 1
                )
        if self.tier_classes[t]:
            st, p_t = self._exchange_issue(st, t)
            pend = pend + (p_t,)
        return st, pend

    def _epoch(self, st: GraphState) -> GraphState:
        """One outermost round = ``cycles_per_epoch`` local cycles, every
        tier exchanged at its own cadence (runs inside shard_map).  Under
        ``overlap`` the split schedule runs instead; the last boundary's
        chain commits before returning (epoch boundaries are host-I/O
        points, so no transfer may stay in flight across them)."""
        if self.overlap:
            st, pend = self._round_split(st, 0)
            st = self._commit_chain(st, 0, pend)
        else:
            st = self._tier_round(st, 0)
        return st.replace(epoch=st.epoch + 1)

    # ------------------------------------------------------------------ run
    def epoch_fn(self):
        """shard_map'd single-epoch function (used by dryrun + benchmarks)."""

        def run(state):
            return self._global_view(self._epoch(self._local_view(state)))

        return self._wrap(run)

    def run_epochs(
        self, state: GraphState, n_epochs: int, *, donate: bool = True
    ) -> GraphState:
        """Advance ``n_epochs`` outermost epochs.

        ``donate=True`` (default) donates the state buffers into the
        compiled loop (``jax.jit(..., donate_argnums=0)``): the wafer state
        is updated in place instead of being copied through HBM on every
        call, and the *input* state must not be reused afterwards.  Pass
        ``donate=False`` to keep the input alive.
        """
        key = ("run", n_epochs, donate)
        if key not in self._jit_cache:
            REGISTRY.inc(f"{self.engine_kind}.compile.count")

            def run(state):
                local = self._local_view(state)
                out = jax.lax.scan(
                    lambda s, _: (self._epoch(s), None), local, None, length=n_epochs
                )[0]
                return self._global_view(out)

            self._jit_cache[key] = jax.jit(
                self._wrap(run),
                donate_argnums=(0,) if donate else (),
            )
        if donate:
            state = _dealias_for_donation(state)
        REGISTRY.inc(f"{self.engine_kind}.dispatch.count")
        REGISTRY.inc(f"{self.engine_kind}.epochs", float(n_epochs))
        return self._jit_cache[key](state)

    def run_cycles(self, state: GraphState, n_cycles: int) -> GraphState:
        """Advance ``ceil(n_cycles / cycles_per_epoch)`` outermost epochs
        (>= n_cycles local cycles)."""
        return self.run_epochs(state, -(-n_cycles // self.cycles_per_epoch))

    def _done_view(self, local: GraphState):
        """What ``run_until``'s predicate sees (the granule-local state).

        Subclasses narrow the view instead of overriding ``run_until`` —
        that keeps the public signature and the jit-cache keying defined in
        exactly one place, so a subclass call can never silently miss the
        cache or drift from the base signature.
        """
        return local

    def run_until(
        self,
        state: GraphState,
        done_fn: Callable[[Any], jax.Array],
        max_epochs: int,
        *,
        cache_key: Any = None,
        donate: bool = True,
    ) -> GraphState:
        """Run epochs until ``done_fn(self._done_view(local))`` holds on
        every granule, or at most ``max_epochs`` MORE epochs from the
        input state (a relative budget: the compiled loop is reusable
        from any starting epoch, so interactive callers never retrace).

        For ``GraphEngine`` the view is the granule-local (squeezed)
        GraphState — padding slots are live in ``block_states``, mask with
        ``local.tables.active[gi]`` when the partition is uneven.
        ``GridEngine`` narrows the view to the cell states.

        The compiled loop is cached per (predicate, max_epochs).  The cache
        pins the predicate object (``cache_key`` if given, else ``done_fn``)
        so a garbage-collected function's recycled id can never alias a
        stale compilation; pass ``cache_key`` when the predicate is a fresh
        lambda per call but semantically constant.

        ``donate=True`` (default) donates the state buffers into the
        compiled loop — see ``run_epochs``; the input state must not be
        reused afterwards.
        """
        anchor = cache_key if cache_key is not None else done_fn
        key = ("until", id(anchor), max_epochs, donate)
        if key not in self._jit_cache:

            def not_done(s):
                # Local sum first (covers a (B,)-shaped batched predicate),
                # then psum over the real mesh axes if there are any.
                nd_ = jnp.sum(
                    1 - done_fn(self._done_view(s)).astype(jnp.int32)
                )
                if self.real_axes:
                    nd_ = jax.lax.psum(nd_, self.real_axes)
                return nd_

            def run(state):
                local = self._local_view(state)
                e0 = _first(local.epoch)

                # The global done flag is computed in the *body* and carried,
                # so the while condition itself contains no collectives.
                def cond(carry):
                    s, pending = carry
                    return (pending > 0) & (_first(s.epoch) - e0 < max_epochs)

                def body(carry):
                    s, _ = carry
                    s = self._epoch(s)
                    return s, not_done(s)

                # An already-done state runs zero epochs, so chunked callers
                # (the session's monitor cadence) can re-enter safely.
                out, _ = jax.lax.while_loop(
                    cond, body, (local, not_done(local))
                )
                return self._global_view(out)

            self._jit_cache[key] = (
                anchor,  # strong ref: keeps the keyed id alive
                jax.jit(
                    self._wrap(run),
                    donate_argnums=(0,) if donate else (),
                ),
            )
        if donate:
            state = _dealias_for_donation(state)
        return self._jit_cache[key][1](state)

    # ------------------------------------------------------- host utilities
    def gather_group(self, state: GraphState, gi: int) -> PyTree:
        """Group ``gi``'s member states in global instantiation order."""
        n_slot = self._n_slot[gi]
        idx = self._member_granule[gi] * n_slot + self._member_slot[gi]

        def pick(x):
            x = np.asarray(x)
            flat = x.reshape((self.G * n_slot,) + x.shape[self.nd + 1:])
            return flat[idx]

        return jax.tree.map(pick, jax.device_get(state.block_states[gi]))

    def group_state(self, state: GraphState, inst) -> PyTree:
        """One instance's (unstacked) state — mirrors NetworkSim.group_state."""
        inst_id = inst if isinstance(inst, int) else inst.inst_id
        gi, k = self.graph.locate(inst_id)
        didx = np.unravel_index(int(self._member_granule[gi][k]), self.dev_shape)
        slot = int(self._member_slot[gi][k])
        return jax.tree.map(
            lambda x: jax.device_get(x)[didx + (slot,)], state.block_states[gi]
        )

    # ---------------------- host-side external ports (PySbTx/PySbRx analogue)
    # External channels are *homed* on the granule that owns their simulated
    # endpoint (``ChannelGraph.ext_home``): host I/O touches only that
    # granule's queue slab, wherever it sits on the mesh.  ``host_push``/
    # ``host_pop`` (+ batched ``_many``) are the primitives the session's
    # Tx/Rx ports drive at epoch boundaries; ``push_external``/
    # ``pop_external`` remain as deprecation shims.
    def _ext_loc(self, cid: int) -> tuple[tuple[int, ...], int]:
        g = int(self._chan_owner[cid])
        didx = tuple(int(i) for i in np.unravel_index(g, self.dev_shape))
        lid = int(max(self._rx_local[cid], self._tx_local[cid]))
        return didx, lid

    def _ext_idx(self, table: dict, name: str) -> tuple:
        didx, lid = self._ext_loc(table[name])
        return didx + (lid,)

    def port_stats(self, state: GraphState) -> dict:
        """Per external port: occupancy/credit of the queue row homed on
        the owning granule — the uniform ``Simulation.stats()["ports"]``
        schema (``_ext_loc`` is the only engine-specific piece, so the
        fused engine inherits this as-is).  Nested by direction so a name
        serving BOTH directions reports each channel's own queue."""
        head = np.asarray(jax.device_get(state.queues.head))
        tail = np.asarray(jax.device_get(state.queues.tail))

        def rec(cid):
            didx, lid = self._ext_loc(cid)
            size = int((head[didx + (lid,)] - tail[didx + (lid,)])
                       % self.capacity)
            return {"occupancy": size, "credit": self.capacity - 1 - size}

        return {
            "tx": {n: rec(c) for n, c in self.graph.ext_in.items()},
            "rx": {n: rec(c) for n, c in self.graph.ext_out.items()},
        }

    def host_push(self, state: GraphState, name: str, payload):
        q2, ok = qmod.host_push(
            state.queues, self._ext_idx(self.graph.ext_in, name),
            jnp.asarray(payload, self.dtype),
        )
        return state.replace(queues=q2), ok

    def host_pop(self, state: GraphState, name: str):
        q2, front, valid = qmod.host_pop(
            state.queues, self._ext_idx(self.graph.ext_out, name)
        )
        return state.replace(queues=q2), front, valid

    def host_push_many(self, state: GraphState, name: str, payloads):
        payloads = jnp.asarray(payloads, self.dtype).reshape(-1, self.W)
        q2, n = qmod.host_push_many(
            state.queues, self._ext_idx(self.graph.ext_in, name), payloads
        )
        return state.replace(queues=q2), n

    def host_pop_many(self, state: GraphState, name: str, max_n: int):
        q2, pays, cnt = qmod.host_pop_many(
            state.queues, self._ext_idx(self.graph.ext_out, name), max_n
        )
        return state.replace(queues=q2), pays, cnt

    def push_external(self, state: GraphState, name: str, payload):
        warnings.warn(
            "push_external is deprecated; use the Simulation session's "
            "tx(name).send(...) (or engine.host_push)",
            DeprecationWarning, stacklevel=2,
        )
        return self.host_push(state, name, payload)

    def pop_external(self, state: GraphState, name: str):
        warnings.warn(
            "pop_external is deprecated; use the Simulation session's "
            "rx(name).recv() (or engine.host_pop)",
            DeprecationWarning, stacklevel=2,
        )
        return self.host_pop(state, name)


class GridEngine(GraphEngine):
    """Uniform R×C grid preset over GraphEngine (the paper's §IV-B manycore).

    cell: Block with ports in=(w_in, n_in), out=(e_out, s_out).
    R, C: global grid shape; mesh: 2-D Mesh with axes (axis_r, axis_c).
    K: cycles per epoch.

    The grid topology is lowered to the channel-graph IR by the vectorized
    ``ChannelGraph.grid`` builder and partitioned block-tile onto the device
    grid; the exchange-class coloring then reduces to exactly the historic
    east + south slab schedule.
    """

    def __init__(
        self,
        cell: Block,
        R: int,
        C: int,
        mesh: Mesh,
        K: int,
        payload_words: int = 2,
        capacity: int = qmod.DEFAULT_CAPACITY,
        dtype: Any = jnp.float32,
        axis_r: str = "gr",
        axis_c: str = "gc",
    ):
        Dr, Dc = mesh.shape[axis_r], mesh.shape[axis_c]
        if R % Dr or C % Dc:
            raise ValueError(f"grid {R}x{C} not divisible by device tile {Dr}x{Dc}")
        graph = ChannelGraph.grid(
            cell, R, C, payload_words=payload_words, dtype=dtype, capacity=capacity
        )
        super().__init__(
            graph, grid_partition(R, C, Dr, Dc), mesh, K=K, axes=(axis_r, axis_c)
        )
        self.cell = cell
        self.R, self.C = R, C
        self.Dr, self.Dc = Dr, Dc
        self.Tr, self.Tc = R // Dr, C // Dc

    def init(self, key: jax.Array, cell_params: PyTree) -> GraphState:
        """cell_params: pytree with leading (R, C) dims (global)."""
        flat = jax.tree.map(
            lambda x: jnp.reshape(jnp.asarray(x), (self.R * self.C,) + jnp.shape(x)[2:]),
            cell_params,
        )
        return super().init(key, group_params={0: flat})

    def _done_view(self, local):
        """``run_until`` predicates see the granule-local cell states,
        leaves (Tr*Tc, ...) — not the whole GraphState."""
        return local.block_states[0]

    def gather_cells(self, state: GraphState) -> PyTree:
        """Return cell states reassembled to global (R, C, ...) layout."""
        flat = self.gather_group(state, 0)
        return jax.tree.map(
            lambda x: x.reshape((self.R, self.C) + x.shape[1:]), flat
        )


def qmod_fill_at(q: qmod.QueueArray, idx: jax.Array, payloads: jax.Array, count: jax.Array) -> qmod.QueueArray:
    """Fill a subset of queues (rows ``idx``) of a QueueArray.

    payloads: (len(idx), max_n, W); count: (len(idx),).  Rows with
    ``count == 0`` are written back unchanged, so duplicate padding indices
    are harmless.
    """
    sub = qmod.QueueArray(
        buf=q.buf[idx], head=q.head[idx], tail=q.tail[idx], capacity=q.capacity
    )
    sub2 = qmod.fill(sub, payloads, count)
    return q.replace(
        buf=q.buf.at[idx].set(sub2.buf),
        head=q.head.at[idx].set(sub2.head),
    )

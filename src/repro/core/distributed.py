"""Distributed epoch-batched grid simulation (paper §II, §IV-B; DESIGN.md §2).

This is the TPU-native adaptation of Switchboard's scale-out story.  A grid
of R×C uniform cells is partitioned into (Dr, Dc) device tiles ("granules",
the paper's network-of-networks).  Each granule advances **K cycles of pure
local simulation** (a ``lax.scan`` touching only granule-local state), then
exchanges the contents of boundary queues with its neighbors via
``lax.ppermute`` inside ``shard_map``:

    paper                      | here
    ---------------------------+---------------------------------
    single-netlist granule     | device tile, vmapped cell step
    shm queue between granules | egress queue -> ppermute slab -> ingress
    free-running processes     | K-cycle epochs (bounded staleness)
    TCP bridge between hosts   | 'pod' tier of the same ppermute
    ready/valid backpressure   | credit return on the reverse ppermute

Functional correctness is *independent of K* because every cross-granule
channel is latency-insensitive — the epoch boundary only adds latency, which
the channels tolerate by construction.  This is property-tested (results
equal the single-netlist ground truth for K in {1..64}).

Credit protocol: the receiver of a boundary channel advertises
``free(ingress)`` after each fill; the sender drains at most that many
packets next epoch.  Safety: only the sender fills the ingress queue, so the
advertised credit can only be consumed by the sender's own future sends.

Flow directions supported: east (gc axis) and south (gr axis), which covers
systolic dataflow (paper Fig. 12) and 1-D pipelines (Dc=1 or Dr=1).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import queue as qmod
from .block import Block
from .struct import pytree_dataclass, static_field

PyTree = Any


@pytree_dataclass
class GridState:
    """All leaves carry leading (Dr, Dc) device dims, sharded P('gr','gc')."""

    cell: PyTree  # leaves (Dr, Dc, Tr, Tc, ...)
    qe: qmod.QueueArray  # (Dr, Dc, Tr*Tc, ...) west-input queues
    qs: qmod.QueueArray  # (Dr, Dc, Tr*Tc, ...) north-input queues
    ee: qmod.QueueArray  # (Dr, Dc, Tr, ...) east egress
    es: qmod.QueueArray  # (Dr, Dc, Tc, ...) south egress
    credit_e: jax.Array  # (Dr, Dc, Tr) packets we may send east
    credit_s: jax.Array  # (Dr, Dc, Tc)
    cycle: jax.Array  # (Dr, Dc) local cycle counters
    epoch: jax.Array  # (Dr, Dc)


def _sq(tree: PyTree) -> PyTree:
    """Strip the leading (1, 1) device dims inside shard_map."""
    return jax.tree.map(lambda x: x.reshape(x.shape[2:]), tree)


def _unsq(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda x: x.reshape((1, 1) + x.shape), tree)


class GridEngine:
    """Epoch-batched distributed simulator for a uniform cell grid.

    cell: Block with ports in=(w_in, n_in), out=(e_out, s_out).
    R, C: global grid shape; mesh: 2-D Mesh with axes (axis_r, axis_c).
    K: cycles per epoch (the staleness/amortization knob — paper's
       "max simulation rate" analogue, swept in the Fig. 15 benchmark).
    """

    def __init__(
        self,
        cell: Block,
        R: int,
        C: int,
        mesh: Mesh,
        K: int,
        payload_words: int = 2,
        capacity: int = qmod.DEFAULT_CAPACITY,
        dtype: Any = jnp.float32,
        axis_r: str = "gr",
        axis_c: str = "gc",
    ):
        self.cell = cell
        self.R, self.C = R, C
        self.mesh = mesh
        self.axis_r, self.axis_c = axis_r, axis_c
        self.Dr = mesh.shape[axis_r]
        self.Dc = mesh.shape[axis_c]
        if R % self.Dr or C % self.Dc:
            raise ValueError(f"grid {R}x{C} not divisible by device tile {self.Dr}x{self.Dc}")
        self.Tr, self.Tc = R // self.Dr, C // self.Dc
        self.K = K
        self.E = min(K, capacity - 1)  # max packets per boundary channel/epoch
        self.W = payload_words
        self.capacity = capacity
        self.dtype = dtype
        self._spec = P(axis_r, axis_c)
        self._jit_cache: dict[Any, Callable] = {}

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array, cell_params: PyTree) -> GridState:
        """cell_params: pytree with leading (R, C) dims (global)."""
        Dr, Dc, Tr, Tc = self.Dr, self.Dc, self.Tr, self.Tc

        def tile(x):
            # (R, C, ...) -> (Dr, Dc, Tr, Tc, ...)
            return x.reshape((Dr, Tr, Dc, Tc) + x.shape[2:]).transpose(
                (0, 2, 1, 3) + tuple(range(4, x.ndim + 2))
            )

        params_t = jax.tree.map(tile, cell_params)
        keys = jax.random.split(key, self.R * self.C).reshape(Dr, Dc, Tr, Tc)
        cell_state = jax.vmap(
            jax.vmap(jax.vmap(jax.vmap(self.cell.init_state)))
        )(keys, params_t)

        def mkq(n):
            q = qmod.make_queues(n, self.W, self.capacity, self.dtype)
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (Dr, Dc) + x.shape), q
            )

        cap1 = self.capacity - 1
        return GridState(
            cell=cell_state,
            qe=mkq(Tr * Tc),
            qs=mkq(Tr * Tc),
            ee=mkq(Tr),
            es=mkq(Tc),
            credit_e=jnp.full((Dr, Dc, Tr), cap1, jnp.int32),
            credit_s=jnp.full((Dr, Dc, Tc), cap1, jnp.int32),
            cycle=jnp.zeros((Dr, Dc), jnp.int32),
            epoch=jnp.zeros((Dr, Dc), jnp.int32),
        )

    def shardings(self) -> PyTree:
        """NamedSharding for every GridState leaf (device-grid major)."""
        return NamedSharding(self.mesh, self._spec)

    def place(self, state: GridState) -> GridState:
        sh = self.shardings()
        return jax.tree.map(lambda x: jax.device_put(x, sh), state)

    # ----------------------------------------------------------- local cycle
    def _local_cycle(self, st: GridState) -> GridState:
        """One cycle of the granule-local network (pre-squeezed state)."""
        Tr, Tc = self.Tr, self.Tc
        qe, qs, ee, es = st.qe, st.qs, st.ee, st.es

        w_front, w_valid = qmod.peek(qe)
        n_front, n_valid = qmod.peek(qs)
        rx = {
            "w_in": (w_front.reshape(Tr, Tc, self.W), w_valid.reshape(Tr, Tc)),
            "n_in": (n_front.reshape(Tr, Tc, self.W), n_valid.reshape(Tr, Tc)),
        }
        qe_ready = (~qmod.full(qe)).reshape(Tr, Tc)
        qs_ready = (~qmod.full(qs)).reshape(Tr, Tc)
        e_ready = jnp.concatenate([qe_ready[:, 1:], (~qmod.full(ee))[:, None]], axis=1)
        s_ready = jnp.concatenate([qs_ready[1:, :], (~qmod.full(es))[None, :]], axis=0)
        tx_ready = {"e_out": e_ready, "s_out": s_ready}

        new_cell, rx_ready, tx = jax.vmap(jax.vmap(self.cell.step))(st.cell, rx, tx_ready)

        e_pay, e_val = tx["e_out"]  # (Tr, Tc, W), (Tr, Tc)
        s_pay, s_val = tx["s_out"]

        # Internal pushes: cell (r, j-1) e_out -> qe[r, j]; shift right.
        zpayc = jnp.zeros((Tr, 1, self.W), self.dtype)
        zvalc = jnp.zeros((Tr, 1), bool)
        qe_push_pay = jnp.concatenate([zpayc, e_pay[:, :-1]], axis=1).reshape(Tr * Tc, self.W)
        qe_push_val = jnp.concatenate([zvalc, e_val[:, :-1]], axis=1).reshape(Tr * Tc)
        zpayr = jnp.zeros((1, Tc, self.W), self.dtype)
        zvalr = jnp.zeros((1, Tc), bool)
        qs_push_pay = jnp.concatenate([zpayr, s_pay[:-1]], axis=0).reshape(Tr * Tc, self.W)
        qs_push_val = jnp.concatenate([zvalr, s_val[:-1]], axis=0).reshape(Tr * Tc)

        qe2, _, _ = qmod.cycle(qe, qe_push_pay, qe_push_val, rx_ready["w_in"].reshape(-1))
        qs2, _, _ = qmod.cycle(qs, qs_push_pay, qs_push_val, rx_ready["n_in"].reshape(-1))
        never = jnp.zeros((Tr,), bool)
        ee2, _, _ = qmod.cycle(ee, e_pay[:, -1], e_val[:, -1], never)
        es2, _, _ = qmod.cycle(es, s_pay[-1], s_val[-1], jnp.zeros((Tc,), bool))

        return st.replace(cell=new_cell, qe=qe2, qs=qs2, ee=ee2, es=es2, cycle=st.cycle + 1)

    # ---------------------------------------------------------------- epoch
    def _epoch(self, st: GridState) -> GridState:
        """K local cycles + boundary exchange (runs inside shard_map)."""
        st = jax.lax.scan(lambda s, _: (self._local_cycle(s), None), st, None, length=self.K)[0]

        Dr, Dc, Tr, Tc = self.Dr, self.Dc, self.Tr, self.Tc
        perm_e = [(j, j + 1) for j in range(Dc - 1)]
        perm_w = [(j + 1, j) for j in range(Dc - 1)]
        perm_s = [(i, i + 1) for i in range(Dr - 1)]
        perm_n = [(i + 1, i) for i in range(Dr - 1)]

        def pshift(x, axis_name, perm):
            if not perm:
                return jnp.zeros_like(x)
            return jax.lax.ppermute(x, axis_name, perm)

        # --- eastward data ---
        ee2, slab_e, cnt_e = qmod.drain(st.ee, self.E, limit=st.credit_e)
        slab_e_in = pshift(slab_e, self.axis_c, perm_e)
        cnt_e_in = pshift(cnt_e, self.axis_c, perm_e)
        idx_w = jnp.arange(Tr, dtype=jnp.int32) * Tc  # local col-0 queue ids
        qe2 = qmod_fill_at(st.qe, idx_w, slab_e_in, cnt_e_in)
        # receiver advertises new free space; flows back west to the sender
        cred_e_new = jnp.take(qmod.free(qe2), idx_w)
        credit_e = pshift(cred_e_new, self.axis_c, perm_w)

        # --- southward data ---
        es2, slab_s, cnt_s = qmod.drain(st.es, self.E, limit=st.credit_s)
        slab_s_in = pshift(slab_s, self.axis_r, perm_s)
        cnt_s_in = pshift(cnt_s, self.axis_r, perm_s)
        idx_n = jnp.arange(Tc, dtype=jnp.int32)  # local row-0 queue ids
        qs2 = qmod_fill_at(st.qs, idx_n, slab_s_in, cnt_s_in)
        cred_s_new = jnp.take(qmod.free(qs2), idx_n)
        credit_s = pshift(cred_s_new, self.axis_r, perm_n)

        return st.replace(
            qe=qe2, qs=qs2, ee=ee2, es=es2,
            credit_e=credit_e, credit_s=credit_s,
            epoch=st.epoch + 1,
        )

    # ------------------------------------------------------------------ run
    def epoch_fn(self):
        """shard_map'd single-epoch function (used by dryrun + benchmarks)."""

        def run(state):
            local = _sq(state)
            return _unsq(self._epoch(local))

        return jax.shard_map(
            run, mesh=self.mesh, in_specs=self._spec, out_specs=self._spec
        )

    def run_epochs(self, state: GridState, n_epochs: int) -> GridState:
        key = ("run", n_epochs)
        if key not in self._jit_cache:
            def run(state):
                local = _sq(state)
                out = jax.lax.scan(
                    lambda s, _: (self._epoch(s), None), local, None, length=n_epochs
                )[0]
                return _unsq(out)

            self._jit_cache[key] = jax.jit(
                jax.shard_map(run, mesh=self.mesh, in_specs=self._spec, out_specs=self._spec)
            )
        return self._jit_cache[key](state)

    def run_until(
        self,
        state: GridState,
        done_fn: Callable[[PyTree], jax.Array],
        max_epochs: int,
    ) -> GridState:
        """Run epochs until ``done_fn(local_cell_states)`` holds everywhere.

        done_fn gets (Tr, Tc, ...) local cell state, returns () bool.
        """
        key = ("until", id(done_fn), max_epochs)
        if key not in self._jit_cache:
            def run(state):
                local = _sq(state)

                # The global done flag is computed in the *body* and carried,
                # so the while condition itself contains no collectives.
                def cond(carry):
                    s, pending = carry
                    return (pending > 0) & (s.epoch < max_epochs)

                def body(carry):
                    s, _ = carry
                    s = self._epoch(s)
                    not_done = 1 - done_fn(s.cell).astype(jnp.int32)
                    pending = jax.lax.psum(
                        jax.lax.psum(not_done, self.axis_r), self.axis_c
                    )
                    return s, pending

                out, _ = jax.lax.while_loop(
                    cond, body, (local, jnp.ones((), jnp.int32))
                )
                return _unsq(out)

            self._jit_cache[key] = jax.jit(
                jax.shard_map(run, mesh=self.mesh, in_specs=self._spec, out_specs=self._spec)
            )
        return self._jit_cache[key](state)

    # ------------------------------------------------------- host utilities
    def gather_cells(self, state: GridState) -> PyTree:
        """Return cell states reassembled to global (R, C, ...) layout."""
        Dr, Dc, Tr, Tc = self.Dr, self.Dc, self.Tr, self.Tc

        def untile(x):
            x = np.asarray(x)
            return x.transpose((0, 2, 1, 3) + tuple(range(4, x.ndim))).reshape(
                (self.R, self.C) + x.shape[4:]
            )

        return jax.tree.map(untile, jax.device_get(state.cell))


def qmod_fill_at(q: qmod.QueueArray, idx: jax.Array, payloads: jax.Array, count: jax.Array) -> qmod.QueueArray:
    """Fill a subset of queues (rows ``idx``) of a QueueArray.

    payloads: (len(idx), max_n, W); count: (len(idx),).
    """
    sub = qmod.QueueArray(
        buf=q.buf[idx], head=q.head[idx], tail=q.tail[idx], capacity=q.capacity
    )
    sub2 = qmod.fill(sub, payloads, count)
    return q.replace(
        buf=q.buf.at[idx].set(sub2.buf),
        head=q.head.at[idx].set(sub2.head),
    )

"""Minimal pytree-dataclass helper (flax.struct-like, zero deps).

Every core data structure (queues, block states, network state) is a frozen
dataclass registered as a JAX pytree so it can flow through jit / scan /
vmap / shard_map without ceremony.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, TypeVar

import jax

_T = TypeVar("_T")


def pytree_dataclass(cls: type[_T]) -> type[_T]:
    """Decorator: freeze ``cls`` and register it as a JAX pytree node.

    All fields are pytree children unless annotated via
    ``field(metadata={'static': True})``, in which case they are hashable
    aux data (useful for shapes, port maps, python ints).
    """
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = dataclasses.fields(cls)
    data_names = [f.name for f in fields if not f.metadata.get("static", False)]
    static_names = [f.name for f in fields if f.metadata.get("static", False)]

    def flatten(obj):
        data = tuple(getattr(obj, n) for n in data_names)
        static = tuple(getattr(obj, n) for n in static_names)
        return data, static

    def flatten_with_keys(obj):
        data = tuple(
            (jax.tree_util.GetAttrKey(n), getattr(obj, n)) for n in data_names
        )
        static = tuple(getattr(obj, n) for n in static_names)
        return data, static

    def unflatten(static, data):
        kwargs = dict(zip(data_names, data))
        kwargs.update(dict(zip(static_names, static)))
        return cls(**kwargs)

    jax.tree_util.register_pytree_with_keys(cls, flatten_with_keys, unflatten, flatten)

    def replace(self, **kwargs):
        return dataclasses.replace(self, **kwargs)

    cls.replace = replace  # type: ignore[attr-defined]
    return cls


def static_field(**kwargs: Any) -> Any:
    """A dataclass field treated as static (pytree aux data)."""
    metadata = dict(kwargs.pop("metadata", {}))
    metadata["static"] = True
    return dataclasses.field(metadata=metadata, **kwargs)


def field(default: Any = dataclasses.MISSING, *, default_factory: Any = dataclasses.MISSING) -> Any:
    if default_factory is not dataclasses.MISSING:
        return dataclasses.field(default_factory=default_factory)
    return dataclasses.field(default=default)

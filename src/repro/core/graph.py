"""Channel-graph intermediate representation (DESIGN.md §1).

The IR sits between the user-facing ``Network`` builder and every execution
backend.  It is a flat, engine-agnostic table of

    (block group, instance slot, port)  ->  channel id

plus the channel endpoint table and the external-port maps.  Everything is
plain numpy — no jax arrays, no device state — so a graph can be built
once and handed to any engine:

    NetworkSim           interprets the whole graph as one netlist
                         (``repro.core.network``),
    GraphEngine          partitions instances into granules and runs the
                         epoch-batched distributed protocol over arbitrary
                         granule adjacency (``repro.core.distributed``),
    RegisterGridEngine   pattern-matches the systolic-grid shape and runs
                         the kernel-fused backend (``repro.core.fastgrid``).

Conventions shared by all consumers:

  * Channel ids 0 and 1 are sentinels: ``NULL_RX`` (reads never valid) and
    ``NULL_TX`` (writes always accepted and dropped).  Unwired input ports
    map to ``NULL_RX``; unwired output ports map to ``NULL_TX``.
  * Instances of the same ``Block`` *object* form one group and are stepped
    by a single vmapped body (the paper's "one prebuilt simulator per
    unique block", §III-F).  ``rx_idx[g][i, p]`` / ``tx_idx[g][i, p]`` give
    the channel driven by member ``i``'s ``p``-th in/out port.
  * Channels are SPSC: each channel has exactly one producer port and one
    consumer port (checked at build time).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Mapping, Sequence

import jax
import numpy as np

from .block import Block

PyTree = Any

NULL_RX = 0
NULL_TX = 1
_N_SENTINELS = 2


@dataclasses.dataclass
class GroupDef:
    """One unique block type and its instances (in instantiation order)."""

    block: Block
    members: np.ndarray  # (n_m,) int32 global instance ids
    names: tuple[str, ...]
    params: PyTree | None  # stacked per-member params (leading n_m dim) or None

    @property
    def n_members(self) -> int:
        return int(self.members.shape[0])


class ChannelGraph:
    """Flat channel-graph IR — the single source of truth for all engines."""

    NULL_RX = NULL_RX
    NULL_TX = NULL_TX

    def __init__(
        self,
        *,
        payload_words: int,
        dtype: Any,
        capacity: int,
        groups: list[GroupDef],
        rx_idx: list[np.ndarray],
        tx_idx: list[np.ndarray],
        chan_src: np.ndarray,
        chan_dst: np.ndarray,
        ext_in: Mapping[str, int],
        ext_out: Mapping[str, int],
    ):
        self.payload_words = payload_words
        self.dtype = dtype
        self.capacity = capacity
        self.groups = groups
        self.rx_idx = rx_idx  # per group: (n_m, n_in) int32 global channel ids
        self.tx_idx = tx_idx  # per group: (n_m, n_out) int32 global channel ids
        self.chan_src = np.asarray(chan_src, np.int32)  # (n_channels,) inst or -1
        self.chan_dst = np.asarray(chan_dst, np.int32)  # (n_channels,) inst or -1
        self.ext_in = dict(ext_in)  # name -> channel id (host pushes)
        self.ext_out = dict(ext_out)  # name -> channel id (host pops)
        self.n_channels = int(self.chan_src.shape[0])
        self.n_instances = sum(g.n_members for g in groups)
        # instance id -> (group index, slot within group)
        self.inst_loc = np.zeros((self.n_instances, 2), np.int32)
        for gi, g in enumerate(groups):
            self.inst_loc[g.members, 0] = gi
            self.inst_loc[g.members, 1] = np.arange(g.n_members, dtype=np.int32)

    # -- construction --------------------------------------------------------
    @classmethod
    def from_network(cls, net) -> "ChannelGraph":
        """Extract the IR from a built ``repro.core.network.Network``.

        Channel numbering (sentinels, connections in declaration order, then
        external-in, then external-out) matches the historical single-netlist
        layout so states remain comparable across engine backends.
        """
        insts = net._instances

        by_block: dict[int, list] = {}
        order: list[int] = []
        for inst in insts:
            key = id(inst.block)
            if key not in by_block:
                by_block[key] = []
                order.append(key)
            by_block[key].append(inst)

        groups: list[GroupDef] = []
        for key in order:
            members = by_block[key]
            if any(m.params is not None for m in members):
                params = jax.tree.map(
                    lambda *xs: np.stack([np.asarray(x) for x in xs]),
                    *[m.params for m in members],
                )
            else:
                params = None
            groups.append(
                GroupDef(
                    block=members[0].block,
                    members=np.array([m.inst_id for m in members], np.int32),
                    names=tuple(m.name for m in members),
                    params=params,
                )
            )

        n_channels = _N_SENTINELS
        chan_of_tx: dict[tuple[int, str], int] = {}
        chan_of_rx: dict[tuple[int, str], int] = {}
        src_list: list[int] = [-1, -1]
        dst_list: list[int] = [-1, -1]
        for tx, rx in net._connections:
            if (tx.inst_id, tx.port) in chan_of_tx:
                raise ValueError(f"output port {tx} connected twice (SPSC)")
            if (rx.inst_id, rx.port) in chan_of_rx:
                raise ValueError(f"input port {rx} connected twice (SPSC)")
            chan_of_tx[(tx.inst_id, tx.port)] = n_channels
            chan_of_rx[(rx.inst_id, rx.port)] = n_channels
            src_list.append(tx.inst_id)
            dst_list.append(rx.inst_id)
            n_channels += 1
        ext_in: dict[str, int] = {}
        for name, rx in net._external_in.items():
            if (rx.inst_id, rx.port) in chan_of_rx:
                raise ValueError(f"input port {rx} connected twice (SPSC)")
            chan_of_rx[(rx.inst_id, rx.port)] = n_channels
            ext_in[name] = n_channels
            src_list.append(-1)
            dst_list.append(rx.inst_id)
            n_channels += 1
        ext_out: dict[str, int] = {}
        for name, tx in net._external_out.items():
            if (tx.inst_id, tx.port) in chan_of_tx:
                raise ValueError(f"output port {tx} connected twice (SPSC)")
            chan_of_tx[(tx.inst_id, tx.port)] = n_channels
            ext_out[name] = n_channels
            src_list.append(tx.inst_id)
            dst_list.append(-1)
            n_channels += 1

        rx_idx: list[np.ndarray] = []
        tx_idx: list[np.ndarray] = []
        for g in groups:
            blk = g.block
            rxm = np.full((g.n_members, len(blk.in_ports)), NULL_RX, np.int32)
            txm = np.full((g.n_members, len(blk.out_ports)), NULL_TX, np.int32)
            for i, inst_id in enumerate(g.members):
                for p, port in enumerate(blk.in_ports):
                    rxm[i, p] = chan_of_rx.get((int(inst_id), port), NULL_RX)
                for p, port in enumerate(blk.out_ports):
                    txm[i, p] = chan_of_tx.get((int(inst_id), port), NULL_TX)
            rx_idx.append(rxm)
            tx_idx.append(txm)

        return cls(
            payload_words=net.payload_words,
            dtype=net.dtype,
            capacity=net.capacity,
            groups=groups,
            rx_idx=rx_idx,
            tx_idx=tx_idx,
            chan_src=np.array(src_list, np.int32),
            chan_dst=np.array(dst_list, np.int32),
            ext_in=ext_in,
            ext_out=ext_out,
        )

    @classmethod
    def _uniform_2port(
        cls,
        cell: Block,
        n: int,
        rxm: np.ndarray,
        txm: np.ndarray,
        chan_src: np.ndarray,
        chan_dst: np.ndarray,
        params: PyTree | None,
        payload_words: int | None,
        dtype: Any,
        capacity: int | None,
    ) -> "ChannelGraph":
        """Assemble a single-group graph from prebuilt vectorized tables."""
        import jax.numpy as jnp
        from . import queue as qmod

        group = GroupDef(
            block=cell,
            members=np.arange(n, dtype=np.int32),
            names=tuple(),  # names elided at this scale
            params=params,
        )
        return cls(
            payload_words=payload_words or cell.payload_words,
            dtype=dtype if dtype is not None else jnp.float32,
            capacity=capacity or qmod.DEFAULT_CAPACITY,
            groups=[group],
            rx_idx=[rxm.astype(np.int32)],
            tx_idx=[txm.astype(np.int32)],
            chan_src=chan_src.astype(np.int32),
            chan_dst=chan_dst.astype(np.int32),
            ext_in={},
            ext_out={},
        )

    @classmethod
    def grid(
        cls,
        cell: Block,
        R: int,
        C: int,
        *,
        params: PyTree | None = None,
        payload_words: int | None = None,
        dtype: Any = None,
        capacity: int | None = None,
    ) -> "ChannelGraph":
        """Vectorized builder for a uniform R×C grid of ``cell`` instances.

        Dataflow is east (``out_ports[0]`` -> ``in_ports[0]``) and south
        (``out_ports[1]`` -> ``in_ports[1]``), instance ids row-major —
        the §IV-B manycore topology.  O(R*C) numpy, no Python per-instance
        loop, so million-core graphs stay cheap to describe.
        """
        if len(cell.in_ports) != 2 or len(cell.out_ports) != 2:
            raise ValueError("grid() needs a cell with 2 in and 2 out ports")
        n = R * C
        rr, cc = np.divmod(np.arange(n, dtype=np.int64), C)

        n_east = R * (C - 1)
        east_of = lambda r, c: _N_SENTINELS + r * (C - 1) + c  # noqa: E731
        south_of = lambda r, c: _N_SENTINELS + n_east + r * C + c  # noqa: E731
        n_channels = _N_SENTINELS + n_east + (R - 1) * C

        chan_src = np.full((n_channels,), -1, np.int64)
        chan_dst = np.full((n_channels,), -1, np.int64)
        er, ec = np.divmod(np.arange(n_east, dtype=np.int64), C - 1) if C > 1 else (
            np.zeros(0, np.int64), np.zeros(0, np.int64))
        chan_src[_N_SENTINELS:_N_SENTINELS + n_east] = er * C + ec
        chan_dst[_N_SENTINELS:_N_SENTINELS + n_east] = er * C + ec + 1
        sr, sc = np.divmod(np.arange((R - 1) * C, dtype=np.int64), C)
        chan_src[_N_SENTINELS + n_east:] = sr * C + sc
        chan_dst[_N_SENTINELS + n_east:] = (sr + 1) * C + sc

        rxm = np.empty((n, 2), np.int64)
        txm = np.empty((n, 2), np.int64)
        rxm[:, 0] = np.where(cc > 0, east_of(rr, cc - 1), NULL_RX)
        rxm[:, 1] = np.where(rr > 0, south_of(rr - 1, cc), NULL_RX)
        txm[:, 0] = np.where(cc < C - 1, east_of(rr, cc), NULL_TX)
        txm[:, 1] = np.where(rr < R - 1, south_of(rr, cc), NULL_TX)

        return cls._uniform_2port(
            cell, n, rxm, txm, chan_src, chan_dst,
            params, payload_words, dtype, capacity,
        )

    @classmethod
    def torus(
        cls,
        cell: Block,
        R: int,
        C: int,
        *,
        params: PyTree | None = None,
        payload_words: int | None = None,
        dtype: Any = None,
        capacity: int | None = None,
    ) -> "ChannelGraph":
        """Vectorized builder for a uniform R×C 2-D torus of ``cell``.

        Same port convention as ``grid`` (east = ``out_ports[0]`` ->
        ``in_ports[0]``, south = ``out_ports[1]`` -> ``in_ports[1]``) but
        with wrap-around links, so every port is wired and every row/column
        is a ring — the wafer-scale many-core topology
        (``examples/wafer_scale.py``).  O(R*C) numpy, no per-instance loop.
        """
        if len(cell.in_ports) != 2 or len(cell.out_ports) != 2:
            raise ValueError("torus() needs a cell with 2 in and 2 out ports")
        n = R * C
        rr, cc = np.divmod(np.arange(n, dtype=np.int64), C)

        # Channel ids: east ring channels first (one per cell), then south.
        east_of = lambda r, c: _N_SENTINELS + r * C + c  # noqa: E731
        south_of = lambda r, c: _N_SENTINELS + n + r * C + c  # noqa: E731
        n_channels = _N_SENTINELS + 2 * n

        chan_src = np.full((n_channels,), -1, np.int64)
        chan_dst = np.full((n_channels,), -1, np.int64)
        chan_src[_N_SENTINELS:_N_SENTINELS + n] = rr * C + cc
        chan_dst[_N_SENTINELS:_N_SENTINELS + n] = rr * C + (cc + 1) % C
        chan_src[_N_SENTINELS + n:] = rr * C + cc
        chan_dst[_N_SENTINELS + n:] = ((rr + 1) % R) * C + cc

        rxm = np.empty((n, 2), np.int64)
        txm = np.empty((n, 2), np.int64)
        rxm[:, 0] = east_of(rr, (cc - 1) % C)
        rxm[:, 1] = south_of((rr - 1) % R, cc)
        txm[:, 0] = east_of(rr, cc)
        txm[:, 1] = south_of(rr, cc)

        return cls._uniform_2port(
            cell, n, rxm, txm, chan_src, chan_dst,
            params, payload_words, dtype, capacity,
        )

    # -- queries -------------------------------------------------------------
    def locate(self, inst_id: int) -> tuple[int, int]:
        """(group index, slot) of a global instance id."""
        gi, slot = self.inst_loc[inst_id]
        return int(gi), int(slot)

    def channel_granules(self, partition: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Per-channel (src granule, dst granule); -1 for host/sentinel ends."""
        part = np.asarray(partition, np.int32)
        src_g = np.where(self.chan_src >= 0, part[np.clip(self.chan_src, 0, None)], -1)
        dst_g = np.where(self.chan_dst >= 0, part[np.clip(self.chan_dst, 0, None)], -1)
        return src_g.astype(np.int32), dst_g.astype(np.int32)

    def ext_ports(self) -> dict[str, tuple[int, bool]]:
        """Unified external-port table: name -> (channel id, is_input).

        ``is_input`` means the *host pushes* (an ``external_in`` port); the
        session layer builds its Tx/Rx queue handles from this table so
        every engine exposes the same host-port namespace.
        """
        ports = {name: (cid, True) for name, cid in self.ext_in.items()}
        ports.update({name: (cid, False) for name, cid in self.ext_out.items()})
        return ports

    def ext_home(self, partition: np.ndarray) -> dict[str, int]:
        """Granule that *homes* each external port under ``partition``.

        An external channel has exactly one simulated endpoint (the other
        end is the host, granule -1); its queue lives with that endpoint's
        granule, so host I/O touches only the owning granule's slab — the
        homing rule every distributed engine shares.
        """
        src_g, dst_g = self.channel_granules(partition)
        owner = np.where(src_g >= 0, src_g, dst_g)
        return {
            name: int(owner[cid]) for name, (cid, _) in self.ext_ports().items()
        }

    def summary(self) -> str:
        return (
            f"ChannelGraph({self.n_instances} instances in {len(self.groups)} "
            f"groups, {self.n_channels - _N_SENTINELS} channels, "
            f"{len(self.ext_in)} ext-in, {len(self.ext_out)} ext-out)"
        )


# -- partition maps ----------------------------------------------------------

def normalize_partition(graph: ChannelGraph, partition, n_granules: int) -> np.ndarray:
    """Canonicalize a partition map to a (n_instances,) int32 granule vector.

    Accepts ``None`` (everything on granule 0), a sequence of granule ids in
    instance order, or a ``{instance_name: granule}`` mapping (unlisted
    instances default to granule 0).
    """
    if partition is None:
        part = np.zeros((graph.n_instances,), np.int32)
    elif isinstance(partition, Mapping):
        part = np.zeros((graph.n_instances,), np.int32)
        name_to_inst = {
            name: int(inst)
            for g in graph.groups
            for name, inst in zip(g.names, g.members)
        }
        for name, gran in partition.items():
            if name not in name_to_inst:
                raise KeyError(f"partition names unknown instance {name!r}")
            part[name_to_inst[name]] = int(gran)
    else:
        part = np.asarray(partition, np.int32)
        if part.shape != (graph.n_instances,):
            raise ValueError(
                f"partition has shape {part.shape}, expected ({graph.n_instances},)"
            )
    if part.size and (part.min() < 0 or part.max() >= n_granules):
        raise ValueError(
            f"partition assigns granules outside [0, {n_granules}): "
            f"[{part.min()}, {part.max()}]"
        )
    return part


def grid_partition(R: int, C: int, Dr: int, Dc: int) -> np.ndarray:
    """Block-tile partition of a row-major R×C grid onto Dr×Dc granules."""
    if R % Dr or C % Dc:
        raise ValueError(f"grid {R}x{C} not divisible by device tile {Dr}x{Dc}")
    Tr, Tc = R // Dr, C // Dc
    rr, cc = np.divmod(np.arange(R * C, dtype=np.int64), C)
    return ((rr // Tr) * Dc + (cc // Tc)).astype(np.int32)


# -- hierarchical partitions (DESIGN.md §3) ----------------------------------

@dataclasses.dataclass(frozen=True)
class Tier:
    """One level of the partition tree: a group of mesh axes + a sync rate.

    axes: the mesh axes this tier spans (e.g. ``("pod",)`` for the DCI tier,
          ``("gr", "gc")`` for the intra-pod ICI tier).
    K:    sync rate.  For the innermost tier, the number of granule-local
          cycles per tier round; for an outer tier, the number of
          next-inner-tier rounds per round of this tier.  A tier-t boundary
          channel is therefore synchronized every ``prod(K_t .. K_inner)``
          cycles (its *period*).
    name: optional label for diagnostics.
    """

    axes: tuple[str, ...]
    K: int = 1
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "axes", tuple(self.axes))
        if self.K < 1:
            raise ValueError(f"tier K must be >= 1, got {self.K}")
        if not self.axes:
            raise ValueError("tier needs at least one mesh axis")


def normalize_tiers(tiers) -> tuple[Tier, ...]:
    """Canonicalize a tier spec: a sequence of ``Tier`` or ``(axes, K)``
    pairs (axes a name or tuple of names), outermost (slowest) first."""
    out: list[Tier] = []
    for t in tiers:
        if isinstance(t, Tier):
            out.append(t)
        else:
            axes, K = t
            if isinstance(axes, str):
                axes = (axes,)
            out.append(Tier(axes=tuple(axes), K=int(K)))
    seen: set[str] = set()
    for t in out:
        for a in t.axes:
            if a in seen:
                raise ValueError(f"mesh axis {a!r} appears in two tiers")
            seen.add(a)
    if not out:
        raise ValueError("need at least one tier")
    return tuple(out)


class PartitionTree:
    """Hierarchical instance -> granule assignment over tiered mesh axes.

    The *leaf granule* id of an instance is the row-major flattening of its
    per-axis device coordinates, axes ordered outermost tier first — i.e.
    ``part`` is exactly the flat granule vector the engines consume, plus
    the tree structure needed to classify boundary channels by the
    outermost tier they cross and to derive per-tier sync periods.

    part:       (n_instances,) int32 leaf granule ids.
    tiers:      outermost-first ``Tier`` sequence (see ``Tier``).
    axis_sizes: mesh-axis name -> size, for every axis named by a tier.
    """

    def __init__(self, part, tiers, axis_sizes: Mapping[str, int]):
        self.tiers = normalize_tiers(tiers)
        self.axes = tuple(a for t in self.tiers for a in t.axes)
        missing = [a for a in self.axes if a not in axis_sizes]
        if missing:
            raise ValueError(f"axis_sizes missing sizes for axes {missing}")
        self.dev_shape = tuple(int(axis_sizes[a]) for a in self.axes)
        self.n_granules = int(np.prod(self.dev_shape))
        self.part = np.asarray(part, np.int32)
        if self.part.ndim != 1:
            raise ValueError("part must be a 1-D granule vector")
        if self.part.size and (
            self.part.min() < 0 or self.part.max() >= self.n_granules
        ):
            raise ValueError(
                f"part assigns granules outside [0, {self.n_granules})"
            )
        # tier t covers axis indices [_axis_start[t], _axis_start[t+1])
        self._axis_start = np.cumsum([0] + [len(t.axes) for t in self.tiers])

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    @property
    def K_tiers(self) -> tuple[int, ...]:
        return tuple(t.K for t in self.tiers)

    def periods(self) -> tuple[int, ...]:
        """Cycles between tier-t synchronizations: prod(K_t .. K_inner)."""
        ps, acc = [], 1
        for t in reversed(self.tiers):
            acc *= t.K
            ps.append(acc)
        return tuple(reversed(ps))

    @property
    def cycles_per_epoch(self) -> int:
        return self.periods()[0]

    def tier_of_edges(self, src_g: np.ndarray, dst_g: np.ndarray) -> np.ndarray:
        """Outermost tier crossed by each (src granule, dst granule) edge.

        Returns (n,) int32: the smallest tier index t such that the two
        granules differ in one of tier t's axes, or -1 when the granules
        are identical (or either end is a host/sentinel, id < 0).
        """
        src_g = np.asarray(src_g, np.int64)
        dst_g = np.asarray(dst_g, np.int64)
        valid = (src_g >= 0) & (dst_g >= 0)
        sc = np.stack(
            np.unravel_index(np.clip(src_g, 0, None), self.dev_shape), axis=0
        )  # (n_axes, n)
        dc = np.stack(
            np.unravel_index(np.clip(dst_g, 0, None), self.dev_shape), axis=0
        )
        tier = np.full(src_g.shape, -1, np.int32)
        # innermost first so the outermost differing tier wins the overwrite
        for t in reversed(range(self.n_tiers)):
            lo, hi = self._axis_start[t], self._axis_start[t + 1]
            diff = (sc[lo:hi] != dc[lo:hi]).any(axis=0)
            tier = np.where(diff, t, tier)
        return np.where(valid, tier, -1).astype(np.int32)

    def summary(self) -> str:
        parts = ", ".join(
            f"{t.name or '/'.join(t.axes)}:K={t.K}" for t in self.tiers
        )
        return (
            f"PartitionTree({self.part.size} instances -> {self.n_granules} "
            f"granules, tiers [{parts}], periods {self.periods()})"
        )


# -- partition lowering (engine-independent) ---------------------------------

def _rank_within(groups: np.ndarray, n_groups: int) -> tuple[np.ndarray, np.ndarray]:
    """For each element, its rank among elements of the same group value.

    Returns (rank, counts).  Stable: earlier elements get lower ranks.
    """
    counts = np.bincount(groups, minlength=n_groups) if groups.size else np.zeros(
        (n_groups,), np.int64
    )
    order = np.argsort(groups, kind="stable")
    starts = np.zeros((n_groups,), np.int64)
    if n_groups > 1:
        starts[1:] = np.cumsum(counts[:-1])
    rank = np.empty((groups.size,), np.int64)
    rank[order] = np.arange(groups.size, dtype=np.int64) - np.repeat(starts, counts)
    return rank, counts


class PartitionLowering:
    """Mesh-independent lowering of (ChannelGraph, PartitionTree) to
    per-granule tables (DESIGN.md §3, §Runtime).

    This is the shared front half of every distributed backend: the
    shard_map engines (``distributed.GraphEngine`` and subclasses) stack
    these tables into device arrays and add the ppermute exchange-class
    schedule; the multiprocess runtime (``repro.runtime``) hands each
    granule its own row and wires the boundary channels to shared-memory
    queues instead.  Keeping the queue-id assignment here — in exactly one
    place — is what makes the engines' granule-local state layouts (and
    therefore their simulated traffic) bit-identical.

    Local queue id assignment: every channel owns one queue per granule it
    touches — internal/external channels one queue in their owner granule;
    boundary channels an egress queue (sender side) and an ingress queue
    (receiver side).  Ids 0/1 are the NULL_RX / NULL_TX sentinels.
    """

    def __init__(self, graph: "ChannelGraph", ptree: "PartitionTree"):
        if ptree.part.shape != (graph.n_instances,):
            raise ValueError(
                f"PartitionTree covers {ptree.part.size} instances, "
                f"graph has {graph.n_instances}"
            )
        self.graph = graph
        self.ptree = ptree
        g, G = graph, ptree.n_granules
        self.G = G
        part = ptree.part
        NRX, NTX = g.NULL_RX, g.NULL_TX
        src_g, dst_g = g.channel_granules(part)
        self.src_g, self.dst_g = src_g, dst_g
        owner = np.where(src_g >= 0, src_g, dst_g)  # ext channels live with
        boundary = (src_g >= 0) & (dst_g >= 0) & (src_g != dst_g)  # their block
        cids = np.arange(g.n_channels, dtype=np.int64)
        self.boundary = boundary

        loc = (owner >= 0) & ~boundary
        ent_g = np.concatenate([owner[loc], src_g[boundary], dst_g[boundary]])
        ent_c = np.concatenate([cids[loc], cids[boundary], cids[boundary]])
        n_loc = int(loc.sum())
        n_bnd = int(boundary.sum())
        ent_kind = np.concatenate(
            [np.zeros(n_loc, np.int8), np.ones(n_bnd, np.int8), np.full(n_bnd, 2, np.int8)]
        )
        rank, counts = _rank_within(ent_g.astype(np.int64), G)
        lid = 2 + rank
        self.n_local = int(2 + (counts.max() if counts.size else 0))

        # channel -> local queue id on its producer/consumer side
        tx_local = np.full((g.n_channels,), NTX, np.int64)
        rx_local = np.full((g.n_channels,), NRX, np.int64)
        tx_local[ent_c[ent_kind == 0]] = lid[ent_kind == 0]
        rx_local[ent_c[ent_kind == 0]] = lid[ent_kind == 0]
        tx_local[ent_c[ent_kind == 1]] = lid[ent_kind == 1]  # egress
        rx_local[ent_c[ent_kind == 2]] = lid[ent_kind == 2]  # ingress
        tx_local[NTX], rx_local[NRX] = NTX, NRX
        self.tx_local, self.rx_local = tx_local, rx_local
        self.chan_owner = owner
        # entity table (granule, channel, kind 0=local 1=egress 2=ingress,
        # local queue id) — FusedEngine re-lowers it onto registers + queues
        self.ent = (ent_g.astype(np.int64), ent_c, ent_kind, lid)

        # Per-group member placement + local port tables (padded to n_slot).
        rx_t, tx_t, act_t = [], [], []
        self.member_of: list[np.ndarray] = []  # (G, n_slot) member index
        self.member_granule: list[np.ndarray] = []  # (n_m,)
        self.member_slot: list[np.ndarray] = []  # (n_m,)
        self.n_slot: list[int] = []
        for gi, grp in enumerate(g.groups):
            gm = part[grp.members].astype(np.int64)
            slot, counts = _rank_within(gm, G)
            n_slot = int(max(counts.max() if counts.size else 0, 1))
            member_of = np.zeros((G, n_slot), np.int64)
            active = np.zeros((G, n_slot), bool)
            member_of[gm, slot] = np.arange(grp.n_members, dtype=np.int64)
            active[gm, slot] = True
            rxm = np.full((G, n_slot, g.rx_idx[gi].shape[1]), NRX, np.int64)
            txm = np.full((G, n_slot, g.tx_idx[gi].shape[1]), NTX, np.int64)
            rxm[gm, slot] = rx_local[g.rx_idx[gi]]
            txm[gm, slot] = tx_local[g.tx_idx[gi]]
            rx_t.append(rxm.astype(np.int32))
            tx_t.append(txm.astype(np.int32))
            act_t.append(active)
            self.member_of.append(member_of)
            self.member_granule.append(gm)
            self.member_slot.append(slot)
            self.n_slot.append(n_slot)
        self.rx_tables, self.tx_tables, self.act_tables = rx_t, tx_t, act_t

        # Boundary channels, classified by the outermost tier they cross,
        # grouped into directed granule-pair routes (tier, src, dst).
        self.chan_tier = ptree.tier_of_edges(src_g, dst_g)  # -1 when local
        routes: dict[tuple[int, int, int], list[int]] = {}
        for c in cids[boundary]:
            key = (int(self.chan_tier[c]), int(src_g[c]), int(dst_g[c]))
            routes.setdefault(key, []).append(int(c))
        self.routes = routes
        self._signatures: list[str] | None = None

    # -- per-granule views (the multiprocess runtime's slices) ---------------
    def tier_channels(self, t: int, granule: int) -> tuple[list[int], list[int]]:
        """Tier-t boundary channels of one granule: (egress, ingress) channel
        ids in deterministic (channel-id) order.  Exchange order within a
        tier is semantically free — every channel owns disjoint queues — so
        channel-id order is simply the canonical one."""
        eg = [c for (tt, s, d), cs in sorted(self.routes.items())
              for c in cs if tt == t and s == granule]
        ing = [c for (tt, s, d), cs in sorted(self.routes.items())
               for c in cs if tt == t and d == granule]
        return sorted(eg), sorted(ing)

    def ext_channels(self, granule: int) -> list[tuple[str, int, bool]]:
        """External ports homed on ``granule``: (name, channel id, is_input),
        in the graph's declaration order."""
        out = []
        for name, (cid, is_input) in self.graph.ext_ports().items():
            if int(self.chan_owner[cid]) == granule:
                out.append((name, cid, is_input))
        return out

    def granule_signature(self, granule: int) -> str:
        """Stable signature of one granule's *compiled shape* — the prebuilt
        simulator cache key (paper §III-F: one prebuilt simulator per unique
        block; here per unique granule shape).

        Two granules share a signature iff their epoch steppers trace to the
        same jaxpr: same block types/configs, same slot counts, same local
        queue count and payload signature, same per-tier exchange shapes.
        Table *values* (port wirings, member placement) are runtime inputs
        to the compiled stepper, not constants, so they are excluded —
        that is exactly what lets N instances of one block compile once.
        """
        g = self.graph
        parts: list[str] = [
            f"W={g.payload_words}", f"cap={g.capacity}",
            f"dtype={np.dtype(g.dtype).str if g.dtype is not None else 'f4'}",
            f"n_local={self.n_local}",
            f"K={self.ptree.K_tiers}",
        ]
        for gi, grp in enumerate(g.groups):
            blk = grp.block
            cfg = {
                k: (f"<{v.shape}:{v.dtype}>" if isinstance(v, np.ndarray)
                    else repr(v))
                for k, v in sorted(vars(blk).items())
                if not k.startswith("_")
            }
            parts.append(
                f"g{gi}:{type(blk).__module__}.{type(blk).__qualname__}"
                f":{cfg}:slots={self.n_slot[gi]}:n_m={grp.n_members}"
                f":div={blk.clock_divider}"
            )
        for t in range(self.ptree.n_tiers):
            n_eg = sum(len(cs) for (tt, s, _), cs in self.routes.items()
                       if tt == t)
            # per-granule egress/ingress counts shape the drain/fill fns
            eg, ing = self.tier_channels(t, granule)
            parts.append(f"t{t}:eg={len(eg)}:in={len(ing)}:all={n_eg}")
        parts.append(f"ext={len(self.ext_channels(granule))}")
        return hashlib.sha256("|".join(parts).encode()).hexdigest()[:16]

    # -- signature batching (PR 6) ------------------------------------------
    def granule_signatures(self) -> list[str]:
        """``granule_signature`` of every granule, computed once and cached
        (the signature walk scans the route table, so the cache matters for
        wide meshes)."""
        if self._signatures is None:
            self._signatures = [
                self.granule_signature(g) for g in range(self.G)
            ]
        return self._signatures

    def signature_groups(self) -> dict[str, list[int]]:
        """Granules grouped by compiled-shape signature.

        Signature -> ascending granule ids.  All granules in one group
        trace to the *same* stepper jaxpr, so they can be stacked on one
        leading batch axis and stepped by a single vmapped dispatch — the
        batching lowering consumed by the in-process engines
        (``batch_axes``) and the multiprocess launcher
        (``batch_signatures``)."""
        groups: dict[str, list[int]] = {}
        for g, sig in enumerate(self.granule_signatures()):
            groups.setdefault(sig, []).append(g)
        return groups

    def batch_plan(self) -> tuple[list[list[int]], dict[int, tuple[int, int]]]:
        """Signature-batch membership + inverse scatter map.

        Returns ``(batches, where)``: ``batches[b]`` lists the granules
        stacked into batch ``b`` (groups in first-granule order, members
        ascending — so batch row == rank within the signature group), and
        ``where[g] = (b, row)`` locates granule ``g``'s slice for
        scatter-back at tier exchange / probe routing."""
        groups = sorted(self.signature_groups().values(), key=lambda m: m[0])
        where = {
            g: (b, r)
            for b, members in enumerate(groups)
            for r, g in enumerate(members)
        }
        return groups, where


def lower_partition(graph: "ChannelGraph", ptree: "PartitionTree") -> PartitionLowering:
    """Lower (graph, partition tree) to per-granule tables — see
    ``PartitionLowering``."""
    return PartitionLowering(graph, ptree)


def tiered_grid_partition(
    R: int, C: int, tiles: Sequence[tuple[int, int]]
) -> np.ndarray:
    """Nested block-tiling of a row-major R×C grid, one tier per level.

    ``tiles`` lists per-tier (rows, cols) device splits outermost first;
    level t carves each level-(t-1) block into ``tr × tc`` sub-blocks.  The
    returned (R*C,) granule vector is flattened with one mesh axis per tier
    of size ``tr * tc`` (outermost first) — i.e. it matches a mesh of shape
    ``tuple(tr * tc for tr, tc in tiles)``.  ``tiles=[(Dr, Dc)]`` reduces to
    ``grid_partition`` modulo the single flattened axis.
    """
    rr, cc = np.divmod(np.arange(R * C, dtype=np.int64), C)
    gid = np.zeros((R * C,), np.int64)
    Rrem, Crem = R, C
    for tr, tc in tiles:
        if Rrem % tr or Crem % tc:
            raise ValueError(
                f"block {Rrem}x{Crem} not divisible by tier tile {tr}x{tc}"
            )
        br, bc = Rrem // tr, Crem // tc
        gid = gid * (tr * tc) + (rr // br) * tc + (cc // bc)
        rr, cc = rr % br, cc % bc
        Rrem, Crem = br, bc
    return gid.astype(np.int32)

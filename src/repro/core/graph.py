"""Channel-graph intermediate representation (DESIGN.md §1).

The IR sits between the user-facing ``Network`` builder and every execution
backend.  It is a flat, engine-agnostic table of

    (block group, instance slot, port)  ->  channel id

plus the channel endpoint table and the external-port maps.  Everything is
plain numpy — no jax arrays, no device state — so a graph can be built
once and handed to any engine:

    NetworkSim           interprets the whole graph as one netlist
                         (``repro.core.network``),
    GraphEngine          partitions instances into granules and runs the
                         epoch-batched distributed protocol over arbitrary
                         granule adjacency (``repro.core.distributed``),
    RegisterGridEngine   pattern-matches the systolic-grid shape and runs
                         the kernel-fused backend (``repro.core.fastgrid``).

Conventions shared by all consumers:

  * Channel ids 0 and 1 are sentinels: ``NULL_RX`` (reads never valid) and
    ``NULL_TX`` (writes always accepted and dropped).  Unwired input ports
    map to ``NULL_RX``; unwired output ports map to ``NULL_TX``.
  * Instances of the same ``Block`` *object* form one group and are stepped
    by a single vmapped body (the paper's "one prebuilt simulator per
    unique block", §III-F).  ``rx_idx[g][i, p]`` / ``tx_idx[g][i, p]`` give
    the channel driven by member ``i``'s ``p``-th in/out port.
  * Channels are SPSC: each channel has exactly one producer port and one
    consumer port (checked at build time).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import numpy as np

from .block import Block

PyTree = Any

NULL_RX = 0
NULL_TX = 1
_N_SENTINELS = 2


@dataclasses.dataclass
class GroupDef:
    """One unique block type and its instances (in instantiation order)."""

    block: Block
    members: np.ndarray  # (n_m,) int32 global instance ids
    names: tuple[str, ...]
    params: PyTree | None  # stacked per-member params (leading n_m dim) or None

    @property
    def n_members(self) -> int:
        return int(self.members.shape[0])


class ChannelGraph:
    """Flat channel-graph IR — the single source of truth for all engines."""

    NULL_RX = NULL_RX
    NULL_TX = NULL_TX

    def __init__(
        self,
        *,
        payload_words: int,
        dtype: Any,
        capacity: int,
        groups: list[GroupDef],
        rx_idx: list[np.ndarray],
        tx_idx: list[np.ndarray],
        chan_src: np.ndarray,
        chan_dst: np.ndarray,
        ext_in: Mapping[str, int],
        ext_out: Mapping[str, int],
    ):
        self.payload_words = payload_words
        self.dtype = dtype
        self.capacity = capacity
        self.groups = groups
        self.rx_idx = rx_idx  # per group: (n_m, n_in) int32 global channel ids
        self.tx_idx = tx_idx  # per group: (n_m, n_out) int32 global channel ids
        self.chan_src = np.asarray(chan_src, np.int32)  # (n_channels,) inst or -1
        self.chan_dst = np.asarray(chan_dst, np.int32)  # (n_channels,) inst or -1
        self.ext_in = dict(ext_in)  # name -> channel id (host pushes)
        self.ext_out = dict(ext_out)  # name -> channel id (host pops)
        self.n_channels = int(self.chan_src.shape[0])
        self.n_instances = sum(g.n_members for g in groups)
        # instance id -> (group index, slot within group)
        self.inst_loc = np.zeros((self.n_instances, 2), np.int32)
        for gi, g in enumerate(groups):
            self.inst_loc[g.members, 0] = gi
            self.inst_loc[g.members, 1] = np.arange(g.n_members, dtype=np.int32)

    # -- construction --------------------------------------------------------
    @classmethod
    def from_network(cls, net) -> "ChannelGraph":
        """Extract the IR from a built ``repro.core.network.Network``.

        Channel numbering (sentinels, connections in declaration order, then
        external-in, then external-out) matches the historical single-netlist
        layout so states remain comparable across engine backends.
        """
        insts = net._instances

        by_block: dict[int, list] = {}
        order: list[int] = []
        for inst in insts:
            key = id(inst.block)
            if key not in by_block:
                by_block[key] = []
                order.append(key)
            by_block[key].append(inst)

        groups: list[GroupDef] = []
        for key in order:
            members = by_block[key]
            if any(m.params is not None for m in members):
                params = jax.tree.map(
                    lambda *xs: np.stack([np.asarray(x) for x in xs]),
                    *[m.params for m in members],
                )
            else:
                params = None
            groups.append(
                GroupDef(
                    block=members[0].block,
                    members=np.array([m.inst_id for m in members], np.int32),
                    names=tuple(m.name for m in members),
                    params=params,
                )
            )

        n_channels = _N_SENTINELS
        chan_of_tx: dict[tuple[int, str], int] = {}
        chan_of_rx: dict[tuple[int, str], int] = {}
        src_list: list[int] = [-1, -1]
        dst_list: list[int] = [-1, -1]
        for tx, rx in net._connections:
            if (tx.inst_id, tx.port) in chan_of_tx:
                raise ValueError(f"output port {tx} connected twice (SPSC)")
            if (rx.inst_id, rx.port) in chan_of_rx:
                raise ValueError(f"input port {rx} connected twice (SPSC)")
            chan_of_tx[(tx.inst_id, tx.port)] = n_channels
            chan_of_rx[(rx.inst_id, rx.port)] = n_channels
            src_list.append(tx.inst_id)
            dst_list.append(rx.inst_id)
            n_channels += 1
        ext_in: dict[str, int] = {}
        for name, rx in net._external_in.items():
            if (rx.inst_id, rx.port) in chan_of_rx:
                raise ValueError(f"input port {rx} connected twice (SPSC)")
            chan_of_rx[(rx.inst_id, rx.port)] = n_channels
            ext_in[name] = n_channels
            src_list.append(-1)
            dst_list.append(rx.inst_id)
            n_channels += 1
        ext_out: dict[str, int] = {}
        for name, tx in net._external_out.items():
            if (tx.inst_id, tx.port) in chan_of_tx:
                raise ValueError(f"output port {tx} connected twice (SPSC)")
            chan_of_tx[(tx.inst_id, tx.port)] = n_channels
            ext_out[name] = n_channels
            src_list.append(tx.inst_id)
            dst_list.append(-1)
            n_channels += 1

        rx_idx: list[np.ndarray] = []
        tx_idx: list[np.ndarray] = []
        for g in groups:
            blk = g.block
            rxm = np.full((g.n_members, len(blk.in_ports)), NULL_RX, np.int32)
            txm = np.full((g.n_members, len(blk.out_ports)), NULL_TX, np.int32)
            for i, inst_id in enumerate(g.members):
                for p, port in enumerate(blk.in_ports):
                    rxm[i, p] = chan_of_rx.get((int(inst_id), port), NULL_RX)
                for p, port in enumerate(blk.out_ports):
                    txm[i, p] = chan_of_tx.get((int(inst_id), port), NULL_TX)
            rx_idx.append(rxm)
            tx_idx.append(txm)

        return cls(
            payload_words=net.payload_words,
            dtype=net.dtype,
            capacity=net.capacity,
            groups=groups,
            rx_idx=rx_idx,
            tx_idx=tx_idx,
            chan_src=np.array(src_list, np.int32),
            chan_dst=np.array(dst_list, np.int32),
            ext_in=ext_in,
            ext_out=ext_out,
        )

    @classmethod
    def grid(
        cls,
        cell: Block,
        R: int,
        C: int,
        *,
        params: PyTree | None = None,
        payload_words: int | None = None,
        dtype: Any = None,
        capacity: int | None = None,
    ) -> "ChannelGraph":
        """Vectorized builder for a uniform R×C grid of ``cell`` instances.

        Dataflow is east (``out_ports[0]`` -> ``in_ports[0]``) and south
        (``out_ports[1]`` -> ``in_ports[1]``), instance ids row-major —
        the §IV-B manycore topology.  O(R*C) numpy, no Python per-instance
        loop, so million-core graphs stay cheap to describe.
        """
        import jax.numpy as jnp
        from . import queue as qmod

        if len(cell.in_ports) != 2 or len(cell.out_ports) != 2:
            raise ValueError("grid() needs a cell with 2 in and 2 out ports")
        n = R * C
        rr, cc = np.divmod(np.arange(n, dtype=np.int64), C)

        n_east = R * (C - 1)
        east_of = lambda r, c: _N_SENTINELS + r * (C - 1) + c  # noqa: E731
        south_of = lambda r, c: _N_SENTINELS + n_east + r * C + c  # noqa: E731
        n_channels = _N_SENTINELS + n_east + (R - 1) * C

        chan_src = np.full((n_channels,), -1, np.int64)
        chan_dst = np.full((n_channels,), -1, np.int64)
        er, ec = np.divmod(np.arange(n_east, dtype=np.int64), C - 1) if C > 1 else (
            np.zeros(0, np.int64), np.zeros(0, np.int64))
        chan_src[_N_SENTINELS:_N_SENTINELS + n_east] = er * C + ec
        chan_dst[_N_SENTINELS:_N_SENTINELS + n_east] = er * C + ec + 1
        sr, sc = np.divmod(np.arange((R - 1) * C, dtype=np.int64), C)
        chan_src[_N_SENTINELS + n_east:] = sr * C + sc
        chan_dst[_N_SENTINELS + n_east:] = (sr + 1) * C + sc

        rxm = np.empty((n, 2), np.int64)
        txm = np.empty((n, 2), np.int64)
        rxm[:, 0] = np.where(cc > 0, east_of(rr, cc - 1), NULL_RX)
        rxm[:, 1] = np.where(rr > 0, south_of(rr - 1, cc), NULL_RX)
        txm[:, 0] = np.where(cc < C - 1, east_of(rr, cc), NULL_TX)
        txm[:, 1] = np.where(rr < R - 1, south_of(rr, cc), NULL_TX)

        group = GroupDef(
            block=cell,
            members=np.arange(n, dtype=np.int32),
            names=tuple(),  # names elided at this scale
            params=params,
        )
        return cls(
            payload_words=payload_words or cell.payload_words,
            dtype=dtype if dtype is not None else jnp.float32,
            capacity=capacity or qmod.DEFAULT_CAPACITY,
            groups=[group],
            rx_idx=[rxm.astype(np.int32)],
            tx_idx=[txm.astype(np.int32)],
            chan_src=chan_src.astype(np.int32),
            chan_dst=chan_dst.astype(np.int32),
            ext_in={},
            ext_out={},
        )

    # -- queries -------------------------------------------------------------
    def locate(self, inst_id: int) -> tuple[int, int]:
        """(group index, slot) of a global instance id."""
        gi, slot = self.inst_loc[inst_id]
        return int(gi), int(slot)

    def channel_granules(self, partition: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Per-channel (src granule, dst granule); -1 for host/sentinel ends."""
        part = np.asarray(partition, np.int32)
        src_g = np.where(self.chan_src >= 0, part[np.clip(self.chan_src, 0, None)], -1)
        dst_g = np.where(self.chan_dst >= 0, part[np.clip(self.chan_dst, 0, None)], -1)
        return src_g.astype(np.int32), dst_g.astype(np.int32)

    def summary(self) -> str:
        return (
            f"ChannelGraph({self.n_instances} instances in {len(self.groups)} "
            f"groups, {self.n_channels - _N_SENTINELS} channels, "
            f"{len(self.ext_in)} ext-in, {len(self.ext_out)} ext-out)"
        )


# -- partition maps ----------------------------------------------------------

def normalize_partition(graph: ChannelGraph, partition, n_granules: int) -> np.ndarray:
    """Canonicalize a partition map to a (n_instances,) int32 granule vector.

    Accepts ``None`` (everything on granule 0), a sequence of granule ids in
    instance order, or a ``{instance_name: granule}`` mapping (unlisted
    instances default to granule 0).
    """
    if partition is None:
        part = np.zeros((graph.n_instances,), np.int32)
    elif isinstance(partition, Mapping):
        part = np.zeros((graph.n_instances,), np.int32)
        name_to_inst = {
            name: int(inst)
            for g in graph.groups
            for name, inst in zip(g.names, g.members)
        }
        for name, gran in partition.items():
            if name not in name_to_inst:
                raise KeyError(f"partition names unknown instance {name!r}")
            part[name_to_inst[name]] = int(gran)
    else:
        part = np.asarray(partition, np.int32)
        if part.shape != (graph.n_instances,):
            raise ValueError(
                f"partition has shape {part.shape}, expected ({graph.n_instances},)"
            )
    if part.size and (part.min() < 0 or part.max() >= n_granules):
        raise ValueError(
            f"partition assigns granules outside [0, {n_granules}): "
            f"[{part.min()}, {part.max()}]"
        )
    return part


def grid_partition(R: int, C: int, Dr: int, Dc: int) -> np.ndarray:
    """Block-tile partition of a row-major R×C grid onto Dr×Dc granules."""
    if R % Dr or C % Dc:
        raise ValueError(f"grid {R}x{C} not divisible by device tile {Dr}x{Dc}")
    Tr, Tc = R // Dr, C // Dc
    rr, cc = np.divmod(np.arange(R * C, dtype=np.int64), C)
    return ((rr // Tr) * Dc + (cc // Tc)).astype(np.int32)

"""Checkpointing: atomic, async-capable, elastic-reshard-safe.

Layout per step::

    <dir>/step_<N>.tmp/      (written, then atomically renamed)
    <dir>/step_<N>/
        tree.json            treedef + shapes + dtypes + metadata
        arrays.npz           all leaves (gathered to host)

Design choices for the 1000-node story (documented honestly):
  * Leaves are gathered and written whole.  At true scale you write
    per-shard files + an index; the *restore* path here already does the
    important half — resharding on load: arrays are ``device_put`` against
    whatever sharding the (possibly different-sized) new mesh requires, so
    elastic restarts (different pod/device count) work today.
  * ``save_async`` moves serialization off the training thread; a failure
    mid-write never corrupts the latest checkpoint (tmp + rename).
  * ``keep_last`` garbage-collects old steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax
import numpy as np

PyTree = Any
_executor = ThreadPoolExecutor(max_workers=1)


def _flatten_with_paths(tree: PyTree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _jsonable(obj):
    """Coerce checkpoint metadata to plain JSON types (numpy scalars and
    arrays sneak in via session port buffers and the multiprocess
    runtime's gathered counters)."""
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    return obj


def save(path: str, step: int, tree: PyTree, meta: dict | None = None, keep_last: int = 3) -> str:
    """Synchronous checkpoint write. Returns the final directory."""
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten_with_paths(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    # ml_dtypes (bfloat16 etc.) don't round-trip through npz: store a raw
    # same-width uint view and record the true dtype in the spec.
    dtypes = [str(a.dtype) for a in host_leaves]
    storable = [
        a if a.dtype.kind in "fiub" else a.view(f"u{a.dtype.itemsize}")
        for a in host_leaves
    ]
    np.savez(os.path.join(tmp, "arrays.npz"), **{f"a{i}": a for i, a in enumerate(storable)})
    spec = {
        "n_leaves": len(host_leaves),
        "dtypes": dtypes,
        "treedef": str(treedef),
        "step": step,
        "meta": _jsonable(meta or {}),
    }
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump(spec, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(path, keep_last)
    return final


def save_async(path: str, step: int, tree: PyTree, meta: dict | None = None, keep_last: int = 3) -> Future:
    """Asynchronous save: leaves are fetched to host synchronously (cheap,
    donation-safe) and written on a background thread."""
    leaves, treedef = _flatten_with_paths(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    host_tree = jax.tree.unflatten(treedef, host_leaves)
    return _executor.submit(save, path, step, host_tree, meta, keep_last)


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(path)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(path: str, template: PyTree, step: int | None = None) -> tuple[PyTree, dict]:
    """Restore into the *structure and shardings* of ``template``.

    The template may live on a different mesh than the checkpoint was saved
    from — each leaf is device_put against the template leaf's sharding
    (elastic resharding).  Returns (tree, meta).
    """
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    final = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(final, "tree.json")) as f:
        spec = json.load(f)
    data = np.load(os.path.join(final, "arrays.npz"))
    import ml_dtypes  # bundled with jax

    leaves = []
    for i in range(spec["n_leaves"]):
        a = data[f"a{i}"]
        want = spec.get("dtypes", [None] * spec["n_leaves"])[i]
        if want and str(a.dtype) != want:
            a = a.view(np.dtype(want))
        leaves.append(a)
    t_leaves, treedef = jax.tree.flatten(template)
    if len(t_leaves) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, template {len(t_leaves)}"
        )
    out = []
    for saved, tmpl in zip(leaves, t_leaves):
        if tuple(saved.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch {saved.shape} vs {tmpl.shape}")
        arr = saved.astype(tmpl.dtype)
        sharding = getattr(tmpl, "sharding", None)
        out.append(jax.device_put(arr, sharding) if sharding is not None else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), spec["meta"]


def _gc(path: str, keep_last: int) -> None:
    steps = sorted(
        d for d in os.listdir(path) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(path, d))

"""Wafer-scale many-core simulation across a tiered mesh (paper §IV-B).

The paper's flagship demo spreads a million RISC-V cores over thousands of
cloud cores with a *tiered* transport: fast shm queues inside a host, slow
TCP bridges between hosts, both tolerated by latency-insensitive channels.
This example is that scenario on the tiered GraphEngine:

  * a >= 64k-core torus of message-passing mini-cores
    (``repro.hw.manycore``) built by the vectorized ``ChannelGraph.torus``
    builder — O(cores) numpy, one vmapped step for every core;
  * hierarchically partitioned over a ``pod`` (DCI analogue) tier and an
    intra-pod granule tier via ``tiered_grid_partition``;
  * per-tier sync rates: intra-pod boundaries exchange every K_inner
    cycles, pod boundaries every K_inner * K_outer — the slow tier simply
    presents deeper elastic buffering (DESIGN.md §3);
  * end-to-end check: the fabric runs a two-phase ring-allreduce in the
    data plane, so the run is correct iff **every core's accumulator equals
    the global sum** — one equality that witnesses every packet crossing
    every tier.

Run (8 simulated devices are forced automatically when only one real
device is visible):

    PYTHONPATH=src python examples/wafer_scale.py               # 256x256
    PYTHONPATH=src python examples/wafer_scale.py --rows 64 --cols 64
"""
from __future__ import annotations

import argparse
import os
import sys
import time

N_DEVICES = 8

# Re-exec with fake devices ONLY as the real main module: the procs
# engine's spawned workers re-import this file as __mp_main__ (with the
# device flag deliberately stripped), and re-execing there would fork-bomb.
if __name__ == "__main__" and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEVICES} "
        + os.environ.get("XLA_FLAGS", "")
    ).strip()
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.manycore import WAFER  # noqa: E402
from repro.core import Simulation, tiered_grid_partition  # noqa: E402
from repro.core.compat import make_mesh  # noqa: E402
from repro.core.distributed import GraphEngine  # noqa: E402
from repro.core.graph import ChannelGraph  # noqa: E402
from repro.hw.manycore import (  # noqa: E402
    ManycoreCell, allreduce_done, expected_total, make_core_params,
)


def build_engine(R: int, C: int, k_inner: int, k_outer: int,
                 capacity: int = WAFER.queue_capacity,
                 engine: str = "graph", batch_signatures: bool = False,
                 overlap="auto", hosts=None) -> tuple[GraphEngine, np.ndarray]:
    """Torus fabric on a (2 pods) x (2x2 granules/pod) tiered mesh — or,
    with ``engine="procs"``, on a (2 pods) x (2 workers/pod) fleet of
    free-running OS processes over shared-memory queues (no mesh at all:
    the paper's actual deployment model, DESIGN.md §Runtime).
    ``batch_signatures`` stacks same-signature procs workers into one
    vmapped dispatch per epoch; ``overlap=True`` splits every exchange
    into issue/commit halves (send-early/receive-late, DESIGN.md §Perf) —
    bit-identical results either way.  ``hosts`` (procs only) shards the
    fleet over N cooperating launcher processes joined by loopback TCP
    ring bridges — the paper's fast-shm-inside / slow-TCP-between tiered
    transport, end to end (DESIGN.md §Multi-host fleet)."""
    values = (np.arange(R * C, dtype=np.int64) % 97 + 1).astype(np.float32)
    cell = ManycoreCell(R, C)
    graph = ChannelGraph.torus(
        cell, R, C, params=make_core_params(values.reshape(R, C)),
        capacity=capacity,
    )
    if engine == "procs":
        from repro.core.graph import PartitionTree, Tier
        from repro.runtime.launcher import ProcsEngine

        part = tiered_grid_partition(R, C, [(2, 1), (2, 1)])
        ptree = PartitionTree(
            part,
            (Tier(axes=("pod",), K=k_outer), Tier(axes=("g",), K=k_inner)),
            {"pod": 2, "g": 2},
        )
        return ProcsEngine(graph, ptree, timeout=120.0,
                           batch_signatures=batch_signatures,
                           overlap=overlap, hosts=hosts), values
    mesh = make_mesh((2, 2, 2), ("pod", "gr", "gc"))
    part = tiered_grid_partition(R, C, [(2, 1), (2, 2)])
    if engine == "fused":
        from repro.core.fused import FusedEngine as Engine
    else:
        Engine = GraphEngine
    eng = Engine(
        graph, part, mesh,
        tiers=[(("pod",), k_outer), ((("gr", "gc")), k_inner)],
        overlap=overlap,
    )
    return eng, values


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=WAFER.grid_rows)
    ap.add_argument("--cols", type=int, default=WAFER.grid_cols)
    ap.add_argument("--k-inner", type=int, default=WAFER.k_inner)
    ap.add_argument("--k-outer", type=int, default=WAFER.k_outer)
    ap.add_argument("--engine", choices=("graph", "fused", "procs"),
                    default="graph",
                    help="queue interpreter, the fused-epoch fast path, or "
                         "the free-running multiprocess runtime (identical "
                         "results; see DESIGN.md §Perf / §Runtime)")
    ap.add_argument("--batch-signatures", action="store_true",
                    help="procs only: stack same-signature workers into one "
                         "vmapped dispatch per epoch (ISSUE 6)")
    ap.add_argument("--overlap", action="store_true",
                    help="split every tier exchange into issue/commit halves "
                         "(send-early/receive-late; bit-identical results, "
                         "transfers hidden under the next window's compute)")
    ap.add_argument("--hosts", type=int, default=None,
                    help="procs only: shard the fleet over N cooperating "
                         "launcher processes joined by loopback TCP ring "
                         "bridges (ISSUE 9; bit-identical results)")
    args = ap.parse_args()
    if args.hosts and args.engine != "procs":
        ap.error("--hosts requires --engine procs")
    R, C = args.rows, args.cols

    print(f"wafer-scale fabric: {R}x{C} torus = {R * C} cores, "
          f"{len(jax.devices())} devices, engine={args.engine}")
    eng, values = build_engine(R, C, args.k_inner, args.k_outer,
                               engine=args.engine,
                               batch_signatures=args.batch_signatures,
                               overlap=True if args.overlap else "auto",
                               hosts=args.hosts)
    periods = eng.periods
    plan = getattr(eng, "host_plan", None)
    if plan is not None:
        print(f"  host mesh: {plan.n_hosts} launcher processes "
              f"{plan.hosts}, {len(eng._links)} TCP ring bridge link(s), "
              f"granules {dict((h, plan.granules_of(h)) for h in plan.hosts)}")
    print(f"  partition: {eng.ptree.summary()}")
    if hasattr(eng, "classes"):
        print(f"  exchange classes/tier: "
              f"{[sum(1 for c in eng.classes if c.tier == t) for t in range(len(eng.tiers))]}, "
              f"sync periods {periods} cycles (pod tier {periods[0] // periods[-1]}x "
              f"rarer than intra-pod)")
    else:
        n_bnd = sum(len(cs) for cs in eng.lowering.routes.values())
        print(f"  {eng.n_workers} free-running workers, {n_bnd} boundary "
              f"channels over shm rings, sync periods {periods} cycles "
              f"({eng.build_stats['n_signatures']} prebuilt granule "
              f"signature(s) for {eng.n_workers} workers)")

    t0 = time.perf_counter()
    sim = Simulation(eng).reset(jax.random.key(0))
    done = lambda s: allreduce_done(s.block_states[0], s.tables.active[0])  # noqa: E731
    sim.run(until=done, max_epochs=100_000, cache_key="allreduce")
    sim.block_until_ready()
    wall = time.perf_counter() - t0

    totals = np.asarray(eng.gather_group(sim.state, 0).total)
    want = expected_total(values)
    assert np.array_equal(totals, np.full_like(totals, want)), (
        f"allreduce mismatch: {np.unique(totals)[:5]} != {want}"
    )
    cycles = sim.cycle
    print(f"  all {R * C} cores converged to the global sum {want:.0f}")
    print(f"  {cycles} simulated cycles in {wall:.2f}s wall "
          f"(incl. compile) = {R * C * cycles / wall:.3e} core-cycles/s")
    print("OK — tiered exchange delivered every packet across both tiers")


if __name__ == "__main__":
    main()

"""Quickstart — the paper's Listing 1/2 loopback example, in JAX.

A block receives an SB packet, increments its data word, and retransmits.
The host builds a ``Simulation`` session, sends a packet through a
``TxPort`` queue handle, and receives the result from an ``RxPort`` —
the exact workflow of Switchboard's PySbTx/PySbRx example, uniform across
every engine backend (DESIGN.md §4).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import Block, Network
from repro.core.struct import pytree_dataclass


@pytree_dataclass
class DutState:
    handshakes: jax.Array


class IncrementDut(Block):
    """Listing 1: `from_rtl_data = to_rtl_data + 1`, ready/valid passthrough."""

    in_ports = ("to_rtl",)
    out_ports = ("from_rtl",)
    payload_words = 2  # [data, tag]

    def init_state(self, key):
        return DutState(handshakes=jnp.zeros((), jnp.int32))

    def step(self, state, rx, tx_ready):
        payload, valid = rx["to_rtl"]
        ready = tx_ready["from_rtl"]
        fire = valid & ready
        out = payload.at[0].add(1.0)
        return (
            state.replace(handshakes=state.handshakes + fire.astype(jnp.int32)),
            {"to_rtl": fire},                 # pop the input queue on fire
            {"from_rtl": (out, fire)},        # push the incremented packet
        )


def main() -> None:
    # "dut = SbDut(); dut.input('testbench.sv'); dut.build()"
    net = Network(payload_words=2, capacity=62)   # paper-standard 62-slot queues
    dut = net.instantiate(IncrementDut(), name="dut")
    net.external_in(dut["to_rtl"], "to_rtl.q")    # tx = PySbTx('to_rtl.q')
    net.external_out(dut["from_rtl"], "from_rtl.q")  # rx = PySbRx('from_rtl.q')

    sim = net.build()              # Simulation session (single-netlist engine)
    sim.reset(jax.random.key(0))
    tx = sim.tx("to_rtl.q")        # "tx = PySbTx('to_rtl.q')"
    rx = sim.rx("from_rtl.q")      # "rx = PySbRx('from_rtl.q')"

    # "txp = PySbPacket(data=...); tx.send(txp)"
    ok = tx.send([41.0, 1.0])
    print(f"sent packet (ok={ok}): data=41")

    sim.run(cycles=4)  # let the simulation advance a few cycles

    # "print(rx.recv())"
    payload = rx.recv()
    print(f"received: data={None if payload is None else float(payload[0])}")
    assert payload is not None and float(payload[0]) == 42.0

    # live probe + handshake counters — the PyMonitor side of the paper
    dut_state = sim.probe(dut)
    stats = sim.stats()
    assert int(dut_state.handshakes) == 1
    assert stats["ports"]["tx"]["to_rtl.q"]["sent"] == 1
    assert stats["ports"]["rx"]["from_rtl.q"]["received"] == 1
    print(f"probe: dut fired {int(dut_state.handshakes)}x at cycle "
          f"{stats['cycle']}")
    print("quickstart OK — the DUT incremented the packet through SPSC queues")


if __name__ == "__main__":
    main()

"""Million-core experiment, scaled (paper §IV-B).

Simulates a grid of systolic MAC cores computing Y = A @ B through
latency-insensitive queues — the paper's wafer-scale proof-of-concept —
using the distributed epoch-batched engine, and demonstrates:

  1. functional exactness vs numpy,
  2. the paper's accuracy/rate trade-off: measured completion cycles vs
     epoch length K (the Fig. 15 phenomenon),
  3. throughput of the engine (cores x cycles / second).

    PYTHONPATH=src python examples/systolic_matmul.py [--rows 16 --cols 16]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import Simulation
from repro.core.compat import make_mesh
from repro.core.distributed import GridEngine
from repro.hw.systolic import SystolicCell, make_cell_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=16)
    ap.add_argument("--cols", type=int, default=16)
    ap.add_argument("--m", type=int, default=32)
    args = ap.parse_args()

    R, C, M = args.rows, args.cols, args.m
    rng = np.random.RandomState(0)
    A = rng.randn(M, R).astype(np.float32)
    B = rng.randn(R, C).astype(np.float32)

    mesh = make_mesh((1, 1), ("gr", "gc"))
    print(f"grid {R}x{C} = {R*C} cores, streaming {M} rows of A")

    def done(cells):
        return ((~cells.is_south) | (cells.y_idx >= M)).all()

    print(f"{'K':>4} {'epochs':>7} {'cycles':>7} {'err':>10} {'wall_s':>7} {'core-cyc/s':>11}")
    for K in (1, 4, 16, 62):
        sim = Simulation(GridEngine(SystolicCell(m_stream=M), R, C, mesh, K=K))
        sim.reset(jax.random.key(0), cell_params=make_cell_params(A, B))
        t0 = time.time()
        sim.run(until=done, max_epochs=1_000_000, cache_key="done")
        wall = time.time() - t0
        cells = sim.engine.gather_cells(sim.state)
        Y = cells.y_buf[R - 1, :, :].T
        err = np.abs(Y - A @ B).max()
        cycles, epochs = sim.cycle, sim.epoch
        rate = R * C * cycles / wall
        print(f"{K:4d} {epochs:7d} {cycles:7d} "
              f"{err:10.2e} {wall:7.2f} {rate:11.3e}")
    print("\nResults exact for every K; measured cycles grow with K —")
    print("the paper's Fig. 15 accuracy/rate trade-off, deterministically.")


if __name__ == "__main__":
    main()

"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps
on the full substrate stack (synthetic pipeline -> model -> AdamW ->
watchdog -> periodic checkpoints), with a mid-run injected crash to
demonstrate restore-and-continue.

    PYTHONPATH=src python examples/train_pipeline.py [--steps 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.launch.train import train
from repro.models.config import ModelConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_pipeline")
    args = ap.parse_args()

    # ~100M-parameter llama-family config (d=512, 8 layers, 32k vocab).
    import repro.configs.llama3_2_1b as base
    cfg100m = dataclasses.replace(
        base.CONFIG,
        name="llama-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab=32768, dtype="float32",
        use_kernels=False,
    )
    n = cfg100m.param_count() / 1e6
    print(f"training {cfg100m.name}: {n:.0f}M params, {args.steps} steps, "
          f"crash injected at step {args.steps//2}")

    # monkey-patch the registry lookup for this run
    import repro.launch.train as T
    T.get_config = lambda a, smoke=True: cfg100m

    out = train(
        arch="llama-100m", smoke=False, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=1e-3, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        fail_at=(args.steps // 2,), log_every=20,
    )
    print(f"\nfinal loss {out['final_loss']:.4f} (first {out['losses'][0]:.4f}), "
          f"restarts={out['restarts']}, steps_run={out['steps_run']}")
    assert out["final_loss"] < out["losses"][0]
    print("train_pipeline OK — loss decreased through a crash/restore cycle")


if __name__ == "__main__":
    main()

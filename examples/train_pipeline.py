"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps
on the full substrate stack (synthetic pipeline -> model -> AdamW ->
watchdog -> periodic checkpoints), with a mid-run injected crash to
demonstrate restore-and-continue.

    PYTHONPATH=src python examples/train_pipeline.py [--steps 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.launch.train import train
from repro.models.config import ModelConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_pipeline")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + few steps (CI-friendly)")
    args = ap.parse_args()
    if args.smoke:
        args.steps = min(args.steps, 8)
        args.batch = 2
        args.seq = 32
        args.ckpt_dir = args.ckpt_dir + "_smoke"
        # a stale checkpoint at/past the final step would leave zero steps
        # to run (and nothing to assert on) — smoke runs start fresh
        import shutil

        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    # ~100M-parameter llama-family config (d=512, 8 layers, 32k vocab);
    # --smoke shrinks it to a ~1M-parameter toy with the same topology.
    import repro.configs.llama3_2_1b as base
    if args.smoke:
        cfg100m = dataclasses.replace(
            base.CONFIG,
            name="llama-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=2, head_dim=16, d_ff=128, vocab=512, dtype="float32",
            use_kernels=False,
        )
    else:
        cfg100m = dataclasses.replace(
            base.CONFIG,
            name="llama-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab=32768, dtype="float32",
            use_kernels=False,
        )
    n = cfg100m.param_count() / 1e6
    print(f"training {cfg100m.name}: {n:.0f}M params, {args.steps} steps, "
          f"crash injected at step {args.steps//2}")

    # monkey-patch the registry lookup for this run
    import repro.launch.train as T
    T.get_config = lambda a, smoke=True: cfg100m

    out = train(
        arch="llama-100m", smoke=False, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=1e-3, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        fail_at=(args.steps // 2,), log_every=20,
    )
    print(f"\nfinal loss {out['final_loss']:.4f} (first {out['losses'][0]:.4f}), "
          f"restarts={out['restarts']}, steps_run={out['steps_run']}")
    assert out["final_loss"] < out["losses"][0]
    print("train_pipeline OK — loss decreased through a crash/restore cycle")


if __name__ == "__main__":
    main()

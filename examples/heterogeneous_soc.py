"""Heterogeneous-model simulation — the web-app scenario (paper §IV-A).

Three *different model types* interoperate through the same queue
abstraction (paper Fig. 3): a cycle-accurate "RTL-like" CPU block, a
functional "SW model" DRAM with fixed service latency, and an analog
"SPICE-like" PWL ramp generator behind a D2A/A2D bridge.  The CPU reads a
program of DRAM addresses, fetches each value, adds the digitized analog
sample, and emits results — while the analog block free-runs on its own
(rate-controlled) clock, exactly the mixed-rate situation §II-C's rate
control exists for.

The same Network description is then **scaled out**: ``build(engine=
"graph")`` partitions the three blocks across every available device and
runs the distributed epoch protocol (DESIGN.md §3).  At K=1 the exchange
runs every cycle, so the distributed run is cycle-accurate and its results
are bit-identical to the single-netlist simulator.

    PYTHONPATH=src python examples/heterogeneous_soc.py
    # multi-device:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/heterogeneous_soc.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Block, Network
from repro.core.struct import pytree_dataclass

N_REQ = 8


# ------------------------------------------------- "RTL" cycle-accurate CPU
@pytree_dataclass
class CpuState:
    pc: jax.Array
    acc: jax.Array
    results: jax.Array
    n_done: jax.Array
    waiting: jax.Array


class Cpu(Block):
    """Issues DRAM reads 0..N-1; result = dram[addr] + latest analog sample."""

    in_ports = ("dram_resp", "adc_in")
    out_ports = ("dram_req",)
    payload_words = 2

    def init_state(self, key):
        return CpuState(
            pc=jnp.zeros((), jnp.int32), acc=jnp.zeros(()),
            results=jnp.zeros((N_REQ,)), n_done=jnp.zeros((), jnp.int32),
            waiting=jnp.zeros((), bool),
        )

    def step(self, state, rx, tx_ready):
        (resp, resp_v) = rx["dram_resp"]
        (adc, adc_v) = rx["adc_in"]
        req_ready = tx_ready["dram_req"]

        # always consume the freshest analog sample
        acc = jnp.where(adc_v, adc[0], state.acc)

        issue = (~state.waiting) & (state.pc < N_REQ) & req_ready
        retire = state.waiting & resp_v
        result = resp[0] + acc
        results = jnp.where(
            retire, state.results.at[state.n_done % N_REQ].set(result), state.results
        )
        new = state.replace(
            pc=state.pc + issue.astype(jnp.int32),
            acc=acc,
            results=results,
            n_done=state.n_done + retire.astype(jnp.int32),
            waiting=(state.waiting | issue) & ~retire,
        )
        return (
            new,
            {"dram_resp": retire, "adc_in": adc_v},
            {"dram_req": (jnp.stack([state.pc.astype(jnp.float32), 0.0]), issue)},
        )


# ------------------------------------------------- "SW model" DRAM
@pytree_dataclass
class DramState:
    mem: jax.Array
    delay: jax.Array
    pending: jax.Array
    has_pending: jax.Array


class DramModel(Block):
    """Functional model: fixed 3-cycle service latency, word-addressed."""

    in_ports = ("req",)
    out_ports = ("resp",)
    payload_words = 2
    LATENCY = 3

    def init_state(self, key):
        return DramState(
            mem=jnp.arange(N_REQ, dtype=jnp.float32) * 10.0,
            delay=jnp.zeros((), jnp.int32),
            pending=jnp.zeros(()), has_pending=jnp.zeros((), bool),
        )

    def step(self, state, rx, tx_ready):
        (req, req_v) = rx["req"]
        resp_ready = tx_ready["resp"]
        accept = req_v & ~state.has_pending
        addr = req[0].astype(jnp.int32) % N_REQ
        value = state.mem[addr]
        ready_to_send = state.has_pending & (state.delay <= 0)
        send = ready_to_send & resp_ready
        new = state.replace(
            delay=jnp.where(accept, self.LATENCY, jnp.maximum(state.delay - 1, 0)),
            pending=jnp.where(accept, value, state.pending),
            has_pending=(state.has_pending | accept) & ~send,
        )
        return (
            new,
            {"req": accept},
            {"resp": (jnp.stack([state.pending, 1.0]), send)},
        )


# ------------------------------------------------- "SPICE" PWL analog block
@pytree_dataclass
class AnalogState:
    t: jax.Array


class AnalogRamp(Block):
    """PWL source v(t) = (t mod 16)/16, sampled by the A2D bridge every
    cycle of its own (divided) clock — the §III-G oversampling scheme."""

    in_ports = ()
    out_ports = ("adc_out",)
    payload_words = 2
    clock_divider = 4  # analog solver steps at 1/4 the digital rate

    def init_state(self, key):
        return AnalogState(t=jnp.zeros((), jnp.int32))

    def step(self, state, rx, tx_ready):
        ready = tx_ready["adc_out"]
        v = (state.t % 16).astype(jnp.float32) / 16.0
        return (
            state.replace(t=state.t + 1),
            {},
            {"adc_out": (jnp.stack([v, 0.0]), ready)},
        )


def build_soc(capacity: int = 8):
    """One Network description, reused by every engine backend."""
    net = Network(payload_words=2, capacity=capacity)
    cpu = net.instantiate(Cpu(), name="cpu")
    dram = net.instantiate(DramModel(), name="dram")
    adc = net.instantiate(AnalogRamp(), name="adc")
    net.connect(cpu["dram_req"], dram["req"])
    net.connect(dram["resp"], cpu["dram_resp"])
    net.connect(adc["adc_out"], cpu["adc_in"])
    return net, cpu


def run_single(cycles: int = 120):
    """Single-netlist ground truth (cycle-accurate)."""
    net, cpu = build_soc()
    sim = net.build()
    sim.reset(jax.random.key(0)).run(cycles=cycles)
    return sim.probe(cpu)


def run_distributed(K: int = 1, cycles: int = 120):
    """The same SoC partitioned one-block-per-device on a granule mesh —
    the SAME session lifecycle as the single netlist, only build() differs."""
    from repro.core.compat import make_mesh

    net, cpu = build_soc()
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev,), ("gx",))
    partition = {"cpu": 0, "dram": 1 % n_dev, "adc": 2 % n_dev}
    sim = net.build(engine="graph", mesh=mesh, partition=partition, K=K)
    sim.reset(jax.random.key(0)).run(cycles=cycles)
    return sim.probe(cpu), sim.engine


def main() -> None:
    cpu_state = run_single()
    print("heterogeneous SoC: RTL CPU + SW DRAM + analog ramp, one queue fabric")
    print("results:", np.asarray(cpu_state.results).round(3))
    print(f"completed {int(cpu_state.n_done)}/{N_REQ} transactions")
    assert int(cpu_state.n_done) == N_REQ
    base = np.arange(N_REQ) * 10.0
    drift = np.asarray(cpu_state.results) - base
    assert (drift >= 0).all() and (drift < 1.0).all()  # analog sample in [0,1)
    print("OK — three model types interoperated through SPSC queues")

    # Scale-out: same description, distributed engine, one block per device.
    cpu_dist, eng = run_distributed(K=1)
    n_dev = len(jax.devices())
    print(f"\ndistributed (GraphEngine, {n_dev} device(s), "
          f"{len(eng.classes)} exchange classes, K=1):")
    print("results:", np.asarray(cpu_dist.results).round(3))
    np.testing.assert_array_equal(
        np.asarray(cpu_dist.results), np.asarray(cpu_state.results)
    )
    assert int(cpu_dist.n_done) == N_REQ
    print("OK — distributed K=1 run is bit-identical to the single netlist")

    # Larger epochs trade timing fidelity for sync cost (paper Fig. 15):
    # the handshaked DRAM transactions still all complete.
    cpu_k8, _ = run_distributed(K=8, cycles=160)
    assert int(cpu_k8.n_done) == N_REQ
    drift8 = np.asarray(cpu_k8.results) - base
    assert (drift8 >= 0).all() and (drift8 < 1.0).all()
    print("OK — K=8 epochs: all transactions complete, analog drift bounded")


if __name__ == "__main__":
    main()

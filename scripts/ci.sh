#!/usr/bin/env bash
# Per-PR gate: tier-1 tests + smoke benchmarks + the distributed examples.
#
#   scripts/ci.sh            # everything
#   scripts/ci.sh tests      # tier-1 tests only
#   scripts/ci.sh smoke      # smoke benchmarks only
#
# The smoke benchmarks run every suite (all four engines, the batched
# tiered exchange, the subprocess multi-device paths) on a tiny cycle
# budget, so engine regressions are caught per-PR even where the full
# benchmark numbers would take too long.  Perf gates enforced here:
#   * compiled single-netlist backend >= interpreted reference
#     (asserted inside benchmarks.backend_speedup AND re-checked from the
#     JSON rows — the PR 2 "0x speedup" regression can't come back);
#   * FusedEngine >= GraphEngine on the smoke wafer hot-loop config, and
#     within collective-noise tolerance on the distributed smoke config.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

stage="${1:-all}"

if [[ "$stage" == "all" || "$stage" == "tests" ]]; then
    if ! python -c "import hypothesis" 2>/dev/null; then
        echo "WARNING: hypothesis not installed — property-based queue/systolic"
        echo "         tests will be SKIPPED.  For full coverage run:"
        echo "         pip install -r requirements-dev.txt"
    fi
    echo "=== tier-1 tests ==="
    python -m pytest -x -q
fi

if [[ "$stage" == "all" || "$stage" == "smoke" ]]; then
    echo "=== fused-engine smoke suite ==="
    python -m pytest -q tests/test_fused.py \
        -k "modes or contract or lowering or chain or capacity"
    echo "=== smoke benchmarks (incl. tiered wafer-scale + engines) ==="
    python -m benchmarks.run --smoke --json BENCH_SMOKE.json
    echo "=== BENCH_SMOKE.json well-formedness + perf gates ==="
    python - <<'EOF'
import json

with open("BENCH_SMOKE.json") as f:
    bench = json.load(f)
for key in ("schema", "git_rev", "smoke", "failed", "baseline", "suites"):
    assert key in bench, f"bench json missing {key!r}"
assert bench["schema"] == "repro-bench-v1", bench["schema"]
assert bench["baseline"].get("ref") == "BENCH_PR2.json", bench["baseline"]
suites = bench["suites"]
assert "wafer_scale" in suites, "wafer-scale smoke suite missing"
rows = {r["name"]: r for r in suites["wafer_scale"]}
assert any(n.startswith("wafer_tiered_") for n in rows), "no tiered rows"
assert any(n.startswith("wafer_engine_fused_") for n in rows), \
    "no fused-engine wafer rows recorded"
# fused >= graph on the smoke wafer config (hot loop: strict; the tiny
# distributed config is collective-bound on fake devices: 20% tolerance)
hot = rows["wafer_fused_speedup_hotloop"]["us_per_call"]
assert hot >= 1.0, f"fused slower than GraphEngine on smoke wafer: {hot}x"
dist = rows["wafer_fused_speedup_Ko4_Ki8"]["us_per_call"]
assert dist >= 0.8, f"fused regressed vs GraphEngine (distributed): {dist}x"
# compiled single-netlist backend must beat the interpreted reference
bs = {r["name"]: r for r in suites["backend_speedup"]}
us_jit = bs["backend_compiled"]["us_per_call"]
us_py = bs["backend_interpreted"]["us_per_call"]
assert us_jit <= us_py, f"compiled {us_jit} us/cyc vs interpreted {us_py}"
for name, rws in suites.items():
    for r in rws:
        assert {"name", "us_per_call", "derived"} <= set(r), (name, r)
print(f"BENCH_SMOKE.json OK: {sum(len(r) for r in suites.values())} rows "
      f"across {len(suites)} suites @ {bench['git_rev'][:12]}; "
      f"fused/graph hotloop {hot:.2f}x, distributed {dist:.2f}x, "
      f"compiled/interpreted {us_py / us_jit:.1f}x")
EOF
    echo "=== committed BENCH_PR3.json well-formedness ==="
    python - <<'EOF'
import json

with open("BENCH_PR3.json") as f:  # the committed full-tier trajectory
    bench = json.load(f)
assert bench["schema"] == "repro-bench-v1"
assert bench["baseline"].get("ref") == "BENCH_PR2.json"
assert bench["baseline"].get("suites", {}).get("wafer_scale"), \
    "baseline must embed the PR 2 wafer rows"
rows = {r["name"]: r for r in bench["suites"]["wafer_scale"]}
speedups = {n: r["us_per_call"] for n, r in rows.items()
            if n.startswith("wafer_fused_speedup_")}
assert speedups, "no fused-vs-graph speedup rows in BENCH_PR3.json"
assert max(speedups.values()) >= 5.0, (
    f"perf trajectory lost the >=5x fused-vs-GraphEngine wafer row: "
    f"{speedups}")
bs = {r["name"]: r for r in bench["suites"]["backend_speedup"]}
assert bs["backend_compiled"]["us_per_call"] <= \
    bs["backend_interpreted"]["us_per_call"], "compiled backend < interpreted"
print(f"BENCH_PR3.json OK: fused/graph best {max(speedups.values()):.2f}x "
      f"({max(speedups, key=speedups.get)})")
EOF
    echo "=== distributed heterogeneous-SoC example (4 fake devices) ==="
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python examples/heterogeneous_soc.py
fi

echo "CI OK"

#!/usr/bin/env bash
# Per-PR gate: tier-1 tests + smoke benchmarks + every example in smoke mode.
#
#   scripts/ci.sh            # everything
#   scripts/ci.sh tests      # tier-1 tests only
#   scripts/ci.sh smoke      # smoke benchmarks only
#   scripts/ci.sh procs      # multiprocess-runtime smoke (hard timeout)
#   scripts/ci.sh fleet      # 2-launcher TCP-bridged fleet smoke (ISSUE 9)
#   scripts/ci.sh obs        # flight-recorder smoke + overhead gates (ISSUE 10)
#   scripts/ci.sh examples   # all examples, smoke-sized, via the session API
#
# The smoke benchmarks run every suite (all four engines, the batched
# tiered exchange, the subprocess multi-device paths) on a tiny cycle
# budget, so engine regressions are caught per-PR even where the full
# benchmark numbers would take too long.  BENCH_*.json summaries are
# validated against the repro-bench-v1 schema by ``benchmarks.schema``,
# which also enforces the perf gates:
#   * compiled single-netlist backend >= interpreted reference
#     (asserted inside benchmarks.backend_speedup AND re-checked from the
#     JSON rows — the PR 2 "0x speedup" regression can't come back);
#   * FusedEngine >= GraphEngine on the smoke wafer hot-loop config, and
#     within collective-noise tolerance on the distributed smoke config;
#   * signature-batched stepping >= the unbatched FusedEngine on the smoke
#     wafer, and the cycles/s/core metric is recorded (ISSUE 6);
#   * the split issue/commit (overlapped) exchange stays within noise of
#     the serial schedule on the smoke wafer, and the receive-late procs
#     fleet never waits longer than the strict serial fleet (ISSUE 7; the
#     >=1x overlap win, the procs wait-fraction drop, and the <=30%
#     perfmodel overlap fit are gated on the committed BENCH_PR8.json);
#   * the self-healing fleet stays affordable (ISSUE 8): recover-mode
#     fault-free runs <= 1.5x raise-mode, warm respawn <= 0.7x a cold
#     build+launch, and the kill-drill MTTR rows are recorded.  The
#     procs stage additionally runs the fault drills themselves (kill ->
#     bit-identical recovery, stall -> FleetStallError) under a hard
#     timeout, plus an env-knob drill (REPRO_ON_FAULT/REPRO_FAULT_PLAN)
#     through a real example;
#   * the multi-host fleet stays honest (ISSUE 9): the 2-launcher
#     TCP-bridged chain keeps >= 0.5x single-host throughput with
#     bit-exactness asserted in-benchmark (gated on the committed
#     BENCH_PR9.json), and the fleet stage drills the bridge framing,
#     loopback bit-exactness, and link-kill recovery under hard timeouts;
#   * the flight recorder stays ~free (ISSUE 10): registry-disabled
#     dispatch <= 1.02x, fully-traced 4-worker fleet <= 1.10x, and the
#     obs stage additionally runs a REPRO_TRACE smoke wafer, validates
#     the exported Perfetto trace, and renders the text report.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

stage="${1:-all}"

if [[ "$stage" == "all" || "$stage" == "tests" ]]; then
    if ! python -c "import hypothesis" 2>/dev/null; then
        echo "WARNING: hypothesis not installed — property-based queue/systolic"
        echo "         tests will be SKIPPED.  For full coverage run:"
        echo "         pip install -r requirements-dev.txt"
    fi
    echo "=== tier-1 tests ==="
    python -m pytest -x -q
fi

if [[ "$stage" == "all" || "$stage" == "smoke" ]]; then
    echo "=== fused-engine smoke suite ==="
    python -m pytest -q tests/test_fused.py \
        -k "modes or contract or lowering or chain or capacity"
    echo "=== signature-batched smoke suite ==="
    python -m pytest -q tests/test_batched.py \
        -k "plan or env or perfmodel or epochs"
    echo "=== pallas-interpret smoke (multi-epoch body via env override) ==="
    REPRO_EPOCH_MODE=pallas REPRO_PALLAS_INTERPRET=1 \
        python -m pytest -q tests/test_batched.py -k "env or epochs"
    echo "=== smoke benchmarks (incl. tiered wafer-scale + engines) ==="
    python -m benchmarks.run --smoke --json BENCH_SMOKE.json
    echo "=== BENCH json schema + perf gates (benchmarks.schema) ==="
    python -m benchmarks.schema BENCH_SMOKE.json --gates smoke
    python -m benchmarks.schema BENCH_PR8.json --gates trajectory
    python -m benchmarks.schema BENCH_PR9.json --gates fleet
    python -m benchmarks.schema BENCH_PR10.json --gates obs
    # every committed trajectory file must validate AND embed its
    # predecessor's rows as baseline (the PR-over-PR audit chain)
    for f in BENCH_PR*.json; do
        python -m benchmarks.schema "$f"
    done
fi

if [[ "$stage" == "all" || "$stage" == "procs" ]]; then
    # The free-running fleet synchronizes through blocking shm rings, so a
    # protocol bug shows up as a DEADLOCK — the hard timeout turns that
    # into a fast failure instead of a hung CI job.  (The launcher's own
    # heartbeat watchdog fires first in-process; `timeout` is the backstop.)
    echo "=== procs runtime: 4-worker wafer smoke (hard 300s timeout) ==="
    timeout 300 python -m pytest -q tests/test_runtime.py \
        -k "wafer or kill" -x
    echo "=== procs runtime: 4-worker tiered wafer example ==="
    timeout 300 python examples/wafer_scale.py --rows 8 --cols 8 \
        --k-inner 4 --engine procs
    echo "=== procs runtime: batched fleet, overlapped exchange ==="
    # signature-batched workers + the ISSUE 7 split issue/commit schedule:
    # one stacked dispatch per worker epoch, receive-late shm-ring pops
    timeout 300 python examples/wafer_scale.py --rows 8 --cols 8 \
        --k-inner 4 --engine procs --batch-signatures --overlap
    echo "=== self-healing fleet: fault drills (hard 300s timeout) ==="
    # ISSUE 8: a plan-killed worker must auto-recover bit-identically, a
    # clean mid-run exit must be detected fast, and a credit deadlock
    # must be diagnosed as FleetStallError — never a hung CI job
    timeout 300 python -m pytest -q tests/test_recovery.py -x \
        -k "stall or clean_exit or (kill_recovery and not 1 and not 2)"
    echo "=== self-healing fleet: env-knob drill (REPRO_ON_FAULT) ==="
    # the same recovery path driven purely by env knobs through a real
    # example: worker 2 is killed mid-allreduce and the invariant at the
    # end of the example still holds on the healed fleet
    REPRO_ON_FAULT=recover REPRO_FAULT_PLAN="kill:2@3" \
        timeout 300 python examples/wafer_scale.py --rows 8 --cols 8 \
        --k-inner 4 --engine procs
fi

if [[ "$stage" == "all" || "$stage" == "fleet" ]]; then
    # ISSUE 9: two cooperating launcher processes joined only by loopback
    # TCP ring bridges.  Same deadlock philosophy as the procs stage: a
    # bridge-protocol bug stalls the fleet, so every step runs under a
    # hard timeout and the in-process watchdog (which now covers bridges
    # as first-class members) fires first with a typed error.
    echo "=== bridged fleet: framing + plan/link units ==="
    timeout 300 python -m pytest -q tests/test_bridge.py -x \
        -k "not fleet_"
    echo "=== bridged fleet: 2-launcher loopback bit-exactness ==="
    timeout 300 python -m pytest -q tests/test_bridge.py -x \
        -k "fleet_bit_exact or fleet_io_parity"
    echo "=== bridged fleet: link-kill recovery drill ==="
    timeout 300 python -m pytest -q tests/test_bridge.py -x \
        -k "fleet_linkkill"
    echo "=== bridged fleet: 2-pod tiered wafer across 2 launchers ==="
    # the acceptance scenario: the pod boundary rides the TCP bridge, the
    # allreduce invariant still witnesses every packet crossing it
    timeout 300 python examples/wafer_scale.py --rows 8 --cols 8 \
        --k-inner 4 --engine procs --hosts 2
fi

if [[ "$stage" == "all" || "$stage" == "obs" ]]; then
    # ISSUE 10: the flight recorder end to end — a procs smoke wafer run
    # traced via the REPRO_TRACE env knob must export a Perfetto-loadable
    # timeline (validated by repro.obs.schema, rendered by
    # repro.obs.report), the obs test suite must pass (bit-identical
    # traced-vs-untraced traffic on every engine, recovery incidents in
    # the timeline), and the overhead ratios must hold their gates.
    OBS_TRACE="${TMPDIR:-/tmp}/repro_ci_trace.json"
    OBS_BENCH="${TMPDIR:-/tmp}/BENCH_OBS_SMOKE.json"
    echo "=== flight recorder: traced smoke wafer (REPRO_TRACE) ==="
    REPRO_TRACE="$OBS_TRACE" timeout 300 python examples/wafer_scale.py \
        --rows 8 --cols 8 --k-inner 4 --engine procs
    echo "=== flight recorder: validate + report the exported trace ==="
    python -m repro.obs.schema "$OBS_TRACE"
    python -m repro.obs.report "$OBS_TRACE" --top 5
    echo "=== flight recorder: obs test suite (hard 600s timeout) ==="
    timeout 600 python -m pytest -q tests/test_obs.py -x
    echo "=== flight recorder: overhead gates (<=1.02x off, <=1.10x on) ==="
    python -m benchmarks.run --only obs_overhead --smoke --json "$OBS_BENCH"
    python -m benchmarks.schema "$OBS_BENCH" --gates obs
fi

if [[ "$stage" == "all" || "$stage" == "examples" ]]; then
    # Every example, smoke-sized, through the Simulation session API.
    echo "=== example: quickstart (session Tx/Rx ports) ==="
    python examples/quickstart.py
    echo "=== example: heterogeneous SoC (4 fake devices) ==="
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python examples/heterogeneous_soc.py
    echo "=== example: systolic matmul (session run_until sweep) ==="
    python examples/systolic_matmul.py --rows 4 --cols 4 --m 6
    echo "=== example: wafer-scale tiered torus (8 fake devices) ==="
    python examples/wafer_scale.py --rows 16 --cols 16
    echo "=== example: train pipeline (tiny config, crash/restore) ==="
    python examples/train_pipeline.py --smoke
fi

echo "CI OK"

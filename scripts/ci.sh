#!/usr/bin/env bash
# Per-PR gate: tier-1 tests + smoke benchmarks + the distributed example.
#
#   scripts/ci.sh            # everything
#   scripts/ci.sh tests      # tier-1 tests only
#   scripts/ci.sh smoke      # smoke benchmarks only
#
# The smoke benchmarks run every suite (all three engines, the distributed
# exchange, the subprocess multi-device paths) on a tiny cycle budget, so
# engine regressions are caught per-PR even where the full benchmark
# numbers would take too long.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

stage="${1:-all}"

if [[ "$stage" == "all" || "$stage" == "tests" ]]; then
    if ! python -c "import hypothesis" 2>/dev/null; then
        echo "WARNING: hypothesis not installed — property-based queue/systolic"
        echo "         tests will be SKIPPED.  For full coverage run:"
        echo "         pip install -r requirements-dev.txt"
    fi
    echo "=== tier-1 tests ==="
    python -m pytest -x -q
fi

if [[ "$stage" == "all" || "$stage" == "smoke" ]]; then
    echo "=== smoke benchmarks ==="
    python -m benchmarks.run --smoke
    echo "=== distributed heterogeneous-SoC example (4 fake devices) ==="
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python examples/heterogeneous_soc.py
fi

echo "CI OK"

#!/usr/bin/env bash
# Per-PR gate: tier-1 tests + smoke benchmarks + the distributed example.
#
#   scripts/ci.sh            # everything
#   scripts/ci.sh tests      # tier-1 tests only
#   scripts/ci.sh smoke      # smoke benchmarks only
#
# The smoke benchmarks run every suite (all three engines, the distributed
# exchange, the subprocess multi-device paths) on a tiny cycle budget, so
# engine regressions are caught per-PR even where the full benchmark
# numbers would take too long.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

stage="${1:-all}"

if [[ "$stage" == "all" || "$stage" == "tests" ]]; then
    if ! python -c "import hypothesis" 2>/dev/null; then
        echo "WARNING: hypothesis not installed — property-based queue/systolic"
        echo "         tests will be SKIPPED.  For full coverage run:"
        echo "         pip install -r requirements-dev.txt"
    fi
    echo "=== tier-1 tests ==="
    python -m pytest -x -q
fi

if [[ "$stage" == "all" || "$stage" == "smoke" ]]; then
    echo "=== smoke benchmarks (incl. tiered wafer-scale) ==="
    python -m benchmarks.run --smoke --json BENCH_PR2.json
    echo "=== BENCH_PR2.json well-formedness ==="
    python - <<'EOF'
import json

with open("BENCH_PR2.json") as f:
    bench = json.load(f)
for key in ("schema", "git_rev", "smoke", "failed", "suites"):
    assert key in bench, f"BENCH_PR2.json missing {key!r}"
assert bench["schema"] == "repro-bench-v1", bench["schema"]
suites = bench["suites"]
assert "wafer_scale" in suites, "wafer-scale smoke suite missing"
assert any(r["name"].startswith("wafer_tiered_") for r in suites["wafer_scale"]), \
    "no tiered wafer-scale rows recorded"
for name, rows in suites.items():
    for r in rows:
        assert {"name", "us_per_call", "derived"} <= set(r), (name, r)
print(f"BENCH_PR2.json OK: {sum(len(r) for r in suites.values())} rows "
      f"across {len(suites)} suites @ {bench['git_rev'][:12]}")
EOF
    echo "=== distributed heterogeneous-SoC example (4 fake devices) ==="
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python examples/heterogeneous_soc.py
fi

echo "CI OK"

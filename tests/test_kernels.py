"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ref import systolic_step_ref


# ------------------------------------------------------------- flash attn
@pytest.mark.parametrize("backend", ["pallas", "xla"])
@pytest.mark.parametrize(
    "B,Hq,Hkv,T,S,D",
    [
        (1, 2, 2, 128, 128, 64),    # MHA
        (2, 4, 2, 256, 256, 64),    # GQA
        (1, 8, 1, 128, 128, 128),   # MQA
        (1, 2, 2, 384, 384, 80),    # non-pow2 head dim (hubert)
    ],
)
@pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 64)])
def test_flash_attention_sweep(backend, B, Hq, Hkv, T, S, D, causal, window):
    rng = np.random.RandomState(hash((B, Hq, T, D)) % 2**31)
    q = jnp.asarray(rng.randn(B, Hq, T, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    out = ops.flash_attention(
        q, k, v, causal=causal, window=window, backend=backend,
        block_q=128, block_k=128,
    )
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 4, 128, 64), dtype)
    k = jnp.asarray(rng.randn(1, 2, 128, 64), dtype)
    v = jnp.asarray(rng.randn(1, 2, 128, 64), dtype)
    out = ops.flash_attention(q, k, v, backend="pallas")
    want = ref.attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )
    assert out.dtype == dtype


@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_flash_attention_grads(backend):
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(1, 4, 128, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 128, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 128, 32), jnp.float32)

    def loss_k(q, k, v):
        return (ops.flash_attention(q, k, v, backend=backend, block_q=64, block_k=64) ** 2).sum()

    def loss_r(q, k, v):
        return (ref.attention_ref(q, k, v) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4, rtol=1e-4)


# ------------------------------------------------------------- rglru
@pytest.mark.parametrize("B,T,D,bt,bd", [(1, 256, 256, 256, 256), (2, 512, 512, 128, 256)])
def test_rglru_sweep(B, T, D, bt, bd):
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(B, T, D), jnp.float32)
    a = jnp.asarray(rng.uniform(0.3, 0.999, (B, T, D)), jnp.float32)
    h0 = jnp.asarray(rng.randn(B, D), jnp.float32)
    h, hl = ops.rglru(x, a, h0, block_t=bt, block_d=bd, backend="pallas")
    hr, hlr = ref.rglru_ref(x, a, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=2e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr), atol=2e-4, rtol=1e-4)


def test_rglru_matches_naive_loop():
    rng = np.random.RandomState(3)
    B, T, D = 1, 64, 256
    x = np.asarray(rng.randn(B, T, D), np.float32)
    a = np.asarray(rng.uniform(0.5, 0.99, (B, T, D)), np.float32)
    h = np.zeros((B, D), np.float32)
    hs = []
    for t in range(T):
        h = a[:, t] * h + x[:, t]
        hs.append(h.copy())
    want = np.stack(hs, axis=1)
    got, _ = ops.rglru(jnp.asarray(x), jnp.asarray(a), backend="pallas")
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=1e-4)


def test_rglru_grad_vs_ref():
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(2, 256, 256), jnp.float32)
    a = jnp.asarray(rng.uniform(0.5, 0.99, (2, 256, 256)), jnp.float32)
    h0 = jnp.asarray(rng.randn(2, 256), jnp.float32)
    w = jnp.asarray(rng.randn(256), jnp.float32)

    def lk(x, a, h0):
        h, hl = ops.rglru(x, a, h0)
        return (h * w).sum() + (hl**2).sum()

    def lr(x, a, h0):
        h, hl = ref.rglru_ref(x, a, h0)
        return (h * w).sum() + (hl**2).sum()

    gk = jax.grad(lk, argnums=(0, 1, 2))(x, a, h0)
    gr = jax.grad(lr, argnums=(0, 1, 2))(x, a, h0)
    for a_, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_), atol=1e-3, rtol=1e-3)


# ------------------------------------------------------------- systolic step
def _tile_state(rng, M, R, C, K):
    rr, cc = np.meshgrid(np.arange(R), np.arange(C), indexing="ij")
    A = rng.randn(M, R).astype(np.float32)
    B = rng.randn(R, C).astype(np.float32)
    a_buf = np.zeros((R, C, M), np.float32)
    a_buf[:, 0, :] = A.T
    z = jnp.zeros
    return A, B, dict(
        b=jnp.asarray(B), a_reg=z((R, C)), a_v=z((R, C), bool),
        p_reg=z((R, C)), p_v=z((R, C), bool),
        a_idx=z((R, C), jnp.int32), y_idx=z((R, C), jnp.int32),
        a_buf=jnp.asarray(a_buf), y_buf=z((R, C, M)),
        is_west=jnp.asarray(cc == 0), is_north=jnp.asarray(rr == 0),
        is_south=jnp.asarray(rr == R - 1), is_east=jnp.asarray(cc == C - 1),
        west_slab=z((R, K)), west_cnt=z((R,), jnp.int32),
        north_slab=z((C, K)), north_cnt=z((C,), jnp.int32),
        widx=z((R,), jnp.int32), nidx=z((C,), jnp.int32),
        east_slab=z((R, K)), east_cnt=z((R,), jnp.int32),
        south_slab=z((C, K)), south_cnt=z((C,), jnp.int32),
    )


@pytest.mark.parametrize("M,R,C,K", [(4, 3, 3, 4), (6, 4, 5, 8), (8, 2, 2, 16)])
def test_systolic_kernel_vs_oracle_and_matmul(M, R, C, K):
    rng = np.random.RandomState(M * 100 + R * 10 + C)
    A, B, state = _tile_state(rng, M, R, C, K)
    s_k, s_r = dict(state), dict(state)
    for _ in range(6 * (M + R + C)):
        s_k = ops.systolic_step(s_k, K)
        s_r.update(
            widx=jnp.zeros((R,), jnp.int32), nidx=jnp.zeros((C,), jnp.int32),
            east_slab=jnp.zeros((R, K)), east_cnt=jnp.zeros((R,), jnp.int32),
            south_slab=jnp.zeros((C, K)), south_cnt=jnp.zeros((C,), jnp.int32),
        )
        s_r = systolic_step_ref(s_r, K)
        for key in ("a_reg", "a_v", "p_reg", "p_v", "y_buf", "y_idx", "a_idx"):
            np.testing.assert_allclose(
                np.asarray(s_k[key], np.float32),
                np.asarray(s_r[key], np.float32),
                atol=1e-6, err_msg=key,
            )
        if bool((np.asarray(s_k["y_idx"][R - 1]) >= M).all()):
            break
    Y = np.asarray(s_k["y_buf"][R - 1]).T
    np.testing.assert_allclose(Y, A @ B, rtol=1e-5)


def test_systolic_kernel_boundary_slabs():
    """West/north slab ingress and east/south egress move packets in order."""
    rng = np.random.RandomState(9)
    M, R, C, K = 4, 2, 2, 8
    _, B, state = _tile_state(rng, M, R, C, K)
    # interior tile: disable edge flags, feed west+north via slabs
    state.update(
        is_west=jnp.zeros((R, C), bool), is_north=jnp.zeros((R, C), bool),
        is_south=jnp.zeros((R, C), bool), is_east=jnp.zeros((R, C), bool),
        west_slab=jnp.asarray(np.arange(R * K, dtype=np.float32).reshape(R, K)),
        west_cnt=jnp.full((R,), 3, jnp.int32),
        north_slab=jnp.zeros((C, K)),
        north_cnt=jnp.full((C,), 3, jnp.int32),
    )
    out = ops.systolic_step(dict(state), K)
    # every fed packet pair must eventually exit; after K cycles with 3 inputs
    # the egress counters are bounded by inputs
    assert int(out["east_cnt"].sum()) <= 3 * R
    assert int(out["south_cnt"].sum()) <= 3 * C
    # conservation: packets consumed from west == forwarded east (+ in-flight)
    consumed = int(out["widx"].sum())
    inflight = int(out["a_v"].sum())
    assert consumed == int(out["east_cnt"].sum()) + inflight


# ------------------------------------------------------------- mlstm chunk
def test_mlstm_chunked_matches_stepwise():
    """Chunkwise-parallel mLSTM == sequential recurrent decode, step by step."""
    from repro.models.recurrent import mlstm_chunked

    rng = np.random.RandomState(11)
    B, T, H, hd = 2, 32, 2, 8
    q = jnp.asarray(rng.randn(B, T, H, hd), jnp.float32)
    k = jnp.asarray(rng.randn(B, T, H, hd), jnp.float32) / np.sqrt(hd)
    v = jnp.asarray(rng.randn(B, T, H, hd), jnp.float32)
    log_i = jnp.asarray(rng.randn(B, T, H), jnp.float32)
    log_f = jnp.asarray(np.log(rng.uniform(0.6, 0.95, (B, T, H))), jnp.float32)

    state = (
        jnp.zeros((B, H, hd, hd)), jnp.zeros((B, H, hd)),
        jnp.full((B, H), -jnp.inf),
    )
    h8, _ = mlstm_chunked(q, k, v, log_i, log_f, state, chunk=8)
    h32, _ = mlstm_chunked(q, k, v, log_i, log_f, state, chunk=32)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h32), atol=1e-5)

    # sequential recurrence oracle
    C = np.zeros((B, H, hd, hd)); n = np.zeros((B, H, hd)); m = np.full((B, H), -np.inf)
    qn, kn, vn = map(np.asarray, (q, k, v))
    lin, lfn = np.asarray(log_i), np.asarray(log_f)
    outs = []
    for t in range(T):
        m_new = np.maximum(lfn[:, t] + m, lin[:, t])
        fdec = np.exp(lfn[:, t] + m - m_new)
        iexp = np.exp(lin[:, t] - m_new)
        C = C * fdec[..., None, None] + iexp[..., None, None] * (
            kn[:, t][..., :, None] @ vn[:, t][..., None, :]
        )
        n = n * fdec[..., None] + iexp[..., None] * kn[:, t]
        m = m_new
        num = np.einsum("bhd,bhde->bhe", qn[:, t], C)
        den = np.maximum(np.abs(np.einsum("bhd,bhd->bh", qn[:, t], n)), np.exp(-m))
        outs.append(num / (den[..., None] + 1e-6))
    want = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h8), want, atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------- slstm scan
@pytest.mark.parametrize("B,T,d,H,bt", [(1, 32, 16, 2, 8), (2, 64, 32, 4, 16), (2, 128, 64, 4, 128)])
def test_slstm_kernel_vs_oracle(B, T, d, H, bt):
    from repro.kernels.slstm_scan import slstm_scan
    from repro.kernels.ref import slstm_scan_ref

    rng = np.random.RandomState(B * 100 + T)
    hd = d // H
    r = {g: jnp.asarray(rng.randn(H, hd, hd) * 0.3, jnp.float32) for g in "ifzo"}
    pre = jnp.asarray(rng.randn(B, T, 4, d), jnp.float32)
    z = jnp.zeros((B, d))
    carry0 = (z, z, z, jnp.full((B, d), -jnp.inf))
    hs_k, seqs_k, fin_k = slstm_scan(r, pre, carry0, block_t=bt, interpret=True)
    hs_r, seqs_r, fin_r = slstm_scan_ref(r, pre, carry0)
    np.testing.assert_allclose(np.asarray(hs_k), np.asarray(hs_r), atol=1e-6)
    for a, b in zip(seqs_k, seqs_r):
        np.testing.assert_allclose(
            np.nan_to_num(np.asarray(a)), np.nan_to_num(np.asarray(b)), atol=1e-6
        )
    for a, b in zip(fin_k, fin_r):
        np.testing.assert_allclose(
            np.nan_to_num(np.asarray(a)), np.nan_to_num(np.asarray(b)), atol=1e-6
        )
